//! Retrieval demo: the downstream task the paper motivates (§1) —
//! distance-based retrieval and kNN classification with a learned metric
//! on LLC-like sparse features (the ImageNet regime).
//!
//! Trains through the `Session` API, then serves the resulting
//! `MetricModel` artifact: kNN classification accuracy and precision@k
//! retrieval, Euclidean vs the learned Mahalanobis metric.
//!
//! ```bash
//! cargo run --release --example retrieval
//! ```

use std::sync::Arc;

use dmlps::config::{FeatureKind, Preset};
use dmlps::data::ExperimentData;
use dmlps::eval::knn_accuracy;
use dmlps::linalg::Mat;
use dmlps::session::{MetricModel, Session};

fn main() -> anyhow::Result<()> {
    let mut cfg = Preset::Tiny.config();
    // LLC-like features, a bit bigger than tiny
    cfg.dataset.kind = FeatureKind::Llc;
    cfg.dataset.dim = 128;
    cfg.dataset.n_classes = 16;
    cfg.dataset.separation = 0.6;
    cfg.dataset.n_train = 1200;
    cfg.dataset.n_test = 400;
    cfg.dataset.n_similar = 4000;
    cfg.dataset.n_dissimilar = 4000;
    cfg.dataset.n_test_pairs = 1000;
    cfg.model.k = 32;
    cfg.optim.steps = 1500;
    cfg.optim.batch_sim = 16;
    cfg.optim.batch_dis = 16;
    cfg.dataset.name = "llc_retrieval".into();
    cfg.artifact_variant = None;

    println!(
        "retrieval: LLC-like features d={} classes={} k={}",
        cfg.dataset.dim, cfg.dataset.n_classes, cfg.model.k
    );
    let steps = cfg.optim.steps;
    let data =
        Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));
    let run = Session::from_config(cfg)
        .data(data.clone())
        .probe(250, (500, 500))
        .train_sequential()?;
    println!(
        "trained {} steps in {:.1}s, objective {:.3} → {:.3}",
        steps,
        run.wall_s,
        run.curve.points.first().unwrap().objective,
        run.curve.points.last().unwrap().objective
    );
    let model = run.into_model()?;

    // kNN classification (paper §1: accuracy depends on the metric)
    for k in [1usize, 5] {
        let acc_eu = knn_accuracy(None, &data.train, &data.test, k, 200);
        let acc_l = knn_accuracy(Some(model.l()), &data.train,
                                 &data.test, k, 200);
        println!(
            "kNN (k={k}): euclidean {:.3} → learned {:.3}",
            acc_eu, acc_l
        );
    }

    // precision@k retrieval: for test queries, fraction of the k nearest
    // *train* points sharing the query's class
    for &topk in &[5usize, 10] {
        let p_eu = precision_at_k(None, &data, topk, 150);
        let p_l = precision_at_k(Some(&model), &data, topk, 150);
        println!(
            "precision@{topk}: euclidean {:.3} → learned {:.3}",
            p_eu, p_l
        );
    }
    Ok(())
}

fn precision_at_k(
    model: Option<&MetricModel>,
    data: &ExperimentData,
    k: usize,
    max_queries: usize,
) -> f64 {
    // project the gallery once (identity for the Euclidean baseline),
    // then retrieval is a Euclidean scan in the projected space
    let (tr, te): (Mat, Mat) = match model {
        Some(m) => (m.transform(&data.train.x),
                    m.transform(&data.test.x)),
        None => (data.train.x.clone(), data.test.x.clone()),
    };
    let nq = data.test.n().min(max_queries);
    let mut hits = 0usize;
    let mut total = 0usize;
    for q in 0..nq {
        for (_, j) in dmlps::eval::nearest_k(&tr, te.row(q), k) {
            hits += usize::from(
                data.train.labels[j] == data.test.labels[q]);
            total += 1;
        }
    }
    hits as f64 / total as f64
}
