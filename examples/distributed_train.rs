//! End-to-end driver: train the paper-true MNIST configuration with the
//! real threaded parameter server over the AOT-compiled XLA artifacts.
//!
//! This is the run recorded in EXPERIMENTS.md §End-to-end: the full
//! three-layer stack composes — Pallas kernels → JAX model → HLO text →
//! PJRT runtime → async parameter server — on a 0.47M-parameter model
//! (the paper's own MNIST model size, Table 1) with minibatch 1000,
//! driven entirely through the `Session` builder; the learned metric
//! leaves as a reloadable `MetricModel` artifact.
//!
//! ```bash
//! cargo run --release --example distributed_train [steps] [workers]
//! ```

use std::sync::Arc;

use dmlps::config::Preset;
use dmlps::data::ExperimentData;
use dmlps::dml::NativeEngine;
use dmlps::eval::{ap_euclidean, ap_of_l};
use dmlps::metrics::curves_to_markdown;
use dmlps::session::{MetricModel, Session};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let workers: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);

    let mut cfg = Preset::Mnist.config();
    cfg.optim.steps = steps;
    cfg.cluster.workers = workers;

    println!(
        "distributed_train (end-to-end): MNIST paper-true shape\n\
         d={} k={} ({} params), minibatch {}+{}, {} workers × {} steps,\n\
         consistency={}, engine=auto (XLA artifacts if built)",
        cfg.dataset.dim,
        cfg.model.k,
        cfg.model.k * cfg.dataset.dim,
        cfg.optim.batch_sim,
        cfg.optim.batch_dis,
        workers,
        steps,
        cfg.cluster.consistency,
    );

    println!("generating synthetic MNIST-like data \
              (100K similar + 100K dissimilar pairs)...");
    let data =
        Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));

    let run = Session::from_config(cfg)
        .engine("auto")
        .data(data.clone())
        .probe(((steps * workers) as u64 / 15).max(1), (200, 200))
        .train_distributed()?;

    println!("{}", curves_to_markdown(
        std::slice::from_ref(&run.curve), 20));
    println!(
        "\nwall time {:.1}s | {} updates applied | {} broadcasts | \
         {:.2} updates/s",
        run.wall_s,
        run.applied_updates,
        run.broadcasts,
        run.applied_updates as f64 / run.wall_s
    );
    for ws in &run.worker_stats {
        println!(
            "worker {}: {} steps, {} grads sent, {} params received, \
             last minibatch loss {:.4}",
            ws.id, ws.steps_done, ws.grads_sent, ws.params_received,
            ws.last_loss
        );
    }

    let mut eng = NativeEngine::new();
    let model = run.require_model()?;
    let ap = ap_of_l(&mut eng, model.l(), &data)?;
    let ap_eu = ap_euclidean(&data);
    println!("\nheld-out pair verification:");
    println!("  ours      AP = {ap:.4}");
    println!("  euclidean AP = {ap_eu:.4}");
    if steps >= 100 {
        anyhow::ensure!(ap > ap_eu, "learned metric must beat Euclidean");
    } else {
        println!("(short run: pass ≥100 steps for the full AP check)");
    }

    // persist the artifact and prove the reload serves the same metric
    let out = std::path::Path::new("mnist_metric.bin");
    model.save(out)?;
    let served = MetricModel::load(out)?;
    anyhow::ensure!(served.l() == model.l(), "reload must be exact");
    println!(
        "\nmodel saved to {} ({}x{}, config digest {:016x}) and \
         reloaded bit-exact",
        out.display(), served.k(), served.dim(),
        served.meta().config_digest
    );
    Ok(())
}
