//! Scalability demo: the paper's Fig 2/3 experiment in miniature.
//!
//! Sweeps simulated cluster sizes (16 → 256 cores, the paper's range) on
//! a dimension-scaled MNIST problem with the simulated clock charged at
//! the FLOP-extrapolated paper-true cost, then prints convergence curves
//! and the speedup table. Each cluster size is one `Session::simulate`
//! run over the shared dataset.
//!
//! ```bash
//! cargo run --release --example scalability [updates]
//! ```

use std::sync::Arc;

use dmlps::session::{calibrate_for, sim_scaled, Session, SimKnobs};

/// Era calibration: the paper's 2014 testbed retires the minibatch
/// gradient ~10x slower than this box's single core (anchor: the paper
/// reports ~0.5 h single-thread MNIST training in section 5.4; ours measures
/// ~2-3 min at the identical shape). The simulated clock charges
/// paper-era cost so compute/communication ratios match the paper's.
const ERA_SLOWDOWN: f64 = 10.0;
use dmlps::config::Preset;
use dmlps::data::ExperimentData;
use dmlps::metrics::{curves_to_markdown, speedup_table};

fn main() -> anyhow::Result<()> {
    let updates: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(800);

    let scaled = sim_scaled(Preset::Mnist);
    let cfg = &scaled.cfg;
    println!(
        "scalability: simulated cluster on {} (d={} k={}, numerics \
         scaled; clock charged at paper-true MNIST cost)",
        cfg.dataset.name, cfg.dataset.dim, cfg.model.k
    );
    let data =
        Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));
    let grad_scaled = calibrate_for(cfg);
    let grad_paper = grad_scaled * scaled.flop_ratio * ERA_SLOWDOWN;
    println!(
        "calibrated: {:.4}s/grad scaled → {:.3}s/grad at paper shape \
         (FLOP ratio {:.1})",
        grad_scaled, grad_paper, scaled.flop_ratio
    );

    let mut curves = Vec::new();
    let mut meas = Vec::new();
    for &cores in &[16usize, 32, 64, 128, 256] {
        let machines = (cores / 16).max(1);
        let r = Session::from_config(cfg.clone())
            .data(data.clone())
            .topology(machines, 16)
            .sim_knobs(SimKnobs {
                grad_seconds: grad_paper,
                bytes_per_msg: Some(scaled.paper_bytes),
                total_updates: updates,
            })
            .simulate()
            .expect("simulated run");
        println!(
            "  {cores:>4} cores: {:>8.1} sim-s, staleness {:>6.1}, \
             final f = {:.4}",
            r.sim_seconds, r.mean_staleness,
            r.curve.final_objective().unwrap_or(f64::NAN)
        );
        meas.push((cores, r.sim_seconds));
        curves.push(r.curve);
    }

    println!("{}", curves_to_markdown(&curves, 10));
    println!("\nspeedup to {updates} applied updates (vs 16 cores):");
    println!("| cores | sim time (s) | speedup | linear |");
    println!("|---|---|---|---|");
    for row in speedup_table(meas) {
        println!(
            "| {} | {:.1} | {:.2}x | {:.2}x |",
            row.cores, row.time_to_target_s, row.speedup, row.linear
        );
    }
    Ok(())
}
