//! Quickstart: learn a Mahalanobis metric on a tiny synthetic dataset in
//! a few seconds through the public `Session` API, persist it as a
//! `MetricModel` artifact, reload it, and compare against Euclidean.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use dmlps::config::Preset;
use dmlps::data::ExperimentData;
use dmlps::eval::ap_euclidean;
use dmlps::session::{MetricModel, Session};

fn main() -> anyhow::Result<()> {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);

    println!(
        "quickstart: d={} k={} lambda={} lr={} steps={}",
        cfg.dataset.dim, cfg.model.k, cfg.optim.lambda, cfg.optim.lr,
        cfg.optim.steps
    );
    let data =
        Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));
    let run = Session::from_config(cfg)
        .data(data.clone())
        .probe(25, (500, 500))
        .train_sequential()?;

    println!("\nobjective curve:");
    for p in run.curve.points.iter().step_by(2) {
        println!("  step {:>5}  t={:>6.2}s  f={:.4}", p.step, p.time_s,
                 p.objective);
    }
    let ap_ours = run.ap_trace.last().map(|&(_, ap)| ap).unwrap_or(0.0);
    println!("\ntest AP: ours {:.4} vs Euclidean {:.4}", ap_ours,
             ap_euclidean(&data));
    println!("trained in {:.2}s", run.wall_s);

    // persist → reload → serve: the train-once/use-everywhere loop
    let path = std::env::temp_dir().join("quickstart_metric.bin");
    let model = run.into_model()?;
    model.save(&path)?;
    let served = MetricModel::load(&path)?;
    assert_eq!(model.l(), served.l());
    let query = data.test.feature(0);
    let hits = served.knn(&data.train, query, 5);
    println!(
        "\nmodel saved to {} and reloaded; 5-NN of test point 0: {:?}",
        path.display(),
        hits.iter().map(|&(i, _)| i).collect::<Vec<_>>()
    );
    Ok(())
}
