//! Quickstart: learn a Mahalanobis metric on a tiny synthetic dataset in
//! a few seconds, single-threaded, and compare against Euclidean.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dmlps::cli::driver::{ap_euclidean, train_single_thread};
use dmlps::config::Preset;
use dmlps::data::ExperimentData;
use dmlps::dml::NativeEngine;

fn main() -> anyhow::Result<()> {
    let mut cfg = Preset::Tiny.config();
    cfg.optim.steps = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);

    println!(
        "quickstart: d={} k={} lambda={} lr={} steps={}",
        cfg.dataset.dim, cfg.model.k, cfg.optim.lambda, cfg.optim.lr,
        cfg.optim.steps
    );
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let mut engine = NativeEngine::new();
    let run = train_single_thread(&cfg, &data, &mut engine, 25)?;

    println!("\nobjective curve:");
    for p in run.curve.points.iter().step_by(2) {
        println!("  step {:>5}  t={:>6.2}s  f={:.4}", p.step, p.time_s,
                 p.objective);
    }
    let ap_ours = run.ap_trace.last().map(|&(_, ap)| ap).unwrap_or(0.0);
    println!("\ntest AP: ours {:.4} vs Euclidean {:.4}", ap_ours,
             ap_euclidean(&data));
    println!("trained in {:.2}s", run.wall_s);
    Ok(())
}
