"""Kernel-vs-reference correctness: the CORE numeric signal of the repo.

Everything the rust runtime executes is lowered from these kernels, so
allclose here + HLO round-trip tests on the rust side == end-to-end
numeric correctness.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dml_grad, pair_dist, ref


def rand_problem(seed, k, d, bs, bd, scale=0.3):
    rng = np.random.RandomState(seed)
    L = (rng.randn(k, d) * scale / np.sqrt(d)).astype(np.float32)
    ds = rng.randn(bs, d).astype(np.float32)
    dd = rng.randn(bd, d).astype(np.float32)
    return L, ds, dd


LAM = np.array([[1.0]], dtype=np.float32)


# ---------------------------------------------------------------------------
# project
# ---------------------------------------------------------------------------

class TestProject:
    @pytest.mark.parametrize("k,d,b,blk", [
        (8, 16, 4, 8),
        (8, 16, 4, 16),
        (3, 30, 5, 10),
        (600, 780, 16, 260),
        (7, 64, 1, 8),
    ])
    def test_matches_ref(self, k, d, b, blk):
        L, ds, _ = rand_problem(0, k, d, b, b)
        got = dml_grad.project(jnp.array(ds), jnp.array(L), blk_d=blk)
        want = ref.project(ds, L)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_single_block(self):
        """blk == d degenerates to one plain matmul."""
        L, ds, _ = rand_problem(1, 5, 12, 3, 3)
        got = dml_grad.project(jnp.array(ds), jnp.array(L), blk_d=12)
        np.testing.assert_allclose(got, ref.project(ds, L),
                                   rtol=1e-4, atol=1e-6)

    def test_accumulation_order_invariance(self):
        """Different d-tilings must agree (up to fp assoc noise)."""
        L, ds, _ = rand_problem(2, 6, 48, 4, 4)
        outs = [
            np.asarray(dml_grad.project(jnp.array(ds), jnp.array(L), blk_d=b))
            for b in (4, 8, 16, 48)
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# loss_grad
# ---------------------------------------------------------------------------

class TestLossGrad:
    @pytest.mark.parametrize("k,d,bs,bd,blk", [
        (8, 16, 4, 4, 8),
        (8, 16, 4, 6, 8),        # asymmetric batch halves
        (16, 64, 10, 10, 16),
        (600, 780, 8, 8, 195),   # mnist-shaped L, tiny batch
    ])
    def test_matches_ref(self, k, d, bs, bd, blk):
        L, ds, dd = rand_problem(3, k, d, bs, bd)
        loss, g = dml_grad.loss_grad(
            jnp.array(L), jnp.array(ds), jnp.array(dd), jnp.array(LAM),
            blk_d=blk)
        rl, rg = ref.loss_grad(jnp.array(L), jnp.array(ds), jnp.array(dd),
                               1.0)
        np.testing.assert_allclose(float(loss[0, 0]), float(rl), rtol=1e-5)
        np.testing.assert_allclose(g, rg, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("lam", [0.0, 0.5, 1.0, 4.0])
    def test_lambda_is_runtime_input(self, lam):
        L, ds, dd = rand_problem(4, 8, 16, 4, 4)
        lam_arr = np.array([[lam]], dtype=np.float32)
        loss, g = dml_grad.loss_grad(
            jnp.array(L), jnp.array(ds), jnp.array(dd), jnp.array(lam_arr),
            blk_d=8)
        rl, rg = ref.loss_grad(jnp.array(L), jnp.array(ds), jnp.array(dd),
                               lam)
        np.testing.assert_allclose(float(loss[0, 0]), float(rl), rtol=1e-5)
        np.testing.assert_allclose(g, rg, rtol=1e-4, atol=1e-5)

    def test_gradient_finite_difference(self):
        """Closed-form gradient vs central differences on the objective."""
        k, d, bs, bd = 4, 6, 3, 3
        L, ds, dd = rand_problem(5, k, d, bs, bd, scale=0.5)
        _, g = dml_grad.loss_grad(
            jnp.array(L), jnp.array(ds), jnp.array(dd), jnp.array(LAM),
            blk_d=6)
        g = np.asarray(g)
        eps = 1e-3
        rng = np.random.RandomState(6)
        for _ in range(10):
            i, j = rng.randint(k), rng.randint(d)
            Lp, Lm = L.copy(), L.copy()
            Lp[i, j] += eps
            Lm[i, j] -= eps
            fp = float(ref.loss(jnp.array(Lp), jnp.array(ds),
                                jnp.array(dd), 1.0))
            fm = float(ref.loss(jnp.array(Lm), jnp.array(ds),
                                jnp.array(dd), 1.0))
            fd = (fp - fm) / (2 * eps)
            np.testing.assert_allclose(g[i, j], fd, rtol=2e-2, atol=1e-3)

    def test_hinge_inactive_when_far(self):
        """Dissimilar pairs already past the margin contribute no grad."""
        k, d = 4, 8
        L = (np.eye(k, d) * 10).astype(np.float32)   # huge distances
        ds = np.zeros((2, d), dtype=np.float32)      # sim term = 0
        dd = np.ones((2, d), dtype=np.float32)
        loss, g = dml_grad.loss_grad(
            jnp.array(L), jnp.array(ds), jnp.array(dd), jnp.array(LAM),
            blk_d=8)
        assert float(loss[0, 0]) == 0.0
        np.testing.assert_allclose(g, np.zeros((k, d)), atol=1e-7)

    def test_hinge_active_when_close(self):
        """Dissimilar pairs inside the margin push L to expand."""
        k, d = 4, 8
        L = (np.eye(k, d) * 1e-3).astype(np.float32)
        ds = np.zeros((2, d), dtype=np.float32)
        dd = np.ones((2, d), dtype=np.float32)
        loss, g = dml_grad.loss_grad(
            jnp.array(L), jnp.array(ds), jnp.array(dd), jnp.array(LAM),
            blk_d=8)
        assert 0.9 < float(loss[0, 0]) <= 1.0    # hinge ~ 1 - eps
        assert np.abs(np.asarray(g)).max() > 0   # gradient nonzero

    def test_zero_L_gives_margin_loss(self):
        """L = 0: sim term 0, every hinge fully active -> loss == lam."""
        k, d = 3, 12
        L = np.zeros((k, d), dtype=np.float32)
        _, ds, dd = rand_problem(7, k, d, 5, 5)
        for lam in (0.5, 1.0, 2.0):
            lam_arr = np.array([[lam]], dtype=np.float32)
            loss, _ = dml_grad.loss_grad(
                jnp.array(L), jnp.array(ds), jnp.array(dd),
                jnp.array(lam_arr), blk_d=12)
            np.testing.assert_allclose(float(loss[0, 0]), lam, rtol=1e-6)


# ---------------------------------------------------------------------------
# pair_dist
# ---------------------------------------------------------------------------

class TestPairDist:
    @pytest.mark.parametrize("k,d,b,blk", [
        (8, 16, 4, 8),
        (600, 780, 32, 260),
        (5, 40, 7, 8),
    ])
    def test_matches_ref(self, k, d, b, blk):
        L, ds, _ = rand_problem(8, k, d, b, b)
        got = pair_dist.pair_dist(jnp.array(ds), jnp.array(L), blk_d=blk)
        want = ref.pair_dist(jnp.array(ds), jnp.array(L))
        np.testing.assert_allclose(got[:, 0], want, rtol=1e-4, atol=1e-5)

    def test_nonnegative(self):
        L, ds, _ = rand_problem(9, 8, 16, 20, 20)
        got = pair_dist.pair_dist(jnp.array(ds), jnp.array(L), blk_d=16)
        assert (np.asarray(got) >= 0).all()

    def test_zero_diff_zero_dist(self):
        L = np.random.RandomState(10).randn(4, 8).astype(np.float32)
        z = np.zeros((3, 8), dtype=np.float32)
        got = pair_dist.pair_dist(jnp.array(z), jnp.array(L), blk_d=8)
        np.testing.assert_allclose(got, np.zeros((3, 1)), atol=1e-8)


# ---------------------------------------------------------------------------
# block-size chooser
# ---------------------------------------------------------------------------

class TestChooseBlockD:
    @pytest.mark.parametrize("d", [16, 780, 2048, 21504, 97])
    def test_divides(self, d):
        blk = dml_grad.choose_block_d(d, 600, 500)
        assert d % blk == 0

    def test_fits_budget(self):
        # Paper's largest config: k=10000, b=50, d=21504.
        k, b, d = 10000, 50, 21504
        blk = dml_grad.choose_block_d(d, k, b)
        resident = 2 * b * k * 4
        streamed = (2 * b + k) * blk * 4 * 2
        assert resident + streamed <= dml_grad.VMEM_BUDGET
        assert blk >= 64   # still a useful tile

    def test_prime_d_degrades_to_1(self):
        # a pathological prime d still yields a legal (if slow) tiling
        assert dml_grad.choose_block_d(9973, 64, 8) == 1


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes & scales
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 24),
    nblk=st.integers(1, 4),
    blk=st.sampled_from([4, 8, 16]),
    bs=st.integers(1, 12),
    bd=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 0.1, 1.0, 10.0]),
)
def test_loss_grad_hypothesis(k, nblk, blk, bs, bd, seed, scale):
    d = nblk * blk
    L, ds, dd = rand_problem(seed % 10000, k, d, bs, bd, scale=scale)
    loss, g = dml_grad.loss_grad(
        jnp.array(L), jnp.array(ds), jnp.array(dd), jnp.array(LAM),
        blk_d=blk)
    rl, rg = ref.loss_grad(jnp.array(L), jnp.array(ds), jnp.array(dd), 1.0)
    np.testing.assert_allclose(float(loss[0, 0]), float(rl),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(g, rg, rtol=1e-3, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 32),
    nblk=st.integers(1, 5),
    blk=st.sampled_from([4, 8]),
    b=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_pair_dist_hypothesis(k, nblk, blk, b, seed):
    d = nblk * blk
    L, ds, _ = rand_problem(seed % 10000, k, d, b, b)
    got = pair_dist.pair_dist(jnp.array(ds), jnp.array(L), blk_d=blk)
    want = ref.pair_dist(jnp.array(ds), jnp.array(L))
    np.testing.assert_allclose(got[:, 0], want, rtol=1e-4, atol=1e-6)
