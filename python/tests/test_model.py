"""L2 model semantics + AOT manifest consistency."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref


def small_problem(seed=0):
    k, d, bs, bd, _ = model.VARIANTS["test_small"]
    rng = np.random.RandomState(seed)
    L = (rng.randn(k, d) * 0.2).astype(np.float32)
    ds = rng.randn(bs, d).astype(np.float32)
    dd = rng.randn(bd, d).astype(np.float32)
    return L, ds, dd


LAM = np.array([[1.0]], dtype=np.float32)
LR = np.array([[0.05]], dtype=np.float32)


class TestStep:
    def test_step_equals_grad_then_update(self):
        L, ds, dd = small_problem()
        loss1, g = model.loss_grad(jnp.array(L), jnp.array(ds),
                                   jnp.array(dd), jnp.array(LAM))
        loss2, L2 = model.step(jnp.array(L), jnp.array(ds), jnp.array(dd),
                               jnp.array(LAM), jnp.array(LR))
        np.testing.assert_allclose(float(loss1[0, 0]), float(loss2[0, 0]),
                                   rtol=1e-6)
        np.testing.assert_allclose(L2, L - 0.05 * np.asarray(g),
                                   rtol=1e-5, atol=1e-6)

    def test_apply_update(self):
        L, ds, dd = small_problem(1)
        _, g = model.loss_grad(jnp.array(L), jnp.array(ds), jnp.array(dd),
                               jnp.array(LAM))
        (L2,) = model.apply_update(jnp.array(L), g, jnp.array(LR))
        np.testing.assert_allclose(L2, L - 0.05 * np.asarray(g), rtol=1e-6)

    def test_training_decreases_objective(self):
        """A few SGD steps on a fixed batch must reduce the loss."""
        L, ds, dd = small_problem(2)
        Lj = jnp.array(L)
        losses = []
        for _ in range(20):
            loss, Lj = model.step(Lj, jnp.array(ds), jnp.array(dd),
                                  jnp.array(LAM), jnp.array(LR))
            losses.append(float(loss[0, 0]))
        assert losses[-1] < losses[0] * 0.9, losses

    def test_step_matches_ref_sgd(self):
        L, ds, dd = small_problem(3)
        _, L2 = model.step(jnp.array(L), jnp.array(ds), jnp.array(dd),
                           jnp.array(LAM), jnp.array(LR))
        _, rL2 = ref.sgd_step(jnp.array(L), jnp.array(ds), jnp.array(dd),
                              1.0, 0.05)
        np.testing.assert_allclose(L2, rL2, rtol=1e-4, atol=1e-6)


class TestVariants:
    def test_all_variants_have_consistent_shapes(self):
        for name, (k, d, bs, bd, be) in model.VARIANTS.items():
            specs = model.specs_for(name)
            fn, args, donate = specs["step"]
            assert args[0].shape == (k, d)
            assert args[1].shape == (bs, d)
            assert args[2].shape == (bd, d)
            assert donate == (0,)
            _, pd_args, _ = specs["pair_dist"]
            assert pd_args[1].shape == (be, d)

    def test_mnist_variant_is_paper_true(self):
        """Table 1: MNIST d=780, k=600, minibatch 1000 (500+500)."""
        k, d, bs, bd, _ = model.VARIANTS["mnist"]
        assert (k, d, bs, bd) == (600, 780, 500, 500)


class TestAotExport:
    @pytest.fixture(scope="class")
    def exported(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        manifest = {"format": "hlo-text/1", "variants": {}, "entries": []}
        aot.export_variant("test_small", str(out), manifest)
        return out, manifest

    def test_files_exist_and_parse(self, exported):
        out, manifest = exported
        for e in manifest["entries"]:
            text = (out / e["file"]).read_text()
            assert "ENTRY" in text and "HloModule" in text
            # donated step must carry the aliasing annotation
            if e["function"] == "step":
                assert "input_output_alias" in text

    def test_manifest_matches_specs(self, exported):
        _, manifest = exported
        by_fn = {e["function"]: e for e in manifest["entries"]}
        assert set(by_fn) == {"loss_grad", "step", "pair_dist",
                              "apply_update"}
        k, d, bs, bd, be = model.VARIANTS["test_small"]
        assert by_fn["step"]["inputs"][0]["shape"] == [k, d]
        assert by_fn["step"]["outputs"][0]["shape"] == [1, 1]
        assert by_fn["step"]["outputs"][1]["shape"] == [k, d]
        assert by_fn["pair_dist"]["outputs"][0]["shape"] == [be, 1]

    def test_checked_in_manifest_is_current(self):
        """artifacts/manifest.json (if built) matches model.VARIANTS."""
        path = os.path.join(os.path.dirname(__file__),
                            "../../artifacts/manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            m = json.load(f)
        assert set(m["variants"]) == set(model.VARIANTS)
        for name, v in model.VARIANTS.items():
            assert m["variants"][name]["k"] == v[0]
            assert m["variants"][name]["d"] == v[1]


class TestNumericsEdgeCases:
    def test_large_scale_inputs_finite(self):
        k, d = 8, 16
        rng = np.random.RandomState(4)
        L = (rng.randn(k, d) * 100).astype(np.float32)
        ds = (rng.randn(4, d) * 100).astype(np.float32)
        dd = (rng.randn(4, d) * 100).astype(np.float32)
        loss, g = model.loss_grad(jnp.array(L), jnp.array(ds),
                                  jnp.array(dd), jnp.array(LAM))
        assert np.isfinite(float(loss[0, 0]))
        assert np.isfinite(np.asarray(g)).all()

    def test_lr_zero_is_identity(self):
        L, ds, dd = small_problem(5)
        zero = np.array([[0.0]], dtype=np.float32)
        _, L2 = model.step(jnp.array(L), jnp.array(ds), jnp.array(dd),
                           jnp.array(LAM), jnp.array(zero))
        np.testing.assert_allclose(L2, L, atol=0)
