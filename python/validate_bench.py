#!/usr/bin/env python3
"""CI gate for machine-readable BENCH_*.json baselines.

Usage: validate_bench.py BENCH_a.json [BENCH_b.json ...]

Every file must parse, every numeric leaf anywhere in the payload must
be finite (the Rust writers refuse NaN/Inf too — this catches a
regression in that guard as much as in the benches), and files whose
top-level "bench" tag is recognised get shape checks on top:

  serving  recall@k floor and a non-empty closed-loop sweep
  lab      non-empty cells, each with params + resource stats, and the
           aggregate/detail sections promised by result_type

Exits nonzero with a per-file message on the first failure.
"""

import json
import math
import sys


def non_finite_paths(node, path=""):
    """Yield JSONPath-ish locations of every non-finite number."""
    if isinstance(node, bool):
        return
    if isinstance(node, (int, float)):
        if not math.isfinite(node):
            yield path or "$"
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from non_finite_paths(v, f"{path}[{i}]")
    elif isinstance(node, dict):
        for k, v in node.items():
            yield from non_finite_paths(v, f"{path}.{k}" if path else k)


def check_serving(doc):
    recall = doc.get("recall_at_k")
    if not isinstance(recall, (int, float)) or recall < 0.9:
        return f"recall_at_k {recall!r} below the 0.9 floor"
    if not doc.get("closed_loop"):
        return "closed_loop sweep is empty"
    return None


def check_lab(doc):
    cells = doc.get("cells")
    if not cells:
        return "lab report has no cells"
    want = set(doc.get("result_type") or [])
    for i, cell in enumerate(cells):
        where = f"cells[{i}] ({cell.get('cell', '?')})"
        if not isinstance(cell.get("params"), dict):
            return f"{where}: missing params object"
        if not isinstance(cell.get("resource"), dict):
            return f"{where}: missing sidecar resource stats"
        if "average" in want and not isinstance(
            cell.get("average"), dict
        ):
            return f"{where}: result_type promises 'average'"
        if "median" in want and not isinstance(cell.get("median"), dict):
            return f"{where}: result_type promises 'median'"
        if "details" in want and not cell.get("details"):
            return f"{where}: result_type promises non-empty 'details'"
    return None


CHECKS = {"serving": check_serving, "lab": check_lab}


def validate(path):
    with open(path) as f:
        doc = json.load(f)
    # json.load accepts bare NaN/Infinity tokens, so scan explicitly
    bad = list(non_finite_paths(doc))
    if bad:
        return f"non-finite values at: {', '.join(bad[:10])}"
    check = CHECKS.get(doc.get("bench"))
    return check(doc) if check else None


def main(argv):
    if not argv:
        print("usage: validate_bench.py BENCH.json [...]",
              file=sys.stderr)
        return 2
    for path in argv:
        try:
            err = validate(path)
        except (OSError, ValueError) as e:
            err = str(e)
        if err:
            print(f"FAIL {path}: {err}", file=sys.stderr)
            return 1
        print(f"ok {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
