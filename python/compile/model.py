"""L2: the DML compute graph, built on the L1 Pallas kernels.

Three exported entry points (each AOT-lowered per shape variant by
``aot.py``; the rust runtime executes them via PJRT):

* ``loss_grad(L, Ds, Dd, lam)    -> (loss(1,1), G(k,d))``
    The async-SGD worker step: the worker computes a gradient on its local
    parameter copy and ships it to the parameter server (paper §4.1).

* ``step(L, Ds, Dd, lam, lr)     -> (loss(1,1), L'(k,d))``
    Fused gradient + SGD update, for single-process training and for the
    server-side "apply aggregated update" fast path. ``L`` is donated so
    XLA updates it in place.

* ``pair_dist(L, D)              -> dist(b,1)``
    Evaluation path: squared Mahalanobis distances for PR/AP sweeps.

Scalars (lam, lr) are (1,1) f32 *runtime inputs*, not baked constants, so
one artifact per shape serves every hyperparameter setting.
"""

import jax
import jax.numpy as jnp

from .kernels import dml_grad
from .kernels import pair_dist as pair_dist_kernel


def loss_grad(L, ds, dd, lam):
    """Minibatch objective + gradient. Returns (loss(1,1), G(k,d))."""
    return dml_grad.loss_grad(L, ds, dd, lam)


def step(L, ds, dd, lam, lr):
    """Fused minibatch SGD step. Returns (loss(1,1), L'(k,d))."""
    loss, g = dml_grad.loss_grad(L, ds, dd, lam)
    return loss, L - lr[0, 0] * g


def pair_dist(L, diffs):
    """Squared Mahalanobis distances. Returns (b,1)."""
    return pair_dist_kernel.pair_dist(diffs, L)


def apply_update(L, g, lr):
    """Server-side parameter update L' = L - lr * G (pure VPU, no MXU)."""
    return (L - lr[0, 0] * g,)


# ---------------------------------------------------------------------------
# Shape variants exported by aot.py.
#
# Paper configs (Table 1):
#   MNIST      d=780    k=600    minibatch 1000 (500 sim + 500 dis)
#   ImNet-60K  d=21504  k=10000  minibatch 100  (50 + 50)
#   ImNet-1M   d=21504  k=1000   minibatch 1000 (500 + 500)
#
# MNIST is exported at paper-true shape. The ImageNet configs are exported
# dimension-scaled for the 1-core CPU testbed (ratios documented in
# DESIGN.md); the paper-true shapes appear in the simulator's cost model
# instead. ``test_small`` backs the rust unit/integration tests.
# ---------------------------------------------------------------------------

VARIANTS = {
    # name:               (k,    d,    bs,  bd,  eval_batch)
    "test_small":         (8,    16,   4,   4,   16),
    "mnist":              (600,  780,  500, 500, 1000),
    "imnet60k_scaled":    (512,  2048, 50,  50,  1000),
    "imnet1m_scaled":     (256,  2048, 500, 500, 1000),
}


def specs_for(name):
    """jax.ShapeDtypeStructs for each exported function of a variant."""
    k, d, bs, bd, be = VARIANTS[name]
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    L = s((k, d), f32)
    ds = s((bs, d), f32)
    dd = s((bd, d), f32)
    scalar = s((1, 1), f32)
    g = s((k, d), f32)
    ev = s((be, d), f32)
    return {
        "loss_grad": (loss_grad, (L, ds, dd, scalar), None),
        "step": (step, (L, ds, dd, scalar, scalar), (0,)),  # donate L
        "pair_dist": (pair_dist, (L, ev), None),
        "apply_update": (apply_update, (L, g, scalar), (0,)),
    }
