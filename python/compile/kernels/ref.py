"""Pure-jnp reference oracles for the DML kernels.

These are the ground truth the Pallas kernels (and, transitively, the HLO
artifacts the rust runtime executes) are validated against in
``python/tests/``.

Notation (paper Eq. 4):

    f(L) = mean_{(x,y) in S} ||L(x-y)||^2
         + lam * mean_{(x,y) in D} max(0, 1 - ||L(x-y)||^2)

We use *mean* (not sum) normalization per pair set so that the learning
rate is invariant to minibatch size; this is a positive rescaling of the
paper's objective and does not change the optimization problem.

Shapes:
    L  : (k, d)   the factor of the Mahalanobis matrix M = L^T L
    Ds : (bs, d)  rows are differences x - y of *similar* pairs
    Dd : (bd, d)  rows are differences x - y of *dissimilar* pairs
"""

import jax.numpy as jnp


def project(diffs, L):
    """Z = diffs @ L.T — the projection of pair differences. (b, k)."""
    return diffs @ L.T


def pair_dist(diffs, L):
    """Squared Mahalanobis distances ||L (x-y)||^2 per pair. (b,)."""
    z = project(diffs, L)
    return jnp.sum(z * z, axis=-1)


def loss(L, ds, dd, lam):
    """Scalar DML objective (mean-normalized Eq. 4)."""
    sim = jnp.mean(pair_dist(ds, L))
    dis = jnp.mean(jnp.maximum(0.0, 1.0 - pair_dist(dd, L)))
    return sim + lam * dis


def loss_grad(L, ds, dd, lam):
    """(loss, dL) computed in closed form (no autodiff).

    d/dL ||L delta||^2 = 2 (L delta) delta^T, so with Z = D L^T:

        G =  (2 / bs) * Zs^T Ds                          (similar term)
          -  (2 lam / bd) * (w * Zd)^T Dd                (hinge term)

    where w_i = 1 if ||L delta_i||^2 < 1 else 0 (hinge active set).
    """
    bs = ds.shape[0]
    bd = dd.shape[0]
    zs = project(ds, L)                      # (bs, k)
    zd = project(dd, L)                      # (bd, k)
    dist_s = jnp.sum(zs * zs, axis=-1)       # (bs,)
    dist_d = jnp.sum(zd * zd, axis=-1)       # (bd,)
    hinge = jnp.maximum(0.0, 1.0 - dist_d)
    obj = jnp.mean(dist_s) + lam * jnp.mean(hinge)
    w = (dist_d < 1.0).astype(L.dtype)       # (bd,)
    g = (2.0 / bs) * zs.T @ ds - (2.0 * lam / bd) * (w[:, None] * zd).T @ dd
    return obj, g


def sgd_step(L, ds, dd, lam, lr):
    """(loss, L') — one fused SGD step on the minibatch."""
    obj, g = loss_grad(L, ds, dd, lam)
    return obj, L - lr * g
