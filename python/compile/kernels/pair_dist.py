"""L1 Pallas kernel: batched squared Mahalanobis pair distances.

dist_i = ||L (x_i - y_i)||^2 for a batch of pair differences. Used by the
evaluation path (precision-recall sweeps, retrieval) and by the serving-
style `eval` subcommand of the rust CLI.

Fuses the projection (d-tiled, MXU) with the row-norm reduction (VPU) in a
single pallas_call: the projection accumulator Z stays VMEM-resident over
the d-grid and the squared row-sum is emitted on the last grid step, so Z
never visits HBM at all.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import dml_grad


def _pair_dist_kernel(d_ref, l_ref, dist_ref, z_scratch):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        z_scratch[...] = jnp.zeros_like(z_scratch)

    z_scratch[...] += jax.lax.dot_general(
        d_ref[...], l_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(i == n - 1)
    def _reduce():
        z = z_scratch[...]
        dist_ref[...] = jnp.sum(z * z, axis=1, keepdims=True)


def pair_dist(diffs, L, blk_d=None):
    """(b, 1) squared distances ||L delta||^2, fused projection+reduction."""
    b, d = diffs.shape
    k, d2 = L.shape
    assert d == d2
    blk = blk_d or dml_grad.choose_block_d(d, k, b)
    assert d % blk == 0
    grid = (d // blk,)
    return pl.pallas_call(
        _pair_dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, blk), lambda i: (0, i)),
            pl.BlockSpec((k, blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((b, k), jnp.float32)],
        interpret=True,
    )(diffs, L)
