"""L1 Pallas kernels for the DML hot spot.

The minibatch gradient of the reformulated objective (paper Eq. 4) is four
matmuls plus an elementwise hinge mask:

    Zs = Ds L^T                    (bs, k)   "project similar diffs"
    Zd = Dd L^T                    (bd, k)   "project dissimilar diffs"
    w  = 1[rowsum(Zd^2) < 1]       (bd,)     "hinge active set"
    G  = (2/bs) Zs^T Ds - (2 lam/bd) (w * Zd)^T Dd        (k, d)

Hardware adaptation (paper targets a CPU cluster; we tile for TPU):

* ``d`` is the huge axis (up to 21504 in the paper) — it is the axis the
  parameter server shards, and it is the grid axis here. Each grid step
  holds one (k, blk_d) slab of L / G plus the (b, blk_d) slabs of the pair
  differences in VMEM; the (b, k) projections stay VMEM-resident across
  the whole grid.
* The matmuls are MXU-shaped ``dot_general``s with f32 accumulation.
* The hinge mask is a VPU elementwise step computed from the resident Zd,
  so Zd never round-trips to HBM between projection and gradient.

Two kernels compose to one fused-in-VMEM pipeline:

* :func:`project`      — Z = D L^T accumulated over the d-grid.
* :func:`hinge_grad`   — per-d-block gradient slab + scalar loss, with the
  hinge mask recomputed from the resident Zd (b*k VPU flops per block,
  negligible next to the 4*b*k*blk MXU flops it saves in HBM traffic).

All ``pallas_call``s use ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the rust
runtime executes. On a real TPU the same BlockSpecs compile unchanged.

VMEM budget (per grid step, f32):
    project:    b*blk + k*blk + b*k
    hinge_grad: bs*k + bd*k + bs*blk + bd*blk + k*blk + 1
For the paper's largest config (k=10000, blk=256, b=50):
    hinge_grad ≈ (50+50)*10000 + (50+50+10000)*256 + 1 ≈ 3.6 MF = 14.4 MB
which fits a 16 MB VMEM — the block size chooser below enforces this.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget we tile for (bytes). Real TPUs have 16 MiB/core; leave
# headroom for double buffering of the streamed d-blocks.
VMEM_BUDGET = 14 * 1024 * 1024


def choose_block_d(d, k, b, budget=VMEM_BUDGET):
    """Largest divisor of ``d`` whose hinge_grad working set fits VMEM.

    Resident across the grid: the projections (2*b*k floats). Streamed per
    block: (2*b + k) * blk floats for the diff slabs and the G slab.
    """
    resident = 2 * b * k * 4
    best = 1
    for blk in range(1, d + 1):
        if d % blk:
            continue
        streamed = (2 * b + k) * blk * 4 * 2  # x2: double buffering
        if resident + streamed <= budget and blk <= 1024:
            best = blk
    return best


# ---------------------------------------------------------------------------
# project: Z = D @ L.T, accumulated over d-blocks
# ---------------------------------------------------------------------------

def _project_kernel(d_ref, l_ref, z_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    z_ref[...] += jax.lax.dot_general(
        d_ref[...], l_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),  # contract both on d
        preferred_element_type=jnp.float32,
    )


def project(diffs, L, blk_d=None):
    """Z = diffs @ L.T via a d-tiled Pallas kernel. (b, k)."""
    b, d = diffs.shape
    k, d2 = L.shape
    assert d == d2, f"diff dim {d} != L dim {d2}"
    blk = blk_d or choose_block_d(d, k, b)
    assert d % blk == 0, f"block {blk} must divide d={d}"
    grid = (d // blk,)
    return pl.pallas_call(
        _project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, blk), lambda i: (0, i)),
            pl.BlockSpec((k, blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=True,
    )(diffs, L)


# ---------------------------------------------------------------------------
# hinge_grad: per-d-block gradient slab + scalar loss
# ---------------------------------------------------------------------------

def _hinge_grad_kernel(bs, bd, zs_ref, zd_ref, ds_ref, dd_ref, lam_ref,
                       g_ref, loss_ref):
    i = pl.program_id(0)
    lam = lam_ref[0, 0]
    zs = zs_ref[...]                                   # (bs, k), resident
    zd = zd_ref[...]                                   # (bd, k), resident
    # Hinge active set, recomputed per block from resident Zd (VPU-cheap).
    dist_d = jnp.sum(zd * zd, axis=1, keepdims=True)   # (bd, 1)
    w = jnp.where(dist_d < 1.0, 1.0, 0.0).astype(zd.dtype)

    @pl.when(i == 0)
    def _loss():
        dist_s = jnp.sum(zs * zs, axis=1)              # (bs,)
        hinge = jnp.maximum(0.0, 1.0 - dist_d[:, 0])   # (bd,)
        loss_ref[0, 0] = jnp.mean(dist_s) + lam * jnp.mean(hinge)

    # G_blk = (2/bs) Zs^T Ds_blk - (2 lam / bd) (w*Zd)^T Dd_blk
    gs = jax.lax.dot_general(
        zs, ds_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),    # (k, blk)
        preferred_element_type=jnp.float32,
    )
    gd = jax.lax.dot_general(
        w * zd, dd_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),    # (k, blk)
        preferred_element_type=jnp.float32,
    )
    g_ref[...] = (2.0 / bs) * gs - (2.0 * lam / bd) * gd


def hinge_grad(zs, zd, ds, dd, lam, blk_d=None):
    """(loss, G) from resident projections + streamed diff slabs.

    ``lam`` must be shaped (1, 1) float32 (kept as a runtime input so one
    artifact serves any tradeoff setting).
    """
    bs, k = zs.shape
    bd, _ = zd.shape
    _, d = ds.shape
    blk = blk_d or choose_block_d(d, k, max(bs, bd))
    assert d % blk == 0, f"block {blk} must divide d={d}"
    grid = (d // blk,)
    kern = functools.partial(_hinge_grad_kernel, bs, bd)
    g, loss = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, k), lambda i: (0, 0)),    # Zs resident
            pl.BlockSpec((bd, k), lambda i: (0, 0)),    # Zd resident
            pl.BlockSpec((bs, blk), lambda i: (0, i)),  # Ds streamed
            pl.BlockSpec((bd, blk), lambda i: (0, i)),  # Dd streamed
            pl.BlockSpec((1, 1), lambda i: (0, 0)),     # lam scalar
        ],
        out_specs=[
            pl.BlockSpec((k, blk), lambda i: (0, i)),   # G streamed out
            pl.BlockSpec((1, 1), lambda i: (0, 0)),     # loss
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,
    )(zs, zd, ds, dd, lam)
    return loss, g


# ---------------------------------------------------------------------------
# fused loss+grad entry point (what model.py calls)
# ---------------------------------------------------------------------------

def loss_grad(L, ds, dd, lam, blk_d=None):
    """(loss(1,1), G(k,d)) for one minibatch — the L1 hot path."""
    zs = project(ds, L, blk_d=blk_d)
    zd = project(dd, L, blk_d=blk_d)
    return hinge_grad(zs, zd, ds, dd, lam, blk_d=blk_d)
