"""AOT lowering: JAX (L2+L1) -> HLO text artifacts for the rust runtime.

Interchange format is **HLO text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``). The HLO *text* parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/README.md.

Each (variant, function) pair becomes ``artifacts/<variant>.<fn>.hlo.txt``
plus one ``artifacts/manifest.json`` describing entry shapes so the rust
side can validate its marshalling without parsing HLO.

Run: ``cd python && python -m compile.aot --out-dir ../artifacts``
(wired as ``make artifacts``; a no-op when inputs are unchanged thanks to
the Makefile dependency list).
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple, even for single-output fns)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(fn, args, donate):
    kwargs = {}
    if donate:
        kwargs["donate_argnums"] = donate
    return jax.jit(fn, **kwargs).lower(*args)


def export_variant(name, out_dir, manifest):
    for fn_name, (fn, args, donate) in model.specs_for(name).items():
        lowered = lower_one(fn, args, donate)
        text = to_hlo_text(lowered)
        fname = f"{name}.{fn_name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_avals = lowered.out_info
        manifest["entries"].append(
            {
                "variant": name,
                "function": fn_name,
                "file": fname,
                "inputs": [
                    {"shape": list(a.shape), "dtype": str(a.dtype)}
                    for a in args
                ],
                "outputs": [
                    {"shape": list(o.shape), "dtype": str(o.dtype)}
                    for o in jax.tree.leaves(out_avals)
                ],
            }
        )
        print(f"  wrote {fname} ({len(text)} chars)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default=",".join(model.VARIANTS),
        help="comma-separated subset of variants to export",
    )
    ns = ap.parse_args()
    os.makedirs(ns.out_dir, exist_ok=True)

    manifest = {
        "format": "hlo-text/1",
        "variants": {
            name: {
                "k": v[0], "d": v[1], "bs": v[2], "bd": v[3],
                "eval_batch": v[4],
            }
            for name, v in model.VARIANTS.items()
        },
        "entries": [],
    }
    for name in ns.variants.split(","):
        print(f"variant {name}: "
              f"k={model.VARIANTS[name][0]} d={model.VARIANTS[name][1]}")
        export_variant(name, ns.out_dir, manifest)

    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['entries'])} entries")


if __name__ == "__main__":
    main()
