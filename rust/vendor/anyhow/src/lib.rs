//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline vendor set has no crates.io access, so this shim provides
//! the subset of the real `anyhow` API the repo uses: the [`Error`] type
//! with a `From<E: std::error::Error>` blanket conversion (so `?` works on
//! std errors), the [`Result`] alias, the [`anyhow!`], [`bail!`] and
//! [`ensure!`] macros, and the [`Context`] extension trait.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket `From` impl
//! coherent.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-carrying error with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The deepest underlying std error, if any.
    pub fn source_ref(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = self.source_ref().and_then(StdError::source);
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cause {
            write!(f, "\n    {e}")?;
            cause = e.source();
        }
        Ok(())
    }
}

/// `?` on any std error converts into [`Error`]. Sound because `Error`
/// itself does not implement `std::error::Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {} at {}", 7, "here");
        assert_eq!(e.to_string(), "bad value 7 at here");
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("x too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
        assert!(f(101).unwrap_err().to_string().contains("too big"));
    }

    #[test]
    fn context_wraps() {
        let r: Result<()> = Err(io_err()).context("loading manifest");
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("loading manifest: "), "{msg}");
        let o: Option<i32> = None;
        assert!(o.context("missing").is_err());
    }
}
