//! `dmlps` CLI launcher — temporary stub; real dispatcher in cli module.
fn main() -> anyhow::Result<()> {
    dmlps::cli::main_entry()
}
