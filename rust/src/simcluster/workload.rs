//! Workloads the simulator drives: real DML numerics or cost-only.

use std::sync::Arc;

use crate::data::{Dataset, PairShard};
use crate::dml::{DmlProblem, Engine, MinibatchRef, NativeEngine,
                 ObjectiveProbe};
use crate::linalg::Mat;
use crate::util::rng::Pcg32;

/// What the simulator needs from a workload: per-machine gradients on the
/// machine's local parameters, and an objective probe on the global
/// parameters.
pub trait Workload {
    /// Parameter dimensions (rows, cols) — (k, d).
    fn param_shape(&self) -> (usize, usize);

    /// Initial parameters.
    fn init(&self) -> Mat;

    /// Compute (loss, grad) for `machine` at its local parameters,
    /// writing into `g`.
    fn grad(&mut self, machine: usize, l: &Mat, g: &mut Mat) -> f32;

    /// Objective value at the global parameters.
    fn objective(&mut self, l: &Mat) -> f64;
}

/// Real DML numerics: each machine owns a pair shard; gradients run on
/// the native engine with reusable minibatch buffers.
pub struct DmlWorkload {
    problem: DmlProblem,
    init_scale: f32,
    seed: u64,
    dataset: Arc<Dataset>,
    shards: Vec<PairShard>,
    rngs: Vec<Pcg32>,
    engine: NativeEngine,
    probe: ObjectiveProbe,
    bs: usize,
    bd: usize,
    ds_buf: Vec<f32>,
    dd_buf: Vec<f32>,
}

impl DmlWorkload {
    /// `shards[m]` is machine m's pair shard (from
    /// [`crate::data::partition_pairs`]).
    pub fn new(
        problem: DmlProblem,
        init_scale: f32,
        dataset: Arc<Dataset>,
        shards: Vec<PairShard>,
        bs: usize,
        bd: usize,
        probe_pairs: (usize, usize),
        seed: u64,
    ) -> DmlWorkload {
        // Objective probe over the union of shards.
        let mut all = crate::data::PairSet::default();
        for s in &shards {
            all.similar.extend_from_slice(&s.pairs.similar);
            all.dissimilar.extend_from_slice(&s.pairs.dissimilar);
        }
        let probe = ObjectiveProbe::new(
            &dataset,
            &all,
            probe_pairs.0,
            probe_pairs.1,
            seed ^ 0x9,
        );
        let rngs = (0..shards.len())
            .map(|m| Pcg32::with_stream(seed, 0x700 + m as u64))
            .collect();
        let d = problem.d;
        DmlWorkload {
            problem,
            init_scale,
            seed,
            dataset,
            shards,
            rngs,
            engine: NativeEngine::new(),
            probe,
            bs,
            bd,
            ds_buf: vec![0.0; bs * d],
            dd_buf: vec![0.0; bd * d],
        }
    }

    pub fn lambda(&self) -> f32 {
        self.problem.lambda
    }

    fn fill_batch(&mut self, machine: usize) {
        let d = self.problem.d;
        let pairs = &self.shards[machine].pairs;
        let rng = &mut self.rngs[machine];
        for r in 0..self.bs {
            let p = pairs.similar[rng.index(pairs.similar.len())];
            self.dataset.diff_into(
                p.i as usize,
                p.j as usize,
                &mut self.ds_buf[r * d..(r + 1) * d],
            );
        }
        for r in 0..self.bd {
            let p = pairs.dissimilar[rng.index(pairs.dissimilar.len())];
            self.dataset.diff_into(
                p.i as usize,
                p.j as usize,
                &mut self.dd_buf[r * d..(r + 1) * d],
            );
        }
    }
}

impl Workload for DmlWorkload {
    fn param_shape(&self) -> (usize, usize) {
        (self.problem.k, self.problem.d)
    }

    fn init(&self) -> Mat {
        self.problem.init_l(self.init_scale, self.seed)
    }

    fn grad(&mut self, machine: usize, l: &Mat, g: &mut Mat) -> f32 {
        self.fill_batch(machine);
        let batch = MinibatchRef::new(
            &self.ds_buf, &self.dd_buf, self.bs, self.bd, self.problem.d,
        );
        self.engine
            .loss_grad(l, &batch, self.problem.lambda, g)
            .expect("sim gradient")
    }

    fn objective(&mut self, l: &Mat) -> f64 {
        self.probe.eval(&mut self.engine, l, self.problem.lambda) as f64
    }
}

/// Cost-only workload: zero-dimensional numerics (1×1 parameters, zero
/// gradients). Lets the event machinery run at paper-true message sizes
/// and compute times without materializing 220M-parameter matrices —
/// used for throughput/speedup analysis at ImageNet scale.
pub struct NullWorkload;

impl Workload for NullWorkload {
    fn param_shape(&self) -> (usize, usize) {
        (1, 1)
    }

    fn init(&self) -> Mat {
        Mat::zeros(1, 1)
    }

    fn grad(&mut self, _machine: usize, _l: &Mat, g: &mut Mat) -> f32 {
        g.data.fill(0.0);
        0.0
    }

    fn objective(&mut self, _l: &Mat) -> f64 {
        f64::NAN
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{partition_pairs, PairSet, SyntheticSpec};

    #[test]
    fn dml_workload_gradients_are_real() {
        let ds = Arc::new(SyntheticSpec::tiny().generate(0));
        let mut rng = Pcg32::new(0);
        let pairs = PairSet::sample(&ds, 100, 100, &mut rng);
        let shards = partition_pairs(&pairs, 2, 1).unwrap();
        let problem = DmlProblem::new(ds.dim(), 8, 1.0);
        let mut w = DmlWorkload::new(
            problem, 0.5, ds, shards, 4, 4, (50, 50), 42,
        );
        let l = w.init();
        let mut g = Mat::zeros(8, l.cols);
        let loss = w.grad(0, &l, &mut g);
        assert!(loss.is_finite() && loss > 0.0);
        assert!(g.fro_norm() > 0.0);
        let obj = w.objective(&l);
        assert!(obj.is_finite() && obj > 0.0);
    }

    #[test]
    fn machines_draw_different_batches() {
        let ds = Arc::new(SyntheticSpec::tiny().generate(1));
        let mut rng = Pcg32::new(1);
        let pairs = PairSet::sample(&ds, 100, 100, &mut rng);
        let shards = partition_pairs(&pairs, 2, 2).unwrap();
        let problem = DmlProblem::new(ds.dim(), 4, 1.0);
        let mut w = DmlWorkload::new(
            problem, 0.5, ds, shards, 4, 4, (50, 50), 43,
        );
        let l = w.init();
        let mut g0 = Mat::zeros(4, l.cols);
        let mut g1 = Mat::zeros(4, l.cols);
        w.grad(0, &l, &mut g0);
        w.grad(1, &l, &mut g1);
        assert!(g0.max_abs_diff(&g1) > 1e-6);
    }

    #[test]
    fn null_workload_is_inert() {
        let mut w = NullWorkload;
        let l = w.init();
        let mut g = Mat::zeros(1, 1);
        assert_eq!(w.grad(0, &l, &mut g), 0.0);
        assert!(w.objective(&l).is_nan());
    }
}
