//! The discrete-event simulation core.
//!
//! Entities: P machines (each an aggregated C-core compute engine that
//! finishes one minibatch gradient every `grad_seconds / C` on average),
//! one parameter server (serial applies of `apply_seconds` each), and the
//! network of [`NetworkModel`]. The protocol simulated is the paper's
//! ASP parameter server: machines never wait; the server applies
//! gradients as they arrive and broadcasts fresh parameters.
//!
//! Numerics are *real*: gradients are computed on the machine's local
//! parameter snapshot at the simulated completion time, so parameter
//! staleness — the thing that makes async SGD converge differently from
//! serial SGD — is faithfully reproduced, just under a simulated clock.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use super::network::NetworkModel;
use super::workload::Workload;
use crate::dml::LrSchedule;
use crate::linalg::Mat;
use crate::metrics::Curve;
use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub machines: usize,
    pub cores_per_machine: usize,
    /// Calibrated single-core minibatch gradient time (seconds).
    pub grad_seconds: f64,
    /// Server parameter-update time per gradient (seconds).
    pub apply_seconds: f64,
    /// Message payload size (bytes) — k·d·4 for dense f32 updates.
    pub bytes_per_msg: f64,
    pub network: NetworkModel,
    /// Relative compute jitter (0.05 = ±5% uniform).
    pub jitter: f64,
    /// Stop after this many gradient updates applied at the server.
    pub total_updates: u64,
    /// Record a curve point every `probe_every` applied updates.
    pub probe_every: u64,
    /// Broadcast fresh parameters every `broadcast_every` applies
    /// (the server-side batching knob; 1 = after every apply).
    pub broadcast_every: u64,
    pub lr: LrSchedule,
    pub seed: u64,
    /// Optional kill/restart scenario (elasticity modeling).
    pub disruption: Option<Disruption>,
}

/// A simulated process-death scenario: the whole cluster dies once at
/// `kill_at_update` applied updates, every in-flight gradient and
/// broadcast dies with it, and after `restart_delay_s` simulated seconds
/// the cluster re-enters from the newest checkpoint — the server state
/// taken every `ckpt_every_updates` applies. `ckpt_every_updates = 0`
/// models running *without* checkpoints: the restart falls all the way
/// back to the initial parameters, which is exactly the baseline the
/// convergence-vs-disruption curves compare against.
#[derive(Clone, Copy, Debug)]
pub struct Disruption {
    pub kill_at_update: u64,
    pub restart_delay_s: f64,
    pub ckpt_every_updates: u64,
}

impl SimConfig {
    /// Effective mean seconds between gradient completions on a machine.
    pub fn machine_interval(&self) -> f64 {
        self.grad_seconds / self.cores_per_machine as f64
    }

    pub fn total_cores(&self) -> usize {
        self.machines * self.cores_per_machine
    }
}

pub struct SimResult {
    pub curve: Curve,
    pub applied_updates: u64,
    pub sim_seconds: f64,
    pub broadcasts: u64,
    /// Mean staleness (server version − version the gradient was computed
    /// at), over all applied updates — the async-SGD health metric.
    pub mean_staleness: f64,
    /// Cluster deaths survived (0 or 1 — one [`Disruption`] per run).
    pub restarts: u64,
    /// Applied updates lost to the rollback and re-done after restart.
    pub redone_updates: u64,
}

#[derive(Debug)]
enum Event {
    /// A machine finished computing one gradient.
    GradReady { machine: usize },
    /// A gradient arrived at the server.
    GradArrive { grad_id: usize },
    /// A parameter broadcast reached a machine.
    ParamArrive { machine: usize, bcast_id: usize },
    /// The server finished applying a gradient.
    ServerFree,
}

/// Heap key with total order on simulated time.
#[derive(PartialEq)]
struct At(f64, u64);

impl Eq for At {}

impl PartialOrd for At {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for At {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0
            .partial_cmp(&other.0)
            .unwrap()
            .then(self.1.cmp(&other.1))
    }
}

pub struct Simulator<'w> {
    cfg: SimConfig,
    workload: &'w mut dyn Workload,
}

impl<'w> Simulator<'w> {
    pub fn new(cfg: SimConfig, workload: &'w mut dyn Workload) -> Self {
        Simulator { cfg, workload }
    }

    pub fn run(self) -> SimResult {
        let (k, d) = self.workload.param_shape();
        let p = self.cfg.machines;
        let mut net = self.cfg.network.clone();
        net.reset();
        let mut rng = Pcg32::with_stream(self.cfg.seed, 0x51A1);

        // global + per-machine parameter state
        let mut l_global = self.workload.init();
        let mut locals: Vec<Mat> = (0..p).map(|_| l_global.clone()).collect();
        let mut local_version = vec![0u64; p];
        let mut local_steps = vec![0u64; p];
        let mut version = 0u64;

        // in-flight gradients / broadcasts
        struct InFlightGrad {
            data: Mat,
            at_version: u64,
        }
        let mut grads: Vec<Option<InFlightGrad>> = Vec::new();
        let mut bcasts: Vec<Option<(u64, Arc<Vec<f32>>)>> = Vec::new();

        let mut heap: BinaryHeap<Reverse<(At, usize)>> = BinaryHeap::new();
        let mut events: Vec<Option<Event>> = Vec::new();
        let mut seq = 0u64;
        let mut push = |heap: &mut BinaryHeap<Reverse<(At, usize)>>,
                        events: &mut Vec<Option<Event>>,
                        t: f64,
                        e: Event| {
            let id = events.len();
            events.push(Some(e));
            heap.push(Reverse((At(t, seq), id)));
            seq += 1;
        };

        // server state
        let mut server_busy_until = 0.0f64;
        let mut server_queue: std::collections::VecDeque<usize> =
            Default::default();
        let mut applied = 0u64;
        let mut broadcasts = 0u64;
        let mut staleness_sum = 0.0f64;

        // disruption state: the newest checkpoint of the server params,
        // and the one pending kill (consumed when it fires)
        let mut ckpt_applied = 0u64;
        let mut ckpt_version = 0u64;
        let mut ckpt_l =
            self.cfg.disruption.as_ref().map(|_| l_global.clone());
        let mut pending_kill = self.cfg.disruption;
        let mut restarts = 0u64;
        let mut redone_updates = 0u64;
        let mut curve = Curve::new(format!(
            "{} cores ({}x{})",
            self.cfg.total_cores(),
            p,
            self.cfg.cores_per_machine
        ));
        let obj0 = self.workload.objective(&l_global);
        curve.push(0.0, 0, obj0);

        // seed: every machine starts computing at t ≈ 0
        for m in 0..p {
            let t = self.interval(&mut rng) * rng.f64();
            push(&mut heap, &mut events, t, Event::GradReady { machine: m });
        }

        let mut g_scratch = Mat::zeros(k, d);
        let mut now = 0.0f64;
        while let Some(Reverse((At(t, _), eid))) = heap.pop() {
            now = t;
            let ev = events[eid].take().expect("event consumed twice");
            match ev {
                Event::GradReady { machine } => {
                    // real gradient on this machine's local snapshot
                    self.workload.grad(machine, &locals[machine],
                                       &mut g_scratch);
                    // the worker applies its own gradient locally so it
                    // keeps progressing between server refreshes (§4.1)
                    let lr_local =
                        self.cfg.lr.at(local_steps[machine] as usize);
                    local_steps[machine] += 1;
                    for (a, gv) in locals[machine]
                        .data
                        .iter_mut()
                        .zip(&g_scratch.data)
                    {
                        *a -= lr_local * gv;
                    }
                    let grad_id = grads.len();
                    grads.push(Some(InFlightGrad {
                        data: g_scratch.clone(),
                        at_version: local_version[machine],
                    }));
                    let arrive = net.to_server(now, self.cfg.bytes_per_msg);
                    push(&mut heap, &mut events, arrive,
                         Event::GradArrive { grad_id });
                    // next gradient from this machine's core pool
                    let next = now + self.interval(&mut rng);
                    push(&mut heap, &mut events, next,
                         Event::GradReady { machine });
                }
                Event::GradArrive { grad_id } => {
                    server_queue.push_back(grad_id);
                    if server_busy_until <= now {
                        // server idle: start applying immediately
                        server_busy_until = now + self.cfg.apply_seconds;
                        push(&mut heap, &mut events, server_busy_until,
                             Event::ServerFree);
                    }
                }
                Event::ServerFree => {
                    // apply exactly one queued gradient per ServerFree
                    if let Some(gid) = server_queue.pop_front() {
                        let g = grads[gid].take().expect("grad consumed");
                        let lr_t = self.cfg.lr.at(applied as usize);
                        for (a, gv) in
                            l_global.data.iter_mut().zip(&g.data.data)
                        {
                            *a -= lr_t * gv;
                        }
                        applied += 1;
                        staleness_sum += (version - g.at_version) as f64;
                        version += 1;
                        // the checkpoint lands before the kill check: a
                        // snapshot taken on the very apply the cluster
                        // dies at was already durable
                        if let Some(d) = &self.cfg.disruption {
                            if d.ckpt_every_updates > 0
                                && applied % d.ckpt_every_updates == 0
                            {
                                ckpt_applied = applied;
                                ckpt_version = version;
                                if let Some(cl) = &mut ckpt_l {
                                    cl.data
                                        .copy_from_slice(&l_global.data);
                                }
                            }
                        }
                        if pending_kill
                            .is_some_and(|d| applied >= d.kill_at_update)
                        {
                            let d = pending_kill.take().expect("checked");
                            restarts += 1;
                            redone_updates += applied - ckpt_applied;
                            // roll the server back to the newest
                            // checkpoint; everything in flight dies with
                            // the processes
                            applied = ckpt_applied;
                            version = ckpt_version;
                            if let Some(cl) = &ckpt_l {
                                l_global.data.copy_from_slice(&cl.data);
                            }
                            heap.clear();
                            server_queue.clear();
                            let restart = now + d.restart_delay_s.max(0.0);
                            server_busy_until = restart;
                            // curve shows the setback at re-entry
                            let obj = self.workload.objective(&l_global);
                            curve.push(restart, applied as usize, obj);
                            for (m, local) in locals.iter_mut().enumerate()
                            {
                                local.data.copy_from_slice(&l_global.data);
                                local_version[m] = version;
                                let t = restart + self.interval(&mut rng);
                                push(&mut heap, &mut events, t,
                                     Event::GradReady { machine: m });
                            }
                            continue;
                        }
                        if applied % self.cfg.probe_every.max(1) == 0 {
                            let obj = self.workload.objective(&l_global);
                            curve.push(now, applied as usize, obj);
                        }
                        // Broadcast coalescing: a real parameter server
                        // pushes its *current* L and never queues stale
                        // snapshots behind a saturated NIC. Skip this
                        // broadcast if more than one full broadcast is
                        // already serializing — the next apply will send
                        // fresher parameters anyway.
                        let egress_ok = net.egress_backlog(now)
                            <= net.egress_cost(self.cfg.bytes_per_msg)
                                * p as f64;
                        if applied
                            % self.cfg.broadcast_every.max(1)
                            == 0
                            && egress_ok
                        {
                            broadcasts += 1;
                            let snapshot =
                                Arc::new(l_global.data.clone());
                            let bcast_id = bcasts.len();
                            bcasts.push(Some((version, snapshot)));
                            for (m, arrive) in net
                                .broadcast(
                                    now,
                                    self.cfg.bytes_per_msg,
                                    p,
                                )
                                .into_iter()
                                .enumerate()
                            {
                                push(&mut heap, &mut events, arrive,
                                     Event::ParamArrive {
                                         machine: m,
                                         bcast_id,
                                     });
                            }
                        }
                        if applied >= self.cfg.total_updates {
                            break;
                        }
                        if !server_queue.is_empty() {
                            server_busy_until =
                                now + self.cfg.apply_seconds;
                            push(&mut heap, &mut events,
                                 server_busy_until, Event::ServerFree);
                        }
                    }
                }
                Event::ParamArrive { machine, bcast_id } => {
                    if let Some((v, snap)) = &bcasts[bcast_id] {
                        // adopt only if newer than what the machine has
                        if *v > local_version[machine] {
                            locals[machine].data.copy_from_slice(snap);
                            local_version[machine] = *v;
                        }
                    }
                    // drop the snapshot once all machines were offered it
                    // (cheap heuristic: last machine index)
                    if machine == p - 1 {
                        bcasts[bcast_id] = None;
                    }
                }
            }
        }

        // final probe
        let obj = self.workload.objective(&l_global);
        curve.push(now, applied as usize, obj);
        SimResult {
            curve,
            applied_updates: applied,
            sim_seconds: now,
            broadcasts,
            mean_staleness: if applied > 0 {
                staleness_sum / applied as f64
            } else {
                0.0
            },
            restarts,
            redone_updates,
        }
    }

    fn interval(&self, rng: &mut Pcg32) -> f64 {
        let base = self.cfg.machine_interval();
        let j = self.cfg.jitter;
        base * (1.0 - j + 2.0 * j * rng.f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{partition_pairs, PairSet, SyntheticSpec};
    use crate::dml::DmlProblem;
    use crate::simcluster::workload::{DmlWorkload, NullWorkload};

    fn base_cfg(machines: usize, cores: usize) -> SimConfig {
        SimConfig {
            machines,
            cores_per_machine: cores,
            grad_seconds: 0.1,
            apply_seconds: 0.0005,
            bytes_per_msg: 4.0 * 8.0 * 16.0,
            network: NetworkModel::ten_gbe(),
            jitter: 0.05,
            total_updates: 200,
            probe_every: 50,
            broadcast_every: 1,
            lr: LrSchedule::new(0.005, 0.001),
            seed: 7,
            disruption: None,
        }
    }

    fn dml_workload(p: usize) -> DmlWorkload {
        let ds = Arc::new(SyntheticSpec::tiny().generate(0));
        let mut rng = Pcg32::new(0);
        let pairs = PairSet::sample(&ds, 400, 400, &mut rng);
        let shards = partition_pairs(&pairs, p, 1).unwrap();
        DmlWorkload::new(
            DmlProblem::new(ds.dim(), 8, 1.0),
            0.5, ds, shards, 8, 8, (100, 100), 11,
        )
    }

    #[test]
    fn objective_decreases_under_sim() {
        let mut w = dml_workload(2);
        let r = Simulator::new(base_cfg(2, 2), &mut w).run();
        assert_eq!(r.applied_updates, 200);
        let first = r.curve.points.first().unwrap().objective;
        let last = r.curve.points.last().unwrap().objective;
        assert!(last < first * 0.9, "{first} -> {last}");
        assert!(r.sim_seconds > 0.0);
    }

    #[test]
    fn more_cores_finish_sooner() {
        let mut w1 = dml_workload(1);
        let t1 = Simulator::new(base_cfg(1, 4), &mut w1).run().sim_seconds;
        let mut w4 = dml_workload(4);
        let t4 = Simulator::new(base_cfg(4, 4), &mut w4).run().sim_seconds;
        // 4x the cores → noticeably faster to the same update count
        assert!(t4 < t1 * 0.5, "t1={t1} t4={t4}");
    }

    #[test]
    fn speedup_is_sublinear_when_server_bound() {
        // huge apply cost → server saturates, speedup flattens
        let mut cfg1 = base_cfg(1, 1);
        cfg1.apply_seconds = 0.05; // half of grad time
        let mut cfg8 = base_cfg(8, 1);
        cfg8.apply_seconds = 0.05;
        let mut w1 = NullWorkload;
        let t1 = Simulator::new(cfg1, &mut w1).run().sim_seconds;
        let mut w8 = NullWorkload;
        let t8 = Simulator::new(cfg8, &mut w8).run().sim_seconds;
        let speedup = t1 / t8;
        assert!(speedup < 4.0, "speedup={speedup} should be server-bound");
        assert!(speedup > 1.2, "some speedup expected: {speedup}");
    }

    #[test]
    fn staleness_grows_with_machines() {
        let mut w2 = dml_workload(2);
        let s2 = Simulator::new(base_cfg(2, 1), &mut w2)
            .run()
            .mean_staleness;
        let mut w8 = dml_workload(8);
        let s8 = Simulator::new(base_cfg(8, 1), &mut w8)
            .run()
            .mean_staleness;
        assert!(s8 > s2, "s2={s2} s8={s8}");
    }

    #[test]
    fn null_workload_runs_fast_at_paper_scale() {
        // ImageNet-63K paper-true message size: 220M params × 4B
        let mut cfg = base_cfg(4, 64);
        cfg.bytes_per_msg = 215_040_000.0 * 4.0;
        cfg.grad_seconds = 30.0;
        cfg.apply_seconds = 0.2;
        cfg.total_updates = 100;
        let mut w = NullWorkload;
        let r = Simulator::new(cfg, &mut w).run();
        assert_eq!(r.applied_updates, 100);
        assert!(r.sim_seconds > 0.0);
    }

    /// A mid-run cluster death rolls back to the newest checkpoint,
    /// costs wall-clock (the restart delay plus the re-done updates),
    /// and still converges to the same update count.
    #[test]
    fn disruption_rolls_back_and_still_converges() {
        let mut w0 = dml_workload(2);
        let undisturbed = Simulator::new(base_cfg(2, 2), &mut w0).run();
        assert_eq!(undisturbed.restarts, 0);

        let mut cfg = base_cfg(2, 2);
        cfg.disruption = Some(Disruption {
            kill_at_update: 100,
            restart_delay_s: 1.0,
            ckpt_every_updates: 40,
        });
        let mut w = dml_workload(2);
        let r = Simulator::new(cfg, &mut w).run();
        assert_eq!(r.restarts, 1);
        // killed at 100 with checkpoints at 40/80 → 20 updates re-done
        assert_eq!(r.redone_updates, 20);
        assert_eq!(r.applied_updates, 200);
        assert!(
            r.sim_seconds > undisturbed.sim_seconds,
            "disruption must cost simulated time: {} vs {}",
            r.sim_seconds, undisturbed.sim_seconds
        );
        let first = r.curve.points.first().unwrap().objective;
        let last = r.curve.points.last().unwrap().objective;
        assert!(last < first * 0.9, "{first} -> {last}");
    }

    /// `ckpt_every_updates = 0` models a checkpoint-free cluster: the
    /// kill throws away every applied update.
    #[test]
    fn disruption_without_checkpoints_redoes_everything() {
        let mut cfg = base_cfg(2, 1);
        cfg.disruption = Some(Disruption {
            kill_at_update: 150,
            restart_delay_s: 0.5,
            ckpt_every_updates: 0,
        });
        let mut w = dml_workload(2);
        let r = Simulator::new(cfg, &mut w).run();
        assert_eq!(r.restarts, 1);
        assert_eq!(r.redone_updates, 150);
        assert_eq!(r.applied_updates, 200);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut wa = dml_workload(3);
        let a = Simulator::new(base_cfg(3, 2), &mut wa).run();
        let mut wb = dml_workload(3);
        let b = Simulator::new(base_cfg(3, 2), &mut wb).run();
        assert_eq!(a.sim_seconds, b.sim_seconds);
        let ao: Vec<f64> =
            a.curve.points.iter().map(|p| p.objective).collect();
        let bo: Vec<f64> =
            b.curve.points.iter().map(|p| p.objective).collect();
        assert_eq!(ao, bo);
    }
}
