//! Network model: per-link serialization + propagation latency, plus a
//! shared server NIC that becomes the scalability ceiling at high
//! machine counts (the effect behind the paper's 3.6–3.8× at 4 machines
//! instead of 4×).

/// Simple fluid model: a transfer of B bytes over a link with bandwidth
/// W occupies the link for B/W seconds; the link is FIFO. Each machine
/// has its own full-duplex link to the switch; the server has one
/// ingress and one egress link shared by all machines.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    pub latency_s: f64,
    /// Per-machine link bandwidth (bytes/sec).
    pub machine_bw: f64,
    /// Server NIC bandwidth, each direction (bytes/sec).
    pub server_bw: f64,
    /// Next time the server ingress link is free.
    ingress_free: f64,
    /// Next time the server egress link is free.
    egress_free: f64,
}

impl NetworkModel {
    /// A 10 GbE cluster (the paper's era): 1.25 GB/s links, 100 µs RTT/2.
    pub fn ten_gbe() -> NetworkModel {
        NetworkModel {
            latency_s: 100e-6,
            machine_bw: 1.25e9,
            server_bw: 1.25e9,
            ingress_free: 0.0,
            egress_free: 0.0,
        }
    }

    /// An idealized infinitely-fast network (ablation).
    pub fn infinite() -> NetworkModel {
        NetworkModel {
            latency_s: 0.0,
            machine_bw: f64::INFINITY,
            server_bw: f64::INFINITY,
            ingress_free: 0.0,
            egress_free: 0.0,
        }
    }

    /// Deliver `bytes` from a machine to the server, starting no earlier
    /// than `t`. Returns arrival time.
    pub fn to_server(&mut self, t: f64, bytes: f64) -> f64 {
        let ser_machine = bytes / self.machine_bw;
        let start = t.max(self.ingress_free);
        let ser_server = bytes / self.server_bw;
        self.ingress_free = start + ser_server;
        start + ser_machine.max(ser_server) + self.latency_s
    }

    /// Broadcast `bytes` from the server to `n` machines starting at `t`;
    /// returns per-machine arrival times. The egress link serializes the
    /// copies (this is what saturates first as machines are added).
    pub fn broadcast(&mut self, t: f64, bytes: f64, n: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        let mut start = t.max(self.egress_free);
        for _ in 0..n {
            let ser = bytes / self.server_bw;
            let arrive = start + ser + bytes / self.machine_bw
                + self.latency_s;
            start += ser;
            out.push(arrive);
        }
        self.egress_free = start;
        out
    }

    /// Seconds of work already queued on the egress link at time `t`
    /// (the server-side backpressure signal used to coalesce broadcasts).
    pub fn egress_backlog(&self, t: f64) -> f64 {
        (self.egress_free - t).max(0.0)
    }

    /// Time to serialize one `bytes` message on the server egress link.
    pub fn egress_cost(&self, bytes: f64) -> f64 {
        bytes / self.server_bw
    }

    pub fn reset(&mut self) {
        self.ingress_free = 0.0;
        self.egress_free = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_transfers_queue_on_ingress() {
        let mut net = NetworkModel {
            latency_s: 0.0,
            machine_bw: f64::INFINITY,
            server_bw: 100.0,
            ingress_free: 0.0,
            egress_free: 0.0,
        };
        let a = net.to_server(0.0, 100.0); // 1s serialization
        let b = net.to_server(0.0, 100.0); // queued behind a
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn broadcast_serializes_on_egress() {
        let mut net = NetworkModel {
            latency_s: 0.5,
            machine_bw: f64::INFINITY,
            server_bw: 10.0,
            ingress_free: 0.0,
            egress_free: 0.0,
        };
        let arr = net.broadcast(0.0, 10.0, 3); // 1s per copy
        assert!((arr[0] - 1.5).abs() < 1e-9);
        assert!((arr[1] - 2.5).abs() < 1e-9);
        assert!((arr[2] - 3.5).abs() < 1e-9);
    }

    #[test]
    fn infinite_network_is_latency_only() {
        let mut net = NetworkModel::infinite();
        assert_eq!(net.to_server(5.0, 1e12), 5.0);
        let arr = net.broadcast(7.0, 1e12, 4);
        assert!(arr.iter().all(|&a| a == 7.0));
    }

    #[test]
    fn ten_gbe_transfer_time_sane() {
        let mut net = NetworkModel::ten_gbe();
        // 1.872 MB (mnist L) at 1.25 GB/s ≈ 1.5 ms + latency
        let t = net.to_server(0.0, 468_000.0 * 4.0);
        assert!(t > 1e-3 && t < 3e-3, "t={t}");
    }
}
