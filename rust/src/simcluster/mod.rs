//! Discrete-event cluster simulator — the substitute testbed for the
//! paper's 4×64-core cluster (this box has one core; see DESIGN.md
//! §substitutions).
//!
//! The simulator executes the *actual* asynchronous-SGD algorithm — real
//! gradients on real data, real staleness — but under a simulated clock:
//! machine compute times, network transfer times, and server apply times
//! are modeled (calibrated from measured single-thread step times), and
//! events are processed in simulated-causal order. Objective-vs-time
//! curves (Fig 2) and time-to-target speedups (Fig 3) therefore reflect
//! true algorithm dynamics, not a throughput extrapolation.
//!
//! A cost-only mode (`NullWorkload`) runs the same event machinery
//! without numerics, which makes the *paper-true* ImageNet shapes
//! (220M parameters) representable for throughput/speedup analysis.

mod network;
mod sim;
mod workload;

pub use network::NetworkModel;
pub use sim::{Disruption, SimConfig, SimResult, Simulator};
pub use workload::{DmlWorkload, NullWorkload, Workload};

use crate::dml::DmlProblem;

/// Calibrate the simulator's *per-core* gradient time by timing the
/// native engine at the given shape (a handful of steps, median).
///
/// Pinned to a 1-thread engine on purpose: the simulator's machine model
/// charges `grad_seconds / C` for a C-core machine, so the calibration
/// must measure one core — letting the now-multicore engine use every
/// lane here would double-count the parallelism.
pub fn calibrate_grad_seconds(
    problem: &DmlProblem,
    bs: usize,
    bd: usize,
    reps: usize,
) -> f64 {
    use crate::dml::{Engine, MinibatchRef, NativeEngine};
    use crate::util::rng::Pcg32;

    let mut rng = Pcg32::new(0xCA11B);
    let l = problem.init_l(0.1, 1);
    let mut ds = vec![0.0f32; bs * problem.d];
    let mut dd = vec![0.0f32; bd * problem.d];
    rng.fill_gaussian(&mut ds, 0.0, 1.0);
    rng.fill_gaussian(&mut dd, 0.0, 1.0);
    let mut g = crate::linalg::Mat::zeros(problem.k, problem.d);
    let mut eng = NativeEngine::with_threads(1);
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(3) {
        let batch = MinibatchRef::new(&ds, &dd, bs, bd, problem.d);
        let t0 = std::time::Instant::now();
        eng.loss_grad(&l, &batch, 1.0, &mut g).expect("calibration");
        times.push(t0.elapsed().as_secs_f64());
    }
    crate::util::stats::median(&times)
}

/// Extrapolate a measured per-core gradient time to a different shape by
/// FLOP ratio (used to cost the paper-true ImageNet shapes that cannot
/// run natively on this box).
pub fn extrapolate_grad_seconds(
    measured: f64,
    measured_flops: f64,
    target_flops: f64,
) -> f64 {
    measured * target_flops / measured_flops
}
