//! # dmlps — Large Scale Distributed Distance Metric Learning
//!
//! A production-shaped reproduction of *"Large Scale Distributed Distance
//! Metric Learning"* (Pengtao Xie & Eric Xing, CMU, 2014) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's system contribution: an asynchronous
//!   parameter server ([`ps`]) with the exact thread/message-queue
//!   architecture of paper §4.2, plus every substrate it needs: synthetic
//!   datasets and pair sampling ([`data`]), the DML problem and a native
//!   CPU engine ([`dml`]), a PJRT runtime that executes the AOT-compiled
//!   JAX/Pallas artifacts ([`runtime`]), the single-machine baselines the
//!   paper compares against ([`baselines`]), evaluation ([`eval`]), a
//!   discrete-event cluster simulator for the scalability study
//!   ([`simcluster`]), metrics ([`metrics`]), and config/CLI plumbing.
//! * **L2/L1 (python/, build-time only)** — the minibatch DML
//!   loss/gradient as a JAX graph calling Pallas kernels, lowered once to
//!   HLO text in `artifacts/` by `make artifacts`. Python never runs on
//!   the training path.
//!
//! ## The problem
//!
//! Given pairs labeled similar (S) or dissimilar (D), learn a Mahalanobis
//! metric `M = LᵀL` (L is `k×d`) by minimizing the paper's Eq. 4:
//!
//! ```text
//! f(L) = mean_{(x,y)∈S} ‖L(x−y)‖² + λ · mean_{(x,y)∈D} max(0, 1 − ‖L(x−y)‖²)
//! ```
//!
//! ## Quickstart
//!
//! The public entry point is the [`session`] module: a [`session::Session`]
//! builder describes a run, the executors perform it, and the learned
//! metric comes back as a durable [`session::MetricModel`] artifact.
//!
//! ```no_run
//! use dmlps::config::Preset;
//! use dmlps::session::Session;
//!
//! # fn main() -> anyhow::Result<()> {
//! let run = Session::from_config(Preset::Tiny.config())
//!     .train_sequential()?;
//! let model = run.into_model()?;
//! model.save(std::path::Path::new("metric.bin"))?;
//! // see examples/quickstart.rs for the full train/eval loop
//! # Ok(()) }
//! ```

pub mod baselines;
pub mod cli;
pub mod config;
pub mod data;
pub mod dml;
pub mod eval;
pub mod lab;
pub mod linalg;
pub mod metrics;
pub mod ps;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod simcluster;
pub mod util;
