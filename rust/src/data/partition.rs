//! Pair partitioning across workers (paper §4.1: "we partition the
//! similarity pair S and dissimilar pair D into P pieces S_1..S_P and
//! D_1..D_P and each machine holds one piece").

use super::pairs::{Pair, PairSet};
use crate::util::rng::Pcg32;

/// One worker's shard of the pair sets.
#[derive(Clone, Debug)]
pub struct PairShard {
    pub worker: usize,
    pub pairs: PairSet,
}

/// Shuffle and split both pair sets into `p` near-equal shards.
///
/// Shuffling before splitting matters: pair generation is class-ordered,
/// and an unshuffled contiguous split would give workers class-biased
/// gradient distributions (slower convergence under ASP).
///
/// Errors (rather than panicking — this is library code reached from
/// the CLI) when `p == 0` or either pair set has fewer pairs than
/// workers, since at least one shard would then be empty and its worker
/// could never form a minibatch.
pub fn partition_pairs(
    pairs: &PairSet,
    p: usize,
    seed: u64,
) -> anyhow::Result<Vec<PairShard>> {
    anyhow::ensure!(p > 0, "need at least one worker");
    anyhow::ensure!(
        pairs.similar.len() >= p && pairs.dissimilar.len() >= p,
        "fewer pairs than workers: {} similar / {} dissimilar pairs \
         across {p} workers (reduce --workers or sample more pairs)",
        pairs.similar.len(),
        pairs.dissimilar.len()
    );
    let mut rng = Pcg32::with_stream(seed, 0x5AAD);
    let mut sim = pairs.similar.clone();
    let mut dis = pairs.dissimilar.clone();
    rng.shuffle(&mut sim);
    rng.shuffle(&mut dis);
    Ok((0..p)
        .map(|w| PairShard {
            worker: w,
            pairs: PairSet {
                similar: slice_shard(&sim, w, p),
                dissimilar: slice_shard(&dis, w, p),
            },
        })
        .collect())
}

/// Contiguous shard `w` of `p` with remainder spread over the first
/// shards (sizes differ by at most 1).
fn slice_shard(xs: &[Pair], w: usize, p: usize) -> Vec<Pair> {
    let n = xs.len();
    let base = n / p;
    let rem = n % p;
    let start = w * base + w.min(rem);
    let len = base + usize::from(w < rem);
    xs[start..start + len].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::SyntheticSpec;

    fn pairs() -> PairSet {
        let ds = SyntheticSpec::tiny().generate(1);
        let mut rng = Pcg32::new(0);
        PairSet::sample(&ds, 1003, 997, &mut rng)
    }

    #[test]
    fn shards_cover_everything_exactly_once() {
        let ps = pairs();
        for p in [1, 2, 3, 7, 16] {
            let shards = partition_pairs(&ps, p, 42).unwrap();
            assert_eq!(shards.len(), p);
            let total_sim: usize =
                shards.iter().map(|s| s.pairs.similar.len()).sum();
            let total_dis: usize =
                shards.iter().map(|s| s.pairs.dissimilar.len()).sum();
            assert_eq!(total_sim, ps.similar.len());
            assert_eq!(total_dis, ps.dissimilar.len());
            // multiset equality via sorting
            let mut all: Vec<(u32, u32)> = shards
                .iter()
                .flat_map(|s| s.pairs.similar.iter().map(|p| (p.i, p.j)))
                .collect();
            all.sort();
            let mut want: Vec<(u32, u32)> =
                ps.similar.iter().map(|p| (p.i, p.j)).collect();
            want.sort();
            assert_eq!(all, want);
        }
    }

    #[test]
    fn shards_are_balanced() {
        let ps = pairs();
        let shards = partition_pairs(&ps, 7, 1).unwrap();
        let sizes: Vec<usize> =
            shards.iter().map(|s| s.pairs.similar.len()).collect();
        let min = sizes.iter().min().unwrap();
        let max = sizes.iter().max().unwrap();
        assert!(max - min <= 1, "{sizes:?}");
    }

    #[test]
    fn partition_is_deterministic_per_seed() {
        let ps = pairs();
        let a = partition_pairs(&ps, 4, 9).unwrap();
        let b = partition_pairs(&ps, 4, 9).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.pairs.similar, y.pairs.similar);
        }
        let c = partition_pairs(&ps, 4, 10).unwrap();
        assert_ne!(a[0].pairs.similar, c[0].pairs.similar);
    }

    #[test]
    fn shards_are_shuffled_not_contiguous() {
        let ps = pairs();
        let shards = partition_pairs(&ps, 2, 3).unwrap();
        // shard 0 should not simply be the first half of the original
        let first_half: Vec<Pair> =
            ps.similar[..shards[0].pairs.similar.len()].to_vec();
        assert_ne!(shards[0].pairs.similar, first_half);
    }

    #[test]
    fn too_many_workers_is_a_clean_error_not_a_panic() {
        let ds = SyntheticSpec::tiny().generate(2);
        let mut rng = Pcg32::new(1);
        let ps = PairSet::sample(&ds, 3, 3, &mut rng);
        let err = partition_pairs(&ps, 10, 0).unwrap_err();
        assert!(err.to_string().contains("fewer pairs"), "{err}");
        let err = partition_pairs(&ps, 0, 0).unwrap_err();
        assert!(err.to_string().contains("at least one"), "{err}");
    }
}
