//! Synthetic class-clustered datasets.
//!
//! Two feature families mirror the paper's two data regimes:
//!
//! * **Gaussian** — each class is an isotropic Gaussian around a random
//!   class mean (stands in for MNIST raw pixels: dense, moderately
//!   separated clusters).
//! * **LLC-like** — sparse non-negative codes: each class activates a
//!   small class-specific subset of coordinates plus noise (stands in for
//!   the paper's Locality-constrained Linear Coding ImageNet features,
//!   which are sparse non-negative codes over a codebook).
//!
//! The `separation` knob scales class-mean distance relative to
//! within-class spread; at the defaults, Euclidean kNN is clearly better
//! than chance but far from clean — the regime where metric learning pays
//! off (and the regime the paper's Fig. 4c illustrates).

use crate::config::{DatasetConfig, FeatureKind};
use crate::linalg::Mat;
use crate::util::rng::Pcg32;

/// A labeled dataset: row-major features (n × d) + class labels.
pub struct Dataset {
    pub x: Mat,
    pub labels: Vec<u32>,
    pub n_classes: usize,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows
    }

    pub fn dim(&self) -> usize {
        self.x.cols
    }

    pub fn feature(&self, i: usize) -> &[f32] {
        self.x.row(i)
    }

    /// Difference vector x_i - x_j written into `out`.
    pub fn diff_into(&self, i: usize, j: usize, out: &mut [f32]) {
        let (a, b) = (self.x.row(i), self.x.row(j));
        for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
            *o = x - y;
        }
    }

    /// Indices grouped by class (used by samplers and kNN eval).
    pub fn by_class(&self) -> Vec<Vec<usize>> {
        let mut groups = vec![Vec::new(); self.n_classes];
        for (i, &c) in self.labels.iter().enumerate() {
            groups[c as usize].push(i);
        }
        groups
    }
}

/// Generator spec for a synthetic dataset family.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub kind: FeatureKind,
    pub dim: usize,
    pub n_classes: usize,
    pub separation: f32,
    /// Fraction of dimensions carrying class signal. The rest are pure
    /// noise with amplified variance — the regime where Euclidean
    /// distance is "uninformative" (paper abstract) and metric learning
    /// pays off.
    pub signal_fraction: f32,
    /// Noise std-dev on the non-signal dimensions (signal dims have 1.0).
    pub noise_amp: f32,
    /// Heavy-tail contamination: each entry is an outlier (noise ×
    /// `outlier_amp`) with this probability. Real image features are
    /// far from Gaussian; this is what breaks covariance-only methods
    /// (KISS) while margin-based objectives stay robust — the effect
    /// behind the paper's §5.4 KISS result.
    pub outlier_prob: f32,
    pub outlier_amp: f32,
    /// LLC: active coordinates per class pattern.
    pub llc_active: usize,
    /// Fixed class structure seed so train and test share class means.
    pub class_seed: u64,
}

impl SyntheticSpec {
    pub fn from_config(cfg: &DatasetConfig) -> SyntheticSpec {
        SyntheticSpec {
            kind: cfg.kind,
            dim: cfg.dim,
            n_classes: cfg.n_classes,
            separation: cfg.separation,
            signal_fraction: 0.25,
            noise_amp: 3.0,
            outlier_prob: 0.02,
            outlier_amp: 8.0,
            llc_active: (cfg.dim / 32).clamp(4, 256),
            class_seed: 0xC1A55,
        }
    }

    /// Small spec used in doctests / unit tests.
    pub fn tiny() -> SyntheticSpec {
        SyntheticSpec {
            kind: FeatureKind::Gaussian,
            dim: 16,
            n_classes: 4,
            separation: 3.0,
            signal_fraction: 0.25,
            noise_amp: 3.0,
            outlier_prob: 0.02,
            outlier_amp: 8.0,
            llc_active: 4,
            class_seed: 0xC1A55,
        }
    }

    /// Generate `n` samples with a fresh RNG derived from `seed`.
    pub fn generate(&self, seed: u64) -> Dataset {
        let mut rng = Pcg32::with_stream(seed, 0x5EED);
        self.generate_with(&mut rng, 1024)
    }

    /// Generate `n` samples, drawing sample noise from `rng` but class
    /// structure from `class_seed` (so separate calls — train/test —
    /// share the same class geometry).
    pub fn generate_with(&self, rng: &mut Pcg32, n: usize) -> Dataset {
        let mut ds = match self.kind {
            FeatureKind::Gaussian => self.gen_gaussian(rng, n),
            FeatureKind::Llc => self.gen_llc(rng, n),
        };
        self.normalize_pair_scale(&mut ds);
        ds
    }

    /// Rescale features so the typical squared pair distance is O(1),
    /// matching the paper's margin-1 objective (their MNIST pixels are
    /// in [0,1] and LLC codes are normalized; raw synthetic scales would
    /// put every dissimilar pair far outside the unit margin and make
    /// SGD conditioning depend on d). Deterministic: uses the class
    /// seed, not the sample RNG.
    fn normalize_pair_scale(&self, ds: &mut Dataset) {
        let mut rng = Pcg32::with_stream(self.class_seed, 0x5CA1E);
        let n = ds.n();
        if n < 2 {
            return;
        }
        let mut total = 0.0f64;
        let samples = 256.min(n * (n - 1) / 2);
        for _ in 0..samples {
            let i = rng.index(n);
            let j = rng.index(n);
            if i == j {
                continue;
            }
            let d2: f32 = ds
                .x
                .row(i)
                .iter()
                .zip(ds.x.row(j))
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            total += d2 as f64;
        }
        let mean = total / samples as f64;
        if mean > 0.0 {
            // target mean squared pair distance: 4 (dissimilar pairs sit
            // a bit outside the unit margin at init; similar pairs well
            // inside — both loss terms active from step 0)
            let scale = (4.0 / mean).sqrt() as f32;
            ds.x.scale_inplace(scale);
        }
    }

    /// Number of class-signal dimensions.
    fn n_signal(&self) -> usize {
        ((self.dim as f32 * self.signal_fraction) as usize)
            .clamp(2.min(self.dim), self.dim)
    }

    /// Deterministic choice of which dimensions carry signal.
    fn signal_dims(&self) -> Vec<usize> {
        let mut crng = Pcg32::with_stream(self.class_seed, 0x5160);
        crng.sample_distinct(self.dim, self.n_signal())
    }

    fn class_means(&self) -> Mat {
        let mut crng = Pcg32::with_stream(self.class_seed, 0xBEEF);
        let signal = self.signal_dims();
        let mut means = Mat::zeros(self.n_classes, self.dim);
        // Class means differ only on the signal dimensions, on a sphere
        // of radius `separation` (within-class noise there is unit, so
        // separation directly controls the SNR where it matters).
        for c in 0..self.n_classes {
            let mut sub = vec![0.0f32; signal.len()];
            crng.fill_gaussian(&mut sub, 0.0, 1.0);
            let norm =
                sub.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
            let row = means.row_mut(c);
            for (&j, &v) in signal.iter().zip(&sub) {
                row[j] = v / norm * self.separation;
            }
        }
        means
    }

    fn gen_gaussian(&self, rng: &mut Pcg32, n: usize) -> Dataset {
        let means = self.class_means();
        let signal = self.signal_dims();
        let mut is_signal = vec![false; self.dim];
        for &j in &signal {
            is_signal[j] = true;
        }
        let mut x = Mat::zeros(n, self.dim);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = rng.index(self.n_classes);
            labels.push(c as u32);
            let row = x.row_mut(i);
            rng.fill_gaussian(row, 0.0, 1.0);
            for (j, v) in row.iter_mut().enumerate() {
                // amplified noise off the signal subspace: this is what
                // makes raw Euclidean distance weak (paper's motivation)
                if !is_signal[j] {
                    *v *= self.noise_amp;
                }
                // heavy-tail contamination (see field docs)
                if self.outlier_prob > 0.0
                    && rng.f32() < self.outlier_prob
                {
                    *v *= self.outlier_amp;
                }
                *v += means.at(c, j);
            }
        }
        Dataset { x, labels, n_classes: self.n_classes }
    }

    fn gen_llc(&self, rng: &mut Pcg32, n: usize) -> Dataset {
        // Class patterns: each class has `llc_active` preferred coords.
        let mut crng = Pcg32::with_stream(self.class_seed, 0x11C);
        let patterns: Vec<Vec<usize>> = (0..self.n_classes)
            .map(|_| crng.sample_distinct(self.dim, self.llc_active))
            .collect();
        let mut x = Mat::zeros(n, self.dim);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = rng.index(self.n_classes);
            labels.push(c as u32);
            let row = x.row_mut(i);
            // Class-selective activations: non-negative, sparse-ish.
            // Only a random subset of the class pattern fires per sample
            // (LLC activates the codebook atoms near *this* image's
            // descriptors, not the whole class vocabulary).
            for &j in &patterns[c] {
                if rng.f32() < 0.6 {
                    row[j] = (self.separation
                        * (0.5 + 0.5 * rng.f32()))
                    .max(0.0);
                }
            }
            // Background activations: more coords than the signal, with
            // noise_amp-scaled amplitudes — cross-class overlap is what
            // makes raw Euclidean distance weak on LLC codes.
            let n_bg = self.llc_active * 3;
            for _ in 0..n_bg {
                let j = rng.index(self.dim);
                row[j] += self.noise_amp * 0.5 * rng.f32();
            }
        }
        Dataset { x, labels, n_classes: self.n_classes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(kind: FeatureKind) -> SyntheticSpec {
        SyntheticSpec {
            kind,
            dim: 32,
            n_classes: 5,
            separation: 3.0,
            signal_fraction: 0.25,
            noise_amp: 2.0,
            outlier_prob: 0.0,
            outlier_amp: 8.0,
            llc_active: 6,
            class_seed: 0xC1A55,
        }
    }

    #[test]
    fn shapes_and_labels() {
        for kind in [FeatureKind::Gaussian, FeatureKind::Llc] {
            let ds = spec(kind).generate(1);
            assert_eq!(ds.n(), 1024);
            assert_eq!(ds.dim(), 32);
            assert!(ds.labels.iter().all(|&c| (c as usize) < 5));
            // every class should appear in 1024 draws
            let groups = ds.by_class();
            assert!(groups.iter().all(|g| !g.is_empty()));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = spec(FeatureKind::Gaussian).generate(7);
        let b = spec(FeatureKind::Gaussian).generate(7);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.x.data, b.x.data);
        let c = spec(FeatureKind::Gaussian).generate(8);
        assert_ne!(a.x.data, c.x.data);
    }

    #[test]
    fn train_test_share_class_geometry() {
        // Same class means: per-class centroids of two independent draws
        // must be close (relative to separation).
        let s = spec(FeatureKind::Gaussian);
        let mut rng = Pcg32::new(3);
        let train = s.generate_with(&mut rng, 4000);
        let test = s.generate_with(&mut rng, 4000);
        for c in 0..5 {
            let centroid = |ds: &Dataset| -> Vec<f32> {
                let idx: Vec<usize> = (0..ds.n())
                    .filter(|&i| ds.labels[i] == c)
                    .collect();
                let mut m = vec![0.0f32; ds.dim()];
                for &i in &idx {
                    for (a, b) in m.iter_mut().zip(ds.feature(i)) {
                        *a += b;
                    }
                }
                m.iter().map(|v| v / idx.len() as f32).collect()
            };
            let ct = centroid(&train);
            let cs = centroid(&test);
            let dist: f32 = ct
                .iter()
                .zip(&cs)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
            assert!(dist < 1.0, "class {c} centroid drift {dist}");
        }
    }

    #[test]
    fn classes_are_separated_but_noisy() {
        let ds = spec(FeatureKind::Gaussian).generate(5);
        // mean within-class vs between-class Euclidean distance
        let mut within = 0.0f64;
        let mut wn = 0;
        let mut between = 0.0f64;
        let mut bn = 0;
        for i in 0..200 {
            for j in (i + 1)..200 {
                let d: f32 = ds
                    .feature(i)
                    .iter()
                    .zip(ds.feature(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if ds.labels[i] == ds.labels[j] {
                    within += d as f64;
                    wn += 1;
                } else {
                    between += d as f64;
                    bn += 1;
                }
            }
        }
        let within = within / wn as f64;
        let between = between / bn as f64;
        assert!(between > within * 1.05,
                "between={between} within={within}");
        assert!(between < within * 3.0,
                "too easy: between={between} within={within}");
    }

    #[test]
    fn llc_features_nonnegative_and_sparse() {
        let ds = spec(FeatureKind::Llc).generate(2);
        assert!(ds.x.data.iter().all(|&v| v >= 0.0));
        let nz = ds.x.data.iter().filter(|&&v| v != 0.0).count();
        let frac = nz as f64 / ds.x.data.len() as f64;
        assert!(frac < 0.5, "not sparse: {frac}");
        assert!(frac > 0.05, "degenerate: {frac}");
    }

    #[test]
    fn diff_into() {
        let ds = spec(FeatureKind::Gaussian).generate(9);
        let mut out = vec![0.0f32; ds.dim()];
        ds.diff_into(3, 8, &mut out);
        for (idx, o) in out.iter().enumerate() {
            assert_eq!(*o, ds.feature(3)[idx] - ds.feature(8)[idx]);
        }
    }
}
