//! Streaming pair-constraint pipeline.
//!
//! The paper's headline workload is 200M labeled pairs (§5); holding
//! them as index vectors costs 1.6 GB plus a full clone-and-shuffle per
//! run before any worker can move. A [`PairStream`] decouples *how pairs
//! are obtained* from *how minibatches consume them*:
//!
//! * [`MaterializedStream`] — compatibility adapter over a sampled
//!   [`PairSet`]; draws with replacement exactly like the pre-stream
//!   minibatch iterator (bit-identical RNG trace).
//! * [`ImplicitPairSampler`] — pair `t` for worker `w` is a pure
//!   function of `(seed, w, t)`: each global pair index gets its own
//!   dedicated [`Pcg32`] stream, so a 200M-pair run needs O(1) pair
//!   memory per worker and zero startup shuffle. Partitioning across
//!   `P` workers is index-space arithmetic — worker `w` owns global
//!   indices `≡ w (mod P)` — so worker index-spaces are disjoint and
//!   jointly exhaustive by construction, and the multiset of pairs a
//!   cluster draws depends only on `(seed, total draws)`, never on the
//!   worker count, batch size, or draw chunking.
//!
//! The implicit sampler also carries the robustness knobs the related
//! work probes (Qian et al., arXiv:1304.1192 / arXiv:1509.04355):
//! a label-noise fraction (a drawn constraint's similar/dissimilar role
//! is flipped) and a class-imbalance skew (Zipf-weighted class draws).

use std::sync::Arc;

use super::dataset::Dataset;
use super::pairs::{Pair, PairSet};
use super::partition::PairShard;
use crate::util::rng::Pcg32;

/// Salt mixed into the sampler seed so pair streams never collide with
/// the repo's other derived RNG streams for the same experiment seed.
const SAMPLER_SALT: u64 = 0x9A12_57AE_D00D_F00D;

/// A source of similar/dissimilar pair constraints.
///
/// Streams are infinite (sampling with replacement, matching the
/// paper's "randomly picks up a mini-batch" loop) and `Send` so a
/// worker's computing thread can own one.
pub trait PairStream: Send {
    /// Next pair from the similar-constraint stream.
    fn next_similar(&mut self) -> Pair;

    /// Next pair from the dissimilar-constraint stream.
    fn next_dissimilar(&mut self) -> Pair;

    /// Total pairs drawn so far (both streams; telemetry).
    fn drawn(&self) -> u64;

    /// Resident bytes of materialized pair storage this stream holds —
    /// the quantity the streaming pipeline makes independent of pair
    /// count (0 for implicit samplers).
    fn pair_bytes(&self) -> usize;
}

// ---------------------------------------------------------------------
// Materialized adapter
// ---------------------------------------------------------------------

/// Compatibility adapter: draws uniformly with replacement from a
/// materialized [`PairSet`], consuming the RNG in exactly the order the
/// pre-stream `MinibatchIter` did (one `rng.index` per draw), so
/// `pairs.mode = materialized` reproduces historical traces bit for bit.
pub struct MaterializedStream {
    pairs: PairSet,
    rng: Pcg32,
    drawn: u64,
}

impl MaterializedStream {
    pub fn new(pairs: PairSet, rng: Pcg32) -> Self {
        assert!(
            !pairs.similar.is_empty() && !pairs.dissimilar.is_empty(),
            "materialized stream needs non-empty pair sets"
        );
        MaterializedStream { pairs, rng, drawn: 0 }
    }
}

impl PairStream for MaterializedStream {
    fn next_similar(&mut self) -> Pair {
        self.drawn += 1;
        self.pairs.similar[self.rng.index(self.pairs.similar.len())]
    }

    fn next_dissimilar(&mut self) -> Pair {
        self.drawn += 1;
        self.pairs.dissimilar[self.rng.index(self.pairs.dissimilar.len())]
    }

    fn drawn(&self) -> u64 {
        self.drawn
    }

    fn pair_bytes(&self) -> usize {
        self.pairs.len() * std::mem::size_of::<Pair>()
    }
}

// ---------------------------------------------------------------------
// Implicit sampler
// ---------------------------------------------------------------------

/// Class membership index shared by all of a run's samplers: O(n) in
/// dataset size, independent of pair count. Also holds the (optionally
/// Zipf-skewed) class-draw weights.
pub struct ClassIndex {
    /// Member indices per class.
    groups: Vec<Vec<u32>>,
    /// Classes with ≥ 2 members (the only ones that can source similar
    /// pairs; skewed draws pick from these).
    eligible: Vec<u32>,
    /// Cumulative unnormalized weights aligned with `eligible`
    /// (`w_i ∝ (i+1)^-imbalance`); empty when the draw is uniform.
    cum: Vec<f64>,
}

impl ClassIndex {
    /// Build the index. `imbalance` is the Zipf exponent skewing class
    /// frequency in streamed draws (0 = uniform, the default).
    pub fn build(ds: &Dataset, imbalance: f32) -> anyhow::Result<ClassIndex> {
        let groups: Vec<Vec<u32>> = ds
            .by_class()
            .into_iter()
            .map(|g| g.into_iter().map(|i| i as u32).collect())
            .collect();
        let eligible: Vec<u32> = (0..groups.len() as u32)
            .filter(|&c| groups[c as usize].len() >= 2)
            .collect();
        anyhow::ensure!(
            eligible.len() >= 2,
            "need >=2 classes with >=2 members to stream pairs \
             ({} eligible of {} classes)",
            eligible.len(),
            groups.len()
        );
        let cum = if imbalance > 0.0 {
            let mut acc = 0.0f64;
            eligible
                .iter()
                .enumerate()
                .map(|(i, _)| {
                    acc += (i as f64 + 1.0).powf(-(imbalance as f64));
                    acc
                })
                .collect()
        } else {
            Vec::new()
        };
        Ok(ClassIndex { groups, eligible, cum })
    }

    /// Draw an eligible class (uniform, or Zipf-skewed when built with
    /// `imbalance > 0`).
    fn pick_class(&self, rng: &mut Pcg32) -> usize {
        if self.cum.is_empty() {
            self.eligible[rng.index(self.eligible.len())] as usize
        } else {
            let total = *self.cum.last().unwrap();
            let u = rng.f64() * total;
            let k = self.cum.partition_point(|&c| c <= u);
            self.eligible[k.min(self.eligible.len() - 1)] as usize
        }
    }

    fn skewed(&self) -> bool {
        !self.cum.is_empty()
    }

    /// Approximate resident bytes (bench telemetry).
    pub fn index_bytes(&self) -> usize {
        self.groups.iter().map(|g| g.len() * 4).sum::<usize>()
            + self.eligible.len() * 4
            + self.cum.len() * 8
    }
}

/// O(1)-memory pair source: pair `t` is a pure function of `(seed, t)`
/// via a dedicated PCG32 stream per global pair index; worker `w` of
/// `P` draws the index-space slice `{w, w+P, w+2P, …}` of each
/// constraint stream.
pub struct ImplicitPairSampler {
    ds: Arc<Dataset>,
    index: Arc<ClassIndex>,
    seed: u64,
    /// Probability a drawn constraint's similar/dissimilar role is
    /// flipped (label noise; decided inside the per-index stream, so
    /// the `(seed, w, t)` contract is unaffected).
    label_noise: f32,
    stride: u64,
    next_sim: u64,
    next_dis: u64,
    drawn: u64,
}

impl ImplicitPairSampler {
    /// Build a sampler with its own class index. `worker`/`stride` place
    /// it in the index space (`stride` = cluster worker count `P`).
    pub fn new(
        ds: Arc<Dataset>,
        seed: u64,
        worker: usize,
        stride: usize,
        label_noise: f32,
        imbalance: f32,
    ) -> anyhow::Result<Self> {
        let index = Arc::new(ClassIndex::build(&ds, imbalance)?);
        Ok(Self::with_index(ds, index, seed, worker, stride, label_noise))
    }

    /// Build a sampler over a shared, pre-built class index (the cheap
    /// path `run_training` uses: one index, `P` samplers).
    pub fn with_index(
        ds: Arc<Dataset>,
        index: Arc<ClassIndex>,
        seed: u64,
        worker: usize,
        stride: usize,
        label_noise: f32,
    ) -> Self {
        assert!(stride > 0 && worker < stride, "worker {worker} of {stride}");
        ImplicitPairSampler {
            ds,
            index,
            seed,
            label_noise,
            stride: stride as u64,
            next_sim: worker as u64,
            next_dis: worker as u64,
            drawn: 0,
        }
    }

    /// The similar-stream pair at global index `t` — pure in `(seed, t)`.
    pub fn similar_at(&self, t: u64) -> Pair {
        let mut rng = Pcg32::with_stream(self.seed ^ SAMPLER_SALT, t << 1);
        self.draw(&mut rng, true)
    }

    /// The dissimilar-stream pair at global index `t` — pure in
    /// `(seed, t)`.
    pub fn dissimilar_at(&self, t: u64) -> Pair {
        let mut rng =
            Pcg32::with_stream(self.seed ^ SAMPLER_SALT, (t << 1) | 1);
        self.draw(&mut rng, false)
    }

    /// Next global index each constraint stream will draw (test hook
    /// for the index-space partitioning contract).
    pub fn cursors(&self) -> (u64, u64) {
        (self.next_sim, self.next_dis)
    }

    /// Resident bytes of the backing class index (shared across a
    /// run's samplers; O(n) in dataset size, not in pair count).
    pub fn index_bytes(&self) -> usize {
        self.index.index_bytes()
    }

    fn draw(&self, rng: &mut Pcg32, want_similar: bool) -> Pair {
        // label noise: flip the constraint's role for this index
        let flip = self.label_noise > 0.0 && rng.f32() < self.label_noise;
        if want_similar != flip {
            self.draw_matched(rng)
        } else {
            self.draw_mismatched(rng)
        }
    }

    /// Same-class pair (mirrors `PairSet::sample`'s similar recipe:
    /// re-pick class and members until the endpoints differ).
    fn draw_matched(&self, rng: &mut Pcg32) -> Pair {
        loop {
            let g = &self.index.groups[self.index.pick_class(rng)];
            let a = g[rng.index(g.len())];
            let b = g[rng.index(g.len())];
            if a != b {
                return Pair { i: a, j: b };
            }
        }
    }

    /// Cross-class pair. The head point follows the class skew (when
    /// enabled); the tail is uniform over the dataset, rejected until
    /// the labels differ — guaranteed to terminate because the index
    /// requires ≥ 2 eligible classes.
    fn draw_mismatched(&self, rng: &mut Pcg32) -> Pair {
        let n = self.ds.n();
        loop {
            let a = if self.index.skewed() {
                let g = &self.index.groups[self.index.pick_class(rng)];
                g[rng.index(g.len())] as usize
            } else {
                rng.index(n)
            };
            let b = rng.index(n);
            if self.ds.labels[a] != self.ds.labels[b] {
                return Pair { i: a as u32, j: b as u32 };
            }
        }
    }
}

impl PairStream for ImplicitPairSampler {
    fn next_similar(&mut self) -> Pair {
        let p = self.similar_at(self.next_sim);
        self.next_sim += self.stride;
        self.drawn += 1;
        p
    }

    fn next_dissimilar(&mut self) -> Pair {
        let p = self.dissimilar_at(self.next_dis);
        self.next_dis += self.stride;
        self.drawn += 1;
        p
    }

    fn drawn(&self) -> u64 {
        self.drawn
    }

    fn pair_bytes(&self) -> usize {
        0 // pairs are generated, never stored
    }
}

// ---------------------------------------------------------------------
// Worker-side selection
// ---------------------------------------------------------------------

/// What a parameter-server worker is handed as its pair source —
/// the `pairs.mode` knob, resolved.
pub enum WorkerPairs {
    /// A materialized shard (paper §4.1 clone-and-shuffle partitioning).
    Materialized(PairShard),
    /// An implicit `(seed, w, t)` sampler (index-space partitioning).
    Streaming(ImplicitPairSampler),
}

impl WorkerPairs {
    /// Turn the source into a boxed stream. `rng` seeds the materialized
    /// adapter's draw sequence (must match the historical per-worker
    /// minibatch RNG for bit-identical traces); the implicit sampler is
    /// `(seed, w, t)`-pure and ignores it.
    pub fn into_stream(self, rng: Pcg32) -> Box<dyn PairStream> {
        match self {
            WorkerPairs::Materialized(shard) => {
                Box::new(MaterializedStream::new(shard.pairs, rng))
            }
            WorkerPairs::Streaming(sampler) => Box::new(sampler),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::SyntheticSpec;

    fn tiny_ds() -> Arc<Dataset> {
        Arc::new(SyntheticSpec::tiny().generate(1))
    }

    #[test]
    fn materialized_stream_matches_direct_indexing() {
        let ds = tiny_ds();
        let mut rng = Pcg32::new(0);
        let pairs = PairSet::sample(&ds, 100, 100, &mut rng);
        let mut s =
            MaterializedStream::new(pairs.clone(), Pcg32::new(7));
        let mut reference = Pcg32::new(7);
        for _ in 0..50 {
            let want = pairs.similar[reference.index(pairs.similar.len())];
            assert_eq!(s.next_similar(), want);
        }
        for _ in 0..50 {
            let want =
                pairs.dissimilar[reference.index(pairs.dissimilar.len())];
            assert_eq!(s.next_dissimilar(), want);
        }
        assert_eq!(s.drawn(), 100);
        assert_eq!(s.pair_bytes(), 200 * std::mem::size_of::<Pair>());
    }

    #[test]
    fn implicit_sampler_is_pure_in_seed_and_index() {
        let ds = tiny_ds();
        let a = ImplicitPairSampler::new(ds.clone(), 9, 0, 1, 0.0, 0.0)
            .unwrap();
        let b = ImplicitPairSampler::new(ds.clone(), 9, 0, 1, 0.0, 0.0)
            .unwrap();
        for t in 0..200 {
            assert_eq!(a.similar_at(t), b.similar_at(t));
            assert_eq!(a.dissimilar_at(t), b.dissimilar_at(t));
        }
        let c = ImplicitPairSampler::new(ds, 10, 0, 1, 0.0, 0.0).unwrap();
        let same = (0..64)
            .filter(|&t| a.similar_at(t) == c.similar_at(t))
            .count();
        assert!(same < 8, "different seeds should decorrelate: {same}");
    }

    #[test]
    fn implicit_sampler_draws_advance_by_stride() {
        let ds = tiny_ds();
        let mut s = ImplicitPairSampler::new(ds, 3, 2, 4, 0.0, 0.0)
            .unwrap();
        assert_eq!(s.cursors(), (2, 2));
        let p0 = s.next_similar();
        let p1 = s.next_similar();
        assert_eq!(s.cursors(), (10, 2));
        assert_eq!(p0, s.similar_at(2));
        assert_eq!(p1, s.similar_at(6));
        assert_eq!(s.pair_bytes(), 0);
        assert_eq!(s.drawn(), 2);
    }

    #[test]
    fn implicit_sampler_respects_labels_without_noise() {
        let ds = tiny_ds();
        let mut s =
            ImplicitPairSampler::new(ds.clone(), 5, 0, 1, 0.0, 0.0)
                .unwrap();
        for _ in 0..500 {
            let p = s.next_similar();
            assert_ne!(p.i, p.j);
            assert_eq!(
                ds.labels[p.i as usize],
                ds.labels[p.j as usize]
            );
            let q = s.next_dissimilar();
            assert_ne!(
                ds.labels[q.i as usize],
                ds.labels[q.j as usize]
            );
        }
    }

    #[test]
    fn class_index_rejects_degenerate_datasets() {
        // one class only → no dissimilar pairs exist
        let mut ds = SyntheticSpec::tiny().generate(2);
        for l in ds.labels.iter_mut() {
            *l = 0;
        }
        let err = ClassIndex::build(&ds, 0.0).unwrap_err();
        assert!(err.to_string().contains("classes"), "{err}");
    }

    #[test]
    fn zipf_skew_overweights_head_classes() {
        let ds = tiny_ds();
        let idx = ClassIndex::build(&ds, 2.0).unwrap();
        let mut rng = Pcg32::new(11);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            if idx.pick_class(&mut rng) == idx.eligible[0] as usize {
                head += 1;
            }
        }
        let frac = head as f64 / n as f64;
        // uniform share over 4 tiny-spec classes would be 0.25
        assert!(frac > 0.5, "head-class share {frac} not skewed");
    }
}
