//! Data substrate: synthetic class-clustered datasets, pair sampling,
//! worker partitioning, minibatch iteration.
//!
//! The paper draws similar/dissimilar pairs from class labels (same digit
//! / same ImageNet class → similar). We have no network access, so the
//! datasets are synthetic analogs (documented in DESIGN.md): what matters
//! for reproducing the paper's behaviour is the *pair geometry* (class
//! clusters in high dimension, Euclidean distance only weakly informative)
//! and the *compute/communication volumes* (d, k, #pairs, minibatch), all
//! of which are preserved.

mod dataset;
mod pairs;
mod partition;
mod stream;

pub use dataset::{Dataset, SyntheticSpec};
pub use pairs::{MinibatchIter, Pair, PairSet};
pub use partition::{partition_pairs, PairShard};
pub use stream::{
    ClassIndex, ImplicitPairSampler, MaterializedStream, PairStream,
    WorkerPairs,
};

use crate::config::{DatasetConfig, PairMode};

/// Generate train/test datasets plus train pair sets and held-out test
/// pairs, all from one seed — the standard entry point used by the CLI,
/// examples, and benches.
pub struct ExperimentData {
    pub train: Dataset,
    pub test: Dataset,
    pub pairs: PairSet,
    pub test_pairs: PairSet,
}

impl ExperimentData {
    pub fn generate(cfg: &DatasetConfig, seed: u64) -> ExperimentData {
        Self::generate_for(cfg, PairMode::Materialized, seed)
    }

    /// Mode-aware generation. `Materialized` is the historical path
    /// (bit-identical to the pre-stream `generate`). `Streaming` skips
    /// materializing the train pair sets entirely — that startup cost
    /// and memory term is the point of the streaming pipeline; workers
    /// draw from [`ImplicitPairSampler`]s instead. Held-out test pairs
    /// are always materialized (evaluation needs a fixed finite set);
    /// because the train-pair draws are skipped, streaming-mode test
    /// pairs come from a later RNG state than materialized-mode ones —
    /// test pairs are mode-local and never compared across modes.
    pub fn generate_for(
        cfg: &DatasetConfig,
        mode: PairMode,
        seed: u64,
    ) -> ExperimentData {
        let spec = SyntheticSpec::from_config(cfg);
        let mut rng = crate::util::rng::Pcg32::with_stream(seed, 0xDA7A);
        let train = spec.generate_with(&mut rng, cfg.n_train);
        let test = spec.generate_with(&mut rng, cfg.n_test);
        let pairs = match mode {
            PairMode::Materialized => PairSet::sample(
                &train,
                cfg.n_similar,
                cfg.n_dissimilar,
                &mut rng,
            ),
            PairMode::Streaming => PairSet::default(),
        };
        let test_pairs =
            PairSet::sample(&test, cfg.n_test_pairs, cfg.n_test_pairs,
                            &mut rng);
        ExperimentData { train, test, pairs, test_pairs }
    }
}

/// Table-1-style statistics for a generated experiment (the `table1`
/// bench prints one row per preset from this).
pub struct DatasetStats {
    pub name: String,
    pub feat_dim: usize,
    pub k: usize,
    pub n_params: usize,
    pub n_samples: usize,
    pub n_similar: usize,
    pub n_dissimilar: usize,
}

impl DatasetStats {
    pub fn of(cfg: &crate::config::ExperimentConfig) -> DatasetStats {
        DatasetStats {
            name: cfg.dataset.name.clone(),
            feat_dim: cfg.dataset.dim,
            k: cfg.model.k,
            n_params: cfg.model.k * cfg.dataset.dim,
            n_samples: cfg.dataset.n_train,
            n_similar: cfg.dataset.n_similar,
            n_dissimilar: cfg.dataset.n_dissimilar,
        }
    }

    pub fn param_str(&self) -> String {
        let p = self.n_params as f64;
        if p >= 1e9 {
            format!("{:.2}B", p / 1e9)
        } else if p >= 1e6 {
            format!("{:.2}M", p / 1e6)
        } else if p >= 1e3 {
            format!("{:.1}K", p / 1e3)
        } else {
            format!("{p}")
        }
    }
}
