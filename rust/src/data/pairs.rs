//! Pair sampling and minibatch iteration.
//!
//! Pairs are stored as index pairs into a [`Dataset`] (not materialized
//! difference vectors): at paper scale (200M pairs × d=21504 f32) the
//! materialized form would be ~17 TB, while index pairs are 1.6 GB. The
//! minibatch iterator materializes difference vectors on the fly into a
//! reusable buffer — this is what the paper's workers do when they "take
//! a minibatch of data pairs" (§4.2).

use super::dataset::Dataset;
use crate::util::rng::Pcg32;

/// An index pair into a dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pair {
    pub i: u32,
    pub j: u32,
}

/// Similar + dissimilar pair sets (paper's S and D).
#[derive(Clone, Debug, Default)]
pub struct PairSet {
    pub similar: Vec<Pair>,
    pub dissimilar: Vec<Pair>,
}

impl PairSet {
    /// Sample pairs by class identity: same class → similar, different
    /// class → dissimilar (exactly the paper's Flickr/ImageNet recipe).
    pub fn sample(
        ds: &Dataset,
        n_similar: usize,
        n_dissimilar: usize,
        rng: &mut Pcg32,
    ) -> PairSet {
        let groups = ds.by_class();
        let nonempty: Vec<usize> = (0..groups.len())
            .filter(|&c| groups[c].len() >= 2)
            .collect();
        assert!(
            nonempty.len() >= 2,
            "need >=2 classes with >=2 members to sample pairs"
        );
        let mut similar = Vec::with_capacity(n_similar);
        while similar.len() < n_similar {
            let c = nonempty[rng.index(nonempty.len())];
            let g = &groups[c];
            let a = g[rng.index(g.len())];
            let b = g[rng.index(g.len())];
            if a != b {
                similar.push(Pair { i: a as u32, j: b as u32 });
            }
        }
        let mut dissimilar = Vec::with_capacity(n_dissimilar);
        while dissimilar.len() < n_dissimilar {
            let a = rng.index(ds.n());
            let b = rng.index(ds.n());
            if ds.labels[a] != ds.labels[b] {
                dissimilar.push(Pair { i: a as u32, j: b as u32 });
            }
        }
        PairSet { similar, dissimilar }
    }

    pub fn len(&self) -> usize {
        self.similar.len() + self.dissimilar.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Validate labels: every similar pair same-class, every dissimilar
    /// pair cross-class (test/debug helper).
    pub fn check_labels(&self, ds: &Dataset) -> bool {
        self.similar
            .iter()
            .all(|p| ds.labels[p.i as usize] == ds.labels[p.j as usize])
            && self.dissimilar.iter().all(|p| {
                ds.labels[p.i as usize] != ds.labels[p.j as usize]
            })
    }
}

/// Streaming minibatch iterator: repeatedly draws `bs` similar and `bd`
/// dissimilar pairs from a [`PairStream`](super::PairStream) (with
/// replacement, matching the paper's "randomly picks up a mini-batch"
/// loop) and materializes their difference vectors into caller-visible
/// row-major buffers.
pub struct MinibatchIter<'a> {
    ds: &'a Dataset,
    stream: Box<dyn super::PairStream>,
    bs: usize,
    bd: usize,
    /// (bs × d) similar diffs, reused across batches.
    pub ds_buf: Vec<f32>,
    /// (bd × d) dissimilar diffs, reused across batches.
    pub dd_buf: Vec<f32>,
}

impl<'a> MinibatchIter<'a> {
    /// Legacy constructor over a materialized [`PairSet`]: wraps a
    /// [`MaterializedStream`](super::MaterializedStream) whose draw
    /// sequence is bit-identical to the pre-stream iterator's.
    pub fn new(
        ds: &'a Dataset,
        pairs: &'a PairSet,
        bs: usize,
        bd: usize,
        rng: Pcg32,
    ) -> Self {
        assert!(!pairs.similar.is_empty() && !pairs.dissimilar.is_empty());
        Self::from_stream(
            ds,
            Box::new(super::MaterializedStream::new(pairs.clone(), rng)),
            bs,
            bd,
        )
    }

    /// Draw batches from any pair stream (the streaming-mode entry
    /// point used by the parameter-server workers).
    pub fn from_stream(
        ds: &'a Dataset,
        stream: Box<dyn super::PairStream>,
        bs: usize,
        bd: usize,
    ) -> Self {
        let d = ds.dim();
        MinibatchIter {
            ds,
            stream,
            bs,
            bd,
            ds_buf: vec![0.0; bs * d],
            dd_buf: vec![0.0; bd * d],
        }
    }

    /// Fill the internal buffers with the next minibatch.
    pub fn next_batch(&mut self) {
        let d = self.ds.dim();
        for r in 0..self.bs {
            let p = self.stream.next_similar();
            self.ds.diff_into(
                p.i as usize,
                p.j as usize,
                &mut self.ds_buf[r * d..(r + 1) * d],
            );
        }
        for r in 0..self.bd {
            let p = self.stream.next_dissimilar();
            self.ds.diff_into(
                p.i as usize,
                p.j as usize,
                &mut self.dd_buf[r * d..(r + 1) * d],
            );
        }
    }

    pub fn shapes(&self) -> (usize, usize, usize) {
        (self.bs, self.bd, self.ds.dim())
    }

    /// Resident pair-storage bytes of the backing stream (telemetry).
    pub fn pair_bytes(&self) -> usize {
        self.stream.pair_bytes()
    }

    /// Pairs drawn so far from the backing stream (telemetry).
    pub fn pairs_drawn(&self) -> u64 {
        self.stream.drawn()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::SyntheticSpec;

    fn tiny_ds() -> Dataset {
        SyntheticSpec::tiny().generate(1)
    }

    #[test]
    fn sampled_pairs_respect_labels() {
        let ds = tiny_ds();
        let mut rng = Pcg32::new(0);
        let ps = PairSet::sample(&ds, 500, 500, &mut rng);
        assert_eq!(ps.similar.len(), 500);
        assert_eq!(ps.dissimilar.len(), 500);
        assert!(ps.check_labels(&ds));
    }

    #[test]
    fn no_self_pairs() {
        let ds = tiny_ds();
        let mut rng = Pcg32::new(1);
        let ps = PairSet::sample(&ds, 1000, 1000, &mut rng);
        assert!(ps.similar.iter().all(|p| p.i != p.j));
        assert!(ps.dissimilar.iter().all(|p| p.i != p.j));
    }

    #[test]
    fn minibatch_diffs_are_correct() {
        let ds = tiny_ds();
        let mut rng = Pcg32::new(2);
        let ps = PairSet::sample(&ds, 50, 50, &mut rng);
        let mut it = MinibatchIter::new(&ds, &ps, 8, 8, Pcg32::new(3));
        it.next_batch();
        let d = ds.dim();
        // every row of ds_buf must equal some pair's difference vector
        'rows: for r in 0..8 {
            let row = &it.ds_buf[r * d..(r + 1) * d];
            for p in &ps.similar {
                let mut diff = vec![0.0f32; d];
                ds.diff_into(p.i as usize, p.j as usize, &mut diff);
                if diff == row {
                    continue 'rows;
                }
            }
            panic!("minibatch row {r} matches no similar pair diff");
        }
    }

    #[test]
    fn minibatch_iterator_deterministic() {
        let ds = tiny_ds();
        let mut rng = Pcg32::new(4);
        let ps = PairSet::sample(&ds, 100, 100, &mut rng);
        let mut a = MinibatchIter::new(&ds, &ps, 4, 4, Pcg32::new(9));
        let mut b = MinibatchIter::new(&ds, &ps, 4, 4, Pcg32::new(9));
        for _ in 0..5 {
            a.next_batch();
            b.next_batch();
            assert_eq!(a.ds_buf, b.ds_buf);
            assert_eq!(a.dd_buf, b.dd_buf);
        }
    }

    #[test]
    fn legacy_constructor_is_bit_identical_to_direct_sampling() {
        // The pre-stream iterator drew `rng.index(len)` per similar row
        // then per dissimilar row; the materialized adapter must consume
        // the RNG identically, or `pairs.mode = materialized` stops
        // reproducing historical traces.
        let ds = tiny_ds();
        let mut rng = Pcg32::new(6);
        let ps = PairSet::sample(&ds, 120, 80, &mut rng);
        let mut it = MinibatchIter::new(&ds, &ps, 5, 3, Pcg32::new(77));
        let mut direct = Pcg32::new(77);
        let d = ds.dim();
        for _ in 0..4 {
            it.next_batch();
            let mut want_s = vec![0.0f32; 5 * d];
            for r in 0..5 {
                let p = ps.similar[direct.index(ps.similar.len())];
                ds.diff_into(p.i as usize, p.j as usize,
                             &mut want_s[r * d..(r + 1) * d]);
            }
            let mut want_d = vec![0.0f32; 3 * d];
            for r in 0..3 {
                let p = ps.dissimilar[direct.index(ps.dissimilar.len())];
                ds.diff_into(p.i as usize, p.j as usize,
                             &mut want_d[r * d..(r + 1) * d]);
            }
            assert_eq!(it.ds_buf, want_s);
            assert_eq!(it.dd_buf, want_d);
        }
    }

    #[test]
    fn batches_vary_over_time() {
        let ds = tiny_ds();
        let mut rng = Pcg32::new(5);
        let ps = PairSet::sample(&ds, 100, 100, &mut rng);
        let mut it = MinibatchIter::new(&ds, &ps, 4, 4, Pcg32::new(10));
        it.next_batch();
        let first = it.ds_buf.clone();
        it.next_batch();
        assert_ne!(first, it.ds_buf);
    }
}
