//! Binary framing for the serving wire protocol.
//!
//! Same shape as the PS protocol (`ps::frame`): every message is one
//! length-prefixed frame,
//!
//! ```text
//! u32le body_len | u8 kind | fixed-width LE header | payload
//! ```
//!
//! and decoding keeps the PS layer's structural/semantic split — but
//! the *recovery policy* differs, because a retrieval server faces
//! arbitrary clients, not a fixed fleet of workers. On the PS wire a
//! malformed body drops the connection; here the length prefix is the
//! trust boundary instead: as long as the prefix itself is sane, the
//! frame boundary is sound even when the body is garbage, so the
//! server rejects the one message (with an [`ServeFrame::Error`]
//! reply and a `rejected_frames` tick) and the connection survives.
//! Only a length prefix beyond [`MAX_FRAME_BYTES`] — where the stream
//! can no longer be trusted to be framed at all — drops the
//! connection.
//!
//! Layouts (everything little-endian):
//!
//! ```text
//! Hello     0x51 | u16 protocol
//! HelloAck  0x52 | u16 protocol | u32 dim | u64 gallery | u64 version
//! Query     0x31 | u64 id | u32 k | u32 nprobe | u32 nrows | u32 dim
//!                | nrows·dim × f32         (nprobe 0 = exact scan)
//! Stats     0x32
//! Answer    0x41 | u64 id | u64 version | u32 nrows
//!                | per row: u32 cnt | cnt × (u32 idx, f32 dist)
//! StatsAck  0x42 | u64 version | u64 queries | u64 rows
//!                | u64 rejected | u64 swaps
//! Error     0x4F | u64 id | u32 len | len × u8 (utf-8 message)
//! ```
//!
//! The exact bytes of a Query/Answer pair are pinned by the goldens in
//! `tests/integration_serve.rs`, so the protocol cannot drift silently.

use crate::linalg::Mat;

/// Serving wire protocol version, checked in Hello/HelloAck.
pub const SERVE_PROTOCOL_VERSION: u16 = 1;

/// Hard structural cap on one frame body: a length prefix beyond this
/// is a corrupt stream, not an allocation order. (Policy caps for
/// honest-but-oversized queries are the server's
/// [`ServeLimits`](super::net::ServeLimits), checked per message.)
pub const MAX_FRAME_BYTES: usize = 1 << 28;

/// Frame kind bytes (client→server: 0x3_, server→client: 0x4_,
/// handshake: 0x5_).
pub const KIND_QUERY: u8 = 0x31;
pub const KIND_STATS: u8 = 0x32;
pub const KIND_ANSWER: u8 = 0x41;
pub const KIND_STATS_ACK: u8 = 0x42;
pub const KIND_ERROR: u8 = 0x4F;
pub const KIND_HELLO: u8 = 0x51;
pub const KIND_HELLO_ACK: u8 = 0x52;

/// A decoded serving frame.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeFrame {
    /// Client → server greeting.
    Hello { protocol: u16 },
    /// Server → client: protocol plus the serving topology (feature
    /// dim, resident gallery size, current epoch version).
    HelloAck { protocol: u16, dim: u32, gallery: u64, version: u64 },
    /// A batch of raw feature queries (`x` is nrows × dim).
    /// `nprobe = 0` requests the exact scan; `nprobe >= nclusters`
    /// degrades to exact bit-for-bit.
    Query { id: u64, k: u32, nprobe: u32, x: Mat },
    /// Counter snapshot request.
    Stats,
    /// Per-query-row top-k hits, all from epoch `version`.
    Answer { id: u64, version: u64, results: Vec<Vec<(u32, f32)>> },
    /// Counter snapshot reply.
    StatsAck {
        version: u64,
        queries: u64,
        rows: u64,
        rejected: u64,
        swaps: u64,
    },
    /// A rejected message (`id` echoes the offending query when known,
    /// 0 otherwise). The connection is still alive.
    Error { id: u64, message: String },
}

/// Why a serving frame was refused — same split as
/// [`ps::frame::FrameError`](crate::ps::frame::FrameError), different
/// recovery policy (see the module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeFrameError {
    /// The body bytes are not a well-formed frame. The frame boundary
    /// is still sound (the length prefix was sane), so the server
    /// rejects the message and keeps the connection.
    Malformed(String),
    /// Well-formed frame whose content violates the serving contract
    /// (wrong feature dim, over-limit batch or k).
    Invalid(String),
}

impl std::fmt::Display for ServeFrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeFrameError::Malformed(m) => {
                write!(f, "malformed frame: {m}")
            }
            ServeFrameError::Invalid(m) => {
                write!(f, "invalid message: {m}")
            }
        }
    }
}

impl std::error::Error for ServeFrameError {}

fn malformed(msg: impl Into<String>) -> ServeFrameError {
    ServeFrameError::Malformed(msg.into())
}

fn invalid(msg: impl Into<String>) -> ServeFrameError {
    ServeFrameError::Invalid(msg.into())
}

// ---------------------------------------------------------------------
// little-endian primitives
// ---------------------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ServeFrameError> {
        if self.buf.len() - self.pos < n {
            return Err(malformed(format!(
                "truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ServeFrameError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, ServeFrameError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, ServeFrameError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ServeFrameError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, ServeFrameError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn done(&self) -> Result<(), ServeFrameError> {
        if self.pos != self.buf.len() {
            return Err(malformed(format!(
                "{} trailing bytes after frame body",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }

    /// A count field used to size an allocation of `elem_size`-byte
    /// elements, checked against the bytes actually remaining in the
    /// frame — same allocation-bomb guard as the PS codec.
    fn count(
        &mut self,
        what: &str,
        elem_size: usize,
    ) -> Result<usize, ServeFrameError> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME_BYTES {
            return Err(malformed(format!("{what} count {n} exceeds cap")));
        }
        let need = n.saturating_mul(elem_size);
        let remaining = self.buf.len() - self.pos;
        if need > remaining {
            return Err(malformed(format!(
                "{what} count {n} needs {need} bytes, \
                 {remaining} remain in frame"
            )));
        }
        Ok(n)
    }
}

// ---------------------------------------------------------------------
// encode / decode
// ---------------------------------------------------------------------

/// Reserve a `u32` length slot, fill the body, patch the length.
fn with_length_prefix(out: &mut Vec<u8>, fill: impl FnOnce(&mut Vec<u8>)) {
    let at = out.len();
    put_u32(out, 0);
    fill(out);
    let body_len = (out.len() - at - 4) as u32;
    out[at..at + 4].copy_from_slice(&body_len.to_le_bytes());
}

/// Append one length-prefixed serving frame.
pub fn encode_frame(f: &ServeFrame, out: &mut Vec<u8>) {
    with_length_prefix(out, |body| match f {
        ServeFrame::Hello { protocol } => {
            body.push(KIND_HELLO);
            put_u16(body, *protocol);
        }
        ServeFrame::HelloAck { protocol, dim, gallery, version } => {
            body.push(KIND_HELLO_ACK);
            put_u16(body, *protocol);
            put_u32(body, *dim);
            put_u64(body, *gallery);
            put_u64(body, *version);
        }
        ServeFrame::Query { id, k, nprobe, x } => {
            body.push(KIND_QUERY);
            put_u64(body, *id);
            put_u32(body, *k);
            put_u32(body, *nprobe);
            put_u32(body, x.rows as u32);
            put_u32(body, x.cols as u32);
            for &v in &x.data {
                put_f32(body, v);
            }
        }
        ServeFrame::Stats => {
            body.push(KIND_STATS);
        }
        ServeFrame::Answer { id, version, results } => {
            body.push(KIND_ANSWER);
            put_u64(body, *id);
            put_u64(body, *version);
            put_u32(body, results.len() as u32);
            for row in results {
                put_u32(body, row.len() as u32);
                for &(idx, dist) in row {
                    put_u32(body, idx);
                    put_f32(body, dist);
                }
            }
        }
        ServeFrame::StatsAck { version, queries, rows, rejected, swaps } => {
            body.push(KIND_STATS_ACK);
            put_u64(body, *version);
            put_u64(body, *queries);
            put_u64(body, *rows);
            put_u64(body, *rejected);
            put_u64(body, *swaps);
        }
        ServeFrame::Error { id, message } => {
            body.push(KIND_ERROR);
            put_u64(body, *id);
            put_u32(body, message.len() as u32);
            body.extend_from_slice(message.as_bytes());
        }
    });
}

/// Decode one frame *body* (the bytes after the `u32` length prefix).
/// Structural errors only; run [`validate_query`] before executing.
pub fn decode_frame(body: &[u8]) -> Result<ServeFrame, ServeFrameError> {
    let mut r = Reader::new(body);
    let frame = match r.u8()? {
        KIND_HELLO => ServeFrame::Hello { protocol: r.u16()? },
        KIND_HELLO_ACK => ServeFrame::HelloAck {
            protocol: r.u16()?,
            dim: r.u32()?,
            gallery: r.u64()?,
            version: r.u64()?,
        },
        KIND_QUERY => {
            let id = r.u64()?;
            let k = r.u32()?;
            let nprobe = r.u32()?;
            let nrows = r.count("query rows", 4)? as u64;
            let dim = r.count("query dim", 4)? as u64;
            let total = nrows.saturating_mul(dim) as usize;
            // the per-field checks bound nrows and dim individually;
            // the product is what actually sizes the allocation
            let remaining = body.len() - r.pos;
            if total.saturating_mul(4) > remaining {
                return Err(malformed(format!(
                    "query payload {nrows}x{dim} needs {} bytes, \
                     frame has {remaining}",
                    total * 4
                )));
            }
            let mut data = Vec::with_capacity(total);
            for _ in 0..total {
                data.push(r.f32()?);
            }
            ServeFrame::Query {
                id,
                k,
                nprobe,
                x: Mat::from_vec(nrows as usize, dim as usize, data),
            }
        }
        KIND_STATS => ServeFrame::Stats,
        KIND_ANSWER => {
            let id = r.u64()?;
            let version = r.u64()?;
            let nrows = r.count("answer rows", 4)?;
            let mut results = Vec::with_capacity(nrows);
            for _ in 0..nrows {
                let cnt = r.count("answer hits", 8)?;
                let mut row = Vec::with_capacity(cnt);
                for _ in 0..cnt {
                    let idx = r.u32()?;
                    let dist = r.f32()?;
                    row.push((idx, dist));
                }
                results.push(row);
            }
            ServeFrame::Answer { id, version, results }
        }
        KIND_STATS_ACK => ServeFrame::StatsAck {
            version: r.u64()?,
            queries: r.u64()?,
            rows: r.u64()?,
            rejected: r.u64()?,
            swaps: r.u64()?,
        },
        KIND_ERROR => {
            let id = r.u64()?;
            let len = r.count("error message", 1)?;
            let bytes = r.take(len)?;
            let message = String::from_utf8_lossy(bytes).into_owned();
            ServeFrame::Error { id, message }
        }
        kind => return Err(malformed(format!("unknown kind 0x{kind:02x}"))),
    };
    r.done()?;
    Ok(frame)
}

// ---------------------------------------------------------------------
// semantic validation against the serving contract
// ---------------------------------------------------------------------

/// Validate a decoded query against the epoch's feature dim and the
/// server's policy limits. An `Invalid` here rejects the one message;
/// the connection stays up.
pub fn validate_query(
    frame: &ServeFrame,
    dim: usize,
    max_rows: usize,
    max_k: usize,
) -> Result<(), ServeFrameError> {
    let ServeFrame::Query { k, x, .. } = frame else {
        return Ok(());
    };
    if x.cols != dim {
        return Err(invalid(format!(
            "query dim {} != model dim {dim}",
            x.cols
        )));
    }
    if x.rows == 0 {
        return Err(invalid("empty query batch"));
    }
    if x.rows > max_rows {
        return Err(invalid(format!(
            "query batch {} exceeds limit {max_rows}",
            x.rows
        )));
    }
    if *k as usize > max_k {
        return Err(invalid(format!("k {k} exceeds limit {max_k}")));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strip_prefix(buf: &[u8]) -> &[u8] {
        let len =
            u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
        assert_eq!(len, buf.len() - 4, "length prefix covers the body");
        &buf[4..]
    }

    fn roundtrip(f: &ServeFrame) -> ServeFrame {
        let mut buf = Vec::new();
        encode_frame(f, &mut buf);
        let decoded = decode_frame(strip_prefix(&buf)).unwrap();
        // byte-stability: re-encoding must reproduce the wire exactly
        let mut buf2 = Vec::new();
        encode_frame(&decoded, &mut buf2);
        assert_eq!(buf, buf2, "frame not byte-stable: {f:?}");
        decoded
    }

    #[test]
    fn every_frame_kind_roundtrips_bitwise() {
        let frames = [
            ServeFrame::Hello { protocol: SERVE_PROTOCOL_VERSION },
            ServeFrame::HelloAck {
                protocol: SERVE_PROTOCOL_VERSION,
                dim: 16,
                gallery: 400,
                version: 3,
            },
            ServeFrame::Query {
                id: 9,
                k: 5,
                nprobe: 0,
                x: Mat::from_vec(
                    2,
                    3,
                    vec![1.5, -0.0, f32::MIN_POSITIVE, 2.5, -3.0, 0.125],
                ),
            },
            ServeFrame::Stats,
            ServeFrame::Answer {
                id: 9,
                version: 3,
                results: vec![
                    vec![(4, 0.25), (0, 1.5)],
                    vec![],
                    vec![(7, f32::MAX)],
                ],
            },
            ServeFrame::StatsAck {
                version: 3,
                queries: 10,
                rows: 20,
                rejected: 1,
                swaps: 2,
            },
            ServeFrame::Error { id: 9, message: "bad dim".into() },
        ];
        for f in &frames {
            assert_eq!(&roundtrip(f), f);
        }
    }

    #[test]
    fn query_floats_roundtrip_to_the_bit() {
        let x = Mat::from_vec(1, 4, vec![-0.0, f32::MIN, 1e-38, 0.1]);
        let q = ServeFrame::Query { id: 1, k: 2, nprobe: 3, x };
        let ServeFrame::Query { x: back, .. } = roundtrip(&q) else {
            panic!("wrong kind")
        };
        let ServeFrame::Query { x: orig, .. } = q else { unreachable!() };
        for (a, b) in orig.data.iter().zip(&back.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn truncation_sweep_is_malformed_never_panics() {
        let q = ServeFrame::Query {
            id: 3,
            k: 2,
            nprobe: 1,
            x: Mat::from_vec(1, 2, vec![1.0, 2.0]),
        };
        let mut buf = Vec::new();
        encode_frame(&q, &mut buf);
        let body = strip_prefix(&buf);
        for cut in 1..body.len() {
            assert!(
                matches!(
                    decode_frame(&body[..cut]),
                    Err(ServeFrameError::Malformed(_))
                ),
                "cut at {cut} must be malformed"
            );
        }
        assert!(matches!(
            decode_frame(&[0x7E]),
            Err(ServeFrameError::Malformed(_))
        ));
    }

    /// Allocation bomb: a tiny frame whose row/dim counts multiply out
    /// to gigabytes must be rejected by the remaining-bytes check
    /// before any `Vec::with_capacity`.
    #[test]
    fn huge_query_counts_in_tiny_frame_are_malformed() {
        let mut body = vec![KIND_QUERY];
        put_u64(&mut body, 0); // id
        put_u32(&mut body, 1); // k
        put_u32(&mut body, 0); // nprobe
        put_u32(&mut body, 1 << 20); // nrows: huge
        put_u32(&mut body, 1 << 20); // dim: huge
        assert!(matches!(
            decode_frame(&body),
            Err(ServeFrameError::Malformed(_))
        ));
        // per-field counts individually fit, but the product overflows
        // the frame: 2×2 needs four floats and only two are present
        let mut body = vec![KIND_QUERY];
        put_u64(&mut body, 0);
        put_u32(&mut body, 1);
        put_u32(&mut body, 0);
        put_u32(&mut body, 2);
        put_u32(&mut body, 2);
        put_f32(&mut body, 0.0);
        put_f32(&mut body, 0.0);
        assert!(matches!(
            decode_frame(&body),
            Err(ServeFrameError::Malformed(_))
        ));
    }

    #[test]
    fn validate_query_enforces_dim_and_limits() {
        let mk = |rows: usize, cols: usize, k: u32| ServeFrame::Query {
            id: 0,
            k,
            nprobe: 0,
            x: Mat::zeros(rows, cols),
        };
        assert!(validate_query(&mk(2, 16, 5), 16, 64, 32).is_ok());
        for bad in [
            mk(2, 15, 5),  // wrong dim
            mk(0, 16, 5),  // empty batch
            mk(65, 16, 5), // over batch limit
            mk(2, 16, 33), // over k limit
        ] {
            assert!(matches!(
                validate_query(&bad, 16, 64, 32),
                Err(ServeFrameError::Invalid(_))
            ));
        }
        // non-query frames pass through untouched
        assert!(validate_query(&ServeFrame::Stats, 16, 64, 32).is_ok());
    }
}
