//! Socket front end for the retrieval engine.
//!
//! Reuses the PS transport's plumbing (`ps::net`: [`NetAddr`],
//! [`Listener`], [`Stream`], [`connect_retry`]) with the serving frame
//! codec ([`super::frame`]). One thread per connection; the engine
//! itself is lock-free on the read path (a query holds one `Arc`
//! snapshot of the current epoch), so connection threads scale without
//! coordinating.
//!
//! ## Error policy — the connection survives bad messages
//!
//! The PS wire connects a fixed fleet where a malformed frame means a
//! mis-deployed binary and the right move is to drop the link. A
//! retrieval server faces arbitrary clients, so the policy here is
//! graded by how much of the stream can still be trusted:
//!
//! * length prefix beyond [`MAX_FRAME_BYTES`], or a socket error —
//!   stream framing itself is gone; count + drop the connection.
//! * body larger than [`ServeLimits::max_body_bytes`] but under the
//!   hard cap — the frame boundary is sound; skip the body in bounded
//!   chunks (never buffering it), count, reply [`ServeFrame::Error`],
//!   keep the connection.
//! * body that fails structural decode, or a well-formed message that
//!   violates the serving contract (wrong dim, over-limit batch/k) —
//!   count, reply `Error` (echoing the query id when known), keep the
//!   connection.
//!
//! Every rejection ticks a shared counter surfaced in
//! [`ServeFrame::StatsAck`], so the integration tests can assert both
//! halves: bad frames are *counted* and the next good query on the
//! same connection is *answered*.

use std::io::{BufWriter, Read, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use crate::linalg::Mat;
use crate::ps::net::{connect_retry, Listener, NetAddr, RetryPolicy, Stream};

use super::engine::{ScanMode, ServeEngine};
use super::frame::{
    decode_frame, encode_frame, validate_query, ServeFrame, ServeFrameError,
    MAX_FRAME_BYTES, SERVE_PROTOCOL_VERSION,
};

/// Per-message policy limits, checked semantically after decode. These
/// bound honest-but-oversized requests; the structural trust boundary
/// is [`MAX_FRAME_BYTES`].
#[derive(Clone, Copy, Debug)]
pub struct ServeLimits {
    /// Largest frame body the server will buffer (bytes). Bigger (but
    /// under the hard cap) bodies are skipped and rejected without the
    /// connection dropping.
    pub max_body_bytes: usize,
    /// Largest query batch (rows) answered in one frame.
    pub max_rows: usize,
    /// Largest per-row k answered.
    pub max_k: usize,
}

impl Default for ServeLimits {
    fn default() -> ServeLimits {
        ServeLimits {
            max_body_bytes: 1 << 22, // 4 MiB ≈ a 4096×256-f32 batch
            max_rows: 4096,
            max_k: 1024,
        }
    }
}

/// What the server tells a client at handshake.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeInfo {
    /// Raw feature dimension queries must match.
    pub dim: usize,
    /// Gallery rows resident at connect time.
    pub gallery: u64,
    /// Epoch version at connect time (later answers may be newer).
    pub version: u64,
}

/// Counter snapshot returned by [`ServeClient::stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    pub version: u64,
    pub queries: u64,
    pub rows: u64,
    pub rejected: u64,
    pub swaps: u64,
}

// ---------------------------------------------------------------------
// server
// ---------------------------------------------------------------------

/// A bound-but-not-yet-serving retrieval server.
pub struct ServeServer {
    listener: Listener,
    engine: Arc<ServeEngine>,
    limits: ServeLimits,
    rejected: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
}

/// Handle to a server running on a background thread; shuts the accept
/// loop down on [`ServeHandle::shutdown`] or drop.
pub struct ServeHandle {
    addr: NetAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl ServeServer {
    /// Bind the listener without accepting yet, so the caller can
    /// publish [`ServeServer::local_addr`] (e.g. port 0 → real port)
    /// before traffic starts.
    pub fn bind(
        addr: &NetAddr,
        engine: Arc<ServeEngine>,
        limits: ServeLimits,
    ) -> Result<ServeServer> {
        Ok(ServeServer {
            listener: Listener::bind(addr)?,
            engine,
            limits,
            rejected: Arc::new(AtomicU64::new(0)),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> Result<NetAddr> {
        self.listener.local_addr()
    }

    /// Total frames rejected across all connections so far.
    pub fn rejected_frames(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Accept loop on the calling thread (the `dmlps serve` path);
    /// runs until the process exits or [`ServeHandle::shutdown`] on a
    /// clone of the stop flag flips it.
    pub fn run(self) -> Result<()> {
        loop {
            let stream = self.listener.accept()?;
            if self.stop.load(Ordering::SeqCst) {
                return Ok(());
            }
            let engine = Arc::clone(&self.engine);
            let rejected = Arc::clone(&self.rejected);
            let limits = self.limits;
            std::thread::Builder::new()
                .name("serve-conn".into())
                .spawn(move || {
                    // per-connection errors end that connection only
                    let _ = serve_connection(stream, &engine, limits, &rejected);
                })
                .context("spawn connection thread")?;
        }
    }

    /// Run the accept loop on a background thread and return a handle
    /// (the in-process path tests and benches use).
    pub fn spawn(self) -> Result<ServeHandle> {
        let addr = self.local_addr()?;
        let stop = Arc::clone(&self.stop);
        let join = std::thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || {
                let _ = self.run();
            })
            .context("spawn accept thread")?;
        Ok(ServeHandle { addr, stop, join: Some(join) })
    }
}

impl ServeHandle {
    /// Address the server is reachable at (real port even if bound 0).
    pub fn addr(&self) -> &NetAddr {
        &self.addr
    }

    /// Stop the accept loop: set the flag, poke the listener with a
    /// throwaway connection so the blocking `accept` observes it, join.
    /// Connections already accepted finish on their own threads.
    pub fn shutdown(&mut self) {
        let Some(join) = self.join.take() else { return };
        self.stop.store(true, Ordering::SeqCst);
        let _ = connect_retry(
            &self.addr,
            RetryPolicy {
                attempts: 1,
                ..RetryPolicy::default()
            },
        );
        let _ = join.join();
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Read one length prefix. `Ok(None)` = clean EOF before a frame.
fn read_len(r: &mut impl Read) -> Result<Option<usize>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => Ok(Some(u32::from_le_bytes(len_buf) as usize)),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Ok(None),
        Err(e) => Err(e).context("read frame length"),
    }
}

/// Discard exactly `len` body bytes in bounded chunks, keeping the
/// stream positioned at the next frame without ever buffering the body.
fn skip_body(r: &mut impl Read, len: usize) -> Result<()> {
    let mut scratch = [0u8; 8192];
    let mut left = len;
    while left > 0 {
        let n = left.min(scratch.len());
        r.read_exact(&mut scratch[..n]).context("skip frame body")?;
        left -= n;
    }
    Ok(())
}

fn send(w: &mut impl Write, f: &ServeFrame) -> Result<()> {
    let mut buf = Vec::new();
    encode_frame(f, &mut buf);
    w.write_all(&buf).context("write frame")?;
    w.flush().context("flush frame")?;
    Ok(())
}

fn serve_connection(
    stream: Stream,
    engine: &ServeEngine,
    limits: ServeLimits,
    rejected: &AtomicU64,
) -> Result<()> {
    let mut reader = stream.try_clone()?;
    let mut writer = BufWriter::new(stream);

    // Handshake: first frame must be a protocol-matching Hello.
    let Some(len) = read_len(&mut reader)? else { return Ok(()) };
    if len > MAX_FRAME_BYTES {
        rejected.fetch_add(1, Ordering::Relaxed);
        bail!("handshake frame length {len} exceeds cap");
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).context("read handshake")?;
    match decode_frame(&body) {
        Ok(ServeFrame::Hello { protocol })
            if protocol == SERVE_PROTOCOL_VERSION =>
        {
            let epoch = engine.snapshot();
            send(&mut writer, &ServeFrame::HelloAck {
                protocol: SERVE_PROTOCOL_VERSION,
                dim: epoch.model().dim() as u32,
                gallery: epoch.gallery_len() as u64,
                version: epoch.version(),
            })?;
        }
        Ok(ServeFrame::Hello { protocol }) => {
            rejected.fetch_add(1, Ordering::Relaxed);
            send(&mut writer, &ServeFrame::Error {
                id: 0,
                message: format!(
                    "protocol {protocol} != {SERVE_PROTOCOL_VERSION}"
                ),
            })?;
            bail!("protocol mismatch");
        }
        _ => {
            rejected.fetch_add(1, Ordering::Relaxed);
            send(&mut writer, &ServeFrame::Error {
                id: 0,
                message: "expected Hello".into(),
            })?;
            bail!("handshake frame was not Hello");
        }
    }

    let mut body = Vec::new();
    loop {
        let Some(len) = read_len(&mut reader)? else { return Ok(()) };
        if len > MAX_FRAME_BYTES {
            // stream can no longer be trusted to be framed
            rejected.fetch_add(1, Ordering::Relaxed);
            bail!("frame length {len} exceeds cap {MAX_FRAME_BYTES}");
        }
        if len > limits.max_body_bytes {
            // framing is sound: reject the message, keep the stream
            skip_body(&mut reader, len)?;
            rejected.fetch_add(1, Ordering::Relaxed);
            send(&mut writer, &ServeFrame::Error {
                id: 0,
                message: format!(
                    "frame body {len} exceeds limit {}",
                    limits.max_body_bytes
                ),
            })?;
            continue;
        }
        body.resize(len, 0);
        reader.read_exact(&mut body).context("read frame body")?;

        let frame = match decode_frame(&body) {
            Ok(f) => f,
            Err(e) => {
                rejected.fetch_add(1, Ordering::Relaxed);
                send(&mut writer, &ServeFrame::Error {
                    id: 0,
                    message: e.to_string(),
                })?;
                continue;
            }
        };
        match frame {
            query @ ServeFrame::Query { .. } => {
                let dim = engine.snapshot().model().dim();
                if let Err(e) =
                    validate_query(&query, dim, limits.max_rows, limits.max_k)
                {
                    let ServeFrame::Query { id, .. } = query else {
                        unreachable!()
                    };
                    rejected.fetch_add(1, Ordering::Relaxed);
                    send(&mut writer, &ServeFrame::Error {
                        id,
                        message: e.to_string(),
                    })?;
                    continue;
                }
                let ServeFrame::Query { id, k, nprobe, x } = query else {
                    unreachable!()
                };
                let mode = if nprobe == 0 {
                    ScanMode::Exact
                } else {
                    ScanMode::Probe(nprobe as usize)
                };
                let ans = engine.query_batch(&x, k as usize, mode);
                send(&mut writer, &ServeFrame::Answer {
                    id,
                    version: ans.version,
                    results: ans.results,
                })?;
            }
            ServeFrame::Stats => {
                let s = engine.stats();
                let epoch = engine.snapshot();
                send(&mut writer, &ServeFrame::StatsAck {
                    version: epoch.version(),
                    queries: s.queries,
                    rows: s.rows_answered,
                    rejected: rejected.load(Ordering::Relaxed),
                    swaps: s.swaps,
                })?;
            }
            other => {
                // well-formed frame a client has no business sending
                rejected.fetch_add(1, Ordering::Relaxed);
                let msg = ServeFrameError::Invalid(format!(
                    "unexpected frame {other:?}"
                ));
                send(&mut writer, &ServeFrame::Error {
                    id: 0,
                    message: msg.to_string(),
                })?;
            }
        }
    }
}

// ---------------------------------------------------------------------
// client
// ---------------------------------------------------------------------

/// Blocking client for the serving protocol. Not `Sync`: one
/// connection carries one request/response exchange at a time (open
/// more clients for parallel load — the bench does).
pub struct ServeClient {
    stream: Stream,
    body: Vec<u8>,
}

impl ServeClient {
    /// Connect (with bounded retry), handshake, return the client plus
    /// what the server advertised.
    pub fn connect(
        addr: &NetAddr,
        policy: RetryPolicy,
    ) -> Result<(ServeClient, ServeInfo)> {
        let stream = connect_retry(addr, policy)?;
        let mut c = ServeClient { stream, body: Vec::new() };
        c.send(&ServeFrame::Hello { protocol: SERVE_PROTOCOL_VERSION })?;
        match c.recv()? {
            ServeFrame::HelloAck { protocol, dim, gallery, version } => {
                if protocol != SERVE_PROTOCOL_VERSION {
                    bail!(
                        "server protocol {protocol} != \
                         {SERVE_PROTOCOL_VERSION}"
                    );
                }
                Ok((c, ServeInfo { dim: dim as usize, gallery, version }))
            }
            ServeFrame::Error { message, .. } => {
                bail!("server refused handshake: {message}")
            }
            other => bail!("unexpected handshake reply: {other:?}"),
        }
    }

    fn send(&mut self, f: &ServeFrame) -> Result<()> {
        let mut buf = Vec::new();
        encode_frame(f, &mut buf);
        self.stream.write_all(&buf).context("write frame")?;
        self.stream.flush().context("flush")?;
        Ok(())
    }

    fn recv(&mut self) -> Result<ServeFrame> {
        let Some(len) = read_len(&mut self.stream)? else {
            bail!("server closed the connection")
        };
        if len > MAX_FRAME_BYTES {
            bail!("reply frame length {len} exceeds cap");
        }
        self.body.resize(len, 0);
        self.stream.read_exact(&mut self.body).context("read reply")?;
        decode_frame(&self.body)
            .map_err(|e| anyhow::anyhow!("bad reply frame: {e}"))
    }

    /// Send one batch query; `nprobe = 0` requests the exact scan.
    /// Returns the answering epoch's version and per-row hits.
    pub fn query(
        &mut self,
        x: &Mat,
        k: usize,
        nprobe: usize,
        id: u64,
    ) -> Result<(u64, Vec<Vec<(u32, f32)>>)> {
        self.send(&ServeFrame::Query {
            id,
            k: k as u32,
            nprobe: nprobe as u32,
            x: x.clone(),
        })?;
        match self.recv()? {
            ServeFrame::Answer { id: rid, version, results } => {
                if rid != id {
                    bail!("answer id {rid} != query id {id}");
                }
                Ok((version, results))
            }
            ServeFrame::Error { message, .. } => {
                bail!("server rejected query: {message}")
            }
            other => bail!("unexpected reply: {other:?}"),
        }
    }

    /// Fetch the server's counter snapshot.
    pub fn stats(&mut self) -> Result<WireStats> {
        self.send(&ServeFrame::Stats)?;
        match self.recv()? {
            ServeFrame::StatsAck { version, queries, rows, rejected, swaps } => {
                Ok(WireStats { version, queries, rows, rejected, swaps })
            }
            other => bail!("unexpected stats reply: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;
    use crate::data::SyntheticSpec;
    use crate::serve::engine::ServeConfig;
    use crate::session::MetricModel;
    use crate::util::rng::Pcg32;

    fn tiny_server() -> (ServeHandle, Arc<ServeEngine>) {
        let cfg = Preset::Tiny.config();
        let data = SyntheticSpec::tiny().generate(7);
        let mut l = Mat::zeros(8, data.dim());
        Pcg32::new(99).fill_gaussian(&mut l.data, 0.0, 0.3);
        let model = MetricModel::new(l, &cfg);
        let engine = Arc::new(ServeEngine::new(
            model,
            &data,
            ServeConfig { nclusters: 4, ..ServeConfig::default() },
        ));
        let server = ServeServer::bind(
            &NetAddr::parse("127.0.0.1:0").unwrap(),
            Arc::clone(&engine),
            ServeLimits::default(),
        )
        .unwrap();
        (server.spawn().unwrap(), engine)
    }

    #[test]
    fn wire_query_matches_in_process_engine_bitwise() {
        let (mut handle, engine) = tiny_server();
        let (mut client, info) =
            ServeClient::connect(handle.addr(), RetryPolicy::default())
                .unwrap();
        let epoch = engine.snapshot();
        assert_eq!(info.dim, epoch.model().dim());
        assert_eq!(info.gallery as usize, epoch.gallery_len());
        assert_eq!(info.version, 1);

        let mut x = Mat::zeros(3, info.dim);
        Pcg32::new(5).fill_gaussian(&mut x.data, 0.0, 1.0);
        let (version, got) = client.query(&x, 4, 0, 11).unwrap();
        let want = engine.query_batch(&x, 4, ScanMode::Exact);
        assert_eq!(version, want.version);
        assert_eq!(got.len(), want.results.len());
        for (g, w) in got.iter().zip(&want.results) {
            assert_eq!(g.len(), w.len());
            for (&(gi, gd), &(wi, wd)) in g.iter().zip(w) {
                assert_eq!(gi, wi);
                assert_eq!(gd.to_bits(), wd.to_bits());
            }
        }
        handle.shutdown();
    }

    #[test]
    fn bad_dim_query_is_rejected_but_connection_survives() {
        let (mut handle, _engine) = tiny_server();
        let (mut client, info) =
            ServeClient::connect(handle.addr(), RetryPolicy::default())
                .unwrap();
        let bad = Mat::zeros(1, info.dim + 1);
        assert!(client.query(&bad, 2, 0, 1).is_err());
        // same connection still answers a good query and counted it
        let good = Mat::zeros(1, info.dim);
        let (_, results) = client.query(&good, 2, 0, 2).unwrap();
        assert_eq!(results.len(), 1);
        let stats = client.stats().unwrap();
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.queries, 1);
        handle.shutdown();
    }
}
