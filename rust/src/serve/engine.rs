//! The in-process retrieval engine: immutable epochs behind an atomic
//! hot-swap, with an exact blocked scan and a cluster-pruned
//! approximate scan over the pre-projected gallery.
//!
//! An [`Epoch`] is the unit of consistency: one `MetricModel` plus the
//! gallery projected through it plus the coarse quantizer built over
//! that projection, all immutable, all tagged with one version number.
//! A query clones the current `Arc<Epoch>` once and runs entirely
//! against that snapshot, so a concurrent [`ServeEngine::swap`] can
//! never tear a response across two model versions; the old epoch's
//! memory is retired when the last in-flight query drops its `Arc`.
//!
//! The approximate path is the paper-scale concession: at million-point
//! galleries a full scan per query is the dominant cost, so gallery
//! rows are bucketed by a k-means coarse quantizer at load time and a
//! query scans only the `nprobe` clusters whose centroids are nearest.
//! The contract with the exact path is exact, not vibes: candidates
//! are re-sorted into ascending row order and fed through the same
//! [`crate::eval::nearest_k_among`] heap as the full scan, so
//! `nprobe = nclusters` is bit-for-bit identical to
//! [`crate::eval::nearest_k`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::data::Dataset;
use crate::linalg::{simd, Mat};
use crate::session::MetricModel;
use crate::util::rng::Pcg32;

/// Build-time knobs for an epoch's quantizer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// Coarse clusters (0 = auto: `√n` clamped to `[1, 256]`).
    pub nclusters: usize,
    /// Lloyd iterations for the k-means build.
    pub kmeans_iters: usize,
    /// Seed for the (deterministic) centroid init.
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig { nclusters: 0, kmeans_iters: 8, seed: 0x5E21 }
    }
}

/// The benched approximate-path default: probe a quarter of the
/// clusters (at least one). `prop_serve` holds recall@10 at this
/// setting to the ≥ 0.9 floor, and `serving_load` reports recall@k for
/// exactly this probe count.
pub fn default_nprobe(nclusters: usize) -> usize {
    (nclusters / 4).max(1)
}

/// How a query scans the gallery.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScanMode {
    /// Full blocked scan — the reference answer.
    Exact,
    /// Scan only the `n` clusters nearest the query (`n >= nclusters`
    /// degrades to a full candidate set and is bit-identical to
    /// `Exact`).
    Probe(usize),
}

/// Coarse k-means quantizer over the projected gallery: centroids in
/// the learned space plus the member rows of each cluster.
#[derive(Debug)]
pub struct Quantizer {
    centroids: Mat,
    members: Vec<Vec<u32>>,
}

impl Quantizer {
    /// Deterministic Lloyd k-means: distinct random rows seed the
    /// centroids, assignment ties break toward the smaller cluster id,
    /// and a cluster that goes empty keeps its previous centroid — the
    /// whole build is a pure function of `(projected, cfg)`.
    fn build(projected: &Mat, cfg: &ServeConfig) -> Quantizer {
        let n = projected.rows;
        let d = projected.cols;
        let c = if cfg.nclusters == 0 {
            ((n as f64).sqrt().round() as usize).clamp(1, 256)
        } else {
            cfg.nclusters
        }
        .clamp(1, n.max(1));
        let mut rng = Pcg32::new(cfg.seed);
        let mut centroids = Mat::zeros(c, d);
        if n > 0 {
            for (ci, &row) in
                rng.sample_distinct(n, c).iter().enumerate()
            {
                centroids.row_mut(ci).copy_from_slice(projected.row(row));
            }
        }
        let mut assign = vec![0u32; n];
        for _ in 0..cfg.kmeans_iters {
            assign_rows(projected, &centroids, &mut assign);
            // recompute means; sequential fixed-order accumulation
            // keeps the result independent of thread count
            let mut sums = Mat::zeros(c, d);
            let mut counts = vec![0u64; c];
            for (i, &a) in assign.iter().enumerate() {
                let dst = sums.row_mut(a as usize);
                for (s, &x) in dst.iter_mut().zip(projected.row(i)) {
                    *s += x;
                }
                counts[a as usize] += 1;
            }
            for ci in 0..c {
                if counts[ci] > 0 {
                    let inv = 1.0 / counts[ci] as f32;
                    let (dst, src) =
                        (centroids.row_mut(ci), sums.row(ci));
                    for (cv, &s) in dst.iter_mut().zip(src) {
                        *cv = s * inv;
                    }
                }
            }
        }
        // final assignment against the final centroids
        assign_rows(projected, &centroids, &mut assign);
        let mut members = vec![Vec::new(); c];
        for (i, &a) in assign.iter().enumerate() {
            members[a as usize].push(i as u32);
        }
        Quantizer { centroids, members }
    }

    pub fn nclusters(&self) -> usize {
        self.centroids.rows
    }

    /// Candidate gallery rows for a projected query: the members of the
    /// `nprobe` nearest clusters (by `(distance, cluster id)`, the same
    /// lexicographic tie order as the scan itself), sorted ascending so
    /// the heap admission order matches the exact scan's.
    pub fn candidates(&self, qp: &[f32], nprobe: usize) -> Vec<usize> {
        let c = self.nclusters();
        let nprobe = nprobe.clamp(1, c);
        let mut order: Vec<(f32, usize)> = (0..c)
            .map(|ci| (simd::sqdist(qp, self.centroids.row(ci)), ci))
            .collect();
        order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut rows: Vec<usize> = order[..nprobe]
            .iter()
            .flat_map(|&(_, ci)| {
                self.members[ci].iter().map(|&r| r as usize)
            })
            .collect();
        rows.sort_unstable();
        rows
    }
}

fn assign_rows(projected: &Mat, centroids: &Mat, assign: &mut [u32]) {
    for (i, a) in assign.iter_mut().enumerate() {
        let q = projected.row(i);
        let mut best = (f32::INFINITY, 0u32);
        for ci in 0..centroids.rows {
            let d = simd::sqdist(q, centroids.row(ci));
            // strict `<`: distance ties keep the smaller cluster id
            if d < best.0 {
                best = (d, ci as u32);
            }
        }
        *a = best.1;
    }
}

/// One immutable serving generation: model + projected gallery +
/// quantizer, tagged with a monotonically increasing version.
#[derive(Debug)]
pub struct Epoch {
    version: u64,
    model: MetricModel,
    projected: Mat,
    quantizer: Quantizer,
}

impl Epoch {
    fn build(
        version: u64,
        model: MetricModel,
        gallery: &Dataset,
        cfg: &ServeConfig,
    ) -> Epoch {
        let projected = model.project_gallery(gallery);
        let quantizer = Quantizer::build(&projected, cfg);
        Epoch { version, model, projected, quantizer }
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn model(&self) -> &MetricModel {
        &self.model
    }

    /// Gallery size (rows of the resident projection).
    pub fn gallery_len(&self) -> usize {
        self.projected.rows
    }

    pub fn quantizer(&self) -> &Quantizer {
        &self.quantizer
    }

    /// Top-k scan for one *projected* query vector, as
    /// `(gallery row, squared distance)` ascending.
    fn scan(
        &self,
        qp: &[f32],
        k: usize,
        mode: ScanMode,
    ) -> Vec<(u32, f32)> {
        let hits = match mode {
            ScanMode::Exact => {
                crate::eval::nearest_k(&self.projected, qp, k)
            }
            ScanMode::Probe(nprobe) => {
                let rows = self.quantizer.candidates(qp, nprobe);
                crate::eval::nearest_k_among(&self.projected, qp, k, &rows)
            }
        };
        hits.into_iter().map(|(d, i)| (i as u32, d)).collect()
    }
}

/// One batch of answers, all computed against a single epoch.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchAnswer {
    /// The epoch every row of `results` came from — the torn-read
    /// detector `prop_serve` hammers.
    pub version: u64,
    /// Per query row: `(gallery index, squared distance)` ascending.
    pub results: Vec<Vec<(u32, f32)>>,
}

/// Cumulative engine counters (monotone; snapshot via
/// [`ServeEngine::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub queries: u64,
    pub rows_answered: u64,
    pub swaps: u64,
}

/// The hot-swappable retrieval engine: concurrent readers, atomic
/// epoch replacement, no torn responses.
pub struct ServeEngine {
    epoch: RwLock<Arc<Epoch>>,
    cfg: ServeConfig,
    next_version: AtomicU64,
    queries: AtomicU64,
    rows_answered: AtomicU64,
    swaps: AtomicU64,
}

impl ServeEngine {
    /// Project the gallery through `model`, build the quantizer, and
    /// install the result as epoch version 1.
    pub fn new(
        model: MetricModel,
        gallery: &Dataset,
        cfg: ServeConfig,
    ) -> ServeEngine {
        let epoch = Arc::new(Epoch::build(1, model, gallery, &cfg));
        ServeEngine {
            epoch: RwLock::new(epoch),
            cfg,
            next_version: AtomicU64::new(2),
            queries: AtomicU64::new(0),
            rows_answered: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
        }
    }

    /// Build a fresh epoch from a newer model and atomically install
    /// it. In-flight queries keep their snapshot; the displaced epoch
    /// is freed when its last `Arc` drops. Returns the new version.
    ///
    /// The (expensive) projection + quantizer build runs *before* the
    /// write lock is taken, so readers are blocked only for the
    /// pointer swap itself.
    pub fn swap(&self, model: MetricModel, gallery: &Dataset) -> u64 {
        let version = self.next_version.fetch_add(1, Ordering::Relaxed);
        let epoch =
            Arc::new(Epoch::build(version, model, gallery, &self.cfg));
        *self.epoch.write().expect("epoch lock poisoned") = epoch;
        self.swaps.fetch_add(1, Ordering::Relaxed);
        version
    }

    /// The current epoch. Queries hold this snapshot for their whole
    /// lifetime; callers doing multi-step work against one generation
    /// should do the same.
    pub fn snapshot(&self) -> Arc<Epoch> {
        Arc::clone(&self.epoch.read().expect("epoch lock poisoned"))
    }

    /// Answer a batch of raw feature queries (`x` is b × d): project
    /// through the epoch's model in one gemm, then scan per row. Every
    /// row is answered against the same epoch, and each row is
    /// bit-identical to [`ServeEngine::query_one`] on that row (single
    /// and batched projection share one gemm path).
    pub fn query_batch(
        &self,
        x: &Mat,
        k: usize,
        mode: ScanMode,
    ) -> BatchAnswer {
        let epoch = self.snapshot();
        let p = epoch.model().transform(x);
        let results: Vec<Vec<(u32, f32)>> =
            (0..p.rows).map(|r| epoch.scan(p.row(r), k, mode)).collect();
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.rows_answered.fetch_add(p.rows as u64, Ordering::Relaxed);
        BatchAnswer { version: epoch.version(), results }
    }

    /// Answer a single raw feature query.
    pub fn query_one(
        &self,
        q: &[f32],
        k: usize,
        mode: ScanMode,
    ) -> (u64, Vec<(u32, f32)>) {
        let epoch = self.snapshot();
        let qp = epoch.model().transform_vec(q);
        let hits = epoch.scan(&qp, k, mode);
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.rows_answered.fetch_add(1, Ordering::Relaxed);
        (epoch.version(), hits)
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            queries: self.queries.load(Ordering::Relaxed),
            rows_answered: self.rows_answered.load(Ordering::Relaxed),
            swaps: self.swaps.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;
    use crate::data::SyntheticSpec;

    fn tiny_engine(seed: u64) -> (ServeEngine, Dataset, MetricModel) {
        let cfg = Preset::Tiny.config();
        let gallery = SyntheticSpec::tiny().generate(seed);
        let mut l = Mat::zeros(8, gallery.dim());
        Pcg32::new(seed).fill_gaussian(&mut l.data, 0.0, 0.3);
        let model = MetricModel::new(l, &cfg);
        let engine = ServeEngine::new(
            model.clone(),
            &gallery,
            ServeConfig { nclusters: 8, ..ServeConfig::default() },
        );
        (engine, gallery, model)
    }

    #[test]
    fn quantizer_partitions_the_gallery() {
        let (engine, gallery, _) = tiny_engine(11);
        let epoch = engine.snapshot();
        let total: usize =
            epoch.quantizer().members.iter().map(|m| m.len()).sum();
        assert_eq!(total, gallery.n());
        // every row appears exactly once
        let mut seen = vec![false; gallery.n()];
        for m in &epoch.quantizer().members {
            for &r in m {
                assert!(!seen[r as usize], "row {r} in two clusters");
                seen[r as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exact_query_matches_model_knn() {
        let (engine, gallery, model) = tiny_engine(12);
        let q = gallery.feature(3).to_vec();
        let (version, hits) = engine.query_one(&q, 5, ScanMode::Exact);
        assert_eq!(version, 1);
        let want = model.knn(&gallery, &q, 5);
        assert_eq!(hits.len(), want.len());
        for ((i1, d1), (i2, d2)) in hits.iter().zip(&want) {
            assert_eq!(*i1 as usize, *i2);
            assert_eq!(d1.to_bits(), d2.to_bits());
        }
    }

    #[test]
    fn swap_bumps_version_and_retires_old_epoch() {
        let (engine, gallery, model) = tiny_engine(13);
        let held = engine.snapshot();
        let v2 = engine.swap(model, &gallery);
        assert_eq!(v2, 2);
        assert_eq!(engine.snapshot().version(), 2);
        // the held snapshot still answers under its own version
        assert_eq!(held.version(), 1);
        assert_eq!(engine.stats().swaps, 1);
    }
}
