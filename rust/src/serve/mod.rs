//! Retrieval serving layer: the deploy half of the learn-vs-deploy
//! split.
//!
//! Training produces a durable [`MetricModel`](crate::session::MetricModel)
//! artifact; this module serves it at query time:
//!
//! * [`engine`] — the in-process core. An immutable
//!   [`Epoch`](engine::Epoch) bundles one model version with its
//!   pre-projected gallery and a coarse k-means quantizer; readers take
//!   one `Arc` snapshot per query and [`ServeEngine::swap`](
//!   engine::ServeEngine::swap) atomically installs a newer model
//!   mid-traffic, old epochs retiring when their last in-flight query
//!   drops. Scans are exact ([`eval::nearest_k`](crate::eval::nearest_k))
//!   or cluster-pruned ([`ScanMode::Probe`](engine::ScanMode)), with
//!   `nprobe >= nclusters` degrading to exact *bit-for-bit*.
//! * [`frame`] — the length-prefixed wire codec (`ps::frame` style)
//!   with golden-pinned byte layouts.
//! * [`net`] — the socket front end (`dmlps serve`) and blocking
//!   client, with a reject-and-survive error policy per message.

pub mod engine;
pub mod frame;
pub mod net;

pub use engine::{
    default_nprobe, BatchAnswer, Epoch, ScanMode, ServeConfig, ServeEngine,
    ServeStats,
};
pub use frame::{ServeFrame, ServeFrameError, SERVE_PROTOCOL_VERSION};
pub use net::{
    ServeClient, ServeHandle, ServeInfo, ServeLimits, ServeServer, WireStats,
};
