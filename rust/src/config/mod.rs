//! Experiment configuration: typed structs, JSON round-trip, presets.
//!
//! Every entry point (CLI, examples, benches) builds an
//! [`ExperimentConfig`] — from a preset name, a JSON file, or both (file
//! overrides preset, CLI overrides file) — so runs are fully described by
//! one serializable value, which the metrics recorder embeds in its
//! output for provenance.

use crate::util::json::Json;

/// How worker parameter copies are synchronized (paper §2 taxonomy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Consistency {
    /// Asynchronous parallel — the paper's choice: no worker ever waits.
    Asp,
    /// Bulk synchronous — barrier every iteration (Hadoop/Spark model).
    Bsp,
    /// Stale synchronous — fastest worker at most `staleness` iterations
    /// ahead of the slowest (Ho et al., 2013).
    Ssp { staleness: usize },
}

impl Consistency {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "asp" => Ok(Consistency::Asp),
            "bsp" => Ok(Consistency::Bsp),
            _ => {
                if let Some(n) = s.strip_prefix("ssp:") {
                    Ok(Consistency::Ssp { staleness: n.parse()? })
                } else {
                    anyhow::bail!("unknown consistency '{s}' (asp|bsp|ssp:N)")
                }
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            Consistency::Asp => "asp".into(),
            Consistency::Bsp => "bsp".into(),
            Consistency::Ssp { staleness } => format!("ssp:{staleness}"),
        }
    }
}

impl std::str::FromStr for Consistency {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        Consistency::parse(s)
    }
}

impl std::fmt::Display for Consistency {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

/// How workers obtain their pair constraints (the `pairs.mode` knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairMode {
    /// Sample and store the full pair sets up front, clone-and-shuffle
    /// partition them across workers — the historical pipeline,
    /// reproduced bit for bit.
    Materialized,
    /// Generate pairs lazily: pair `t` for worker `w` is a pure
    /// function of `(seed, w, t)`; O(1) pair memory per worker, zero
    /// startup shuffle, partitioning by index arithmetic.
    Streaming,
}

impl PairMode {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "materialized" => Ok(PairMode::Materialized),
            "streaming" => Ok(PairMode::Streaming),
            _ => anyhow::bail!(
                "unknown pairs mode '{s}' (materialized|streaming)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PairMode::Materialized => "materialized",
            PairMode::Streaming => "streaming",
        }
    }
}

impl std::str::FromStr for PairMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        PairMode::parse(s)
    }
}

impl std::fmt::Display for PairMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Pair-pipeline knobs (`cluster.pairs` in the JSON config).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PairsConfig {
    pub mode: PairMode,
    /// Streaming only: fraction of drawn constraints whose
    /// similar/dissimilar role is flipped (label-noise robustness
    /// scenario; 0 = clean labels).
    pub label_noise: f32,
    /// Streaming only: Zipf exponent skewing class frequency in pair
    /// draws (class-imbalance scenario; 0 = uniform classes).
    pub imbalance: f32,
}

impl Default for PairsConfig {
    fn default() -> Self {
        PairsConfig {
            mode: PairMode::Materialized,
            label_noise: 0.0,
            imbalance: 0.0,
        }
    }
}

/// How gradient/parameter slices are encoded on the PS wire
/// (`cluster.compression.mode`). Every mode is self-describing on the
/// wire and decodes to a dense f32 slice on the receiving side; workers
/// keep per-shard error-feedback residuals, so compression delays update
/// mass but never loses it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompressionMode {
    /// Dense f32 slices — the historical protocol, bit for bit.
    None,
    /// Stochastic int8 quantization with a per-slice scale (gradients
    /// and parameter broadcasts).
    Int8,
    /// Top-k magnitude sparsification of gradient slices, f32 values,
    /// delta-varint coordinates (parameters stay dense: they are
    /// absolute state, not deltas, so there is no residual to absorb
    /// the dropped mass).
    TopK,
    /// Top-k sparsification + int8 values on gradients, int8 parameter
    /// broadcasts — the full compression stack.
    TopKInt8,
}

impl CompressionMode {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "none" => Ok(CompressionMode::None),
            "int8" => Ok(CompressionMode::Int8),
            "topk" => Ok(CompressionMode::TopK),
            "topk_int8" => Ok(CompressionMode::TopKInt8),
            _ => anyhow::bail!(
                "unknown compression mode '{s}' \
                 (none|int8|topk|topk_int8)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CompressionMode::None => "none",
            CompressionMode::Int8 => "int8",
            CompressionMode::TopK => "topk",
            CompressionMode::TopKInt8 => "topk_int8",
        }
    }

    /// Whether gradient slices are top-k sparsified under this mode.
    pub fn sparsifies(&self) -> bool {
        matches!(self, CompressionMode::TopK | CompressionMode::TopKInt8)
    }

    /// All modes, for sweeps and parse tests.
    pub fn all() -> [CompressionMode; 4] {
        [CompressionMode::None, CompressionMode::Int8,
         CompressionMode::TopK, CompressionMode::TopKInt8]
    }

    /// Whether values travel as int8 under this mode.
    pub fn quantizes(&self) -> bool {
        matches!(self, CompressionMode::Int8 | CompressionMode::TopKInt8)
    }
}

impl std::str::FromStr for CompressionMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        CompressionMode::parse(s)
    }
}

impl std::fmt::Display for CompressionMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// PS wire-compression knobs (`cluster.compression` in the JSON config).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompressionConfig {
    pub mode: CompressionMode,
    /// Top-k modes only: fraction of slice coordinates kept per push
    /// (`ceil(keep · len)`, clamped to at least one). Ignored by
    /// `none`/`int8`.
    pub keep: f32,
}

impl Default for CompressionConfig {
    fn default() -> Self {
        CompressionConfig { mode: CompressionMode::None, keep: 0.25 }
    }
}

/// Socket-transport knobs for process-mode runs (`dmlps cluster` /
/// `dmlps node`).
///
/// Deliberately **not** part of [`ExperimentConfig`] or its JSON: the
/// transport never changes the learning problem, and the config digest
/// embedded in model artifacts must stay identical whether the same
/// experiment runs over in-memory channels or sockets. These knobs
/// travel as CLI flags instead.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetConfig {
    /// Address the server binds and workers dial: `host:port` for TCP
    /// or `unix:/path` for a Unix domain socket.
    pub addr: String,
    /// Connection attempts a worker makes before giving up (the server
    /// may bind after workers start; see `RetryPolicy` in `ps::net`).
    pub connect_attempts: u32,
    /// First retry backoff in milliseconds (doubles per attempt).
    pub backoff_ms: u64,
    /// Ceiling on the doubled backoff, in milliseconds.
    pub max_backoff_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "127.0.0.1:7600".into(),
            connect_attempts: 30,
            backoff_ms: 20,
            max_backoff_ms: 1000,
        }
    }
}

/// Checkpoint cadence for process-mode runs (`dmlps cluster` /
/// `dmlps node`): how often each server shard snapshots its parameter
/// slice, clocks, and telemetry into the `DMLPSCKPT` run directory.
///
/// Like [`NetConfig`], deliberately **not** part of
/// [`ExperimentConfig`] or its JSON: checkpointing never changes the
/// learning problem, and the config digest embedded in model artifacts
/// must stay identical whether a run checkpoints or not (and across a
/// kill/resume). These knobs travel as CLI flags instead.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CheckpointConfig {
    /// Snapshot every N applied slice updates per shard (0 = no
    /// step-based cadence; the all-zero default disables checkpointing).
    pub every_steps: u64,
    /// Snapshot when this many seconds elapsed since a shard's last
    /// snapshot (0 = no time-based cadence).
    pub every_secs: f64,
}

impl CheckpointConfig {
    /// Whether either cadence is active.
    pub fn enabled(&self) -> bool {
        self.every_steps > 0 || self.every_secs > 0.0
    }
}

/// Synthetic dataset family (see `data` module for generators).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureKind {
    /// Dense Gaussian class clusters — stands in for MNIST raw pixels.
    Gaussian,
    /// Sparse non-negative LLC-like codes — stands in for the paper's
    /// ImageNet Locality-constrained Linear Coding features.
    Llc,
}

impl FeatureKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "gaussian" => Ok(FeatureKind::Gaussian),
            "llc" => Ok(FeatureKind::Llc),
            _ => anyhow::bail!("unknown feature kind '{s}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FeatureKind::Gaussian => "gaussian",
            FeatureKind::Llc => "llc",
        }
    }
}

impl std::str::FromStr for FeatureKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        FeatureKind::parse(s)
    }
}

impl std::fmt::Display for FeatureKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct DatasetConfig {
    pub name: String,
    pub kind: FeatureKind,
    pub n_train: usize,
    pub n_test: usize,
    pub dim: usize,
    pub n_classes: usize,
    /// Class-separation / within-class-spread ratio (higher = easier).
    pub separation: f32,
    pub n_similar: usize,
    pub n_dissimilar: usize,
    pub n_test_pairs: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Rows of L (M = LᵀL is dim×dim, L is k×dim).
    pub k: usize,
    pub init_scale: f32,
}

#[derive(Clone, Debug, PartialEq)]
pub struct OptimConfig {
    pub lr: f32,
    pub lambda: f32,
    /// Similar / dissimilar halves of each minibatch (paper: 500+500 for
    /// MNIST & ImageNet-1M, 50+50 for ImageNet-63K).
    pub batch_sim: usize,
    pub batch_dis: usize,
    pub steps: usize,
    /// Learning-rate decay: lr_t = lr / (1 + decay * t).
    pub lr_decay: f32,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Worker count for the real threaded parameter server.
    pub workers: usize,
    pub consistency: Consistency,
    /// Server-side gradient batch: how many worker updates each shard's
    /// update thread folds in per dequeue round.
    pub server_batch: usize,
    /// Parameter-server shards: L's rows are partitioned into this many
    /// independent server shards, each with its own update thread and
    /// queues; messages carry per-shard row slices. `1` = the paper's
    /// single central server (clamped to the row count `k` at run time).
    pub server_shards: usize,
    /// Compute threads per worker engine — the paper's "C cores per
    /// machine" knob. `0` = use all available cores (machine default).
    pub threads_per_worker: usize,
    /// Pair-pipeline mode and scenario knobs (absent in legacy configs
    /// → materialized, clean, balanced).
    pub pairs: PairsConfig,
    /// Wire-compression mode and knobs for gradient/parameter slices
    /// (absent in legacy configs → `none`, the dense f32 protocol).
    pub compression: CompressionConfig,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub dataset: DatasetConfig,
    pub model: ModelConfig,
    pub optim: OptimConfig,
    pub cluster: ClusterConfig,
    pub seed: u64,
    /// Which AOT artifact variant backs the XLA engine for this config
    /// (None = native engine only).
    pub artifact_variant: Option<String>,
}

/// Built-in presets, mirrored on the Python side in
/// `python/compile/model.py::VARIANTS` (shapes must match the artifacts).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preset {
    /// Tiny shapes for tests and the quickstart example.
    Tiny,
    /// Paper-true MNIST: d=780, k=600, minibatch 500+500 (Table 1 row 1).
    Mnist,
    /// ImageNet-63K scaled for the 1-core testbed (paper: d=21504,
    /// k=10000, b=50+50 → here d=2048, k=512, b=50+50).
    Imnet60kScaled,
    /// ImageNet-1M scaled (paper: d=21504, k=1000, b=500+500 →
    /// here d=2048, k=256, b=500+500).
    Imnet1mScaled,
}

impl Preset {
    pub fn parse(s: &str) -> anyhow::Result<Preset> {
        match s {
            "tiny" | "test_small" => Ok(Preset::Tiny),
            "mnist" => Ok(Preset::Mnist),
            "imnet60k" | "imnet60k_scaled" => Ok(Preset::Imnet60kScaled),
            "imnet1m" | "imnet1m_scaled" => Ok(Preset::Imnet1mScaled),
            _ => anyhow::bail!(
                "unknown preset '{s}' (tiny|mnist|imnet60k|imnet1m)"
            ),
        }
    }

    pub fn all() -> [Preset; 4] {
        [Preset::Tiny, Preset::Mnist, Preset::Imnet60kScaled,
         Preset::Imnet1mScaled]
    }

    pub fn config(self) -> ExperimentConfig {
        match self {
            Preset::Tiny => ExperimentConfig {
                dataset: DatasetConfig {
                    name: "tiny".into(),
                    kind: FeatureKind::Gaussian,
                    n_train: 400,
                    n_test: 200,
                    dim: 16,
                    n_classes: 4,
                    separation: 3.0,
                    n_similar: 800,
                    n_dissimilar: 800,
                    n_test_pairs: 400,
                },
                model: ModelConfig { k: 8, init_scale: 0.3 },
                optim: OptimConfig {
                    lr: 0.1,
                    lambda: 1.0,
                    batch_sim: 4,
                    batch_dis: 4,
                    steps: 200,
                    lr_decay: 0.002,
                },
                cluster: ClusterConfig {
                    workers: 2,
                    consistency: Consistency::Asp,
                    server_batch: 4,
                    server_shards: 1,
                    threads_per_worker: 0,
                    pairs: PairsConfig::default(),
                    compression: CompressionConfig::default(),
                },
                seed: 42,
                artifact_variant: Some("test_small".into()),
            },
            Preset::Mnist => ExperimentConfig {
                dataset: DatasetConfig {
                    name: "mnist".into(),
                    kind: FeatureKind::Gaussian,
                    n_train: 60_000,
                    n_test: 10_000,
                    dim: 780,
                    n_classes: 10,
                    separation: 24.0,
                    n_similar: 100_000,
                    n_dissimilar: 100_000,
                    n_test_pairs: 10_000,
                },
                model: ModelConfig { k: 600, init_scale: 0.5 },
                optim: OptimConfig {
                    lr: 0.1,
                    lambda: 1.0,
                    batch_sim: 500,
                    batch_dis: 500,
                    steps: 300,
                    lr_decay: 0.001,
                },
                cluster: ClusterConfig {
                    workers: 2,
                    consistency: Consistency::Asp,
                    server_batch: 4,
                    server_shards: 1,
                    threads_per_worker: 0,
                    pairs: PairsConfig::default(),
                    compression: CompressionConfig::default(),
                },
                seed: 42,
                artifact_variant: Some("mnist".into()),
            },
            Preset::Imnet60kScaled => ExperimentConfig {
                dataset: DatasetConfig {
                    name: "imnet60k_scaled".into(),
                    kind: FeatureKind::Llc,
                    n_train: 6_300,
                    n_test: 1_000,
                    dim: 2048,
                    n_classes: 100,
                    separation: 1.0,
                    n_similar: 10_000,
                    n_dissimilar: 10_000,
                    n_test_pairs: 2_000,
                },
                model: ModelConfig { k: 512, init_scale: 0.1 },
                optim: OptimConfig {
                    lr: 0.1,
                    lambda: 1.0,
                    batch_sim: 50,
                    batch_dis: 50,
                    steps: 200,
                    lr_decay: 0.001,
                },
                cluster: ClusterConfig {
                    workers: 2,
                    consistency: Consistency::Asp,
                    server_batch: 4,
                    server_shards: 1,
                    threads_per_worker: 0,
                    pairs: PairsConfig::default(),
                    compression: CompressionConfig::default(),
                },
                seed: 42,
                artifact_variant: Some("imnet60k_scaled".into()),
            },
            Preset::Imnet1mScaled => ExperimentConfig {
                dataset: DatasetConfig {
                    name: "imnet1m_scaled".into(),
                    kind: FeatureKind::Llc,
                    n_train: 20_000,
                    n_test: 2_000,
                    dim: 2048,
                    n_classes: 100,
                    separation: 1.0,
                    n_similar: 40_000,
                    n_dissimilar: 40_000,
                    n_test_pairs: 4_000,
                },
                model: ModelConfig { k: 256, init_scale: 0.1 },
                optim: OptimConfig {
                    lr: 0.1,
                    lambda: 1.0,
                    batch_sim: 500,
                    batch_dis: 500,
                    steps: 200,
                    lr_decay: 0.001,
                },
                cluster: ClusterConfig {
                    workers: 2,
                    consistency: Consistency::Asp,
                    server_batch: 4,
                    server_shards: 1,
                    threads_per_worker: 0,
                    pairs: PairsConfig::default(),
                    compression: CompressionConfig::default(),
                },
                seed: 42,
                artifact_variant: Some("imnet1m_scaled".into()),
            },
        }
    }
}

/// Paper-true shapes for the three Table-1 datasets — used by the cluster
/// simulator's cost model (it never materializes the parameters, so the
/// full 220M-parameter ImageNet-63K config is representable).
#[derive(Clone, Copy, Debug)]
pub struct PaperShape {
    pub name: &'static str,
    pub d: usize,
    pub k: usize,
    pub batch: usize,
    pub n_similar: usize,
    pub n_dissimilar: usize,
    pub n_samples: usize,
}

pub const PAPER_SHAPES: [PaperShape; 3] = [
    PaperShape { name: "MNIST", d: 780, k: 600, batch: 1000,
                 n_similar: 100_000, n_dissimilar: 100_000,
                 n_samples: 60_000 },
    PaperShape { name: "ImNet-60K", d: 21504, k: 10_000, batch: 100,
                 n_similar: 100_000, n_dissimilar: 100_000,
                 n_samples: 63_000 },
    PaperShape { name: "ImNet-1M", d: 21504, k: 1000, batch: 1000,
                 n_similar: 100_000_000, n_dissimilar: 100_000_000,
                 n_samples: 1_000_000 },
];

impl PaperShape {
    /// Number of parameters in L (paper Table 1 "# parameters").
    pub fn n_params(&self) -> usize {
        self.d * self.k
    }

    /// FLOPs of one minibatch gradient: 4 matmuls of b×k×d MACs each.
    pub fn step_flops(&self) -> f64 {
        4.0 * 2.0 * self.batch as f64 / 2.0 * self.k as f64 * self.d as f64
    }
}

// ---------------------------------------------------------------------
// JSON round-trip
// ---------------------------------------------------------------------

impl ExperimentConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::obj(vec![
                ("name", Json::Str(self.dataset.name.clone())),
                ("kind", Json::Str(self.dataset.kind.name().into())),
                ("n_train", Json::Num(self.dataset.n_train as f64)),
                ("n_test", Json::Num(self.dataset.n_test as f64)),
                ("dim", Json::Num(self.dataset.dim as f64)),
                ("n_classes", Json::Num(self.dataset.n_classes as f64)),
                ("separation", Json::Num(self.dataset.separation as f64)),
                ("n_similar", Json::Num(self.dataset.n_similar as f64)),
                ("n_dissimilar",
                 Json::Num(self.dataset.n_dissimilar as f64)),
                ("n_test_pairs",
                 Json::Num(self.dataset.n_test_pairs as f64)),
            ])),
            ("model", Json::obj(vec![
                ("k", Json::Num(self.model.k as f64)),
                ("init_scale", Json::Num(self.model.init_scale as f64)),
            ])),
            ("optim", Json::obj(vec![
                ("lr", Json::Num(self.optim.lr as f64)),
                ("lambda", Json::Num(self.optim.lambda as f64)),
                ("batch_sim", Json::Num(self.optim.batch_sim as f64)),
                ("batch_dis", Json::Num(self.optim.batch_dis as f64)),
                ("steps", Json::Num(self.optim.steps as f64)),
                ("lr_decay", Json::Num(self.optim.lr_decay as f64)),
            ])),
            ("cluster", Json::obj(vec![
                ("workers", Json::Num(self.cluster.workers as f64)),
                ("consistency",
                 Json::Str(self.cluster.consistency.name())),
                ("server_batch",
                 Json::Num(self.cluster.server_batch as f64)),
                ("server_shards",
                 Json::Num(self.cluster.server_shards as f64)),
                ("threads_per_worker",
                 Json::Num(self.cluster.threads_per_worker as f64)),
                ("pairs", Json::obj(vec![
                    ("mode",
                     Json::Str(self.cluster.pairs.mode.name().into())),
                    ("label_noise",
                     Json::Num(self.cluster.pairs.label_noise as f64)),
                    ("imbalance",
                     Json::Num(self.cluster.pairs.imbalance as f64)),
                ])),
                ("compression", Json::obj(vec![
                    ("mode",
                     Json::Str(
                         self.cluster.compression.mode.name().into(),
                     )),
                    ("keep",
                     Json::Num(self.cluster.compression.keep as f64)),
                ])),
            ])),
            ("seed", Json::Num(self.seed as f64)),
            ("artifact_variant", match &self.artifact_variant {
                Some(v) => Json::Str(v.clone()),
                None => Json::Null,
            }),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        // A typo'd block name ("clustre") used to be silently ignored,
        // leaving every knob under it at its default — reject instead,
        // pointing at the nearest known key.
        const KNOWN: [&str; 6] = [
            "dataset", "model", "optim", "cluster", "seed",
            "artifact_variant",
        ];
        if let Some(map) = j.as_obj() {
            reject_unknown_keys(map, &KNOWN, "top-level config")?;
        }
        fn us(j: &Json, k: &str) -> anyhow::Result<usize> {
            j.get(k)
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("missing/invalid '{k}'"))
        }
        fn f(j: &Json, k: &str) -> anyhow::Result<f32> {
            Ok(j.get(k)
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("missing/invalid '{k}'"))?
                as f32)
        }
        let d = j.get("dataset");
        let m = j.get("model");
        let o = j.get("optim");
        let c = j.get("cluster");
        let cfg = ExperimentConfig {
            dataset: DatasetConfig {
                name: d.get("name").as_str().unwrap_or("custom").into(),
                kind: FeatureKind::parse(
                    d.get("kind").as_str().unwrap_or("gaussian"),
                )?,
                n_train: us(d, "n_train")?,
                n_test: us(d, "n_test")?,
                dim: us(d, "dim")?,
                n_classes: us(d, "n_classes")?,
                separation: f(d, "separation")?,
                n_similar: us(d, "n_similar")?,
                n_dissimilar: us(d, "n_dissimilar")?,
                n_test_pairs: us(d, "n_test_pairs")?,
            },
            model: ModelConfig {
                k: us(m, "k")?,
                init_scale: f(m, "init_scale")?,
            },
            optim: OptimConfig {
                lr: f(o, "lr")?,
                lambda: f(o, "lambda")?,
                batch_sim: us(o, "batch_sim")?,
                batch_dis: us(o, "batch_dis")?,
                steps: us(o, "steps")?,
                lr_decay: f(o, "lr_decay")?,
            },
            cluster: ClusterConfig {
                workers: us(c, "workers")?,
                consistency: Consistency::parse(
                    c.get("consistency").as_str().unwrap_or("asp"),
                )?,
                server_batch: us(c, "server_batch")?,
                // absent in configs predating the sharding knob → the
                // paper's single central server
                server_shards: c
                    .get("server_shards")
                    .as_usize()
                    .unwrap_or(1)
                    .max(1),
                // absent in configs predating the threads knob → auto
                threads_per_worker: c
                    .get("threads_per_worker")
                    .as_usize()
                    .unwrap_or(0),
                // absent in configs predating the streaming pipeline →
                // materialized, clean labels, balanced classes
                pairs: PairsConfig {
                    mode: PairMode::parse(
                        c.get("pairs")
                            .get("mode")
                            .as_str()
                            .unwrap_or("materialized"),
                    )?,
                    label_noise: c
                        .get("pairs")
                        .get("label_noise")
                        .as_f64()
                        .unwrap_or(0.0) as f32,
                    imbalance: c
                        .get("pairs")
                        .get("imbalance")
                        .as_f64()
                        .unwrap_or(0.0) as f32,
                },
                // absent in configs predating wire compression → the
                // dense f32 protocol (and the default keep fraction)
                compression: CompressionConfig {
                    mode: CompressionMode::parse(
                        c.get("compression")
                            .get("mode")
                            .as_str()
                            .unwrap_or("none"),
                    )?,
                    keep: c
                        .get("compression")
                        .get("keep")
                        .as_f64()
                        .unwrap_or(CompressionConfig::default().keep as f64)
                        as f32,
                },
            },
            seed: j.get("seed").as_f64().unwrap_or(42.0) as u64,
            artifact_variant: j
                .get("artifact_variant")
                .as_str()
                .map(|s| s.to_string()),
        };
        // same bounds the CLI enforces; NaN fails the range check
        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.cluster.pairs.label_noise),
            "cluster.pairs.label_noise must be in [0, 1], got {}",
            cfg.cluster.pairs.label_noise
        );
        anyhow::ensure!(
            cfg.cluster.pairs.imbalance >= 0.0
                && cfg.cluster.pairs.imbalance.is_finite(),
            "cluster.pairs.imbalance must be finite and >= 0, got {}",
            cfg.cluster.pairs.imbalance
        );
        anyhow::ensure!(
            cfg.cluster.compression.keep > 0.0
                && cfg.cluster.compression.keep <= 1.0,
            "cluster.compression.keep must be in (0, 1], got {}",
            cfg.cluster.compression.keep
        );
        Ok(cfg)
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        // crash-atomic like every other persisted artifact: a manager
        // killed mid-save must not leave a torn config.json for a
        // resumed node to half-parse
        crate::linalg::io::atomic_write(path, |w| {
            use std::io::Write;
            w.write_all(self.to_json().to_string_pretty().as_bytes())?;
            Ok(())
        })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_json(&Json::parse_file(path)?)
    }
}

/// Reject any key of `map` not in `known`, suggesting the nearest known
/// key by edit distance. `ctx` names the object being validated in the
/// error ("top-level config", "lab experiment", ...). Shared by
/// [`ExperimentConfig::from_json`] and the lab-harness config loader so
/// every JSON surface rejects typos the same way.
pub fn reject_unknown_keys(
    map: &std::collections::BTreeMap<String, Json>,
    known: &[&str],
    ctx: &str,
) -> anyhow::Result<()> {
    for key in map.keys() {
        if !known.contains(&key.as_str()) {
            let nearest = known
                .iter()
                .min_by_key(|k| edit_distance(k, key))
                .expect("known key list must be non-empty");
            anyhow::bail!(
                "unknown {ctx} key '{key}' (did you mean '{nearest}'?)"
            );
        }
    }
    Ok(())
}

/// Levenshtein edit distance — powers the "did you mean" suggestion in
/// [`reject_unknown_keys`].
fn edit_distance(a: &str, b: &str) -> usize {
    let (a, b): (Vec<char>, Vec<char>) =
        (a.chars().collect(), b.chars().collect());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse_and_build() {
        for p in Preset::all() {
            let cfg = p.config();
            assert!(cfg.model.k <= cfg.dataset.dim,
                    "k must be <= d (Weinberger factorization)");
            assert!(cfg.optim.batch_sim > 0 && cfg.optim.batch_dis > 0);
        }
    }

    #[test]
    fn mnist_preset_is_paper_true() {
        let cfg = Preset::Mnist.config();
        assert_eq!(cfg.dataset.dim, 780);
        assert_eq!(cfg.model.k, 600);
        assert_eq!(cfg.optim.batch_sim + cfg.optim.batch_dis, 1000);
        assert_eq!(cfg.dataset.n_similar, 100_000);
        // Table 1: 0.47M parameters
        assert_eq!(cfg.model.k * cfg.dataset.dim, 468_000);
    }

    #[test]
    fn json_roundtrip_all_presets() {
        for p in Preset::all() {
            let cfg = p.config();
            let j = cfg.to_json();
            let cfg2 = ExperimentConfig::from_json(&j).unwrap();
            assert_eq!(cfg, cfg2, "{p:?}");
        }
    }

    #[test]
    fn legacy_json_without_server_shards_defaults_to_one() {
        let mut j = Preset::Tiny.config().to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(c)) = m.get_mut("cluster") {
                c.remove("server_shards");
            }
        }
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.cluster.server_shards, 1);
    }

    #[test]
    fn legacy_json_without_pairs_block_defaults_to_materialized() {
        let mut j = Preset::Tiny.config().to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(c)) = m.get_mut("cluster") {
                c.remove("pairs");
            }
        }
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.cluster.pairs, PairsConfig::default());
    }

    #[test]
    fn pairs_block_roundtrips() {
        let mut cfg = Preset::Tiny.config();
        cfg.cluster.pairs = PairsConfig {
            mode: PairMode::Streaming,
            label_noise: 0.1,
            imbalance: 1.5,
        };
        let cfg2 = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, cfg2);
    }

    #[test]
    fn invalid_pairs_knobs_rejected_on_load() {
        let mut cfg = Preset::Tiny.config();
        cfg.cluster.pairs.label_noise = 7.0;
        let err =
            ExperimentConfig::from_json(&cfg.to_json()).unwrap_err();
        assert!(err.to_string().contains("label_noise"), "{err}");
        let mut cfg = Preset::Tiny.config();
        cfg.cluster.pairs.imbalance = -1.0;
        let err =
            ExperimentConfig::from_json(&cfg.to_json()).unwrap_err();
        assert!(err.to_string().contains("imbalance"), "{err}");
    }

    #[test]
    fn legacy_json_without_compression_block_defaults_to_none() {
        let mut j = Preset::Tiny.config().to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(c)) = m.get_mut("cluster") {
                c.remove("compression");
            }
        }
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.cluster.compression, CompressionConfig::default());
        assert_eq!(cfg.cluster.compression.mode, CompressionMode::None);
    }

    #[test]
    fn compression_block_roundtrips() {
        for mode in [CompressionMode::None, CompressionMode::Int8,
                     CompressionMode::TopK, CompressionMode::TopKInt8] {
            let mut cfg = Preset::Tiny.config();
            cfg.cluster.compression =
                CompressionConfig { mode, keep: 0.125 };
            let cfg2 =
                ExperimentConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(cfg, cfg2, "{mode:?}");
        }
    }

    #[test]
    fn invalid_compression_keep_rejected_on_load() {
        for keep in [0.0f32, -0.5, 1.5, f32::NAN] {
            let mut cfg = Preset::Tiny.config();
            cfg.cluster.compression.keep = keep;
            let err =
                ExperimentConfig::from_json(&cfg.to_json()).unwrap_err();
            assert!(err.to_string().contains("keep"), "{keep}: {err}");
        }
    }

    #[test]
    fn compression_mode_parse_roundtrip() {
        for m in [CompressionMode::None, CompressionMode::Int8,
                  CompressionMode::TopK, CompressionMode::TopKInt8] {
            assert_eq!(CompressionMode::parse(m.name()).unwrap(), m);
        }
        assert!(CompressionMode::parse("gzip").is_err());
    }

    #[test]
    fn pair_mode_parse_roundtrip() {
        for m in [PairMode::Materialized, PairMode::Streaming] {
            assert_eq!(PairMode::parse(m.name()).unwrap(), m);
        }
        assert!(PairMode::parse("implicit").is_err());
    }

    #[test]
    fn consistency_parse_roundtrip() {
        for c in [Consistency::Asp, Consistency::Bsp,
                  Consistency::Ssp { staleness: 3 }] {
            assert_eq!(Consistency::parse(&c.name()).unwrap(), c);
        }
        assert!(Consistency::parse("nope").is_err());
    }

    #[test]
    fn preset_parse_aliases() {
        assert_eq!(Preset::parse("mnist").unwrap(), Preset::Mnist);
        assert_eq!(Preset::parse("imnet60k").unwrap(),
                   Preset::Imnet60kScaled);
        assert!(Preset::parse("bogus").is_err());
    }

    #[test]
    fn paper_shapes_match_table1() {
        // Table 1 "# parameters": 0.47M, 220M, 21.5M
        assert_eq!(PAPER_SHAPES[0].n_params(), 468_000);
        assert_eq!(PAPER_SHAPES[1].n_params(), 215_040_000);
        assert_eq!(PAPER_SHAPES[2].n_params(), 21_504_000);
    }

    #[test]
    fn typod_top_level_key_rejected_with_suggestion() {
        // regression: a "clustre" block used to be silently ignored,
        // running the experiment with every cluster knob defaulted
        let mut j = Preset::Tiny.config().to_json();
        if let Json::Obj(m) = &mut j {
            let cluster = m.remove("cluster").unwrap();
            m.insert("clustre".into(), cluster);
        }
        let err = ExperimentConfig::from_json(&j).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("clustre"), "{msg}");
        assert!(msg.contains("did you mean 'cluster'"), "{msg}");
    }

    #[test]
    fn unknown_top_level_key_rejected() {
        let mut j = Preset::Tiny.config().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("bogus_block".into(), Json::Num(1.0));
        }
        let err = ExperimentConfig::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("bogus_block"), "{err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("cluster", "clustre"), 2);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("seed", "seed"), 0);
        assert_eq!(edit_distance("optim", "optin"), 1);
    }

    #[test]
    fn fromstr_display_roundtrip_all_enums() {
        // the FromStr/Display pairs are backed by parse()/name(); the
        // CLI and config loader route through them
        for c in [Consistency::Asp, Consistency::Bsp,
                  Consistency::Ssp { staleness: 2 }] {
            assert_eq!(c.to_string().parse::<Consistency>().unwrap(), c);
        }
        for m in CompressionMode::all() {
            assert_eq!(
                m.to_string().parse::<CompressionMode>().unwrap(), m);
        }
        for m in [PairMode::Materialized, PairMode::Streaming] {
            assert_eq!(m.to_string().parse::<PairMode>().unwrap(), m);
        }
        for k in [FeatureKind::Gaussian, FeatureKind::Llc] {
            assert_eq!(k.to_string().parse::<FeatureKind>().unwrap(), k);
        }
        assert!("nope".parse::<Consistency>().is_err());
        assert!("gzip".parse::<CompressionMode>().is_err());
    }

    #[test]
    fn net_config_stays_out_of_experiment_json() {
        // NetConfig is CLI-flag plumbing; if it ever leaks into the
        // experiment JSON the config digests pinned by api_session's
        // goldens would shift between channel and socket runs.
        let j = Preset::Tiny.config().to_json();
        let map = j.as_obj().unwrap();
        assert!(!map.contains_key("net"));
        assert!(!map.contains_key("transport"));
        let d = NetConfig::default();
        assert!(d.connect_attempts > 0 && d.backoff_ms > 0);
        assert!(d.max_backoff_ms >= d.backoff_ms);
    }

    #[test]
    fn checkpoint_config_stays_out_of_experiment_json() {
        // same contract as NetConfig: checkpoint cadence is CLI-flag
        // plumbing. If it leaked into the experiment JSON, the config
        // digest a resumed run embeds in its model artifact would
        // differ from the original run's — breaking provenance across
        // a kill/restart.
        let j = Preset::Tiny.config().to_json();
        let map = j.as_obj().unwrap();
        assert!(!map.contains_key("checkpoint"));
        assert!(!map.contains_key("ckpt"));
        let d = CheckpointConfig::default();
        assert!(!d.enabled(), "checkpointing must default off");
        assert!(CheckpointConfig { every_steps: 5, every_secs: 0.0 }
            .enabled());
        assert!(CheckpointConfig { every_steps: 0, every_secs: 1.5 }
            .enabled());
    }

    #[test]
    fn file_roundtrip() {
        let cfg = Preset::Tiny.config();
        let dir = std::env::temp_dir().join("dmlps_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        cfg.save(&path).unwrap();
        let cfg2 = ExperimentConfig::load(&path).unwrap();
        assert_eq!(cfg, cfg2);
    }
}
