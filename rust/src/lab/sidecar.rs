//! Resource-telemetry sidecar: a thread that samples `/proc` on a
//! fixed cadence while an experiment runs and appends each sample to an
//! NDJSON stream. The merge step later windows these samples between
//! each trial's start/end timestamps to attribute peak RSS, CPU
//! seconds, thread count, and IO to individual cells.
//!
//! Every probe is best-effort `Option`: on non-Linux hosts (or a
//! hardened `/proc`) samples simply carry nulls and the harness still
//! runs — telemetry must never be the reason a benchmark fails.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::ndjson;
use crate::util::json::Json;

/// One `/proc` snapshot, stamped relative to the run origin so trial
/// windows and samples share a clock.
#[derive(Clone, Debug, Default)]
pub struct ResourceSample {
    /// Seconds since the run origin.
    pub t_s: f64,
    /// Current resident set size (`VmRSS`), bytes.
    pub rss_bytes: Option<f64>,
    /// Process-lifetime RSS high-water mark (`VmHWM`), bytes. Reported
    /// for context only — per-cell peaks come from windowed `rss_bytes`
    /// samples, since the lifetime peak would cross-contaminate cells.
    pub hwm_bytes: Option<f64>,
    /// Thread count.
    pub threads: Option<f64>,
    /// Cumulative user+system CPU seconds (utime+stime).
    pub cpu_s: Option<f64>,
    /// Cumulative bytes fetched from the storage layer.
    pub io_read_bytes: Option<f64>,
    /// Cumulative bytes sent to the storage layer.
    pub io_write_bytes: Option<f64>,
}

impl ResourceSample {
    /// Probe `/proc/self` now, stamping against `origin`.
    pub fn now(origin: Instant) -> ResourceSample {
        let status = proc_status();
        let io = proc_io();
        ResourceSample {
            t_s: origin.elapsed().as_secs_f64(),
            rss_bytes: status.0,
            hwm_bytes: status.1,
            threads: status.2,
            cpu_s: proc_cpu_s(),
            io_read_bytes: io.0,
            io_write_bytes: io.1,
        }
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        Json::obj(vec![
            ("t_s", Json::Num(self.t_s)),
            ("rss_bytes", opt(self.rss_bytes)),
            ("hwm_bytes", opt(self.hwm_bytes)),
            ("threads", opt(self.threads)),
            ("cpu_s", opt(self.cpu_s)),
            ("io_read_bytes", opt(self.io_read_bytes)),
            ("io_write_bytes", opt(self.io_write_bytes)),
        ])
    }

    pub fn from_json(j: &Json) -> ResourceSample {
        ResourceSample {
            t_s: j.get("t_s").as_f64().unwrap_or(0.0),
            rss_bytes: j.get("rss_bytes").as_f64(),
            hwm_bytes: j.get("hwm_bytes").as_f64(),
            threads: j.get("threads").as_f64(),
            cpu_s: j.get("cpu_s").as_f64(),
            io_read_bytes: j.get("io_read_bytes").as_f64(),
            io_write_bytes: j.get("io_write_bytes").as_f64(),
        }
    }
}

/// `VmRSS` / `VmHWM` / `Threads` from `/proc/self/status`.
/// Sizes arrive as "<n> kB".
fn proc_status() -> (Option<f64>, Option<f64>, Option<f64>) {
    let Ok(text) = std::fs::read_to_string("/proc/self/status") else {
        return (None, None, None);
    };
    let mut rss = None;
    let mut hwm = None;
    let mut threads = None;
    for line in text.lines() {
        let Some((key, rest)) = line.split_once(':') else { continue };
        let rest = rest.trim();
        match key {
            "VmRSS" | "VmHWM" => {
                let kb = rest
                    .strip_suffix("kB")
                    .unwrap_or(rest)
                    .trim()
                    .parse::<f64>()
                    .ok();
                let bytes = kb.map(|k| k * 1024.0);
                if key == "VmRSS" {
                    rss = bytes;
                } else {
                    hwm = bytes;
                }
            }
            "Threads" => threads = rest.parse::<f64>().ok(),
            _ => {}
        }
    }
    (rss, hwm, threads)
}

/// utime+stime from `/proc/self/stat` in seconds. The comm field can
/// contain spaces and parens, so split after the *last* ')' — utime
/// and stime are then whitespace fields 11 and 12 of the remainder
/// (stat fields 14 and 15), in USER_HZ (100/s on every mainstream
/// kernel config).
fn proc_cpu_s() -> Option<f64> {
    let text = std::fs::read_to_string("/proc/self/stat").ok()?;
    let (_, rest) = text.rsplit_once(')')?;
    let fields: Vec<&str> = rest.split_whitespace().collect();
    let utime: f64 = fields.get(11)?.parse().ok()?;
    let stime: f64 = fields.get(12)?.parse().ok()?;
    Some((utime + stime) / 100.0)
}

/// `read_bytes` / `write_bytes` from `/proc/self/io` (may be absent or
/// unreadable under some sandboxes).
fn proc_io() -> (Option<f64>, Option<f64>) {
    let Ok(text) = std::fs::read_to_string("/proc/self/io") else {
        return (None, None);
    };
    let mut read = None;
    let mut write = None;
    for line in text.lines() {
        let Some((key, val)) = line.split_once(':') else { continue };
        match key {
            "read_bytes" => read = val.trim().parse::<f64>().ok(),
            "write_bytes" => write = val.trim().parse::<f64>().ok(),
            _ => {}
        }
    }
    (read, write)
}

/// The sampling thread. [`Sidecar::spawn`] starts it; [`Sidecar::stop`]
/// takes one final sample, then joins. Append failures are swallowed —
/// a full disk degrades telemetry, not the run.
pub struct Sidecar {
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<()>,
}

impl Sidecar {
    pub fn spawn(
        path: PathBuf,
        every: Duration,
        origin: Instant,
    ) -> Sidecar {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("lab-sidecar".into())
            .spawn(move || {
                loop {
                    let sample = ResourceSample::now(origin);
                    let _ = ndjson::append(&path, &sample.to_json());
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    std::thread::sleep(every);
                }
            })
            .expect("spawn sidecar thread");
        Sidecar { stop, handle }
    }

    /// Signal the thread, wait for its final sample, join.
    pub fn stop(self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.handle.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_json_roundtrips_including_nulls() {
        let s = ResourceSample {
            t_s: 1.5,
            rss_bytes: Some(4096.0),
            hwm_bytes: None,
            threads: Some(3.0),
            cpu_s: Some(0.25),
            io_read_bytes: None,
            io_write_bytes: Some(0.0),
        };
        let back = ResourceSample::from_json(&s.to_json());
        assert_eq!(back.t_s, 1.5);
        assert_eq!(back.rss_bytes, Some(4096.0));
        assert_eq!(back.hwm_bytes, None);
        assert_eq!(back.threads, Some(3.0));
        assert_eq!(back.cpu_s, Some(0.25));
        assert_eq!(back.io_read_bytes, None);
        assert_eq!(back.io_write_bytes, Some(0.0));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn linux_probe_reports_rss_and_cpu() {
        let s = ResourceSample::now(Instant::now());
        assert!(s.rss_bytes.unwrap_or(0.0) > 0.0, "{s:?}");
        assert!(s.cpu_s.is_some(), "{s:?}");
        assert!(s.threads.unwrap_or(0.0) >= 1.0, "{s:?}");
    }

    #[test]
    fn sidecar_writes_samples_and_stops() {
        let path = std::env::temp_dir().join(format!(
            "dmlps-sidecar-{}.ndjson",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let sc = Sidecar::spawn(
            path.clone(),
            Duration::from_millis(5),
            Instant::now(),
        );
        std::thread::sleep(Duration::from_millis(30));
        sc.stop();
        let recs = ndjson::read_all(&path).unwrap();
        assert!(!recs.is_empty());
        // timestamps are monotone
        let ts: Vec<f64> = recs
            .iter()
            .map(|r| r.get("t_s").as_f64().unwrap())
            .collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "{ts:?}");
        let _ = std::fs::remove_file(&path);
    }
}
