//! Append-only NDJSON streams: one compact JSON record per line.
//!
//! The runner and the sidecar both write through [`append`] — a plain
//! `O_APPEND` write, no locking, because each stream has exactly one
//! writer. Records survive a crashed run up to the last complete line;
//! [`read_all`] treats a missing file as an empty stream so the merge
//! step degrades gracefully.

use std::fs::OpenOptions;
use std::io::Write;
use std::path::Path;

use crate::util::json::Json;

/// Append one record to `path` as a single line.
pub fn append(path: &Path, record: &Json) -> anyhow::Result<()> {
    let mut f = OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
    let mut line = record.to_string_compact();
    line.push('\n');
    f.write_all(line.as_bytes())
        .map_err(|e| anyhow::anyhow!("append {}: {e}", path.display()))?;
    Ok(())
}

/// Read every record of an NDJSON file. A missing file is an empty
/// stream; a malformed line is an error naming the file and line.
pub fn read_all(path: &Path) -> anyhow::Result<Vec<Json>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(Vec::new())
        }
        Err(e) => {
            anyhow::bail!("read {}: {e}", path.display())
        }
    };
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        out.push(Json::parse(line).map_err(|e| {
            anyhow::anyhow!("{}:{}: {e}", path.display(), i + 1)
        })?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "dmlps-ndjson-{}-{name}",
            std::process::id()
        ))
    }

    #[test]
    fn append_then_read_roundtrips_in_order() {
        let path = tmp("roundtrip.ndjson");
        let _ = std::fs::remove_file(&path);
        for i in 0..3 {
            append(
                &path,
                &Json::obj(vec![("i", Json::Num(i as f64))]),
            )
            .unwrap();
        }
        let recs = read_all(&path).unwrap();
        assert_eq!(recs.len(), 3);
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.get("i").as_usize(), Some(i));
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_is_empty_stream() {
        assert!(read_all(&tmp("never-created.ndjson"))
            .unwrap()
            .is_empty());
    }

    #[test]
    fn malformed_line_errors_with_location() {
        let path = tmp("bad.ndjson");
        std::fs::write(&path, "{\"ok\": 1}\nnot json\n").unwrap();
        let msg = read_all(&path).unwrap_err().to_string();
        assert!(msg.contains(":2:"), "{msg}");
        let _ = std::fs::remove_file(&path);
    }
}
