//! Cross-product expansion of an experiment's parameter axes.
//!
//! Axes arrive name-sorted (the config layer reads them out of a
//! `BTreeMap`), and [`expand`] walks them odometer-style with the
//! *last* axis spinning fastest, so cell order is a pure function of
//! the config — two runs of the same matrix line up cell-for-cell,
//! which is what lets `lab diff` match cells across reports.

use crate::util::json::Json;

/// One point of the matrix: its position in expansion order plus the
/// `(axis, value)` assignments, in axis order.
#[derive(Clone, Debug, PartialEq)]
pub struct Cell {
    pub index: usize,
    pub params: Vec<(String, Json)>,
}

/// Expand axes to their full cross-product. An experiment with no axes
/// is a single cell with no parameters (still measured, still
/// aggregated). The number of cells is exactly the product of the
/// axis lengths.
pub fn expand(axes: &[(String, Vec<Json>)]) -> Vec<Cell> {
    let total: usize =
        axes.iter().map(|(_, vals)| vals.len()).product();
    let mut cells = Vec::with_capacity(total);
    for index in 0..total {
        // decode `index` in mixed radix, last axis fastest
        let mut rem = index;
        let mut params = Vec::with_capacity(axes.len());
        for (name, vals) in axes.iter().rev() {
            params.push((name.clone(), vals[rem % vals.len()].clone()));
            rem /= vals.len();
        }
        params.reverse();
        cells.push(Cell { index, params });
    }
    cells
}

/// Canonical `key=value,key=value` label for a cell — the join key
/// between trial records, sidecar windows, and old/new diff reports.
pub fn cell_key(params: &[(String, Json)]) -> String {
    params
        .iter()
        .map(|(k, v)| format!("{k}={}", v.to_string_compact()))
        .collect::<Vec<_>>()
        .join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn axis(name: &str, vals: &[i64]) -> (String, Vec<Json>) {
        (
            name.to_string(),
            vals.iter().map(|&v| Json::Num(v as f64)).collect(),
        )
    }

    #[test]
    fn cell_count_is_product_of_axis_lengths() {
        let axes = vec![
            axis("a", &[1, 2]),
            axis("b", &[10, 20, 30]),
            axis("c", &[0, 1]),
        ];
        let cells = expand(&axes);
        assert_eq!(cells.len(), 2 * 3 * 2);
        // all keys distinct
        let keys: std::collections::BTreeSet<String> =
            cells.iter().map(|c| cell_key(&c.params)).collect();
        assert_eq!(keys.len(), cells.len());
    }

    #[test]
    fn last_axis_spins_fastest_and_order_is_deterministic() {
        let axes = vec![axis("a", &[1, 2]), axis("b", &[10, 20])];
        let keys: Vec<String> = expand(&axes)
            .iter()
            .map(|c| cell_key(&c.params))
            .collect();
        assert_eq!(
            keys,
            vec!["a=1,b=10", "a=1,b=20", "a=2,b=10", "a=2,b=20"]
        );
        assert_eq!(expand(&axes), expand(&axes));
    }

    #[test]
    fn no_axes_is_one_empty_cell() {
        let cells = expand(&[]);
        assert_eq!(cells.len(), 1);
        assert!(cells[0].params.is_empty());
        assert_eq!(cell_key(&cells[0].params), "");
    }

    #[test]
    fn string_values_render_with_quotes() {
        let axes = vec![(
            "consistency".to_string(),
            vec![Json::Str("asp".into())],
        )];
        let cells = expand(&axes);
        assert_eq!(cell_key(&cells[0].params), "consistency=\"asp\"");
    }
}
