//! Merge the runner's trial stream and the sidecar's sample stream
//! into one per-experiment `BENCH_lab_<name>.json`.
//!
//! The merge is *order-insensitive*: records are grouped by cell index
//! and sorted by trial number, so a stream whose lines arrive shuffled
//! (interleaved writers, resumed runs) flattens to the same report.
//! Sidecar samples are attributed to a trial by windowing on the
//! trial's `[start_s, end_s]` stamps — both streams share the run
//! origin clock.

use std::collections::BTreeMap;

use super::config::{LabExperiment, ResultType};
use super::matrix;
use super::sidecar::ResourceSample;
use crate::util::json::Json;
use crate::util::stats;

/// Per-trial resource attribution: endpoint deltas for the cumulative
/// counters (CPU, IO), windowed max for the instantaneous ones (RSS,
/// threads).
struct TrialResource {
    peak_rss_bytes: Option<f64>,
    cpu_s: Option<f64>,
    max_threads: Option<f64>,
    io_read_bytes: Option<f64>,
    io_write_bytes: Option<f64>,
    samples: usize,
}

fn attribute(
    start: &ResourceSample,
    end: &ResourceSample,
    window: &[&ResourceSample],
) -> TrialResource {
    let mut peak_rss = match (start.rss_bytes, end.rss_bytes) {
        (Some(a), Some(b)) => Some(a.max(b)),
        (a, b) => a.or(b),
    };
    let mut max_threads = match (start.threads, end.threads) {
        (Some(a), Some(b)) => Some(a.max(b)),
        (a, b) => a.or(b),
    };
    for s in window {
        if let Some(r) = s.rss_bytes {
            peak_rss = Some(peak_rss.map_or(r, |p| p.max(r)));
        }
        if let Some(t) = s.threads {
            max_threads = Some(max_threads.map_or(t, |p| p.max(t)));
        }
    }
    let delta = |a: Option<f64>, b: Option<f64>| match (a, b) {
        (Some(a), Some(b)) => Some((b - a).max(0.0)),
        _ => None,
    };
    TrialResource {
        peak_rss_bytes: peak_rss,
        cpu_s: delta(start.cpu_s, end.cpu_s),
        max_threads,
        io_read_bytes: delta(start.io_read_bytes, end.io_read_bytes),
        io_write_bytes: delta(start.io_write_bytes, end.io_write_bytes),
        samples: window.len(),
    }
}

fn opt(v: Option<f64>) -> Json {
    v.map(Json::Num).unwrap_or(Json::Null)
}

/// Aggregate one cell's trial resources: peaks stay maxima, the
/// cumulative deltas report both per-trial mean and total.
fn cell_resource(trials: &[TrialResource]) -> Json {
    let maxes = |f: fn(&TrialResource) -> Option<f64>| {
        trials
            .iter()
            .filter_map(f)
            .fold(None::<f64>, |acc, v| Some(acc.map_or(v, |a| a.max(v))))
    };
    let collect = |f: fn(&TrialResource) -> Option<f64>| -> Vec<f64> {
        trials.iter().filter_map(f).collect()
    };
    let cpu = collect(|t| t.cpu_s);
    let sum_of = |f: fn(&TrialResource) -> Option<f64>| {
        let v = collect(f);
        if v.is_empty() {
            None
        } else {
            Some(v.iter().sum::<f64>())
        }
    };
    Json::obj(vec![
        ("peak_rss_bytes", opt(maxes(|t| t.peak_rss_bytes))),
        (
            "cpu_s",
            opt(if cpu.is_empty() {
                None
            } else {
                Some(stats::mean(&cpu))
            }),
        ),
        ("cpu_s_total", opt(sum_of(|t| t.cpu_s))),
        ("max_threads", opt(maxes(|t| t.max_threads))),
        ("io_read_bytes", opt(sum_of(|t| t.io_read_bytes))),
        ("io_write_bytes", opt(sum_of(|t| t.io_write_bytes))),
        (
            "samples",
            Json::Num(
                trials.iter().map(|t| t.samples).sum::<usize>() as f64,
            ),
        ),
    ])
}

fn trial_resource_json(r: &TrialResource) -> Json {
    Json::obj(vec![
        ("peak_rss_bytes", opt(r.peak_rss_bytes)),
        ("cpu_s", opt(r.cpu_s)),
        ("max_threads", opt(r.max_threads)),
        ("io_read_bytes", opt(r.io_read_bytes)),
        ("io_write_bytes", opt(r.io_write_bytes)),
        ("samples", Json::Num(r.samples as f64)),
    ])
}

/// Flatten one experiment's trial records and sidecar samples into the
/// merged report payload. `trial_records` may arrive in any order.
pub fn merge_streams(
    exp: &LabExperiment,
    result_types: &[ResultType],
    trial_records: &[Json],
    sysinfo: &[Json],
) -> anyhow::Result<Json> {
    let samples: Vec<ResourceSample> =
        sysinfo.iter().map(ResourceSample::from_json).collect();

    // group by cell index, then order trials within each group
    let mut groups: BTreeMap<usize, Vec<&Json>> = BTreeMap::new();
    for rec in trial_records {
        let cell = rec.get("cell").as_usize().ok_or_else(|| {
            anyhow::anyhow!(
                "trial record without a 'cell' index: {}",
                rec.to_string_compact()
            )
        })?;
        groups.entry(cell).or_default().push(rec);
    }
    anyhow::ensure!(
        !groups.is_empty(),
        "experiment '{}' produced no trial records",
        exp.name
    );

    let mut cells = Vec::new();
    for (cell_idx, mut recs) in groups {
        recs.sort_by_key(|r| r.get("trial").as_usize().unwrap_or(0));
        let params = recs[0].get("params").clone();
        let key = recs[0]
            .get("cell_key")
            .as_str()
            .map(str::to_string)
            .unwrap_or_else(|| format!("cell{cell_idx}"));

        // union of metric keys across trials (a trial may legitimately
        // miss a metric, e.g. a worker stat absent in process mode)
        let mut metric_keys: Vec<String> = Vec::new();
        for r in &recs {
            if let Some(m) = r.get("metrics").as_obj() {
                for k in m.keys() {
                    if !metric_keys.contains(k) {
                        metric_keys.push(k.clone());
                    }
                }
            }
        }
        metric_keys.sort();

        let mut resources = Vec::new();
        let mut details = Vec::new();
        for r in &recs {
            let start_s = r.get("start_s").as_f64().unwrap_or(0.0);
            let end_s = r.get("end_s").as_f64().unwrap_or(start_s);
            let window: Vec<&ResourceSample> = samples
                .iter()
                .filter(|s| s.t_s >= start_s && s.t_s <= end_s)
                .collect();
            let res = attribute(
                &ResourceSample::from_json(r.get("resource_start")),
                &ResourceSample::from_json(r.get("resource_end")),
                &window,
            );
            details.push(Json::obj(vec![
                (
                    "trial",
                    Json::Num(
                        r.get("trial").as_usize().unwrap_or(0) as f64,
                    ),
                ),
                ("start_s", Json::Num(start_s)),
                ("end_s", Json::Num(end_s)),
                ("metrics", r.get("metrics").clone()),
                ("resource", trial_resource_json(&res)),
            ]));
            resources.push(res);
        }

        let aggregate = |f: fn(&[f64]) -> f64| -> Json {
            let mut m = std::collections::BTreeMap::new();
            for k in &metric_keys {
                let vals: Vec<f64> = recs
                    .iter()
                    .filter_map(|r| r.get("metrics").get(k).as_f64())
                    .collect();
                if !vals.is_empty() {
                    m.insert(k.clone(), Json::Num(f(&vals)));
                }
            }
            Json::Obj(m)
        };

        let mut cell = vec![
            ("cell", Json::Str(key)),
            ("params", params),
        ];
        for rt in result_types {
            match rt {
                ResultType::Average => {
                    cell.push(("average", aggregate(stats::mean)))
                }
                ResultType::Median => {
                    cell.push(("median", aggregate(stats::median)))
                }
                ResultType::Details => {
                    cell.push(("details", Json::Arr(details.clone())))
                }
            }
        }
        cell.push(("resource", cell_resource(&resources)));
        cells.push(Json::obj(cell));
    }

    let axes = Json::Obj(
        exp.axes
            .iter()
            .map(|(name, vals)| {
                (name.clone(), Json::Arr(vals.clone()))
            })
            .collect(),
    );
    Ok(Json::obj(vec![
        ("bench", Json::Str("lab".into())),
        ("experiment", Json::Str(exp.name.clone())),
        ("kind", Json::Str(exp.kind.name().into())),
        ("exec", Json::Str(exp.exec.name().into())),
        ("trials", Json::Num(exp.trials as f64)),
        (
            "result_type",
            Json::Arr(
                result_types
                    .iter()
                    .map(|rt| Json::Str(rt.name().into()))
                    .collect(),
            ),
        ),
        ("axes", axes),
        ("cells", Json::Arr(cells)),
    ]))
}

/// Convenience used by tests: rebuild the canonical cell key from a
/// record's params object (axis order == sorted key order, matching
/// the config layer's `BTreeMap` axes).
pub fn key_of_params(params: &Json) -> String {
    let Some(map) = params.as_obj() else { return String::new() };
    let kv: Vec<(String, Json)> =
        map.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
    matrix::cell_key(&kv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lab::config::{ExecMode, LabKind};

    fn exp() -> LabExperiment {
        LabExperiment {
            name: "t".into(),
            kind: LabKind::Train,
            preset: "tiny".into(),
            exec: ExecMode::Session,
            overrides: BTreeMap::new(),
            axes: vec![(
                "workers".into(),
                vec![Json::Num(1.0), Json::Num(2.0)],
            )],
            trials: 2,
        }
    }

    fn record(cell: usize, trial: usize, loss: f64) -> Json {
        Json::obj(vec![
            ("experiment", Json::Str("t".into())),
            ("cell", Json::Num(cell as f64)),
            ("cell_key", Json::Str(format!("workers={}", cell + 1))),
            ("trial", Json::Num(trial as f64)),
            (
                "params",
                Json::obj(vec![(
                    "workers",
                    Json::Num((cell + 1) as f64),
                )]),
            ),
            ("start_s", Json::Num(trial as f64)),
            ("end_s", Json::Num(trial as f64 + 0.5)),
            (
                "metrics",
                Json::obj(vec![
                    ("last_loss", Json::Num(loss)),
                    ("wall_s", Json::Num(0.5)),
                ]),
            ),
            (
                "resource_start",
                Json::obj(vec![
                    ("t_s", Json::Num(trial as f64)),
                    ("cpu_s", Json::Num(1.0 + trial as f64)),
                    ("rss_bytes", Json::Num(1000.0)),
                ]),
            ),
            (
                "resource_end",
                Json::obj(vec![
                    ("t_s", Json::Num(trial as f64 + 0.5)),
                    ("cpu_s", Json::Num(1.4 + trial as f64)),
                    ("rss_bytes", Json::Num(2000.0)),
                ]),
            ),
        ])
    }

    #[test]
    fn merge_is_order_insensitive() {
        let all = vec![ResultType::Average, ResultType::Details];
        let recs = vec![
            record(0, 0, 4.0),
            record(0, 1, 2.0),
            record(1, 0, 3.0),
            record(1, 1, 1.0),
        ];
        let shuffled =
            vec![recs[3].clone(), recs[1].clone(), recs[0].clone(),
                 recs[2].clone()];
        let a = merge_streams(&exp(), &all, &recs, &[]).unwrap();
        let b = merge_streams(&exp(), &all, &shuffled, &[]).unwrap();
        assert_eq!(a.to_string_pretty(), b.to_string_pretty());
    }

    #[test]
    fn average_and_median_match_reference() {
        let all = vec![ResultType::Average, ResultType::Median];
        let recs = vec![record(0, 0, 4.0), record(0, 1, 2.0)];
        let out = merge_streams(&exp(), &all, &recs, &[]).unwrap();
        let cell = out.get("cells").idx(0);
        assert_eq!(
            cell.get("average").get("last_loss").as_f64(),
            Some(3.0)
        );
        assert_eq!(
            cell.get("median").get("last_loss").as_f64(),
            Some(3.0)
        );
        // no details block was requested
        assert!(cell.get("details").is_null());
    }

    #[test]
    fn resource_windows_attribute_samples_and_deltas() {
        let all = vec![ResultType::Details];
        let recs = vec![record(0, 0, 1.0)];
        // trial 0 window is [0.0, 0.5]; the 9000-byte spike at 0.25 is
        // inside, the one at 0.9 is not
        let sys = vec![
            Json::obj(vec![
                ("t_s", Json::Num(0.25)),
                ("rss_bytes", Json::Num(9000.0)),
                ("threads", Json::Num(7.0)),
            ]),
            Json::obj(vec![
                ("t_s", Json::Num(0.9)),
                ("rss_bytes", Json::Num(99000.0)),
            ]),
        ];
        let out = merge_streams(&exp(), &all, &recs, &sys).unwrap();
        let res = out.get("cells").idx(0).get("resource");
        assert_eq!(res.get("peak_rss_bytes").as_f64(), Some(9000.0));
        assert!(
            (res.get("cpu_s").as_f64().unwrap() - 0.4).abs() < 1e-9
        );
        assert_eq!(res.get("max_threads").as_f64(), Some(7.0));
        assert_eq!(res.get("samples").as_f64(), Some(1.0));
    }

    #[test]
    fn missing_cell_index_is_an_error() {
        let bad = vec![Json::obj(vec![("trial", Json::Num(0.0))])];
        assert!(merge_streams(
            &exp(),
            &[ResultType::Average],
            &bad,
            &[]
        )
        .is_err());
    }
}
