//! Predefined experiment blocks — quick variants of the repo's
//! standalone benches (`microbench_hotpath`, `ablation_wire`,
//! `serving_load`) expressed as lab matrices, so a config can pull a
//! known-good trajectory in with `{"predefined": "<name>"}`.
//!
//! Each block is a JSON string validated by the config layer's own
//! test (`predefined_blocks_resolve_and_take_trial_overrides`), which
//! parses every name through the full `LabExperiment` pipeline.

/// Quick `loss_grad` kernel sweep: threads × backend, the same shape
/// `microbench_hotpath --quick` times (d=780, k=600 is the paper's
/// MNIST-scale model).
const HOTPATH_QUICK: &str = r#"{
  "name": "hotpath_quick",
  "kind": "hotpath",
  "overrides": {"d": 780, "k": 600, "batch": 500},
  "params": {"threads": [1, 2], "kernel_backend": ["scalar", "auto"]}
}"#;

/// Quick wire-format ablation: one short MNIST-shaped distributed run
/// per compression mode, mirroring `ablation_wire --quick`.
const WIRE_QUICK: &str = r#"{
  "name": "wire_quick",
  "kind": "train",
  "preset": "mnist",
  "trials": 1,
  "overrides": {
    "n_train": 6000, "n_test": 500,
    "n_similar": 20000, "n_dissimilar": 20000, "n_test_pairs": 1000,
    "steps": 8, "workers": 2, "server_shards": 2, "keep": 0.25
  },
  "params": {"compression": ["none", "int8", "topk", "topk_int8"]}
}"#;

/// Quick retrieval load: exact vs cluster-pruned scans at two batch
/// sizes over a small gallery, mirroring `serving_load --quick`.
const SERVING_QUICK: &str = r#"{
  "name": "serving_quick",
  "kind": "serving",
  "overrides": {"gallery": 2000, "queries": 400, "k": 10},
  "params": {"nclusters": [32], "scan": ["exact", "approx"],
             "batch": [1, 16]}
}"#;

/// Look up a predefined block's JSON source by name.
pub fn predefined(name: &str) -> Option<&'static str> {
    match name {
        "hotpath_quick" => Some(HOTPATH_QUICK),
        "wire_quick" => Some(WIRE_QUICK),
        "serving_quick" => Some(SERVING_QUICK),
        _ => None,
    }
}

/// All predefined block names (for error messages and docs).
pub fn names() -> Vec<&'static str> {
    vec!["hotpath_quick", "serving_quick", "wire_quick"]
}
