//! Regression comparator for merged lab reports: `dmlps lab diff`
//! matches cells between an old and a new `BENCH_lab_<name>.json` by
//! their canonical parameter key and flags every metric whose relative
//! drift exceeds the tolerance. The CLI exits nonzero on any drift
//! line, which is what gates CI.

use std::collections::BTreeMap;
use std::path::Path;

use super::report::key_of_params;
use crate::util::json::Json;

/// Relative drift between two measurements: 0 when bit-equal,
/// `|a-b| / max(|a|,|b|)` otherwise (symmetric, scale-free), infinite
/// when either side is non-finite.
pub fn rel_drift(a: f64, b: f64) -> f64 {
    if a == b {
        return 0.0;
    }
    if !a.is_finite() || !b.is_finite() {
        return f64::INFINITY;
    }
    (a - b).abs() / a.abs().max(b.abs())
}

/// The aggregate metrics a cell is compared on: `average` if present,
/// else `median`, else the mean over `details` rows — so reports
/// written with any `result_type` subset stay diffable.
fn aggregate_metrics(cell: &Json) -> BTreeMap<String, f64> {
    for view in ["average", "median"] {
        if let Some(m) = cell.get(view).as_obj() {
            return m
                .iter()
                .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
                .collect();
        }
    }
    let mut sums: BTreeMap<String, (f64, usize)> = BTreeMap::new();
    if let Some(rows) = cell.get("details").as_arr() {
        for row in rows {
            if let Some(m) = row.get("metrics").as_obj() {
                for (k, v) in m {
                    if let Some(x) = v.as_f64() {
                        let e = sums.entry(k.clone()).or_insert((0.0, 0));
                        e.0 += x;
                        e.1 += 1;
                    }
                }
            }
        }
    }
    sums.into_iter()
        .map(|(k, (s, n))| (k, s / n as f64))
        .collect()
}

fn cells_by_key(report: &Json) -> BTreeMap<String, Json> {
    let mut out = BTreeMap::new();
    if let Some(cells) = report.get("cells").as_arr() {
        for c in cells {
            out.insert(key_of_params(c.get("params")), c.clone());
        }
    }
    out
}

/// Compare two merged reports. Returns one human-readable line per
/// divergence; empty means "within tolerance". Resource stats are
/// advisory by default (they vary with machine load) — pass
/// `include_resource` to gate on them too.
pub fn diff_reports(
    old: &Json,
    new: &Json,
    tolerance: f64,
    include_resource: bool,
) -> Vec<String> {
    let mut out = Vec::new();
    let (oe, ne) = (
        old.get("experiment").as_str().unwrap_or("?").to_string(),
        new.get("experiment").as_str().unwrap_or("?").to_string(),
    );
    if oe != ne {
        out.push(format!(
            "experiment name mismatch: old '{oe}' vs new '{ne}'"
        ));
    }
    let old_cells = cells_by_key(old);
    let new_cells = cells_by_key(new);
    for key in old_cells.keys() {
        if !new_cells.contains_key(key) {
            out.push(format!("cell [{key}] missing from new report"));
        }
    }
    for key in new_cells.keys() {
        if !old_cells.contains_key(key) {
            out.push(format!("cell [{key}] only in new report"));
        }
    }
    for (key, oc) in &old_cells {
        let Some(nc) = new_cells.get(key) else { continue };
        let om = aggregate_metrics(oc);
        let nm = aggregate_metrics(nc);
        for (metric, &a) in &om {
            let Some(&b) = nm.get(metric) else {
                out.push(format!(
                    "[{key}] metric '{metric}' missing from new report"
                ));
                continue;
            };
            let d = rel_drift(a, b);
            if d > tolerance {
                out.push(format!(
                    "[{key}] {metric}: {a} -> {b} \
                     (drift {d:.3} > tolerance {tolerance})"
                ));
            }
        }
        for metric in nm.keys() {
            if !om.contains_key(metric) {
                out.push(format!(
                    "[{key}] metric '{metric}' only in new report"
                ));
            }
        }
        if include_resource {
            let res = |c: &Json| -> BTreeMap<String, f64> {
                c.get("resource")
                    .as_obj()
                    .map(|m| {
                        m.iter()
                            .filter_map(|(k, v)| {
                                v.as_f64().map(|x| (k.clone(), x))
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            let (or, nr) = (res(oc), res(nc));
            for (metric, &a) in &or {
                if let Some(&b) = nr.get(metric) {
                    let d = rel_drift(a, b);
                    if d > tolerance {
                        out.push(format!(
                            "[{key}] resource.{metric}: {a} -> {b} \
                             (drift {d:.3} > tolerance {tolerance})"
                        ));
                    }
                }
            }
        }
    }
    out
}

/// [`diff_reports`] over two files on disk.
pub fn diff_files(
    old: &Path,
    new: &Path,
    tolerance: f64,
    include_resource: bool,
) -> anyhow::Result<Vec<String>> {
    let o = Json::parse_file(old)
        .map_err(|e| anyhow::anyhow!("{}: {e}", old.display()))?;
    let n = Json::parse_file(new)
        .map_err(|e| anyhow::anyhow!("{}: {e}", new.display()))?;
    Ok(diff_reports(&o, &n, tolerance, include_resource))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(qps: f64, with_extra_cell: bool) -> Json {
        let mut cells = vec![Json::obj(vec![
            (
                "params",
                Json::obj(vec![("workers", Json::Num(1.0))]),
            ),
            (
                "average",
                Json::obj(vec![("qps", Json::Num(qps))]),
            ),
            (
                "resource",
                Json::obj(vec![(
                    "peak_rss_bytes",
                    Json::Num(1e6),
                )]),
            ),
        ])];
        if with_extra_cell {
            cells.push(Json::obj(vec![
                (
                    "params",
                    Json::obj(vec![("workers", Json::Num(2.0))]),
                ),
                (
                    "average",
                    Json::obj(vec![("qps", Json::Num(qps))]),
                ),
            ]));
        }
        Json::obj(vec![
            ("experiment", Json::Str("t".into())),
            ("cells", Json::Arr(cells)),
        ])
    }

    #[test]
    fn identical_reports_diff_clean() {
        let r = report(100.0, true);
        assert!(diff_reports(&r, &r, 0.0, true).is_empty());
    }

    #[test]
    fn drift_beyond_tolerance_is_flagged() {
        let old = report(100.0, false);
        let new = report(140.0, false);
        // drift = 40/140 ≈ 0.286
        assert!(diff_reports(&old, &new, 0.3, false).is_empty());
        let drifts = diff_reports(&old, &new, 0.25, false);
        assert_eq!(drifts.len(), 1, "{drifts:?}");
        assert!(drifts[0].contains("qps"), "{drifts:?}");
    }

    #[test]
    fn missing_and_extra_cells_are_reported() {
        let old = report(100.0, true);
        let new = report(100.0, false);
        let drifts = diff_reports(&old, &new, 0.5, false);
        assert_eq!(drifts.len(), 1, "{drifts:?}");
        assert!(drifts[0].contains("missing from new"), "{drifts:?}");
        let drifts = diff_reports(&new, &old, 0.5, false);
        assert!(drifts[0].contains("only in new"), "{drifts:?}");
    }

    #[test]
    fn details_fallback_aggregates_when_no_average() {
        let cell = |vals: &[f64]| {
            Json::obj(vec![
                ("params", Json::obj(vec![])),
                (
                    "details",
                    Json::Arr(
                        vals.iter()
                            .map(|&v| {
                                Json::obj(vec![(
                                    "metrics",
                                    Json::obj(vec![(
                                        "x",
                                        Json::Num(v),
                                    )]),
                                )])
                            })
                            .collect(),
                    ),
                ),
            ])
        };
        let rep = |vals: &[f64]| {
            Json::obj(vec![
                ("experiment", Json::Str("t".into())),
                ("cells", Json::Arr(vec![cell(vals)])),
            ])
        };
        // means are 2.0 vs 2.0 — clean even though trials differ
        let old = rep(&[1.0, 3.0]);
        let new = rep(&[2.0, 2.0]);
        assert!(diff_reports(&old, &new, 1e-9, false).is_empty());
        let drifted = rep(&[4.0, 4.0]);
        assert!(!diff_reports(&old, &drifted, 0.25, false).is_empty());
    }

    #[test]
    fn rel_drift_edge_cases() {
        assert_eq!(rel_drift(0.0, 0.0), 0.0);
        assert_eq!(rel_drift(f64::NAN, f64::NAN), f64::INFINITY);
        assert_eq!(rel_drift(1.0, f64::INFINITY), f64::INFINITY);
        assert!((rel_drift(100.0, 140.0) - 40.0 / 140.0).abs() < 1e-12);
        assert_eq!(rel_drift(-1.0, 1.0), 2.0 / 1.0);
    }
}
