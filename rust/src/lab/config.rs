//! Lab config model: one global block + experiment blocks, with the
//! same typo discipline as the experiment config — every unknown key is
//! rejected through [`reject_unknown_keys`] with a "did you mean"
//! suggestion, and every axis value is validated at load time so a bad
//! matrix fails in milliseconds, not after an hour of cells.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use crate::config::{
    reject_unknown_keys, CompressionMode, Consistency, PairMode, Preset,
};
use crate::linalg::simd::KernelBackend;
use crate::ps::FaultSpec;
use crate::util::json::Json;

/// Aggregation views the merged `BENCH_lab_*.json` carries per cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResultType {
    Average,
    Median,
    /// Every trial's raw metrics (plus its resource window).
    Details,
}

impl ResultType {
    pub fn parse(s: &str) -> anyhow::Result<ResultType> {
        match s {
            "average" => Ok(ResultType::Average),
            "median" => Ok(ResultType::Median),
            "details" => Ok(ResultType::Details),
            other => anyhow::bail!(
                "unknown result_type '{other}' (average|median|details)"
            ),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ResultType::Average => "average",
            ResultType::Median => "median",
            ResultType::Details => "details",
        }
    }
}

/// What an experiment block measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LabKind {
    /// A PS training run (the `Session` path, or `dmlps cluster` under
    /// [`ExecMode::Process`]).
    Train,
    /// The `loss_grad` kernel hot path (quick `microbench_hotpath`).
    Hotpath,
    /// In-process retrieval over a [`ServeEngine`]
    /// (quick `serving_load`).
    ///
    /// [`ServeEngine`]: crate::serve::ServeEngine
    Serving,
}

impl LabKind {
    pub fn parse(s: &str) -> anyhow::Result<LabKind> {
        match s {
            "train" => Ok(LabKind::Train),
            "hotpath" => Ok(LabKind::Hotpath),
            "serving" => Ok(LabKind::Serving),
            other => anyhow::bail!(
                "unknown lab kind '{other}' (train|hotpath|serving)"
            ),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LabKind::Train => "train",
            LabKind::Hotpath => "hotpath",
            LabKind::Serving => "serving",
        }
    }
}

/// Whether a train cell runs in-process or as a spawned
/// `dmlps cluster` (real sockets, real process death).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Session,
    Process,
}

impl ExecMode {
    pub fn parse(s: &str) -> anyhow::Result<ExecMode> {
        match s {
            "session" => Ok(ExecMode::Session),
            "process" => Ok(ExecMode::Process),
            other => anyhow::bail!(
                "unknown exec mode '{other}' (session|process)"
            ),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Session => "session",
            ExecMode::Process => "process",
        }
    }
}

/// The leading global block of a lab config.
#[derive(Clone, Debug)]
pub struct LabGlobal {
    /// Directory for NDJSON streams and merged `BENCH_lab_*.json`.
    pub output: PathBuf,
    pub result_types: Vec<ResultType>,
    /// Default trials per cell (experiment blocks may override).
    pub trials: usize,
    /// Sidecar sampling cadence in milliseconds.
    pub sample_ms: u64,
}

impl Default for LabGlobal {
    fn default() -> LabGlobal {
        LabGlobal {
            output: PathBuf::from("lab-out"),
            result_types: vec![
                ResultType::Average,
                ResultType::Median,
                ResultType::Details,
            ],
            trials: 1,
            sample_ms: 50,
        }
    }
}

impl LabGlobal {
    fn from_json(j: &Json) -> anyhow::Result<LabGlobal> {
        let map = j.as_obj().ok_or_else(|| {
            anyhow::anyhow!("the first lab block must be a global object")
        })?;
        const KNOWN: [&str; 4] =
            ["output", "result_type", "sample_ms", "trials"];
        reject_unknown_keys(map, &KNOWN, "lab global")?;
        let mut g = LabGlobal::default();
        if let Some(s) = j.get("output").as_str() {
            anyhow::ensure!(!s.is_empty(), "lab 'output' must be non-empty");
            g.output = PathBuf::from(s);
        }
        if let Some(arr) = j.get("result_type").as_arr() {
            anyhow::ensure!(
                !arr.is_empty(),
                "lab 'result_type' must list at least one view"
            );
            g.result_types = arr
                .iter()
                .map(|v| {
                    ResultType::parse(v.as_str().unwrap_or_default())
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
        }
        if !j.get("trials").is_null() {
            g.trials = j.get("trials").as_usize().ok_or_else(|| {
                anyhow::anyhow!("lab 'trials' must be a positive integer")
            })?;
            anyhow::ensure!(g.trials > 0, "lab 'trials' must be >= 1");
        }
        if !j.get("sample_ms").is_null() {
            g.sample_ms =
                j.get("sample_ms").as_usize().ok_or_else(|| {
                    anyhow::anyhow!("lab 'sample_ms' must be an integer")
                })? as u64;
            anyhow::ensure!(
                g.sample_ms > 0,
                "lab 'sample_ms' must be >= 1"
            );
        }
        Ok(g)
    }
}

/// One experiment block: a parameter matrix over one measurement kind.
#[derive(Clone, Debug)]
pub struct LabExperiment {
    pub name: String,
    pub kind: LabKind,
    /// Base preset for train cells (`tiny|mnist|imnet60k|imnet1m`).
    pub preset: String,
    pub exec: ExecMode,
    /// Fixed scalar knobs applied before the axes.
    pub overrides: BTreeMap<String, Json>,
    /// Parameter lists, name-sorted; their cross-product is the matrix.
    pub axes: Vec<(String, Vec<Json>)>,
    pub trials: usize,
}

/// Axis names each kind sweeps (sorted; the error suggestions and the
/// README table both read from here).
pub fn axes_for(kind: LabKind) -> &'static [&'static str] {
    match kind {
        LabKind::Train => &[
            "compression",
            "consistency",
            "fault_profile",
            "keep",
            "kernel_backend",
            "pairs_mode",
            "server_shards",
            "threads",
            "workers",
        ],
        LabKind::Hotpath => &["kernel_backend", "threads"],
        LabKind::Serving => &["batch", "nclusters", "scan"],
    }
}

/// Fixed-knob override names each kind accepts.
fn overrides_for(kind: LabKind) -> &'static [&'static str] {
    match kind {
        LabKind::Train => &[
            "keep",
            "n_dissimilar",
            "n_similar",
            "n_test",
            "n_test_pairs",
            "n_train",
            "seed",
            "server_batch",
            "server_shards",
            "steps",
            "threads",
            "workers",
        ],
        LabKind::Hotpath => &["batch", "d", "k"],
        LabKind::Serving => &["gallery", "k", "kproj", "queries"],
    }
}

impl LabExperiment {
    fn from_json(j: &Json, global: &LabGlobal) -> anyhow::Result<Self> {
        let map = j.as_obj().ok_or_else(|| {
            anyhow::anyhow!("every lab experiment must be a JSON object")
        })?;
        // {"predefined": "..."} pulls in a shipped block; only a trial
        // override may ride along.
        if map.contains_key("predefined") {
            reject_unknown_keys(
                map,
                &["predefined", "trials"],
                "lab predefined block",
            )?;
            let name =
                j.get("predefined").as_str().ok_or_else(|| {
                    anyhow::anyhow!("'predefined' must be a string")
                })?;
            let src = super::presets::predefined(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown predefined experiment '{name}' \
                     (available: {})",
                    super::presets::names().join(", ")
                )
            })?;
            let block = Json::parse(src).map_err(|e| {
                anyhow::anyhow!("predefined '{name}' is invalid: {e}")
            })?;
            let mut exp = LabExperiment::from_json(&block, global)?;
            if !j.get("trials").is_null() {
                exp.trials =
                    j.get("trials").as_usize().ok_or_else(|| {
                        anyhow::anyhow!("'trials' must be an integer")
                    })?;
                anyhow::ensure!(exp.trials > 0, "'trials' must be >= 1");
            }
            return Ok(exp);
        }

        const KNOWN: [&str; 7] = [
            "exec", "kind", "name", "overrides", "params", "preset",
            "trials",
        ];
        reject_unknown_keys(map, &KNOWN, "lab experiment")?;
        let name = j
            .get("name")
            .as_str()
            .filter(|s| !s.is_empty())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "every lab experiment needs a non-empty 'name'"
                )
            })?
            .to_string();
        anyhow::ensure!(
            name.chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-'),
            "experiment name '{name}' must be [A-Za-z0-9_-] \
             (it names files)"
        );
        let kind = LabKind::parse(j.get("kind").as_str().unwrap_or("train"))?;
        let exec =
            ExecMode::parse(j.get("exec").as_str().unwrap_or("session"))?;
        anyhow::ensure!(
            exec == ExecMode::Session || kind == LabKind::Train,
            "experiment '{name}': exec=process supports only kind=train"
        );
        let preset = j.get("preset").as_str().unwrap_or("tiny").to_string();
        if kind == LabKind::Train {
            // fail on a typo'd preset at load time, not mid-matrix
            Preset::parse(&preset)?;
        }

        let mut overrides = BTreeMap::new();
        if !j.get("overrides").is_null() {
            let ov = j.get("overrides").as_obj().ok_or_else(|| {
                anyhow::anyhow!(
                    "experiment '{name}': 'overrides' must be an object"
                )
            })?;
            reject_unknown_keys(
                ov,
                overrides_for(kind),
                &format!("lab '{}' override", kind.name()),
            )?;
            overrides = ov.clone();
        }

        let mut axes: Vec<(String, Vec<Json>)> = Vec::new();
        if !j.get("params").is_null() {
            let params = j.get("params").as_obj().ok_or_else(|| {
                anyhow::anyhow!(
                    "experiment '{name}': 'params' must be an object \
                     of value lists"
                )
            })?;
            reject_unknown_keys(
                params,
                axes_for(kind),
                &format!("lab '{}' axis", kind.name()),
            )?;
            // BTreeMap iteration = name-sorted axes = deterministic
            // expansion order
            for (axis, vals) in params {
                let vals = vals.as_arr().ok_or_else(|| {
                    anyhow::anyhow!(
                        "experiment '{name}': axis '{axis}' must be \
                         a list"
                    )
                })?;
                anyhow::ensure!(
                    !vals.is_empty(),
                    "experiment '{name}': axis '{axis}' is empty"
                );
                for v in vals {
                    validate_axis_value(kind, axis, v).map_err(|e| {
                        anyhow::anyhow!("experiment '{name}': {e}")
                    })?;
                }
                axes.push((axis.clone(), vals.to_vec()));
            }
        }
        if exec == ExecMode::Process {
            for (axis, vals) in &axes {
                if axis == "fault_profile" {
                    anyhow::ensure!(
                        vals.iter().all(|v| v.as_str() == Some("none")),
                        "experiment '{name}': fault injection needs \
                         exec=session (the socket transport has no \
                         fault hooks)"
                    );
                }
            }
        }

        let mut trials = global.trials;
        if !j.get("trials").is_null() {
            trials = j.get("trials").as_usize().ok_or_else(|| {
                anyhow::anyhow!(
                    "experiment '{name}': 'trials' must be an integer"
                )
            })?;
            anyhow::ensure!(
                trials > 0,
                "experiment '{name}': 'trials' must be >= 1"
            );
        }
        Ok(LabExperiment {
            name,
            kind,
            preset,
            exec,
            overrides,
            axes,
            trials,
        })
    }
}

/// Check one axis value parses into its typed knob.
fn validate_axis_value(
    kind: LabKind,
    axis: &str,
    v: &Json,
) -> anyhow::Result<()> {
    let num = || {
        v.as_usize().ok_or_else(|| {
            anyhow::anyhow!(
                "axis '{axis}' value {} must be a non-negative integer",
                v.to_string_compact()
            )
        })
    };
    let string = || {
        v.as_str().ok_or_else(|| {
            anyhow::anyhow!(
                "axis '{axis}' value {} must be a string",
                v.to_string_compact()
            )
        })
    };
    match (kind, axis) {
        (_, "workers") | (_, "server_shards") | (_, "nclusters")
        | (_, "batch") => {
            anyhow::ensure!(num()? >= 1, "axis '{axis}' must be >= 1");
        }
        (_, "threads") => {
            // 0 = machine default, same contract as the CLI knob
            num()?;
        }
        (_, "consistency") => {
            Consistency::parse(string()?)?;
        }
        (_, "compression") => {
            string()?.parse::<CompressionMode>()?;
        }
        (_, "keep") => {
            let x = v.as_f64().unwrap_or(f64::NAN);
            anyhow::ensure!(
                x > 0.0 && x <= 1.0,
                "axis 'keep' must be in (0, 1]"
            );
        }
        (_, "pairs_mode") => {
            string()?.parse::<PairMode>()?;
        }
        (_, "fault_profile") => {
            parse_fault_profile(string()?)?;
        }
        (_, "kernel_backend") => {
            parse_backend(string()?)?;
        }
        (_, "scan") => {
            let s = string()?;
            anyhow::ensure!(
                s == "exact" || s == "approx",
                "axis 'scan' must be exact|approx, got '{s}'"
            );
        }
        _ => {} // key membership already checked by reject_unknown_keys
    }
    Ok(())
}

/// Parse a `kernel_backend` value: `auto` (runtime dispatch) or a
/// forced backend. Forcing `simd` on a build/CPU without it degrades
/// to scalar, same as the env knob.
pub(crate) fn parse_backend(
    s: &str,
) -> anyhow::Result<Option<KernelBackend>> {
    match s {
        "auto" => Ok(None),
        "scalar" => Ok(Some(KernelBackend::Scalar)),
        "simd" => Ok(Some(KernelBackend::Simd)),
        other => anyhow::bail!(
            "kernel_backend must be auto|scalar|simd, got '{other}'"
        ),
    }
}

/// Parse a `fault_profile` axis value into a [`FaultSpec`]: `none`, or
/// `+`-joined terms `drop:<p>` (drop gradient *and* parameter messages
/// with probability p) and `lat:<ms>` (delivery latency), e.g.
/// `drop:0.1+lat:5`.
pub fn parse_fault_profile(s: &str) -> anyhow::Result<FaultSpec> {
    let mut spec = FaultSpec::perfect();
    if s == "none" {
        return Ok(spec);
    }
    anyhow::ensure!(!s.is_empty(), "empty fault_profile (use 'none')");
    for term in s.split('+') {
        if let Some(p) = term.strip_prefix("drop:") {
            let p: f64 = p
                .parse()
                .map_err(|e| anyhow::anyhow!("fault term '{term}': {e}"))?;
            anyhow::ensure!(
                (0.0..1.0).contains(&p),
                "drop probability must be in [0, 1), got {p}"
            );
            spec.drop_grad_prob = p;
            spec.drop_param_prob = p;
        } else if let Some(ms) = term.strip_prefix("lat:") {
            let ms: f64 = ms
                .parse()
                .map_err(|e| anyhow::anyhow!("fault term '{term}': {e}"))?;
            anyhow::ensure!(
                ms.is_finite() && ms >= 0.0,
                "latency must be finite and >= 0, got {ms}"
            );
            spec.latency = Duration::from_secs_f64(ms / 1e3);
        } else {
            anyhow::bail!(
                "unknown fault term '{term}' \
                 (none | drop:<p> | lat:<ms>, '+'-joined)"
            );
        }
    }
    Ok(spec)
}

/// A parsed lab config: global block + at least one experiment.
#[derive(Clone, Debug)]
pub struct LabConfig {
    pub global: LabGlobal,
    pub experiments: Vec<LabExperiment>,
}

impl LabConfig {
    pub fn parse(j: &Json) -> anyhow::Result<LabConfig> {
        let blocks = j.as_arr().ok_or_else(|| {
            anyhow::anyhow!(
                "a lab config is a JSON array: one global block, then \
                 experiment blocks"
            )
        })?;
        anyhow::ensure!(
            blocks.len() >= 2,
            "a lab config needs a global block plus at least one \
             experiment ({} block(s) found)",
            blocks.len()
        );
        let global = LabGlobal::from_json(&blocks[0])?;
        let mut experiments = Vec::new();
        for b in &blocks[1..] {
            let exp = LabExperiment::from_json(b, &global)?;
            anyhow::ensure!(
                experiments
                    .iter()
                    .all(|e: &LabExperiment| e.name != exp.name),
                "duplicate experiment name '{}'",
                exp.name
            );
            experiments.push(exp);
        }
        Ok(LabConfig { global, experiments })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<LabConfig> {
        Self::parse(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal(extra: &str) -> String {
        format!(
            r#"[{{"output": "o", "trials": 2}},
                {{"name": "t", "kind": "train", {extra}
                  "params": {{"workers": [1, 2]}}}}]"#
        )
    }

    #[test]
    fn parses_minimal_config() {
        let cfg =
            LabConfig::parse(&Json::parse(&minimal("")).unwrap()).unwrap();
        assert_eq!(cfg.global.trials, 2);
        assert_eq!(cfg.experiments.len(), 1);
        let e = &cfg.experiments[0];
        assert_eq!(e.kind, LabKind::Train);
        assert_eq!(e.trials, 2);
        assert_eq!(e.axes.len(), 1);
    }

    #[test]
    fn unknown_global_key_suggests_nearest() {
        let j = Json::parse(
            r#"[{"trails": 3}, {"name": "x", "params": {}}]"#,
        )
        .unwrap();
        let msg = LabConfig::parse(&j).unwrap_err().to_string();
        assert!(msg.contains("unknown lab global key 'trails'"), "{msg}");
        assert!(msg.contains("did you mean 'trials'"), "{msg}");
    }

    #[test]
    fn unknown_experiment_key_suggests_nearest() {
        let j = Json::parse(
            r#"[{}, {"name": "x", "parms": {"workers": [1]}}]"#,
        )
        .unwrap();
        let msg = LabConfig::parse(&j).unwrap_err().to_string();
        assert!(msg.contains("did you mean 'params'"), "{msg}");
    }

    #[test]
    fn unknown_axis_suggests_nearest() {
        let j = Json::parse(
            r#"[{}, {"name": "x", "params": {"worker": [1]}}]"#,
        )
        .unwrap();
        let msg = LabConfig::parse(&j).unwrap_err().to_string();
        assert!(msg.contains("unknown lab 'train' axis key"), "{msg}");
        assert!(msg.contains("did you mean 'workers'"), "{msg}");
    }

    #[test]
    fn bad_axis_values_fail_at_load() {
        for (axis, val) in [
            ("consistency", "\"sspx\""),
            ("compression", "\"gzip\""),
            ("kernel_backend", "\"avx\""),
            ("keep", "1.5"),
            ("fault_profile", "\"drop:2\""),
            ("workers", "0"),
        ] {
            let j = Json::parse(&format!(
                r#"[{{}}, {{"name": "x",
                     "params": {{"{axis}": [{val}]}}}}]"#
            ))
            .unwrap();
            assert!(
                LabConfig::parse(&j).is_err(),
                "{axis}={val} must be rejected"
            );
        }
    }

    #[test]
    fn fault_profiles_parse() {
        assert!(parse_fault_profile("none").unwrap().is_perfect());
        let f = parse_fault_profile("drop:0.25").unwrap();
        assert_eq!(f.drop_grad_prob, 0.25);
        assert_eq!(f.drop_param_prob, 0.25);
        let f = parse_fault_profile("drop:0.1+lat:5").unwrap();
        assert_eq!(f.drop_grad_prob, 0.1);
        assert_eq!(f.latency, Duration::from_millis(5));
        assert!(parse_fault_profile("jitter:1").is_err());
        assert!(parse_fault_profile("").is_err());
    }

    #[test]
    fn process_mode_rejects_fault_injection() {
        let j = Json::parse(
            r#"[{}, {"name": "x", "exec": "process",
                 "params": {"fault_profile": ["drop:0.1"]}}]"#,
        )
        .unwrap();
        let msg = LabConfig::parse(&j).unwrap_err().to_string();
        assert!(msg.contains("exec=session"), "{msg}");
    }

    #[test]
    fn predefined_blocks_resolve_and_take_trial_overrides() {
        let j = Json::parse(
            r#"[{"trials": 3},
                {"predefined": "hotpath_quick", "trials": 1}]"#,
        )
        .unwrap();
        let cfg = LabConfig::parse(&j).unwrap();
        let e = &cfg.experiments[0];
        assert_eq!(e.name, "hotpath_quick");
        assert_eq!(e.kind, LabKind::Hotpath);
        assert_eq!(e.trials, 1);
        // every shipped block must parse on its own
        for name in super::super::presets::names() {
            let j = Json::parse(&format!(
                r#"[{{}}, {{"predefined": "{name}"}}]"#
            ))
            .unwrap();
            LabConfig::parse(&j)
                .unwrap_or_else(|e| panic!("predefined {name}: {e}"));
        }
    }

    #[test]
    fn duplicate_names_rejected() {
        let j = Json::parse(
            r#"[{}, {"name": "x", "params": {}},
                    {"name": "x", "params": {}}]"#,
        )
        .unwrap();
        let msg = LabConfig::parse(&j).unwrap_err().to_string();
        assert!(msg.contains("duplicate experiment name"), "{msg}");
    }
}
