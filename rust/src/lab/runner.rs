//! Execute a parsed [`LabConfig`]: every experiment's cells × trials,
//! with the sidecar sampling alongside, NDJSON streams on disk, and a
//! merged `BENCH_lab_<name>.json` per experiment at the end.
//!
//! Heavyweight fixtures — generated datasets, serve-engine epochs,
//! hotpath input buffers — are cached across cells so a matrix sweep
//! pays generation cost once per distinct shape, not once per cell.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::config::{
    parse_backend, parse_fault_profile, ExecMode, LabConfig,
    LabExperiment, LabKind,
};
use super::matrix::{self, Cell};
use super::ndjson;
use super::report;
use super::sidecar::{ResourceSample, Sidecar};
use crate::config::{ExperimentConfig, Preset};
use crate::data::{ExperimentData, SyntheticSpec};
use crate::dml::{DmlProblem, Engine, MinibatchRef, NativeEngine};
use crate::linalg::simd::{self, KernelBackend};
use crate::linalg::Mat;
use crate::ps::{FaultSpec, RunOptions};
use crate::serve::{default_nprobe, ScanMode, ServeConfig, ServeEngine};
use crate::session::{MetricModel, Session};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::util::stats::percentile;

/// Cross-cell fixture caches, keyed by the knobs that change the
/// fixture's contents.
#[derive(Default)]
struct Caches {
    /// Generated train/test data per (dataset shape, pair mode, seed).
    data: BTreeMap<String, Arc<ExperimentData>>,
    /// Serve engine + query matrix per (gallery, queries, kproj,
    /// nclusters).
    serve: BTreeMap<String, Arc<(ServeEngine, Mat)>>,
    /// Hotpath input buffers for the current (d, k, batch) shape.
    hotpath: Option<HotpathInputs>,
}

struct HotpathInputs {
    d: usize,
    k: usize,
    batch: usize,
    l: Mat,
    dsb: Vec<f32>,
    ddb: Vec<f32>,
}

/// Run every experiment of `cfg`. Returns the merged report paths in
/// experiment order.
pub fn run(cfg: &LabConfig) -> anyhow::Result<Vec<PathBuf>> {
    std::fs::create_dir_all(&cfg.global.output).map_err(|e| {
        anyhow::anyhow!(
            "create {}: {e}",
            cfg.global.output.display()
        )
    })?;
    let origin = Instant::now();
    let mut caches = Caches::default();
    let mut written = Vec::new();
    for exp in &cfg.experiments {
        let cells = matrix::expand(&exp.axes);
        println!(
            "lab: experiment '{}' ({}, {}): {} cell(s) across {} \
             axis/axes × {} trial(s)",
            exp.name,
            exp.kind.name(),
            exp.exec.name(),
            cells.len(),
            exp.axes.len(),
            exp.trials
        );
        let trials_path = cfg
            .global
            .output
            .join(format!("{}.trials.ndjson", exp.name));
        let sys_path = cfg
            .global
            .output
            .join(format!("{}.sysinfo.ndjson", exp.name));
        // a re-run must not merge a previous run's records
        let _ = std::fs::remove_file(&trials_path);
        let _ = std::fs::remove_file(&sys_path);

        let sidecar = Sidecar::spawn(
            sys_path.clone(),
            Duration::from_millis(cfg.global.sample_ms),
            origin,
        );
        // separate fn so the sidecar is stopped on *every* exit path
        // before the error propagates
        let outcome = run_trials(
            exp,
            &cells,
            &trials_path,
            &cfg.global.output,
            origin,
            &mut caches,
        );
        sidecar.stop();
        outcome?;

        let trial_records = ndjson::read_all(&trials_path)?;
        let sys = ndjson::read_all(&sys_path)?;
        let merged = report::merge_streams(
            exp,
            &cfg.global.result_types,
            &trial_records,
            &sys,
        )?;
        crate::metrics::finite_guard(&merged)?;
        let path = cfg
            .global
            .output
            .join(format!("BENCH_lab_{}.json", exp.name));
        crate::linalg::io::atomic_write(&path, |w| {
            use std::io::Write;
            w.write_all(merged.to_string_pretty().as_bytes())?;
            Ok(())
        })?;
        println!("lab: wrote {}", path.display());
        written.push(path);
    }
    Ok(written)
}

/// One experiment's cell × trial loop, appending a trial record to the
/// NDJSON stream after each cell run.
fn run_trials(
    exp: &LabExperiment,
    cells: &[Cell],
    trials_path: &std::path::Path,
    output: &std::path::Path,
    origin: Instant,
    caches: &mut Caches,
) -> anyhow::Result<()> {
    for cell in cells {
        let key = matrix::cell_key(&cell.params);
        for trial in 0..exp.trials {
            let start = ResourceSample::now(origin);
            let metrics =
                run_cell(exp, cell, trial, output, caches).map_err(
                    |e| {
                        anyhow::anyhow!(
                            "experiment '{}' cell [{}] trial {}: {e}",
                            exp.name,
                            key,
                            trial
                        )
                    },
                )?;
            let end = ResourceSample::now(origin);
            let record = Json::obj(vec![
                ("experiment", Json::Str(exp.name.clone())),
                ("cell", Json::Num(cell.index as f64)),
                ("cell_key", Json::Str(key.clone())),
                ("trial", Json::Num(trial as f64)),
                (
                    "params",
                    Json::Obj(cell.params.iter().cloned().collect()),
                ),
                ("start_s", Json::Num(start.t_s)),
                ("end_s", Json::Num(end.t_s)),
                ("metrics", metrics),
                ("resource_start", start.to_json()),
                ("resource_end", end.to_json()),
            ]);
            ndjson::append(trials_path, &record)?;
        }
    }
    Ok(())
}

fn run_cell(
    exp: &LabExperiment,
    cell: &Cell,
    trial: usize,
    output: &std::path::Path,
    caches: &mut Caches,
) -> anyhow::Result<Json> {
    match exp.kind {
        LabKind::Train => match exp.exec {
            ExecMode::Session => train_cell(exp, cell, trial, caches),
            ExecMode::Process => {
                process_cell(exp, cell, trial, output)
            }
        },
        LabKind::Hotpath => hotpath_cell(exp, cell, caches),
        LabKind::Serving => serving_cell(exp, cell, caches),
    }
}

// ----------------------------------------------------------------------
// train cells
// ----------------------------------------------------------------------

/// Resolve one train cell's config + fault spec + forced backend from
/// the preset, the experiment overrides, and the cell's axis values.
fn train_config(
    exp: &LabExperiment,
    cell: &Cell,
    trial: usize,
) -> anyhow::Result<(ExperimentConfig, FaultSpec, Option<KernelBackend>)>
{
    let mut cfg = Preset::parse(&exp.preset)?.config();
    let mut faults = FaultSpec::perfect();
    let mut backend = None;
    for (key, v) in exp
        .overrides
        .iter()
        .map(|(k, v)| (k.as_str(), v))
        .chain(cell.params.iter().map(|(k, v)| (k.as_str(), v)))
    {
        apply_train_knob(&mut cfg, &mut faults, &mut backend, key, v)?;
    }
    // trials are independent repetitions: distinct seeds, same knobs
    cfg.seed = cfg.seed.wrapping_add(trial as u64);
    Ok((cfg, faults, backend))
}

fn apply_train_knob(
    cfg: &mut ExperimentConfig,
    faults: &mut FaultSpec,
    backend: &mut Option<KernelBackend>,
    key: &str,
    v: &Json,
) -> anyhow::Result<()> {
    let num = || {
        v.as_usize().ok_or_else(|| {
            anyhow::anyhow!(
                "'{key}' must be a non-negative integer, got {}",
                v.to_string_compact()
            )
        })
    };
    let string = || {
        v.as_str().ok_or_else(|| {
            anyhow::anyhow!(
                "'{key}' must be a string, got {}",
                v.to_string_compact()
            )
        })
    };
    match key {
        "workers" => cfg.cluster.workers = num()?.max(1),
        "server_shards" => cfg.cluster.server_shards = num()?.max(1),
        "server_batch" => cfg.cluster.server_batch = num()?.max(1),
        "threads" => cfg.cluster.threads_per_worker = num()?,
        "steps" => cfg.optim.steps = num()?.max(1),
        "n_train" => cfg.dataset.n_train = num()?.max(1),
        "n_test" => cfg.dataset.n_test = num()?.max(1),
        "n_similar" => cfg.dataset.n_similar = num()?.max(1),
        "n_dissimilar" => cfg.dataset.n_dissimilar = num()?.max(1),
        "n_test_pairs" => cfg.dataset.n_test_pairs = num()?.max(1),
        "seed" => cfg.seed = num()? as u64,
        "consistency" => {
            cfg.cluster.consistency = string()?.parse()?
        }
        "compression" => {
            cfg.cluster.compression.mode = string()?.parse()?
        }
        "keep" => {
            let x = v.as_f64().unwrap_or(f64::NAN);
            anyhow::ensure!(
                x > 0.0 && x <= 1.0,
                "'keep' must be in (0, 1]"
            );
            cfg.cluster.compression.keep = x as f32;
        }
        "pairs_mode" => cfg.cluster.pairs.mode = string()?.parse()?,
        "fault_profile" => *faults = parse_fault_profile(string()?)?,
        "kernel_backend" => *backend = parse_backend(string()?)?,
        other => anyhow::bail!("unhandled train knob '{other}'"),
    }
    Ok(())
}

fn train_cell(
    exp: &LabExperiment,
    cell: &Cell,
    trial: usize,
    caches: &mut Caches,
) -> anyhow::Result<Json> {
    let (cfg, faults, backend) = train_config(exp, cell, trial)?;
    let data_key = format!(
        "{:?}|{}|{}",
        cfg.dataset, cfg.cluster.pairs.mode, cfg.seed
    );
    let data = caches
        .data
        .entry(data_key)
        .or_insert_with(|| {
            Arc::new(ExperimentData::generate_for(
                &cfg.dataset,
                cfg.cluster.pairs.mode,
                cfg.seed,
            ))
        })
        .clone();
    let opts = RunOptions {
        faults,
        // endpoint-only probing: the server always records a final
        // probe on the assembled L, so final_objective stays reliable
        // while the probe thread costs nothing mid-run
        probe_every: u64::MAX / 2,
        probe_pairs: (50, 50),
        ..RunOptions::default()
    };
    simd::force_backend(backend);
    let run = Session::from_config(cfg)
        .engine("native")
        .data(data)
        .run_options(opts)
        .train_distributed();
    simd::force_backend(None);
    let run = run?;

    let final_objective =
        run.curve.final_objective().ok_or_else(|| {
            anyhow::anyhow!("run recorded no objective probe")
        })?;
    let steps_sent: u64 = run
        .worker_stats
        .iter()
        .map(|w| w.grads_sent)
        .sum();
    let grads_dropped: u64 = run
        .worker_stats
        .iter()
        .map(|w| w.grads_dropped)
        .sum();
    let wait_s: f64 =
        run.worker_stats.iter().map(|w| w.wait_s).sum();
    let max_staleness = run
        .worker_stats
        .iter()
        .map(|w| w.max_staleness)
        .max()
        .unwrap_or(0);
    Ok(Json::obj(vec![
        ("wall_s", Json::Num(run.wall_s)),
        ("applied_updates", Json::Num(run.applied_updates as f64)),
        (
            "updates_per_sec",
            Json::Num(
                run.applied_updates as f64 / run.wall_s.max(1e-9),
            ),
        ),
        ("slice_updates", Json::Num(run.slice_updates as f64)),
        ("broadcasts", Json::Num(run.broadcasts as f64)),
        ("param_msgs", Json::Num(run.param_msgs as f64)),
        ("last_loss", Json::Num(run.last_loss as f64)),
        ("final_objective", Json::Num(final_objective)),
        (
            "grad_bytes_received",
            Json::Num(run.grad_bytes_received as f64),
        ),
        ("param_bytes_sent", Json::Num(run.param_bytes_sent as f64)),
        (
            "grad_bytes_per_step",
            Json::Num(
                run.grad_bytes_received as f64
                    / steps_sent.max(1) as f64,
            ),
        ),
        ("misroutes", Json::Num(run.misroutes as f64)),
        ("grads_dropped", Json::Num(grads_dropped as f64)),
        ("wait_s", Json::Num(wait_s)),
        ("max_staleness", Json::Num(max_staleness as f64)),
        (
            "simd_active",
            Json::Num(
                (run.kernel.backend == KernelBackend::Simd) as u8
                    as f64,
            ),
        ),
    ]))
}

/// A process-mode train cell: spawn `dmlps cluster` on the resolved
/// config (real sockets, real child processes) and lift the combined
/// `cluster.json` server metrics into the trial record. The kernel
/// backend travels as `DMLPS_KERNEL` since `force_backend` cannot
/// reach another process.
fn process_cell(
    exp: &LabExperiment,
    cell: &Cell,
    trial: usize,
    output: &std::path::Path,
) -> anyhow::Result<Json> {
    let (cfg, faults, backend) = train_config(exp, cell, trial)?;
    anyhow::ensure!(
        faults.is_perfect(),
        "process-mode cells cannot inject transport faults"
    );
    let dir = output.join(format!(
        "{}_c{}_t{}",
        exp.name, cell.index, trial
    ));
    std::fs::create_dir_all(&dir)?;
    let cfg_path = dir.join("config.json");
    cfg.save(&cfg_path)?;

    let exe = std::env::current_exe()?;
    let started = Instant::now();
    let status = std::process::Command::new(&exe)
        .arg("cluster")
        .arg("--config")
        .arg(&cfg_path)
        .arg("--run-dir")
        .arg(&dir)
        .arg("--engine")
        .arg("native")
        .arg("--timeout-s")
        .arg("600")
        .env(
            "DMLPS_KERNEL",
            backend.map(|b| b.name()).unwrap_or("auto"),
        )
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::inherit())
        .status()?;
    let spawn_wall_s = started.elapsed().as_secs_f64();
    anyhow::ensure!(
        status.success(),
        "dmlps cluster exited with {status}"
    );

    let combined = Json::parse_file(&dir.join("cluster.json"))?;
    let mut metrics = BTreeMap::new();
    metrics.insert(
        "spawn_wall_s".to_string(),
        Json::Num(spawn_wall_s),
    );
    metrics.insert(
        "attempts".to_string(),
        Json::Num(combined.get("attempts").as_f64().unwrap_or(1.0)),
    );
    // lift every scalar server metric (applied_updates, wall_s,
    // final_objective, wire byte counters, ...) without hardcoding the
    // report's key list here
    if let Some(map) = combined.get("server").as_obj() {
        for (k, v) in map {
            if let Json::Num(x) = v {
                metrics.insert(k.clone(), Json::Num(*x));
            }
        }
    }
    Ok(Json::Obj(metrics))
}

// ----------------------------------------------------------------------
// hotpath cells
// ----------------------------------------------------------------------

fn hotpath_cell(
    exp: &LabExperiment,
    cell: &Cell,
    caches: &mut Caches,
) -> anyhow::Result<Json> {
    let get = |key: &str, default: usize| -> anyhow::Result<usize> {
        match exp.overrides.get(key) {
            None => Ok(default),
            Some(v) => v.as_usize().ok_or_else(|| {
                anyhow::anyhow!("override '{key}' must be an integer")
            }),
        }
    };
    let d = get("d", 780)?.max(1);
    let k = get("k", 600)?.max(1).min(d);
    let batch = get("batch", 500)?.max(1);

    let mut threads = 0usize;
    let mut backend = None;
    for (key, v) in &cell.params {
        match key.as_str() {
            "threads" => {
                threads = v.as_usize().ok_or_else(|| {
                    anyhow::anyhow!("'threads' must be an integer")
                })?
            }
            "kernel_backend" => {
                backend = parse_backend(v.as_str().unwrap_or(""))?
            }
            other => {
                anyhow::bail!("unhandled hotpath axis '{other}'")
            }
        }
    }

    let regen = !matches!(
        &caches.hotpath,
        Some(h) if h.d == d && h.k == k && h.batch == batch
    );
    if regen {
        let mut rng = Pcg32::new(3);
        let mut l = Mat::zeros(k, d);
        rng.fill_gaussian(&mut l.data, 0.0, 0.1);
        let mut dsb = vec![0.0f32; batch * d];
        let mut ddb = vec![0.0f32; batch * d];
        rng.fill_gaussian(&mut dsb, 0.0, 1.0);
        rng.fill_gaussian(&mut ddb, 0.0, 1.0);
        caches.hotpath = Some(HotpathInputs { d, k, batch, l, dsb, ddb });
    }
    let inputs = caches.hotpath.as_ref().unwrap();

    let mut eng = if threads == 0 {
        NativeEngine::new()
    } else {
        NativeEngine::with_threads(threads)
    };
    let mb = MinibatchRef::new(&inputs.dsb, &inputs.ddb, batch, batch, d);
    let mut g = Mat::zeros(k, d);

    simd::force_backend(backend);
    let outcome = timed_loss_grad(&mut eng, &inputs.l, &mb, &mut g);
    simd::force_backend(None);
    let (total_s, iters, simd_active) = outcome?;

    let flops = DmlProblem::new(d, k, 1.0).step_flops(batch, batch);
    let mean_s = total_s / iters as f64;
    Ok(Json::obj(vec![
        ("loss_grad_gflops", Json::Num(flops / mean_s / 1e9)),
        ("loss_grad_mean_s", Json::Num(mean_s)),
        ("iters", Json::Num(iters as f64)),
        ("engine_threads", Json::Num(eng.threads() as f64)),
        ("simd_active", Json::Num(simd_active as u8 as f64)),
    ]))
}

/// The timed hotpath loop, separated so the caller restores the forced
/// kernel backend on *every* exit path. Returns
/// `(total_s, iters, simd_active)`.
fn timed_loss_grad(
    eng: &mut NativeEngine,
    l: &Mat,
    mb: &MinibatchRef<'_>,
    g: &mut Mat,
) -> anyhow::Result<(f64, usize, bool)> {
    // warmup allocates engine scratch outside the timed loop
    eng.loss_grad(l, mb, 1.0, g)?;
    let target = Duration::from_millis(200);
    let started = Instant::now();
    let mut iters = 0usize;
    while iters < 3 || started.elapsed() < target {
        eng.loss_grad(l, mb, 1.0, g)?;
        iters += 1;
    }
    let total_s = started.elapsed().as_secs_f64();
    anyhow::ensure!(
        g.data.iter().all(|v| v.is_finite()),
        "loss_grad produced a non-finite gradient"
    );
    let simd_active = simd::report().backend == KernelBackend::Simd;
    Ok((total_s, iters, simd_active))
}

// ----------------------------------------------------------------------
// serving cells
// ----------------------------------------------------------------------

fn serving_cell(
    exp: &LabExperiment,
    cell: &Cell,
    caches: &mut Caches,
) -> anyhow::Result<Json> {
    let get = |key: &str, default: usize| -> anyhow::Result<usize> {
        match exp.overrides.get(key) {
            None => Ok(default),
            Some(v) => v.as_usize().ok_or_else(|| {
                anyhow::anyhow!("override '{key}' must be an integer")
            }),
        }
    };
    let n_gallery = get("gallery", 2_000)?.max(16);
    let n_queries = get("queries", 400)?.max(1);
    let k = get("k", 10)?.max(1);
    let kproj = get("kproj", 16)?.max(1);

    let mut nclusters = 32usize;
    let mut scan = "exact".to_string();
    let mut batch = 1usize;
    for (key, v) in &cell.params {
        match key.as_str() {
            "nclusters" => {
                nclusters = v.as_usize().unwrap_or(nclusters)
            }
            "scan" => {
                scan = v.as_str().unwrap_or("exact").to_string()
            }
            "batch" => batch = v.as_usize().unwrap_or(1).max(1),
            other => {
                anyhow::bail!("unhandled serving axis '{other}'")
            }
        }
    }

    // one epoch build per distinct (gallery, queries, kproj,
    // nclusters) — scan mode and batch reuse it
    let cache_key =
        format!("g{n_gallery}q{n_queries}p{kproj}c{nclusters}");
    let entry = caches
        .serve
        .entry(cache_key)
        .or_insert_with(|| {
            // the serving_load recipe: gallery and queries from one
            // synthetic family so coarse clusters are real structure
            let mut spec = SyntheticSpec::tiny();
            spec.dim = 32;
            spec.n_classes = 16;
            spec.separation = 4.0;
            let mut rng = Pcg32::with_stream(7, 0x5EED);
            let gallery = spec.generate_with(&mut rng, n_gallery);
            let queries =
                spec.generate_with(&mut rng, n_queries).x;
            let mut l = Mat::zeros(kproj, spec.dim);
            Pcg32::new(21).fill_gaussian(&mut l.data, 0.0, 0.3);
            let model = MetricModel::new(l, &Preset::Tiny.config());
            let engine = ServeEngine::new(
                model,
                &gallery,
                ServeConfig {
                    nclusters,
                    ..ServeConfig::default()
                },
            );
            Arc::new((engine, queries))
        })
        .clone();
    let (engine, queries) = (&entry.0, &entry.1);

    let mode = match scan.as_str() {
        "exact" => ScanMode::Exact,
        "approx" => ScanMode::Probe(default_nprobe(nclusters)),
        other => anyhow::bail!("unknown scan mode '{other}'"),
    };

    // recall@k of `mode` against the exact reference
    let n_recall = queries.rows.min(100);
    let mut hit = 0usize;
    let mut denom = 0usize;
    for r in 0..n_recall {
        let q = queries.row(r);
        let (_, exact) = engine.query_one(q, k, ScanMode::Exact);
        let (_, got) = engine.query_one(q, k, mode);
        denom += exact.len();
        for (i, _) in &got {
            if exact.iter().any(|(j, _)| j == i) {
                hit += 1;
            }
        }
    }
    let recall = hit as f64 / denom.max(1) as f64;

    // closed-loop batches against the in-process engine
    let n_batches = (256 / batch).max(20);
    let mut x = Mat::zeros(batch, queries.cols);
    let mut lat_ms = Vec::with_capacity(n_batches);
    let started = Instant::now();
    for b in 0..n_batches {
        for r in 0..batch {
            x.row_mut(r).copy_from_slice(
                queries.row((b * batch + r) % queries.rows),
            );
        }
        let sent = Instant::now();
        let ans = engine.query_batch(&x, k, mode);
        anyhow::ensure!(
            ans.results.len() == batch,
            "query_batch returned {} rows for a {batch}-row batch",
            ans.results.len()
        );
        lat_ms.push(sent.elapsed().as_secs_f64() * 1e3);
    }
    let wall = started.elapsed().as_secs_f64();
    let rows = (n_batches * batch) as f64;
    Ok(Json::obj(vec![
        ("qps", Json::Num(rows / wall.max(1e-9))),
        ("p50_ms", Json::Num(percentile(&lat_ms, 50.0))),
        ("p99_ms", Json::Num(percentile(&lat_ms, 99.0))),
        ("recall_at_k", Json::Num(recall)),
        ("batches", Json::Num(n_batches as f64)),
    ]))
}
