//! Experiment-matrix harness: a config-driven scenario runner with a
//! resource-telemetry sidecar — the subsystem that turns the repo's
//! hand-rolled benches into one regression-gated perf trajectory.
//!
//! A lab config is a JSON **array**: one global block (output dir,
//! `result_type`, trial count, sidecar cadence) followed by experiment
//! blocks whose `params` lists expand to their full cross-product
//! ([`matrix::expand`]), secretsharing-testbed style. The runner
//! ([`run`]) executes every cell × trial through the existing
//! [`Session`](crate::session::Session) API (or a spawned
//! `dmlps cluster` for process-mode cells), emitting one NDJSON record
//! per trial while a sidecar thread ([`sidecar::Sidecar`]) samples
//! `/proc` (RSS, CPU time, thread count, IO) into a parallel NDJSON
//! stream. [`report::merge_streams`] then flattens both streams into a
//! per-experiment `BENCH_lab_<name>.json` (average / median / details
//! aggregation plus per-cell resource stats), and [`diff_files`] is the
//! regression comparator `dmlps lab diff` exits nonzero on.
//!
//! ```text
//! [ {"output": "lab-out", "result_type": ["average","median","details"],
//!    "trials": 2},
//!   {"name": "train_matrix", "kind": "train", "preset": "tiny",
//!    "overrides": {"steps": 60},
//!    "params": {"workers": [1,2], "consistency": ["asp","bsp"]}},
//!   {"predefined": "hotpath_quick"} ]
//! ```

pub mod config;
pub mod diff;
pub mod matrix;
pub mod ndjson;
pub mod presets;
pub mod report;
pub mod runner;
pub mod sidecar;

pub use config::{
    parse_fault_profile, ExecMode, LabConfig, LabExperiment, LabGlobal,
    LabKind, ResultType,
};
pub use diff::{diff_files, diff_reports};
pub use matrix::{cell_key, expand, Cell};
pub use report::merge_streams;
pub use runner::run;
pub use sidecar::{ResourceSample, Sidecar};
