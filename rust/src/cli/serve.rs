//! `dmlps serve` — the retrieval server over a saved metric model.
//!
//! Loads a `DMLPSMM1` artifact, regenerates the preset's dataset
//! deterministically (same `(config, seed)` → same gallery as any
//! in-process test), projects the chosen split through the model, and
//! answers top-k queries over the serving wire protocol
//! ([`crate::serve`]). With `--reload-secs N` the model file is polled
//! for a newer mtime and hot-swapped atomically mid-traffic.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, SystemTime};

use crate::data::ExperimentData;
use crate::linalg::io::atomic_write;
use crate::ps::net::NetAddr;
use crate::serve::{ServeConfig, ServeEngine, ServeLimits, ServeServer};

use super::{common_parser, load_config, load_model};

pub fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let p = common_parser(
        "dmlps serve",
        "serve batched top-k retrieval over a saved metric model",
    )
    .req("model",
         "path to a saved metric model (DMLPSMM1, or legacy DMLPSMAT)")
    .opt("addr", "127.0.0.1:0",
         "listen address: host:port (0 = kernel-picked) or unix:/path")
    .opt("addr-file", "",
         "write the actually-bound address here once listening")
    .opt("gallery", "train", "dataset split to serve: train|test")
    .opt("nclusters", "0",
         "coarse quantizer clusters (0 = auto, ~sqrt(gallery))")
    .opt("kmeans-iters", "8", "quantizer Lloyd iterations")
    .opt("max-batch", "4096", "largest query batch answered")
    .opt("max-k", "1024", "largest per-row k answered")
    .opt("reload-secs", "0",
         "poll the model file every N seconds and hot-swap the engine \
          when its mtime changes (0 = never reload)");
    let a = p.parse(args)?;
    let cfg = load_config(&a)?;

    let model_path = a.get("model").to_string();
    let (model, legacy) = load_model(Path::new(&model_path))?;
    anyhow::ensure!(
        model.dim() == cfg.dataset.dim,
        "model dim {} != dataset dim {}", model.dim(), cfg.dataset.dim
    );

    // the gallery is regenerated, not shipped: `(dataset config, seed)`
    // fully determines it, so server and clients agree on row indices
    let data = Arc::new(ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    ));
    let split = a.get("gallery").to_string();
    anyhow::ensure!(
        split == "train" || split == "test",
        "--gallery must be train|test, got '{split}'"
    );
    fn pick<'a>(d: &'a ExperimentData, split: &str) -> &'a crate::data::Dataset {
        if split == "test" { &d.test } else { &d.train }
    }

    let serve_cfg = ServeConfig {
        nclusters: a.get_usize("nclusters")?,
        kmeans_iters: a.get_usize("kmeans-iters")?,
        ..ServeConfig::default()
    };
    let engine = Arc::new(ServeEngine::new(
        model.clone(),
        pick(&data, &split),
        serve_cfg,
    ));
    let limits = ServeLimits {
        max_rows: a.get_usize("max-batch")?,
        max_k: a.get_usize("max-k")?,
        ..ServeLimits::default()
    };

    let server = ServeServer::bind(
        &NetAddr::parse(a.get("addr"))?,
        Arc::clone(&engine),
        limits,
    )?;
    let bound = server.local_addr()?;
    {
        let e = engine.snapshot();
        println!(
            "serve: listening on {bound} — gallery {} ({} rows, dim {}), \
             model {}x{}{}, {} clusters, epoch v{}",
            split, e.gallery_len(), model.dim(), model.k(), model.dim(),
            if legacy { " (legacy matrix)" } else { "" },
            e.quantizer().nclusters(), e.version(),
        );
    }
    if !a.get("addr-file").is_empty() {
        atomic_write(Path::new(a.get("addr-file")), |w| {
            use std::io::Write;
            w.write_all(bound.to_string().as_bytes())?;
            Ok(())
        })?;
    }

    let reload_secs = a.get_u64("reload-secs")?;
    if reload_secs > 0 {
        let engine = Arc::clone(&engine);
        let data = Arc::clone(&data);
        let split = split.clone();
        let mut last = mtime_of(&model_path);
        std::thread::Builder::new()
            .name("serve-reload".into())
            .spawn(move || loop {
                std::thread::sleep(Duration::from_secs(reload_secs));
                let now = mtime_of(&model_path);
                if now == last {
                    continue;
                }
                // a half-written file fails to load: keep the running
                // epoch and retry on the next poll
                match load_model(Path::new(&model_path)) {
                    Ok((m, _)) => {
                        let v = engine.swap(m, pick(&data, &split));
                        println!("serve: hot-swapped model, epoch v{v}");
                        last = now;
                    }
                    Err(e) => {
                        eprintln!("serve: reload failed ({e}), will retry");
                    }
                }
            })?;
    }

    server.run()
}

fn mtime_of(path: &str) -> Option<SystemTime> {
    std::fs::metadata(path).and_then(|m| m.modified()).ok()
}
