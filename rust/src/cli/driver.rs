//! Shared run drivers used by the CLI, examples, and benches — one
//! implementation of "train this config" / "simulate this cluster" so
//! every entry point produces identical, comparable runs.

use std::sync::Arc;

use crate::baselines::{ApTrace, LearnedMetric};
use crate::config::ExperimentConfig;
use crate::data::{partition_pairs, ExperimentData};
use crate::dml::{
    native_factory, DmlProblem, Engine, EngineFactory, LrSchedule,
    MinibatchRef, ObjectiveProbe,
};
use crate::linalg::Mat;
use crate::metrics::{Curve, Stopwatch};
use crate::ps::{run_training, RunOptions, TrainResult};
use crate::simcluster::{
    calibrate_grad_seconds, DmlWorkload, NetworkModel, SimConfig,
    Simulator,
};
use crate::util::rng::Pcg32;

/// Resolve an engine factory by name: "native", "xla", or "auto"
/// (xla when the runtime is compiled in and artifacts are present, else
/// native). Per-worker compute width is applied by the worker itself:
/// `run_training` copies `cluster.threads_per_worker` into
/// `WorkerConfig::threads` and each worker calls `Engine::set_threads`.
pub fn engine_factory(
    name: &str,
    cfg: &ExperimentConfig,
) -> anyhow::Result<EngineFactory> {
    match name {
        "native" => Ok(native_factory()),
        "xla" => {
            anyhow::ensure!(
                cfg!(feature = "xla"),
                "this binary was built without the XLA/PJRT runtime \
                 (rebuild with `--features xla`)"
            );
            let variant = cfg.artifact_variant.clone().ok_or_else(|| {
                anyhow::anyhow!("config has no artifact variant for xla")
            })?;
            anyhow::ensure!(
                crate::runtime::artifacts_available(),
                "artifacts not built (run `make artifacts`)"
            );
            Ok(crate::runtime::xla_factory(&variant))
        }
        "auto" => {
            if cfg!(feature = "xla")
                && crate::runtime::artifacts_available()
                && cfg.artifact_variant.is_some()
            {
                engine_factory("xla", cfg)
            } else {
                engine_factory("native", cfg)
            }
        }
        other => anyhow::bail!("unknown engine '{other}' (native|xla|auto)"),
    }
}

/// Single-threaded SGD training (the paper's §5.4 single-thread setting,
/// used for the Fig 4a/4b method comparison). Records an objective curve
/// and an AP-vs-time trace on held-out test pairs.
pub struct SingleThreadRun {
    pub l: Mat,
    pub curve: Curve,
    pub ap_trace: ApTrace,
    pub wall_s: f64,
}

pub fn train_single_thread(
    cfg: &ExperimentConfig,
    data: &ExperimentData,
    engine: &mut dyn Engine,
    probe_every: usize,
) -> anyhow::Result<SingleThreadRun> {
    let problem =
        DmlProblem::new(cfg.dataset.dim, cfg.model.k, cfg.optim.lambda);
    let mut l = problem.init_l(cfg.model.init_scale, cfg.seed);
    let lr = LrSchedule::new(cfg.optim.lr, cfg.optim.lr_decay);
    let probe = ObjectiveProbe::new(
        &data.train,
        &data.pairs,
        500.min(data.pairs.similar.len()),
        500.min(data.pairs.dissimilar.len()),
        cfg.seed ^ 0xB0B,
    );
    let (bs, bd, d) = (cfg.optim.batch_sim, cfg.optim.batch_dis,
                       cfg.dataset.dim);
    let mut rng = Pcg32::with_stream(cfg.seed, 0x51);
    let mut ds_buf = vec![0.0f32; bs * d];
    let mut dd_buf = vec![0.0f32; bd * d];
    let mut curve = Curve::new("ours (single thread)");
    let mut ap_trace = ApTrace::new();
    let watch = Stopwatch::start();
    curve.push(0.0, 0, probe.eval(engine, &l, cfg.optim.lambda) as f64);
    for step in 0..cfg.optim.steps {
        fill_batch(&data.train, &data.pairs, &mut rng, &mut ds_buf,
                   &mut dd_buf, bs, bd);
        let batch = MinibatchRef::new(&ds_buf, &dd_buf, bs, bd, d);
        engine.step(&mut l, &batch, cfg.optim.lambda, lr.at(step))?;
        if (step + 1) % probe_every == 0 || step + 1 == cfg.optim.steps {
            let t = watch.elapsed_s();
            curve.push(t, step + 1,
                       probe.eval(engine, &l, cfg.optim.lambda) as f64);
            ap_trace.push((t, ap_of_l(engine, &l, data)?));
        }
    }
    Ok(SingleThreadRun { l, curve, ap_trace, wall_s: watch.elapsed_s() })
}

/// AP of a learned L on the held-out test pairs (scores through the
/// factored form; materializing M = LᵀL at d=780 would be wasteful).
pub fn ap_of_l(
    engine: &mut dyn Engine,
    l: &Mat,
    data: &ExperimentData,
) -> anyhow::Result<f64> {
    let (sim, dis) =
        crate::eval::score_pairs(engine, l, &data.test, &data.test_pairs)?;
    Ok(crate::eval::average_precision(&sim, &dis))
}

/// AP of the Euclidean baseline on the held-out test pairs.
pub fn ap_euclidean(data: &ExperimentData) -> f64 {
    let (sim, dis) =
        crate::eval::score_pairs_euclidean(&data.test, &data.test_pairs);
    crate::eval::average_precision(&sim, &dis)
}

fn fill_batch(
    train: &crate::data::Dataset,
    pairs: &crate::data::PairSet,
    rng: &mut Pcg32,
    ds_buf: &mut [f32],
    dd_buf: &mut [f32],
    bs: usize,
    bd: usize,
) {
    let d = train.dim();
    for r in 0..bs {
        let p = pairs.similar[rng.index(pairs.similar.len())];
        train.diff_into(p.i as usize, p.j as usize,
                        &mut ds_buf[r * d..(r + 1) * d]);
    }
    for r in 0..bd {
        let p = pairs.dissimilar[rng.index(pairs.dissimilar.len())];
        train.diff_into(p.i as usize, p.j as usize,
                        &mut dd_buf[r * d..(r + 1) * d]);
    }
}

/// Run the real threaded parameter server on a config.
pub fn train_distributed(
    cfg: &ExperimentConfig,
    data: &ExperimentData,
    engine_name: &str,
    opts: &RunOptions,
) -> anyhow::Result<TrainResult> {
    let engines = engine_factory(engine_name, cfg)?;
    let dataset = Arc::new(clone_dataset(&data.train));
    run_training(cfg, dataset, &data.pairs, engines, opts)
}

fn clone_dataset(ds: &crate::data::Dataset) -> crate::data::Dataset {
    crate::data::Dataset {
        x: ds.x.clone(),
        labels: ds.labels.clone(),
        n_classes: ds.n_classes,
    }
}

/// Cost knobs for a simulated run; default derives everything from the
/// config's own (scaled) shape. For paper-true clocking, override
/// `grad_seconds` (FLOP-extrapolated) and `bytes_per_msg`.
#[derive(Clone, Copy, Debug)]
pub struct SimKnobs {
    pub grad_seconds: f64,
    pub bytes_per_msg: Option<f64>,
    pub total_updates: u64,
}

/// One simulated-cluster convergence run at `machines × cores`.
///
/// `knobs.grad_seconds` should come from [`calibrate_for`] (possibly
/// FLOP-extrapolated to the paper-true shape) so the simulated clock is
/// anchored to real measured compute cost. Errors when the materialized
/// pair sets cannot cover `machines` workers.
pub fn simulate_convergence(
    cfg: &ExperimentConfig,
    data: &ExperimentData,
    machines: usize,
    cores_per_machine: usize,
    knobs: SimKnobs,
) -> anyhow::Result<crate::simcluster::SimResult> {
    let problem =
        DmlProblem::new(cfg.dataset.dim, cfg.model.k, cfg.optim.lambda);
    let shards = partition_pairs(&data.pairs, machines, cfg.seed ^ 0xFA)?;
    let dataset = Arc::new(clone_dataset(&data.train));
    let mut workload = DmlWorkload::new(
        problem,
        cfg.model.init_scale,
        dataset,
        shards,
        cfg.optim.batch_sim,
        cfg.optim.batch_dis,
        (500, 500),
        cfg.seed,
    );
    let n_params = (cfg.model.k * cfg.dataset.dim) as f64;
    let bytes = knobs.bytes_per_msg.unwrap_or(n_params * 4.0);
    let sim_cfg = SimConfig {
        machines,
        cores_per_machine,
        grad_seconds: knobs.grad_seconds,
        // server-side apply: streaming axpy over the parameters at
        // ~4 GB/s effective memory bandwidth (two passes of 4 bytes)
        apply_seconds: bytes * 2.0 / 4.0e9,
        bytes_per_msg: bytes,
        network: NetworkModel::ten_gbe(),
        jitter: 0.05,
        total_updates: knobs.total_updates,
        probe_every: (knobs.total_updates / 40).max(1),
        broadcast_every: 1,
        lr: LrSchedule::new(cfg.optim.lr, cfg.optim.lr_decay),
        seed: cfg.seed,
    };
    Ok(Simulator::new(sim_cfg, &mut workload).run())
}

/// A dimension-scaled copy of a config for simulator numerics, plus the
/// FLOP ratio to the paper-true shape.
///
/// The simulator runs *real* gradients serially on this box, so Fig 2/3
/// sweeps use a scaled shape for the numerics while the simulated clock
/// charges each gradient the *extrapolated paper-true* cost (FLOP-ratio
/// scaling of the calibrated native step time). Convergence shape is
/// preserved (same algorithm, same staleness structure); absolute
/// objective values are those of the scaled problem — which is what we
/// compare across core counts, never against the paper's absolute values.
pub struct SimScaled {
    pub cfg: ExperimentConfig,
    /// paper-true FLOPs / scaled FLOPs per minibatch gradient.
    pub flop_ratio: f64,
    /// paper-true parameter bytes per message.
    pub paper_bytes: f64,
}

pub fn sim_scaled(preset: crate::config::Preset) -> SimScaled {
    use crate::config::{PaperShape, Preset, PAPER_SHAPES};
    let mut cfg = preset.config();
    let paper: &PaperShape = match preset {
        Preset::Mnist | Preset::Tiny => &PAPER_SHAPES[0],
        Preset::Imnet60kScaled => &PAPER_SHAPES[1],
        Preset::Imnet1mScaled => &PAPER_SHAPES[2],
    };
    // Scale to ~10 ms/grad on this box: divide d, k, batch.
    let (d, k, bs) = match preset {
        Preset::Mnist => (260, 200, 160),
        Preset::Imnet60kScaled => (512, 128, 25),
        Preset::Imnet1mScaled => (512, 64, 125),
        Preset::Tiny => (16, 8, 4),
    };
    cfg.dataset.dim = d;
    cfg.model.k = k;
    cfg.optim.batch_sim = bs;
    cfg.optim.batch_dis = bs;
    cfg.dataset.name = format!("{}_sim", cfg.dataset.name);
    cfg.artifact_variant = None;
    // keep data volume small enough for quick generation
    cfg.dataset.n_train = cfg.dataset.n_train.min(20_000);
    cfg.dataset.n_similar = cfg.dataset.n_similar.min(50_000);
    cfg.dataset.n_dissimilar = cfg.dataset.n_dissimilar.min(50_000);
    let scaled_flops = 4.0 * (2.0 * bs as f64) / 2.0 * k as f64
        * d as f64 * 2.0;
    let paper_flops = paper.step_flops();
    SimScaled {
        cfg,
        flop_ratio: paper_flops / scaled_flops,
        paper_bytes: paper.n_params() as f64 * 4.0,
    }
}

/// Calibrate per-core gradient seconds for a config on this machine.
pub fn calibrate_for(cfg: &ExperimentConfig) -> f64 {
    let problem =
        DmlProblem::new(cfg.dataset.dim, cfg.model.k, cfg.optim.lambda);
    calibrate_grad_seconds(
        &problem,
        cfg.optim.batch_sim,
        cfg.optim.batch_dis,
        5,
    )
}

/// Fit our method plus the three baselines, returning labeled AP traces
/// (the Fig 4a payload). Baselines run on the same train/test pairs.
pub fn ap_traces_all_methods(
    cfg: &ExperimentConfig,
    data: &ExperimentData,
    probe_every: usize,
    xing_iters: usize,
    itml_sweeps: usize,
) -> anyhow::Result<Vec<(String, ApTrace)>> {
    use crate::baselines::{Itml, ItmlConfig, Kiss, KissConfig, Xing2002,
                           Xing2002Config};
    let mut out = Vec::new();

    // ours (single-thread, native engine — MATLAB-comparable setting)
    let mut engine = crate::dml::NativeEngine::new();
    let run = train_single_thread(cfg, data, &mut engine, probe_every)?;
    out.push(("ours".to_string(), run.ap_trace));

    // Xing2002
    let x = Xing2002::new(Xing2002Config {
        iters: xing_iters,
        ..Default::default()
    });
    let (_, trace) =
        x.fit_traced(&data.train, &data.pairs, &data.test,
                     &data.test_pairs);
    out.push(("Xing2002".to_string(), trace));

    // ITML
    let itml = Itml::new(ItmlConfig {
        sweeps: itml_sweeps,
        ..Default::default()
    });
    let (_, trace) =
        itml.fit_traced(&data.train, &data.pairs, &data.test,
                        &data.test_pairs);
    out.push(("ITML".to_string(), trace));

    // KISS (one-shot: trace has a single point)
    let watch = Stopwatch::start();
    let kiss = Kiss::new(KissConfig {
        // PCA only for invertibility (paper §5.4); keep full dim when
        // the pair count supports it
        pca_dim: cfg.dataset.dim.min(data.pairs.similar.len() / 20).max(8),
        ..Default::default()
    });
    let metric = kiss.fit(&data.train, &data.pairs);
    let ap = metric.ap(&data.test, &data.test_pairs);
    out.push(("KISS".to_string(), vec![(watch.elapsed_s(), ap)]));

    // Euclidean reference line
    let ap = LearnedMetric::Euclidean.ap(&data.test, &data.test_pairs);
    out.push(("Euclidean".to_string(), vec![(0.0, ap)]));
    Ok(out)
}
