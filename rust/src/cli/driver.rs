//! Legacy run drivers, kept as thin compatibility shims.
//!
//! Every implementation here moved behind the
//! [`Session`](crate::session::Session) builder — the one entry point
//! the CLI, examples, and benches consume. What remains are the
//! historical calling conventions, each delegating to exactly the code
//! the session executors run (the `api_session` golden tests pin the
//! shims bit-identical), plus re-exports so old import paths keep
//! compiling:
//!
//! * [`train_single_thread`] (deprecated) →
//!   [`Session::train_sequential`](crate::session::Session::train_sequential)
//! * [`train_distributed`] →
//!   [`Session::train_distributed`](crate::session::Session::train_distributed)
//! * [`simulate_convergence`] →
//!   [`Session::simulate`](crate::session::Session::simulate)
//! * [`engine_factory`] → [`crate::dml::engine_factory`]
//! * [`ap_of_l`] / [`ap_euclidean`] → [`crate::eval`]
//! * [`SimKnobs`] / [`SimScaled`] / [`sim_scaled`] / [`calibrate_for`]
//!   → [`crate::session`]

use std::sync::Arc;

use crate::baselines::ApTrace;
use crate::config::ExperimentConfig;
use crate::data::ExperimentData;
use crate::dml::Engine;
use crate::linalg::Mat;
use crate::metrics::{Curve, Stopwatch};
use crate::ps::{RunOptions, TrainResult};
use crate::session::clone_dataset;
use crate::simcluster::SimResult;

pub use crate::dml::engine_factory;
pub use crate::eval::{ap_euclidean, ap_of_l};
pub use crate::session::{calibrate_for, sim_scaled, SimKnobs, SimScaled};

/// Single-threaded training report (legacy shape; the session returns
/// the unified [`Run`](crate::session::Run) instead).
pub struct SingleThreadRun {
    pub l: Mat,
    pub curve: Curve,
    pub ap_trace: ApTrace,
    pub wall_s: f64,
}

/// Single-threaded SGD training (the paper's §5.4 single-thread setting,
/// used for the Fig 4a/4b method comparison). Records an objective curve
/// and an AP-vs-time trace on held-out test pairs.
#[deprecated(
    since = "0.2.0",
    note = "use session::Session::from_config(cfg).train_sequential()"
)]
pub fn train_single_thread(
    cfg: &ExperimentConfig,
    data: &ExperimentData,
    engine: &mut dyn Engine,
    probe_every: usize,
) -> anyhow::Result<SingleThreadRun> {
    // same core Session::train_sequential runs; (500, 500) is the
    // probe-subsample bound this entry point always used
    let out = crate::session::run_sequential(
        cfg, data, engine, probe_every, (500, 500), None,
    )?;
    Ok(SingleThreadRun {
        l: out.l,
        curve: out.curve,
        ap_trace: out.ap_trace,
        wall_s: out.wall_s,
    })
}

/// Run the real threaded parameter server on a config (legacy calling
/// convention; same executor core as
/// [`Session::train_distributed`](crate::session::Session::train_distributed),
/// borrowing the caller's pair set instead of copying it into a
/// session).
pub fn train_distributed(
    cfg: &ExperimentConfig,
    data: &ExperimentData,
    engine_name: &str,
    opts: &RunOptions,
) -> anyhow::Result<TrainResult> {
    let engines = engine_factory(engine_name, cfg)?;
    crate::session::run_distributed(
        cfg,
        Arc::new(clone_dataset(&data.train)),
        &data.pairs,
        engines,
        opts,
        None,
    )
}

/// One simulated-cluster convergence run at `machines × cores` (legacy
/// calling convention; same executor core as
/// [`Session::simulate`](crate::session::Session::simulate), borrowing
/// the caller's data instead of copying it into a session).
pub fn simulate_convergence(
    cfg: &ExperimentConfig,
    data: &ExperimentData,
    machines: usize,
    cores_per_machine: usize,
    knobs: SimKnobs,
) -> anyhow::Result<SimResult> {
    crate::session::run_simulated(
        cfg, data, machines, cores_per_machine, knobs,
    )
}

/// Fit our method plus the three baselines, returning labeled AP traces
/// (the Fig 4a payload). Baselines run on the same train/test pairs.
pub fn ap_traces_all_methods(
    cfg: &ExperimentConfig,
    data: &ExperimentData,
    probe_every: usize,
    xing_iters: usize,
    itml_sweeps: usize,
) -> anyhow::Result<Vec<(String, ApTrace)>> {
    use crate::baselines::{Itml, ItmlConfig, Kiss, KissConfig,
                           LearnedMetric, Xing2002, Xing2002Config};
    let mut out = Vec::new();

    // ours (single-thread, native engine — MATLAB-comparable setting)
    let mut engine = crate::dml::NativeEngine::new();
    let run = crate::session::run_sequential(
        cfg, data, &mut engine, probe_every, (500, 500), None,
    )?;
    out.push(("ours".to_string(), run.ap_trace));

    // Xing2002
    let x = Xing2002::new(Xing2002Config {
        iters: xing_iters,
        ..Default::default()
    });
    let (_, trace) =
        x.fit_traced(&data.train, &data.pairs, &data.test,
                     &data.test_pairs);
    out.push(("Xing2002".to_string(), trace));

    // ITML
    let itml = Itml::new(ItmlConfig {
        sweeps: itml_sweeps,
        ..Default::default()
    });
    let (_, trace) =
        itml.fit_traced(&data.train, &data.pairs, &data.test,
                        &data.test_pairs);
    out.push(("ITML".to_string(), trace));

    // KISS (one-shot: trace has a single point)
    let watch = Stopwatch::start();
    let kiss = Kiss::new(KissConfig {
        // PCA only for invertibility (paper §5.4); keep full dim when
        // the pair count supports it
        pca_dim: cfg.dataset.dim.min(data.pairs.similar.len() / 20).max(8),
        ..Default::default()
    });
    let metric = kiss.fit(&data.train, &data.pairs);
    let ap = metric.ap(&data.test, &data.test_pairs);
    out.push(("KISS".to_string(), vec![(watch.elapsed_s(), ap)]));

    // Euclidean reference line
    let ap = LearnedMetric::Euclidean.ap(&data.test, &data.test_pairs);
    out.push(("Euclidean".to_string(), vec![(0.0, ap)]));
    Ok(out)
}
