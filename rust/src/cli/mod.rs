//! `dmlps` CLI: the launcher for training, simulation, and evaluation.
//!
//! ```text
//! dmlps train    --preset mnist --workers 2 --engine auto [--save-model f]
//! dmlps simulate --preset mnist --cores 16,32,64,128,256
//! dmlps eval     --preset mnist --model f.bin
//! dmlps gen-data --preset mnist
//! dmlps inspect-artifacts
//! ```

pub mod driver;

use crate::config::{
    CompressionMode, Consistency, ExperimentConfig, PairMode, Preset,
};
use crate::data::{DatasetStats, ExperimentData};
use crate::util::cli::ArgParser;

pub fn main_entry() -> anyhow::Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_usage();
        return Ok(());
    }
    let sub = args.remove(0);
    match sub.as_str() {
        "train" => cmd_train(&args),
        "simulate" => cmd_simulate(&args),
        "eval" => cmd_eval(&args),
        "gen-data" => cmd_gen_data(&args),
        "inspect-artifacts" => cmd_inspect_artifacts(&args),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            anyhow::bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_usage() {
    println!(
        "dmlps — Large Scale Distributed Distance Metric Learning\n\
         (reproduction of Xie & Xing, 2014)\n\n\
         subcommands:\n\
         \x20 train              run the threaded async parameter server\n\
         \x20 simulate           discrete-event cluster scalability study\n\
         \x20 eval               evaluate a saved metric (PR curve, AP)\n\
         \x20 gen-data           print dataset statistics (Table 1)\n\
         \x20 inspect-artifacts  list AOT artifacts and shapes\n\n\
         run `dmlps <subcommand> --help` for options"
    );
}

/// Build a config from --preset/--config plus common overrides.
fn load_config(a: &crate::util::cli::Args) -> anyhow::Result<ExperimentConfig> {
    let mut cfg = if a.get("config").is_empty() {
        Preset::parse(a.get("preset"))?.config()
    } else {
        ExperimentConfig::load(std::path::Path::new(a.get("config")))?
    };
    if let Ok(w) = a.get_usize("workers") {
        if w > 0 {
            cfg.cluster.workers = w;
        }
    }
    if let Ok(s) = a.get_usize("steps") {
        if s > 0 {
            cfg.optim.steps = s;
        }
    }
    let cons = a.get("consistency");
    if !cons.is_empty() {
        cfg.cluster.consistency = Consistency::parse(cons)?;
    }
    if let Ok(seed) = a.get_u64("seed") {
        cfg.seed = seed;
    }
    if let Ok(t) = a.get_usize("threads") {
        if t > 0 {
            cfg.cluster.threads_per_worker = t;
        }
    }
    if let Ok(s) = a.get_usize("server-shards") {
        if s > 0 {
            cfg.cluster.server_shards = s;
        }
    }
    let pm = a.get("pairs-mode");
    if !pm.is_empty() {
        cfg.cluster.pairs.mode = PairMode::parse(pm)?;
    }
    // exactly -1 = keep the preset/config value; anything else must be
    // a valid knob value — never a silent fallback
    let x = a.get_f64("pair-noise")?;
    if x != -1.0 {
        anyhow::ensure!(
            (0.0..=1.0).contains(&x),
            "--pair-noise must be in [0, 1] (or -1 for preset default)"
        );
        cfg.cluster.pairs.label_noise = x as f32;
    }
    let x = a.get_f64("pair-imbalance")?;
    if x != -1.0 {
        anyhow::ensure!(
            x >= 0.0 && x.is_finite(),
            "--pair-imbalance must be finite and >= 0 \
             (or -1 for preset default)"
        );
        cfg.cluster.pairs.imbalance = x as f32;
    }
    let cm = a.get("compression");
    if !cm.is_empty() {
        cfg.cluster.compression.mode = CompressionMode::parse(cm)?;
    }
    let x = a.get_f64("keep")?;
    if x != -1.0 {
        anyhow::ensure!(
            x > 0.0 && x <= 1.0,
            "--keep must be in (0, 1] (or -1 for preset default)"
        );
        cfg.cluster.compression.keep = x as f32;
    }
    Ok(cfg)
}

fn common_parser(cmd: &str, about: &str) -> ArgParser {
    ArgParser::new(cmd, about)
        .opt("preset", "tiny", "tiny|mnist|imnet60k|imnet1m")
        .opt("config", "", "path to a JSON experiment config")
        .opt("workers", "0", "override worker count (0 = preset)")
        .opt("steps", "0", "override steps per worker (0 = preset)")
        .opt("consistency", "", "asp|bsp|ssp:N (default from preset)")
        .opt("seed", "42", "PRNG seed")
        .opt("threads", "0",
             "compute threads per worker engine (0 = all cores)")
        .opt("server-shards", "0",
             "parameter-server shards (0 = preset; 1 = single server)")
        .opt("pairs-mode", "",
             "materialized|streaming pair pipeline (default from preset)")
        .opt("pair-noise", "-1",
             "streaming label-noise fraction in [0,1] (-1 = preset)")
        .opt("pair-imbalance", "-1",
             "streaming class-imbalance Zipf exponent (-1 = preset)")
        .opt("compression", "",
             "PS wire compression: none|int8|topk|topk_int8 \
              (default from preset)")
        .opt("keep", "-1",
             "top-k kept fraction in (0,1] (-1 = preset)")
}

fn cmd_train(args: &[String]) -> anyhow::Result<()> {
    let p = common_parser("dmlps train", "threaded async-PS training")
        .opt("engine", "auto", "native|xla|auto")
        .opt("save-model", "", "write learned L to this path")
        .opt("save-curve", "", "write convergence curve CSV to this path");
    let a = p.parse(args)?;
    let cfg = load_config(&a)?;
    println!(
        "train: dataset={} d={} k={} workers={} threads/worker={} \
         server-shards={} steps={} engine={} consistency={} pairs={} \
         compression={} (keep={})",
        cfg.dataset.name, cfg.dataset.dim, cfg.model.k,
        cfg.cluster.workers,
        if cfg.cluster.threads_per_worker == 0 {
            "auto".to_string()
        } else {
            cfg.cluster.threads_per_worker.to_string()
        },
        cfg.cluster.server_shards,
        cfg.optim.steps, a.get("engine"),
        cfg.cluster.consistency.name(),
        cfg.cluster.pairs.mode.name(),
        cfg.cluster.compression.mode.name(),
        cfg.cluster.compression.keep
    );
    // streaming mode never materializes the train pair sets — the
    // startup cost and memory term the implicit sampler removes
    let data = ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    );
    let opts = crate::ps::RunOptions::default();
    let result =
        driver::train_distributed(&cfg, &data, a.get("engine"), &opts)?;
    let first = result.curve.points.first().map(|p| p.objective)
        .unwrap_or(f64::NAN);
    let last = result.curve.points.last().map(|p| p.objective)
        .unwrap_or(f64::NAN);
    println!(
        "done in {:.2}s: {} updates applied ({} slice updates over {} \
         shards), {} broadcasts, objective {first:.4} -> {last:.4}, \
         last minibatch loss {:.4}",
        result.wall_s, result.applied_updates, result.slice_updates,
        result.server_shards, result.broadcasts, result.last_loss
    );
    println!(
        "wire: {} grad bytes folded, {} param bytes broadcast \
         ({} param msgs)",
        result.grad_bytes_received, result.param_bytes_sent,
        result.param_msgs
    );
    for ws in &result.worker_stats {
        println!(
            "  worker {}: {} steps, {} grads sent ({} dropped, \
             {} grad bytes), {} params received ({} param bytes), \
             waited {:.2}s, max staleness {}, \
             {} pairs drawn ({} pair bytes resident)",
            ws.id, ws.steps_done, ws.grads_sent, ws.grads_dropped,
            ws.grad_bytes_sent, ws.params_received,
            ws.param_bytes_received, ws.wait_s, ws.max_staleness,
            ws.pairs_drawn, ws.pair_bytes
        );
    }
    let mut eng = crate::dml::NativeEngine::new();
    let ap = driver::ap_of_l(&mut eng, &result.l, &data)?;
    println!("test AP: {ap:.4} (Euclidean baseline {:.4})",
             driver::ap_euclidean(&data));
    if !a.get("save-model").is_empty() {
        result.l.save(std::path::Path::new(a.get("save-model")))?;
        println!("model saved to {}", a.get("save-model"));
    }
    if !a.get("save-curve").is_empty() {
        std::fs::write(a.get("save-curve"), result.curve.to_csv())?;
        println!("curve saved to {}", a.get("save-curve"));
    }
    Ok(())
}

fn cmd_simulate(args: &[String]) -> anyhow::Result<()> {
    let p = common_parser(
        "dmlps simulate",
        "discrete-event cluster scalability study (Fig 2/3)",
    )
    .opt("cores", "16,32,64,128,256", "total core counts to simulate")
    .opt("cores-per-machine", "16", "cores per simulated machine")
    .opt("updates", "2000", "total applied updates per run");
    let a = p.parse(args)?;
    let cfg = load_config(&a)?;
    // the simulator's workload consumes materialized pair shards; fail
    // clearly rather than silently ignoring a streaming request
    anyhow::ensure!(
        cfg.cluster.pairs.mode == PairMode::Materialized,
        "simulate supports only the materialized pair pipeline \
         (drop --pairs-mode streaming)"
    );
    // the simulator's cost model charges dense f32 bytes per message;
    // fail clearly rather than print dense-wire scalability numbers
    // for a config that asked for a compressed wire
    anyhow::ensure!(
        cfg.cluster.compression.mode == CompressionMode::None,
        "simulate models the dense f32 wire only \
         (drop --compression {})",
        cfg.cluster.compression.mode.name()
    );
    let data = ExperimentData::generate(&cfg.dataset, cfg.seed);
    let grad_s = driver::calibrate_for(&cfg);
    println!(
        "simulate: dataset={} d={} k={} calibrated grad time \
         {:.4}s/core-minibatch",
        cfg.dataset.name, cfg.dataset.dim, cfg.model.k, grad_s
    );
    let cpm = a.get_usize("cores-per-machine")?;
    let updates = a.get_usize("updates")? as u64;
    let mut meas = Vec::new();
    for cores in a.get_usize_list("cores")? {
        let machines = (cores / cpm).max(1);
        let r = driver::simulate_convergence(
            &cfg, &data, machines, cpm.min(cores),
            driver::SimKnobs {
                grad_seconds: grad_s,
                bytes_per_msg: None,
                total_updates: updates,
            },
        )?;
        println!(
            "  {:>4} cores ({} machines): {:.2} sim-s for {} updates, \
             mean staleness {:.2}, final objective {:.4}",
            machines * cpm.min(cores), machines, r.sim_seconds,
            r.applied_updates, r.mean_staleness,
            r.curve.final_objective().unwrap_or(f64::NAN)
        );
        meas.push((machines * cpm.min(cores), r.sim_seconds));
    }
    println!("\nspeedup (time to {} updates):", updates);
    for row in crate::metrics::speedup_table(meas) {
        println!(
            "  {:>4} cores: {:>8.2}s  speedup {:>5.2}x (linear {:>5.2}x)",
            row.cores, row.time_to_target_s, row.speedup, row.linear
        );
    }
    Ok(())
}

fn cmd_eval(args: &[String]) -> anyhow::Result<()> {
    let p = common_parser("dmlps eval", "evaluate a saved metric")
        .req("model", "path to a saved L matrix (DMLPSMAT)");
    let a = p.parse(args)?;
    let cfg = load_config(&a)?;
    // eval only touches the (always materialized) test pairs; honoring
    // the mode skips the pointless train-pair sampling
    let data = ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    );
    let l = crate::linalg::Mat::load(std::path::Path::new(a.get("model")))?;
    anyhow::ensure!(
        l.cols == cfg.dataset.dim,
        "model dim {} != dataset dim {}", l.cols, cfg.dataset.dim
    );
    let mut eng = crate::dml::NativeEngine::new();
    let (sim, dis) = crate::eval::score_pairs(
        &mut eng, &l, &data.test, &data.test_pairs,
    )?;
    let ap = crate::eval::average_precision(&sim, &dis);
    println!("test AP: {ap:.4} (Euclidean {:.4})",
             driver::ap_euclidean(&data));
    println!("PR curve (sampled):");
    let curve = crate::eval::pr_curve(&sim, &dis);
    let stride = (curve.len() / 20).max(1);
    println!("  recall  precision");
    for pt in curve.iter().step_by(stride) {
        println!("  {:.4}  {:.4}", pt.recall, pt.precision);
    }
    Ok(())
}

fn cmd_gen_data(args: &[String]) -> anyhow::Result<()> {
    let p = common_parser("dmlps gen-data",
                          "generate + describe synthetic datasets");
    let a = p.parse(args)?;
    let cfg = load_config(&a)?;
    let stats = DatasetStats::of(&cfg);
    println!(
        "| dataset | feat. dim | k | # parameters | # samples | \
         # similar | # dissimilar |"
    );
    println!("|---|---|---|---|---|---|---|");
    println!(
        "| {} | {} | {} | {} | {} | {} | {} |",
        stats.name, stats.feat_dim, stats.k, stats.param_str(),
        stats.n_samples, stats.n_similar, stats.n_dissimilar
    );
    let data = ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    );
    println!(
        "\ngenerated: train {}×{}, test {}×{}, pairs {}S/{}D \
         (labels verified: {})",
        data.train.n(), data.train.dim(), data.test.n(), data.test.dim(),
        data.pairs.similar.len(), data.pairs.dissimilar.len(),
        data.pairs.check_labels(&data.train)
    );
    Ok(())
}

fn cmd_inspect_artifacts(_args: &[String]) -> anyhow::Result<()> {
    let dir = crate::runtime::artifacts_dir();
    anyhow::ensure!(
        dir.join("manifest.json").is_file(),
        "no artifacts at {} (run `make artifacts`)", dir.display()
    );
    let m = crate::runtime::Manifest::load(&dir)?;
    println!("artifacts at {}:", dir.display());
    for (name, v) in &m.variants {
        println!(
            "  {name}: k={} d={} batch={}+{} eval_batch={}",
            v.k, v.d, v.bs, v.bd, v.eval_batch
        );
    }
    for e in &m.entries {
        let size = std::fs::metadata(m.hlo_path(e))
            .map(|md| md.len())
            .unwrap_or(0);
        println!(
            "  {}.{} ({} inputs, {} outputs, {} bytes)",
            e.variant, e.function, e.inputs.len(), e.outputs.len(), size
        );
    }
    Ok(())
}
