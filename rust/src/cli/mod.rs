//! `dmlps` CLI: the launcher for training, simulation, and evaluation.
//!
//! ```text
//! dmlps train    --preset mnist --workers 2 --engine auto [--save-model f]
//! dmlps cluster  --preset tiny --workers 2 [--addr 127.0.0.1:0]
//! dmlps node     --role server|worker --config f.json --addr host:port
//! dmlps simulate --preset mnist --cores 16,32,64,128,256
//! dmlps serve    --preset tiny --model f.bin [--addr 127.0.0.1:0]
//! dmlps eval     --preset mnist --model f.bin
//! dmlps gen-data --preset mnist
//! dmlps inspect-artifacts
//! ```
//!
//! Every subcommand is a thin adapter from parsed flags to the
//! [`Session`](crate::session::Session) builder; training emits a
//! versioned [`MetricModel`](crate::session::MetricModel) artifact that
//! `eval` reloads and serves (legacy bare-`Mat` model files still load).

pub mod cluster;
pub mod driver;
pub mod lab;
pub mod serve;

use std::sync::Arc;

use crate::config::{
    CompressionMode, Consistency, ExperimentConfig, PairMode, Preset,
};
use crate::data::{DatasetStats, ExperimentData};
use crate::session::{
    DoneEvent, EventSink, MetricModel, ModelMeta, ProbeEvent, Session,
    SimKnobs,
};
use crate::util::cli::ArgParser;

pub fn main_entry() -> anyhow::Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_usage();
        return Ok(());
    }
    let sub = args.remove(0);
    match sub.as_str() {
        "train" => cmd_train(&args),
        "cluster" => cluster::cmd_cluster(&args),
        "node" => cluster::cmd_node(&args),
        "lab" => lab::cmd_lab(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => serve::cmd_serve(&args),
        "eval" => cmd_eval(&args),
        "gen-data" => cmd_gen_data(&args),
        "inspect-artifacts" => cmd_inspect_artifacts(&args),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            anyhow::bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_usage() {
    println!(
        "dmlps — Large Scale Distributed Distance Metric Learning\n\
         (reproduction of Xie & Xing, 2014)\n\n\
         subcommands:\n\
         \x20 train              run the threaded async parameter server\n\
         \x20 cluster            spawn a server + worker process cluster\n\
         \x20 node               run one server/worker role over sockets\n\
         \x20 lab                run/diff a config-driven experiment matrix\n\
         \x20 simulate           discrete-event cluster scalability study\n\
         \x20 serve              retrieval server over a saved metric\n\
         \x20 eval               evaluate a saved metric (PR curve, AP)\n\
         \x20 gen-data           print dataset statistics (Table 1)\n\
         \x20 inspect-artifacts  list AOT artifacts and shapes\n\n\
         run `dmlps <subcommand> --help` for options"
    );
}

/// Live run reporting: probe points and worker completions, fed by the
/// session's [`EventSink`] instead of peeking at internals.
struct ProgressSink;

impl EventSink for ProgressSink {
    fn on_probe(&self, e: &ProbeEvent) {
        println!(
            "  probe @ {:>6} updates: f = {:.4}  (t = {:.2}s)",
            e.step, e.objective, e.time_s
        );
    }

    fn on_done(&self, e: &DoneEvent) {
        println!(
            "  worker {} finished: {} steps, last loss {:.4}, \
             waited {:.2}s, max staleness {}",
            e.worker, e.steps, e.last_loss, e.wait_s, e.max_staleness
        );
    }
}

/// Build a config from --preset/--config plus common overrides. Enum
/// knobs route through their `FromStr` impls (one parse path for the
/// CLI, the JSON loader, and tests).
pub(crate) fn load_config(
    a: &crate::util::cli::Args,
) -> anyhow::Result<ExperimentConfig> {
    let mut cfg = if a.get("config").is_empty() {
        Preset::parse(a.get("preset"))?.config()
    } else {
        ExperimentConfig::load(std::path::Path::new(a.get("config")))?
    };
    if let Ok(w) = a.get_usize("workers") {
        if w > 0 {
            cfg.cluster.workers = w;
        }
    }
    if let Ok(s) = a.get_usize("steps") {
        if s > 0 {
            cfg.optim.steps = s;
        }
    }
    let cons = a.get("consistency");
    if !cons.is_empty() {
        cfg.cluster.consistency = cons.parse::<Consistency>()?;
    }
    // tri-state: an empty --seed means "not given", so a config file's
    // seed survives. (The old default of "42" clobbered it and forced
    // `dmlps cluster` to re-pass --seed to every child.)
    let seed = a.get("seed");
    if !seed.is_empty() {
        cfg.seed = seed
            .parse::<u64>()
            .map_err(|e| anyhow::anyhow!("--seed: {e}"))?;
    }
    if let Ok(t) = a.get_usize("threads") {
        if t > 0 {
            cfg.cluster.threads_per_worker = t;
        }
    }
    if let Ok(s) = a.get_usize("server-shards") {
        if s > 0 {
            cfg.cluster.server_shards = s;
        }
    }
    let pm = a.get("pairs-mode");
    if !pm.is_empty() {
        cfg.cluster.pairs.mode = pm.parse::<PairMode>()?;
    }
    // exactly -1 = keep the preset/config value; anything else must be
    // a valid knob value — never a silent fallback
    let x = a.get_f64("pair-noise")?;
    if x != -1.0 {
        anyhow::ensure!(
            (0.0..=1.0).contains(&x),
            "--pair-noise must be in [0, 1] (or -1 for preset default)"
        );
        cfg.cluster.pairs.label_noise = x as f32;
    }
    let x = a.get_f64("pair-imbalance")?;
    if x != -1.0 {
        anyhow::ensure!(
            x >= 0.0 && x.is_finite(),
            "--pair-imbalance must be finite and >= 0 \
             (or -1 for preset default)"
        );
        cfg.cluster.pairs.imbalance = x as f32;
    }
    let cm = a.get("compression");
    if !cm.is_empty() {
        cfg.cluster.compression.mode = cm.parse::<CompressionMode>()?;
    }
    let x = a.get_f64("keep")?;
    if x != -1.0 {
        anyhow::ensure!(
            x > 0.0 && x <= 1.0,
            "--keep must be in (0, 1] (or -1 for preset default)"
        );
        cfg.cluster.compression.keep = x as f32;
    }
    Ok(cfg)
}

pub(crate) fn common_parser(cmd: &str, about: &str) -> ArgParser {
    ArgParser::new(cmd, about)
        .opt("preset", "tiny", "tiny|mnist|imnet60k|imnet1m")
        .opt("config", "", "path to a JSON experiment config")
        .opt("workers", "0", "override worker count (0 = preset)")
        .opt("steps", "0", "override steps per worker (0 = preset)")
        .opt("consistency", "", "asp|bsp|ssp:N (default from preset)")
        .opt("seed", "", "PRNG seed (default: preset/config seed)")
        .opt("threads", "0",
             "compute threads per worker engine (0 = all cores)")
        .opt("server-shards", "0",
             "parameter-server shards (0 = preset; 1 = single server)")
        .opt("pairs-mode", "",
             "materialized|streaming pair pipeline (default from preset)")
        .opt("pair-noise", "-1",
             "streaming label-noise fraction in [0,1] (-1 = preset)")
        .opt("pair-imbalance", "-1",
             "streaming class-imbalance Zipf exponent (-1 = preset)")
        .opt("compression", "",
             "PS wire compression: none|int8|topk|topk_int8 \
              (default from preset)")
        .opt("keep", "-1",
             "top-k kept fraction in (0,1] (-1 = preset)")
}

fn cmd_train(args: &[String]) -> anyhow::Result<()> {
    let p = common_parser("dmlps train", "threaded async-PS training")
        .opt("engine", "auto", "native|xla|auto")
        .opt("save-model", "", "write the learned metric model to this path")
        .opt("save-curve", "", "write convergence curve CSV to this path");
    let a = p.parse(args)?;
    let cfg = load_config(&a)?;
    println!(
        "train: dataset={} d={} k={} workers={} threads/worker={} \
         server-shards={} steps={} engine={} consistency={} pairs={} \
         compression={} (keep={})",
        cfg.dataset.name, cfg.dataset.dim, cfg.model.k,
        cfg.cluster.workers,
        if cfg.cluster.threads_per_worker == 0 {
            "auto".to_string()
        } else {
            cfg.cluster.threads_per_worker.to_string()
        },
        cfg.cluster.server_shards,
        cfg.optim.steps, a.get("engine"),
        cfg.cluster.consistency,
        cfg.cluster.pairs.mode,
        cfg.cluster.compression.mode,
        cfg.cluster.compression.keep
    );
    // streaming mode never materializes the train pair sets — the
    // startup cost and memory term the implicit sampler removes
    let data = Arc::new(ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    ));
    let run = Session::from_config(cfg)
        .engine(a.get("engine"))
        .data(data.clone())
        .events(Arc::new(ProgressSink))
        .train_distributed()?;
    let first = run.curve.points.first().map(|p| p.objective)
        .unwrap_or(f64::NAN);
    let last = run.curve.points.last().map(|p| p.objective)
        .unwrap_or(f64::NAN);
    println!(
        "done in {:.2}s: {} updates applied ({} slice updates over {} \
         shards), {} broadcasts, objective {first:.4} -> {last:.4}, \
         last minibatch loss {:.4}",
        run.wall_s, run.applied_updates, run.slice_updates,
        run.server_shards, run.broadcasts, run.last_loss
    );
    println!(
        "wire: {} grad bytes folded, {} param bytes broadcast \
         ({} param msgs)",
        run.grad_bytes_received, run.param_bytes_sent, run.param_msgs
    );
    println!("kernel backend: {}", run.kernel);
    for ws in &run.worker_stats {
        println!(
            "  worker {}: {} steps, {} grads sent ({} dropped, \
             {} grad bytes), {} params received ({} param bytes), \
             waited {:.2}s, max staleness {}, \
             {} pairs drawn ({} pair bytes resident)",
            ws.id, ws.steps_done, ws.grads_sent, ws.grads_dropped,
            ws.grad_bytes_sent, ws.params_received,
            ws.param_bytes_received, ws.wait_s, ws.max_staleness,
            ws.pairs_drawn, ws.pair_bytes
        );
    }
    let model = run.require_model()?;
    let mut eng = crate::dml::NativeEngine::new();
    let ap = crate::eval::ap_of_l(&mut eng, model.l(), &data)?;
    println!("test AP: {ap:.4} (Euclidean baseline {:.4})",
             crate::eval::ap_euclidean(&data));
    if !a.get("save-model").is_empty() {
        model.save(std::path::Path::new(a.get("save-model")))?;
        println!(
            "model saved to {} ({}x{}, seed {}, config digest {:016x})",
            a.get("save-model"), model.k(), model.dim(),
            model.meta().seed, model.meta().config_digest
        );
    }
    if !a.get("save-curve").is_empty() {
        std::fs::write(a.get("save-curve"), run.curve.to_csv())?;
        println!("curve saved to {}", a.get("save-curve"));
    }
    Ok(())
}

fn cmd_simulate(args: &[String]) -> anyhow::Result<()> {
    let p = common_parser(
        "dmlps simulate",
        "discrete-event cluster scalability study (Fig 2/3)",
    )
    .opt("cores", "16,32,64,128,256", "total core counts to simulate")
    .opt("cores-per-machine", "16", "cores per simulated machine")
    .opt("updates", "2000", "total applied updates per run");
    let a = p.parse(args)?;
    let cfg = load_config(&a)?;
    // Session::simulate enforces the same constraints, but only after
    // data generation + calibration — check here so a bad flag fails in
    // milliseconds, not after seconds of setup work.
    anyhow::ensure!(
        cfg.cluster.pairs.mode == PairMode::Materialized,
        "simulate supports only the materialized pair pipeline \
         (drop --pairs-mode streaming)"
    );
    anyhow::ensure!(
        cfg.cluster.compression.mode == CompressionMode::None,
        "simulate models the dense f32 wire only \
         (drop --compression {})",
        cfg.cluster.compression.mode
    );
    let data = Arc::new(ExperimentData::generate(&cfg.dataset, cfg.seed));
    let grad_s = crate::session::calibrate_for(&cfg);
    println!(
        "simulate: dataset={} d={} k={} calibrated grad time \
         {:.4}s/core-minibatch",
        cfg.dataset.name, cfg.dataset.dim, cfg.model.k, grad_s
    );
    let cpm = a.get_usize("cores-per-machine")?;
    let updates = a.get_usize("updates")? as u64;
    let mut meas = Vec::new();
    for cores in a.get_usize_list("cores")? {
        let machines = (cores / cpm).max(1);
        let r = Session::from_config(cfg.clone())
            .data(data.clone())
            .topology(machines, cpm.min(cores))
            .sim_knobs(SimKnobs {
                grad_seconds: grad_s,
                total_updates: updates,
                ..SimKnobs::default()
            })
            .simulate()?;
        println!(
            "  {:>4} cores ({} machines): {:.2} sim-s for {} updates, \
             mean staleness {:.2}, final objective {:.4}",
            machines * cpm.min(cores), machines, r.sim_seconds,
            r.applied_updates, r.mean_staleness,
            r.curve.final_objective().unwrap_or(f64::NAN)
        );
        meas.push((machines * cpm.min(cores), r.sim_seconds));
    }
    println!("\nspeedup (time to {} updates):", updates);
    for row in crate::metrics::speedup_table(meas) {
        println!(
            "  {:>4} cores: {:>8.2}s  speedup {:>5.2}x (linear {:>5.2}x)",
            row.cores, row.time_to_target_s, row.speedup, row.linear
        );
    }
    Ok(())
}

fn cmd_eval(args: &[String]) -> anyhow::Result<()> {
    let p = common_parser("dmlps eval", "evaluate a saved metric")
        .req("model",
             "path to a saved metric model (DMLPSMM1, or legacy \
              DMLPSMAT matrix)");
    let a = p.parse(args)?;
    let cfg = load_config(&a)?;
    // eval only touches the (always materialized) test pairs; honoring
    // the mode skips the pointless train-pair sampling
    let data = ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    );
    let path = std::path::Path::new(a.get("model"));
    let (model, legacy) = load_model(path)?;
    anyhow::ensure!(
        model.dim() == cfg.dataset.dim,
        "model dim {} != dataset dim {}", model.dim(), cfg.dataset.dim
    );
    if legacy {
        println!(
            "model: {}x{} (legacy matrix file: no provenance header)",
            model.k(), model.dim()
        );
    } else {
        println!(
            "model: {}x{} (seed {}, config digest {:016x})",
            model.k(), model.dim(), model.meta().seed,
            model.meta().config_digest
        );
    }
    let mut eng = crate::dml::NativeEngine::new();
    let (sim, dis) = crate::eval::score_pairs(
        &mut eng, model.l(), &data.test, &data.test_pairs,
    )?;
    let ap = crate::eval::average_precision(&sim, &dis);
    println!("test AP: {ap:.4} (Euclidean {:.4})",
             crate::eval::ap_euclidean(&data));
    println!("PR curve (sampled):");
    let curve = crate::eval::pr_curve(&sim, &dis);
    let stride = (curve.len() / 20).max(1);
    println!("  recall  precision");
    for pt in curve.iter().step_by(stride) {
        println!("  {:.4}  {:.4}", pt.recall, pt.precision);
    }
    Ok(())
}

/// Load a metric model: the versioned `DMLPSMM1` artifact, or (for
/// files written before the artifact existed) a bare `DMLPSMAT` matrix
/// wrapped with unknown provenance (returns `legacy = true`; version 0
/// and zeroed seed/digest mean "no header", never a claim — real
/// artifacts start at format version 1).
pub(crate) fn load_model(
    path: &std::path::Path,
) -> anyhow::Result<(MetricModel, bool)> {
    match MetricModel::load(path) {
        Ok(m) => Ok((m, false)),
        Err(model_err) => match crate::linalg::Mat::load(path) {
            Ok(l) => {
                let meta = ModelMeta {
                    version: 0,
                    k: l.rows as u64,
                    d: l.cols as u64,
                    seed: 0,
                    config_digest: 0,
                };
                Ok((MetricModel::from_parts(l, meta), true))
            }
            Err(mat_err) => anyhow::bail!(
                "cannot load '{}': not a metric model ({model_err}) \
                 and not a legacy matrix ({mat_err})",
                path.display()
            ),
        },
    }
}

fn cmd_gen_data(args: &[String]) -> anyhow::Result<()> {
    let p = common_parser("dmlps gen-data",
                          "generate + describe synthetic datasets");
    let a = p.parse(args)?;
    let cfg = load_config(&a)?;
    let stats = DatasetStats::of(&cfg);
    println!(
        "| dataset | feat. dim | k | # parameters | # samples | \
         # similar | # dissimilar |"
    );
    println!("|---|---|---|---|---|---|---|");
    println!(
        "| {} | {} | {} | {} | {} | {} | {} |",
        stats.name, stats.feat_dim, stats.k, stats.param_str(),
        stats.n_samples, stats.n_similar, stats.n_dissimilar
    );
    let data = ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    );
    println!(
        "\ngenerated: train {}×{}, test {}×{}, pairs {}S/{}D \
         (labels verified: {})",
        data.train.n(), data.train.dim(), data.test.n(), data.test.dim(),
        data.pairs.similar.len(), data.pairs.dissimilar.len(),
        data.pairs.check_labels(&data.train)
    );
    Ok(())
}

fn cmd_inspect_artifacts(_args: &[String]) -> anyhow::Result<()> {
    let dir = crate::runtime::artifacts_dir();
    anyhow::ensure!(
        dir.join("manifest.json").is_file(),
        "no artifacts at {} (run `make artifacts`)", dir.display()
    );
    let m = crate::runtime::Manifest::load(&dir)?;
    println!("artifacts at {}:", dir.display());
    for (name, v) in &m.variants {
        println!(
            "  {name}: k={} d={} batch={}+{} eval_batch={}",
            v.k, v.d, v.bs, v.bd, v.eval_batch
        );
    }
    for e in &m.entries {
        let size = std::fs::metadata(m.hlo_path(e))
            .map(|md| md.len())
            .unwrap_or(0);
        println!(
            "  {}.{} ({} inputs, {} outputs, {} bytes)",
            e.variant, e.function, e.inputs.len(), e.outputs.len(), size
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|t| t.to_string()).collect()
    }

    /// `--seed` is tri-state: absent keeps the preset/config seed,
    /// present overrides it. The old unconditional default silently
    /// clobbered config-file seeds with 42.
    #[test]
    fn seed_resolves_only_when_explicitly_given() {
        let p = common_parser("t", "t");

        // preset default survives without --seed
        let a = p.parse(&toks(&[])).unwrap();
        assert_eq!(load_config(&a).unwrap().seed, 42);

        // explicit --seed overrides
        let a = p.parse(&toks(&["--seed", "7"])).unwrap();
        assert_eq!(load_config(&a).unwrap().seed, 7);

        // a config file's seed is preserved — the regression the
        // unconditional CLI default used to cause
        let path = std::env::temp_dir().join(format!(
            "dmlps-cli-seed-{}.json",
            std::process::id()
        ));
        std::fs::write(&path, r#"{"seed": 1234}"#).unwrap();
        let a = p
            .parse(&toks(&["--config", path.to_str().unwrap()]))
            .unwrap();
        assert_eq!(load_config(&a).unwrap().seed, 1234);

        // ...unless --seed is also given
        let a = p
            .parse(&toks(&[
                "--config",
                path.to_str().unwrap(),
                "--seed",
                "9",
            ]))
            .unwrap();
        assert_eq!(load_config(&a).unwrap().seed, 9);
        let _ = std::fs::remove_file(&path);

        // a malformed seed is an error, never a silent fallback
        let a = p.parse(&toks(&["--seed", "banana"])).unwrap();
        let msg = load_config(&a).unwrap_err().to_string();
        assert!(msg.contains("--seed"), "{msg}");
    }
}
