//! `dmlps lab` — the experiment-matrix harness front end.
//!
//! ```text
//! dmlps lab run  <config.json> [--output dir] [--trials N]
//! dmlps lab diff <old.json> <new.json> [--tolerance 0.25]
//!                [--include-resource]
//! ```
//!
//! `run` executes every experiment block of a lab config (see
//! [`crate::lab`]) and writes one merged `BENCH_lab_<name>.json` per
//! experiment. `diff` compares two merged reports cell-by-cell and
//! exits nonzero if any metric drifts beyond the tolerance — the CI
//! regression gate.

use crate::lab::{self, LabConfig};
use crate::util::cli::ArgParser;

pub fn cmd_lab(args: &[String]) -> anyhow::Result<()> {
    let usage = "usage: dmlps lab <run|diff> ... \
                 (run `dmlps lab run --help` for options)";
    let Some(verb) = args.first() else {
        println!("{usage}");
        return Ok(());
    };
    let rest = &args[1..];
    match verb.as_str() {
        "run" => cmd_run(rest),
        "diff" => cmd_diff(rest),
        "--help" | "-h" | "help" => {
            println!("{usage}");
            Ok(())
        }
        other => {
            println!("{usage}");
            anyhow::bail!("unknown lab verb '{other}'")
        }
    }
}

fn cmd_run(args: &[String]) -> anyhow::Result<()> {
    let p = ArgParser::new(
        "dmlps lab run",
        "run a lab config's experiment matrix",
    )
    .opt("output", "", "override the config's output directory")
    .opt("trials", "0", "override trials per cell (0 = config value)");
    let a = p.parse(args)?;
    anyhow::ensure!(
        a.positionals.len() == 1,
        "lab run takes exactly one config path \
         ({} given)",
        a.positionals.len()
    );
    let mut cfg = LabConfig::load(std::path::Path::new(
        &a.positionals[0],
    ))?;
    if !a.get("output").is_empty() {
        cfg.global.output = std::path::PathBuf::from(a.get("output"));
    }
    let trials = a.get_usize("trials")?;
    if trials > 0 {
        for exp in &mut cfg.experiments {
            exp.trials = trials;
        }
    }
    let written = lab::run(&cfg)?;
    println!(
        "lab: {} experiment(s) complete:",
        written.len()
    );
    for path in &written {
        println!("  {}", path.display());
    }
    Ok(())
}

fn cmd_diff(args: &[String]) -> anyhow::Result<()> {
    let p = ArgParser::new(
        "dmlps lab diff",
        "compare two merged lab reports; nonzero exit on drift",
    )
    .opt(
        "tolerance",
        "0.25",
        "max relative drift per metric before failing",
    )
    .flag(
        "include-resource",
        "also gate on per-cell resource stats (RSS, CPU)",
    );
    let a = p.parse(args)?;
    anyhow::ensure!(
        a.positionals.len() == 2,
        "lab diff takes exactly two report paths (old new), \
         {} given",
        a.positionals.len()
    );
    let tolerance = a.get_f64("tolerance")?;
    anyhow::ensure!(
        tolerance.is_finite() && tolerance >= 0.0,
        "--tolerance must be finite and >= 0"
    );
    let drifts = lab::diff_files(
        std::path::Path::new(&a.positionals[0]),
        std::path::Path::new(&a.positionals[1]),
        tolerance,
        a.has_flag("include-resource"),
    )?;
    if drifts.is_empty() {
        println!(
            "lab diff: OK — all metrics within tolerance {tolerance}"
        );
        return Ok(());
    }
    for d in &drifts {
        eprintln!("DRIFT: {d}");
    }
    anyhow::bail!(
        "{} metric(s) drifted beyond tolerance {tolerance}",
        drifts.len()
    )
}
