//! Process-mode subcommands: `dmlps cluster` (the manager) and
//! `dmlps node` (one server or worker role).
//!
//! The manager resolves the experiment config once, writes it to a run
//! directory, then spawns `current_exe() node --role ...` for the
//! server and each worker — secretsharing-testbed style: one binary,
//! the manager mode orchestrates, the node mode executes a role. Nodes
//! do not receive datasets over the wire; each regenerates dataset /
//! initial L / pair partition deterministically from the shared config
//! + seed (see `session::dist`), so the only cross-process traffic is
//! the PS protocol itself on the socket transport (`ps::net`).
//!
//! Each node writes a JSON report; the manager collects them, checks
//! the per-worker `grads_sent + grads_dropped == steps` accounting
//! identity, and writes a combined `cluster.json`.

use std::path::{Path, PathBuf};
use std::process::{Child, Command};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{ExperimentConfig, NetConfig};
use crate::data::ExperimentData;
use crate::ps::net::{NetAddr, NetServer, NetWorkerTransport, RetryPolicy};
use crate::ps::{RunOptions, Transport, TransportStats};
use crate::session::{
    plan_for, run_server_node, run_worker_node, MetricModel,
};
use crate::util::cli::{ArgParser, Args};
use crate::util::json::Json;

use super::{common_parser, load_config, ProgressSink};

// ---------------------------------------------------------------------
// shared flag plumbing
// ---------------------------------------------------------------------

/// Socket flags shared by `cluster` and `node`. Defaults come from
/// [`NetConfig::default`] so the knobs have one source of truth.
fn with_net_opts(p: ArgParser, default_addr: &str) -> ArgParser {
    let nd = NetConfig::default();
    p.opt("addr", default_addr,
          "server address: host:port (port 0 = auto-pick) or unix:/path")
        .opt("connect-attempts", &nd.connect_attempts.to_string(),
             "worker connect attempts before giving up")
        .opt("backoff-ms", &nd.backoff_ms.to_string(),
             "first connect-retry backoff in ms (doubles per attempt)")
        .opt("max-backoff-ms", &nd.max_backoff_ms.to_string(),
             "connect-retry backoff ceiling in ms")
}

fn net_from_args(a: &Args) -> anyhow::Result<NetConfig> {
    let net = NetConfig {
        addr: a.get("addr").to_string(),
        connect_attempts: a.get_u64("connect-attempts")? as u32,
        backoff_ms: a.get_u64("backoff-ms")?,
        max_backoff_ms: a.get_u64("max-backoff-ms")?,
    };
    anyhow::ensure!(net.connect_attempts > 0,
                    "--connect-attempts must be >= 1");
    Ok(net)
}

fn retry_policy(net: &NetConfig) -> RetryPolicy {
    RetryPolicy {
        attempts: net.connect_attempts,
        initial_backoff: Duration::from_millis(net.backoff_ms),
        max_backoff: Duration::from_millis(net.max_backoff_ms),
    }
}

fn stats_json(s: &TransportStats) -> Json {
    Json::obj(vec![
        ("frames_sent", Json::Num(s.frames_sent as f64)),
        ("frames_received", Json::Num(s.frames_received as f64)),
        ("bytes_sent", Json::Num(s.bytes_sent as f64)),
        ("bytes_received", Json::Num(s.bytes_received as f64)),
        ("rejected_frames", Json::Num(s.rejected_frames as f64)),
    ])
}

fn write_report(path: &str, j: &Json) -> anyhow::Result<()> {
    if !path.is_empty() {
        std::fs::write(path, j.to_string_pretty())?;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// `dmlps node` — one role of a process-mode run
// ---------------------------------------------------------------------

pub fn cmd_node(args: &[String]) -> anyhow::Result<()> {
    let p = with_net_opts(
        common_parser("dmlps node",
                      "one server/worker role over the socket transport"),
        &NetConfig::default().addr,
    )
    .req("role", "server|worker")
    .opt("worker-id", "0", "this node's worker slot (worker role)")
    .opt("engine", "auto", "native|xla|auto (worker role)")
    .opt("report", "", "write this role's JSON report to this path")
    .opt("save-model", "",
         "write the learned metric model here (server role)");
    let a = p.parse(args)?;
    let cfg = load_config(&a)?;
    let net = net_from_args(&a)?;
    let addr = NetAddr::parse(&net.addr)?;
    match a.get("role") {
        "server" => node_server(&a, &cfg, &addr),
        "worker" => node_worker(&a, &cfg, &addr, retry_policy(&net)),
        other => anyhow::bail!("--role must be server|worker, got '{other}'"),
    }
}

fn node_server(
    a: &Args,
    cfg: &ExperimentConfig,
    addr: &NetAddr,
) -> anyhow::Result<()> {
    let plan = plan_for(cfg);
    let server = NetServer::bind(addr)?;
    println!(
        "node server: listening on {} ({} workers, {} shards, {})",
        server.local_addr()?, cfg.cluster.workers, plan.shards(),
        cfg.cluster.consistency,
    );
    let data = ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    );
    let ExperimentData { train, pairs, .. } = data;
    let mut transport = server.accept_workers(&plan, cfg.cluster.workers)?;
    let opts = RunOptions::default();
    let r = run_server_node(
        cfg,
        Arc::new(train),
        &pairs,
        &opts,
        Some(Arc::new(ProgressSink)),
        &mut transport,
    )?;
    let stats = transport.finish();
    println!(
        "node server done in {:.2}s: {} updates applied, last loss \
         {:.4}, {} misroutes, {} rejected frames",
        r.wall_s, r.applied_updates, r.last_loss, r.misroutes,
        stats.rejected_frames,
    );
    if !a.get("save-model").is_empty() {
        let model = MetricModel::new(r.l.clone(), cfg);
        model.save(Path::new(a.get("save-model")))?;
        println!("model saved to {}", a.get("save-model"));
    }
    write_report(a.get("report"), &Json::obj(vec![
        ("role", Json::Str("server".into())),
        ("applied_updates", Json::Num(r.applied_updates as f64)),
        ("slice_updates", Json::Num(r.slice_updates as f64)),
        ("broadcasts", Json::Num(r.broadcasts as f64)),
        ("param_msgs", Json::Num(r.param_msgs as f64)),
        ("server_shards", Json::Num(r.server_shards as f64)),
        ("last_loss", Json::Num(r.last_loss as f64)),
        ("grad_bytes_received",
         Json::Num(r.grad_bytes_received as f64)),
        ("param_bytes_sent", Json::Num(r.param_bytes_sent as f64)),
        ("misroutes", Json::Num(r.misroutes as f64)),
        ("wall_s", Json::Num(r.wall_s)),
        ("final_objective",
         Json::Num(r.curve.final_objective().unwrap_or(f64::NAN))),
        ("transport", stats_json(&stats)),
    ]))?;
    Ok(())
}

fn node_worker(
    a: &Args,
    cfg: &ExperimentConfig,
    addr: &NetAddr,
    policy: RetryPolicy,
) -> anyhow::Result<()> {
    let w = a.get_usize("worker-id")?;
    let plan = plan_for(cfg);
    println!(
        "node worker {w}: connecting to {addr} ({} steps, engine {})",
        cfg.optim.steps, a.get("engine"),
    );
    let data = ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    );
    let ExperimentData { train, pairs, .. } = data;
    let engines = crate::dml::engine_factory(a.get("engine"), cfg)?;
    let mut transport =
        NetWorkerTransport::connect(addr, w, &plan, policy)?;
    let opts = RunOptions::default();
    let ws = run_worker_node(
        cfg,
        w,
        Arc::new(train),
        &pairs,
        engines,
        &opts,
        Some(Arc::new(ProgressSink)),
        &mut transport,
    )?;
    let stats = transport.finish();
    println!(
        "node worker {w} done: {} steps, {} grads sent ({} dropped), \
         waited {:.2}s",
        ws.steps_done, ws.grads_sent, ws.grads_dropped, ws.wait_s,
    );
    write_report(a.get("report"), &Json::obj(vec![
        ("role", Json::Str("worker".into())),
        ("worker", Json::Num(w as f64)),
        ("steps_done", Json::Num(ws.steps_done as f64)),
        ("grads_sent", Json::Num(ws.grads_sent as f64)),
        ("grads_dropped", Json::Num(ws.grads_dropped as f64)),
        ("params_received", Json::Num(ws.params_received as f64)),
        ("wait_s", Json::Num(ws.wait_s)),
        ("max_staleness", Json::Num(ws.max_staleness as f64)),
        ("last_loss", Json::Num(ws.last_loss as f64)),
        ("grad_bytes_sent", Json::Num(ws.grad_bytes_sent as f64)),
        ("param_bytes_received",
         Json::Num(ws.param_bytes_received as f64)),
        ("transport", stats_json(&stats)),
    ]))?;
    Ok(())
}

// ---------------------------------------------------------------------
// `dmlps cluster` — the manager
// ---------------------------------------------------------------------

pub fn cmd_cluster(args: &[String]) -> anyhow::Result<()> {
    let p = with_net_opts(
        common_parser("dmlps cluster",
                      "spawn a server + worker process cluster and \
                       drive one run"),
        "127.0.0.1:0",
    )
    .opt("engine", "auto", "worker engine: native|xla|auto")
    .opt("run-dir", "",
         "directory for config + report files (default: a fresh \
          temp dir)")
    .opt("timeout-s", "600", "kill the run after this many seconds")
    .opt("save-model", "",
         "have the server write the learned metric model here");
    let a = p.parse(args)?;
    let cfg = load_config(&a)?;
    let net = net_from_args(&a)?;
    let addr = resolve_addr(&net.addr)?;
    let p_workers = cfg.cluster.workers;

    let run_dir = if a.get("run-dir").is_empty() {
        std::env::temp_dir()
            .join(format!("dmlps-cluster-{}", std::process::id()))
    } else {
        PathBuf::from(a.get("run-dir"))
    };
    std::fs::create_dir_all(&run_dir)?;
    let cfg_path = run_dir.join("config.json");
    cfg.save(&cfg_path)?;
    println!(
        "cluster: {} workers + 1 server on {addr}, run dir {}",
        p_workers, run_dir.display(),
    );

    let exe = std::env::current_exe()?;
    let mut children: Vec<(String, Child)> = Vec::new();
    let server_report = run_dir.join("server.json");
    let mut sc = node_command(&exe, "server", &cfg, &cfg_path, &addr, &a);
    sc.arg("--report").arg(&server_report);
    if !a.get("save-model").is_empty() {
        sc.arg("--save-model").arg(a.get("save-model"));
    }
    children.push(("server".into(), sc.spawn()?));
    let mut worker_reports = Vec::new();
    for w in 0..p_workers {
        let report = run_dir.join(format!("worker{w}.json"));
        let mut wc =
            node_command(&exe, "worker", &cfg, &cfg_path, &addr, &a);
        wc.arg("--worker-id").arg(w.to_string())
            .arg("--engine").arg(a.get("engine"))
            .arg("--report").arg(&report);
        worker_reports.push(report);
        children.push((format!("worker {w}"), wc.spawn()?));
    }

    wait_all(&mut children, a.get_u64("timeout-s")?)?;

    // ---- collect reports, check the accounting identity ----
    let server = Json::parse_file(&server_report)?;
    println!(
        "cluster done: {} updates applied, final objective {:.4}, \
         {} misroutes",
        server.get("applied_updates").as_f64().unwrap_or(f64::NAN),
        server.get("final_objective").as_f64().unwrap_or(f64::NAN),
        server.get("misroutes").as_f64().unwrap_or(f64::NAN),
    );
    let steps = cfg.optim.steps as f64;
    let mut workers = Vec::new();
    for (w, path) in worker_reports.iter().enumerate() {
        let r = Json::parse_file(path)?;
        let sent = r.get("grads_sent").as_f64().unwrap_or(f64::NAN);
        let dropped = r.get("grads_dropped").as_f64().unwrap_or(f64::NAN);
        println!(
            "  worker {w}: sent {sent} + dropped {dropped} \
             (= {steps} steps: {})",
            if sent + dropped == steps { "ok" } else { "MISMATCH" },
        );
        anyhow::ensure!(
            sent + dropped == steps,
            "worker {w} accounting identity broken: \
             {sent} sent + {dropped} dropped != {steps} steps"
        );
        workers.push(r);
    }
    let combined = Json::obj(vec![
        ("addr", Json::Str(addr.clone())),
        ("config", Json::Str(cfg_path.display().to_string())),
        ("server", server),
        ("workers", Json::Arr(workers)),
    ]);
    let combined_path = run_dir.join("cluster.json");
    std::fs::write(&combined_path, combined.to_string_pretty())?;
    println!("combined report: {}", combined_path.display());
    Ok(())
}

/// Resolve `host:0` to a concrete kernel-chosen port by briefly binding
/// it. The listener is dropped before the server node rebinds; on
/// localhost the window for another process to steal the port is
/// negligible, and a steal fails loudly at the server's bind.
fn resolve_addr(requested: &str) -> anyhow::Result<String> {
    if requested.starts_with("unix:") || !requested.ends_with(":0") {
        return Ok(requested.to_string());
    }
    let l = std::net::TcpListener::bind(requested)?;
    Ok(l.local_addr()?.to_string())
}

/// Base `dmlps node` invocation. `--seed` travels explicitly because
/// `load_config` applies the CLI seed unconditionally (its default
/// would otherwise clobber the config file's seed in the child).
fn node_command(
    exe: &Path,
    role: &str,
    cfg: &ExperimentConfig,
    cfg_path: &Path,
    addr: &str,
    a: &Args,
) -> Command {
    let mut c = Command::new(exe);
    c.arg("node")
        .arg("--role").arg(role)
        .arg("--config").arg(cfg_path)
        .arg("--seed").arg(cfg.seed.to_string())
        .arg("--addr").arg(addr)
        .arg("--connect-attempts").arg(a.get("connect-attempts"))
        .arg("--backoff-ms").arg(a.get("backoff-ms"))
        .arg("--max-backoff-ms").arg(a.get("max-backoff-ms"));
    c
}

/// Poll every child until all exit cleanly; kill the whole run on the
/// first failure or on timeout so no node is orphaned.
fn wait_all(
    children: &mut Vec<(String, Child)>,
    timeout_s: u64,
) -> anyhow::Result<()> {
    let deadline = Instant::now() + Duration::from_secs(timeout_s.max(1));
    let mut done = vec![false; children.len()];
    let mut failure: Option<String> = None;
    while !done.iter().all(|&d| d) {
        if Instant::now() > deadline {
            failure = Some(format!(
                "cluster run exceeded --timeout-s {timeout_s}"
            ));
            break;
        }
        for (i, (name, child)) in children.iter_mut().enumerate() {
            if done[i] {
                continue;
            }
            match child.try_wait()? {
                Some(status) if status.success() => done[i] = true,
                Some(status) => {
                    failure = Some(format!("{name} exited with {status}"));
                    break;
                }
                None => {}
            }
        }
        if failure.is_some() {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    if let Some(why) = failure {
        for (_, child) in children.iter_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
        anyhow::bail!("{why} (all nodes killed)");
    }
    Ok(())
}
