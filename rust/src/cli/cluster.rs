//! Process-mode subcommands: `dmlps cluster` (the manager) and
//! `dmlps node` (one server or worker role).
//!
//! The manager resolves the experiment config once, writes it to a run
//! directory, then spawns `current_exe() node --role ...` for the
//! server and each worker — secretsharing-testbed style: one binary,
//! the manager mode orchestrates, the node mode executes a role. Nodes
//! do not receive datasets over the wire; each regenerates dataset /
//! initial L / pair partition deterministically from the shared config
//! + seed (see `session::dist`), so the only cross-process traffic is
//! the PS protocol itself on the socket transport (`ps::net`).
//!
//! Each node writes a JSON report; the manager collects them, checks
//! the per-worker `start_step + grads_sent + grads_dropped == steps`
//! accounting identity, and writes a combined `cluster.json`.
//!
//! Elasticity: with `--ckpt-every-steps`/`--ckpt-every-secs` the server
//! node checkpoints its sharded state into `--ckpt-dir`, and
//! `--restart-policy cluster` makes the manager respawn the whole
//! cluster with `--resume` when any node dies — the respawned roles
//! re-enter the protocol at the newest consistent generation.
//! `--chaos-kill` SIGKILLs a chosen role mid-run (at a wall-clock
//! offset or once the first checkpoint generation lands), which is how
//! the kill/restart integration tests drive real process death.

use std::path::{Path, PathBuf};
use std::process::{Child, Command};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{CheckpointConfig, ExperimentConfig, NetConfig};
use crate::data::ExperimentData;
use crate::linalg::io::atomic_write;
use crate::ps::net::{NetAddr, NetServer, NetWorkerTransport, RetryPolicy};
use crate::ps::{CheckpointSpec, RunOptions, Transport, TransportStats};
use crate::session::{
    plan_for, run_server_node, run_worker_node, MetricModel,
};
use crate::util::cli::{ArgParser, Args};
use crate::util::json::Json;

use super::{common_parser, load_config, ProgressSink};

// ---------------------------------------------------------------------
// shared flag plumbing
// ---------------------------------------------------------------------

/// Socket flags shared by `cluster` and `node`. Defaults come from
/// [`NetConfig::default`] so the knobs have one source of truth.
fn with_net_opts(p: ArgParser, default_addr: &str) -> ArgParser {
    let nd = NetConfig::default();
    p.opt("addr", default_addr,
          "server address: host:port (port 0 = auto-pick) or unix:/path")
        .opt("connect-attempts", &nd.connect_attempts.to_string(),
             "worker connect attempts before giving up")
        .opt("backoff-ms", &nd.backoff_ms.to_string(),
             "first connect-retry backoff in ms (doubles per attempt)")
        .opt("max-backoff-ms", &nd.max_backoff_ms.to_string(),
             "connect-retry backoff ceiling in ms")
}

fn net_from_args(a: &Args) -> anyhow::Result<NetConfig> {
    let net = NetConfig {
        addr: a.get("addr").to_string(),
        connect_attempts: a.get_u64("connect-attempts")? as u32,
        backoff_ms: a.get_u64("backoff-ms")?,
        max_backoff_ms: a.get_u64("max-backoff-ms")?,
    };
    anyhow::ensure!(net.connect_attempts > 0,
                    "--connect-attempts must be >= 1");
    Ok(net)
}

fn retry_policy(net: &NetConfig) -> RetryPolicy {
    RetryPolicy {
        attempts: net.connect_attempts,
        initial_backoff: Duration::from_millis(net.backoff_ms),
        max_backoff: Duration::from_millis(net.max_backoff_ms),
    }
}

fn stats_json(s: &TransportStats) -> Json {
    Json::obj(vec![
        ("frames_sent", Json::Num(s.frames_sent as f64)),
        ("frames_received", Json::Num(s.frames_received as f64)),
        ("bytes_sent", Json::Num(s.bytes_sent as f64)),
        ("bytes_received", Json::Num(s.bytes_received as f64)),
        ("rejected_frames", Json::Num(s.rejected_frames as f64)),
    ])
}

fn write_report(path: &str, j: &Json) -> anyhow::Result<()> {
    if !path.is_empty() {
        // crash-atomic: the manager may be polling this path while a
        // chaos kill lands mid-write
        atomic_write(Path::new(path), |w| {
            use std::io::Write;
            w.write_all(j.to_string_pretty().as_bytes())?;
            Ok(())
        })?;
    }
    Ok(())
}

/// Checkpoint/resume flags shared by both roles of `dmlps node` (and
/// forwarded by the manager).
fn with_ckpt_opts(p: ArgParser) -> ArgParser {
    p.opt("ckpt-dir", "",
          "checkpoint run directory (server role writes, both roles \
           resume from it)")
        .opt("ckpt-every-steps", "0",
             "checkpoint every N applied slice updates per shard \
              (0 = off)")
        .opt("ckpt-every-secs", "0",
             "checkpoint at least every S seconds per shard (0 = off)")
        .opt("resume", "",
             "resume from the newest consistent checkpoint in this \
              directory (empty/never-written directory = fresh start)")
}

/// Build the node's [`RunOptions`] from the checkpoint/resume flags.
fn run_opts_from_args(a: &Args) -> anyhow::Result<RunOptions> {
    let mut opts = RunOptions::default();
    let cadence = CheckpointConfig {
        every_steps: a.get_u64("ckpt-every-steps")?,
        every_secs: a.get_f64("ckpt-every-secs")?,
    };
    if cadence.enabled() {
        let dir = a.get("ckpt-dir");
        anyhow::ensure!(
            !dir.is_empty(),
            "--ckpt-every-steps/--ckpt-every-secs need --ckpt-dir"
        );
        opts.checkpoint =
            Some(CheckpointSpec { dir: PathBuf::from(dir), cadence });
    }
    if !a.get("resume").is_empty() {
        opts.resume_from = Some(PathBuf::from(a.get("resume")));
    }
    Ok(opts)
}

// ---------------------------------------------------------------------
// `dmlps node` — one role of a process-mode run
// ---------------------------------------------------------------------

pub fn cmd_node(args: &[String]) -> anyhow::Result<()> {
    let p = with_ckpt_opts(with_net_opts(
        common_parser("dmlps node",
                      "one server/worker role over the socket transport"),
        &NetConfig::default().addr,
    ))
    .req("role", "server|worker")
    .opt("worker-id", "0", "this node's worker slot (worker role)")
    .opt("engine", "auto", "native|xla|auto (worker role)")
    .opt("report", "", "write this role's JSON report to this path")
    .opt("addr-file", "",
         "write the actually-bound server address here once listening \
          (server role; lets the manager hand workers a :0-picked port \
          without ever binding it itself)")
    .opt("save-model", "",
         "write the learned metric model here (server role)");
    let a = p.parse(args)?;
    let cfg = load_config(&a)?;
    let net = net_from_args(&a)?;
    let addr = NetAddr::parse(&net.addr)?;
    match a.get("role") {
        "server" => node_server(&a, &cfg, &addr),
        "worker" => node_worker(&a, &cfg, &addr, retry_policy(&net)),
        other => anyhow::bail!("--role must be server|worker, got '{other}'"),
    }
}

fn node_server(
    a: &Args,
    cfg: &ExperimentConfig,
    addr: &NetAddr,
) -> anyhow::Result<()> {
    let plan = plan_for(cfg);
    // the server binds its own listener (`:0` = kernel-picked port) and
    // *then* publishes the concrete address — no resolve-then-rebind
    // window for another process to steal the port
    let server = NetServer::bind(addr)?;
    let bound = server.local_addr()?;
    println!(
        "node server: listening on {bound} ({} workers, {} shards, {})",
        cfg.cluster.workers, plan.shards(), cfg.cluster.consistency,
    );
    if !a.get("addr-file").is_empty() {
        atomic_write(Path::new(a.get("addr-file")), |w| {
            use std::io::Write;
            w.write_all(bound.to_string().as_bytes())?;
            Ok(())
        })?;
    }
    let data = ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    );
    let ExperimentData { train, pairs, .. } = data;
    let mut transport = server.accept_workers(&plan, cfg.cluster.workers)?;
    let opts = run_opts_from_args(a)?;
    let r = run_server_node(
        cfg,
        Arc::new(train),
        &pairs,
        &opts,
        Some(Arc::new(ProgressSink)),
        &mut transport,
    )?;
    let stats = transport.finish();
    println!(
        "node server done in {:.2}s: {} updates applied, last loss \
         {:.4}, {} misroutes, {} rejected frames",
        r.wall_s, r.applied_updates, r.last_loss, r.misroutes,
        stats.rejected_frames,
    );
    if !a.get("save-model").is_empty() {
        let model = MetricModel::new(r.l.clone(), cfg);
        model.save(Path::new(a.get("save-model")))?;
        println!("model saved to {}", a.get("save-model"));
    }
    write_report(a.get("report"), &Json::obj(vec![
        ("role", Json::Str("server".into())),
        ("applied_updates", Json::Num(r.applied_updates as f64)),
        ("slice_updates", Json::Num(r.slice_updates as f64)),
        ("broadcasts", Json::Num(r.broadcasts as f64)),
        ("param_msgs", Json::Num(r.param_msgs as f64)),
        ("server_shards", Json::Num(r.server_shards as f64)),
        ("last_loss", Json::Num(r.last_loss as f64)),
        ("grad_bytes_received",
         Json::Num(r.grad_bytes_received as f64)),
        ("param_bytes_sent", Json::Num(r.param_bytes_sent as f64)),
        ("misroutes", Json::Num(r.misroutes as f64)),
        ("wall_s", Json::Num(r.wall_s)),
        ("final_objective",
         Json::Num(r.curve.final_objective().unwrap_or(f64::NAN))),
        ("transport", stats_json(&stats)),
    ]))?;
    Ok(())
}

fn node_worker(
    a: &Args,
    cfg: &ExperimentConfig,
    addr: &NetAddr,
    policy: RetryPolicy,
) -> anyhow::Result<()> {
    let w = a.get_usize("worker-id")?;
    let plan = plan_for(cfg);
    println!(
        "node worker {w}: connecting to {addr} ({} steps, engine {})",
        cfg.optim.steps, a.get("engine"),
    );
    let data = ExperimentData::generate_for(
        &cfg.dataset, cfg.cluster.pairs.mode, cfg.seed,
    );
    let ExperimentData { train, pairs, .. } = data;
    let engines = crate::dml::engine_factory(a.get("engine"), cfg)?;
    let mut transport =
        NetWorkerTransport::connect(addr, w, &plan, policy)?;
    let opts = run_opts_from_args(a)?;
    let ws = run_worker_node(
        cfg,
        w,
        Arc::new(train),
        &pairs,
        engines,
        &opts,
        Some(Arc::new(ProgressSink)),
        &mut transport,
    )?;
    let stats = transport.finish();
    println!(
        "node worker {w} done: {} steps (resumed at {}), {} grads sent \
         ({} dropped), waited {:.2}s",
        ws.steps_done, ws.start_step, ws.grads_sent, ws.grads_dropped,
        ws.wait_s,
    );
    write_report(a.get("report"), &Json::obj(vec![
        ("role", Json::Str("worker".into())),
        ("worker", Json::Num(w as f64)),
        ("start_step", Json::Num(ws.start_step as f64)),
        ("steps_done", Json::Num(ws.steps_done as f64)),
        ("grads_sent", Json::Num(ws.grads_sent as f64)),
        ("grads_dropped", Json::Num(ws.grads_dropped as f64)),
        ("params_received", Json::Num(ws.params_received as f64)),
        ("wait_s", Json::Num(ws.wait_s)),
        ("max_staleness", Json::Num(ws.max_staleness as f64)),
        ("last_loss", Json::Num(ws.last_loss as f64)),
        ("grad_bytes_sent", Json::Num(ws.grad_bytes_sent as f64)),
        ("param_bytes_received",
         Json::Num(ws.param_bytes_received as f64)),
        ("transport", stats_json(&stats)),
    ]))?;
    Ok(())
}

// ---------------------------------------------------------------------
// `dmlps cluster` — the manager
// ---------------------------------------------------------------------

/// Which role a `--chaos-kill` directive targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ChaosTarget {
    Server,
    Worker(usize),
}

/// When the chaos kill fires: at a wall-clock offset into the attempt,
/// or as soon as the first checkpoint generation is on disk (the
/// deterministic "mid-run, state exists" trigger the kill/restart tests
/// use).
#[derive(Clone, Copy, Debug)]
enum ChaosWhen {
    Secs(f64),
    Ckpt,
}

#[derive(Clone, Copy, Debug)]
struct ChaosKill {
    target: ChaosTarget,
    when: ChaosWhen,
}

/// Parse `--chaos-kill` (`server@1.5`, `worker0@ckpt`, ...).
fn parse_chaos(s: &str) -> anyhow::Result<Option<ChaosKill>> {
    if s.is_empty() {
        return Ok(None);
    }
    let (role, when) = s.split_once('@').ok_or_else(|| {
        anyhow::anyhow!(
            "--chaos-kill wants <role>@<secs|ckpt>, got '{s}'"
        )
    })?;
    let target = if role == "server" {
        ChaosTarget::Server
    } else if let Some(idx) = role.strip_prefix("worker") {
        ChaosTarget::Worker(idx.parse().map_err(|_| {
            anyhow::anyhow!("bad --chaos-kill worker index '{idx}'")
        })?)
    } else {
        anyhow::bail!(
            "--chaos-kill role must be server|worker<N>, got '{role}'"
        );
    };
    let when = if when == "ckpt" {
        ChaosWhen::Ckpt
    } else {
        ChaosWhen::Secs(when.parse().map_err(|_| {
            anyhow::anyhow!("bad --chaos-kill time '{when}'")
        })?)
    };
    Ok(Some(ChaosKill { target, when }))
}

/// One cluster attempt's verdict from the supervisor.
enum Attempt {
    /// Every node exited 0.
    Done,
    /// A node died (or was chaos-killed); the rest were killed too.
    /// Restartable under `--restart-policy cluster`.
    Crashed(String),
}

pub fn cmd_cluster(args: &[String]) -> anyhow::Result<()> {
    let p = with_ckpt_opts(with_net_opts(
        common_parser("dmlps cluster",
                      "spawn a server + worker process cluster and \
                       drive one run"),
        "127.0.0.1:0",
    ))
    .opt("engine", "auto", "worker engine: native|xla|auto")
    .opt("run-dir", "",
         "directory for config + report files (default: a fresh \
          temp dir)")
    .opt("timeout-s", "600", "kill the run after this many seconds")
    .opt("restart-policy", "none",
         "none = any node death fails the run; cluster = respawn the \
          whole cluster with --resume on a node death")
    .opt("max-restarts", "2",
         "restart budget under --restart-policy cluster")
    .opt("chaos-kill", "",
         "SIGKILL one role mid-run: <role>@<secs|ckpt> where role is \
          server or worker<N>, and ckpt fires once the first \
          checkpoint generation is on disk")
    .opt("save-model", "",
         "have the server write the learned metric model here");
    let a = p.parse(args)?;
    let cfg = load_config(&a)?;
    let net = net_from_args(&a)?;
    let p_workers = cfg.cluster.workers;

    let run_dir = if a.get("run-dir").is_empty() {
        std::env::temp_dir()
            .join(format!("dmlps-cluster-{}", std::process::id()))
    } else {
        PathBuf::from(a.get("run-dir"))
    };
    std::fs::create_dir_all(&run_dir)?;
    let cfg_path = run_dir.join("config.json");
    cfg.save(&cfg_path)?;

    let cadence = CheckpointConfig {
        every_steps: a.get_u64("ckpt-every-steps")?,
        every_secs: a.get_f64("ckpt-every-secs")?,
    };
    // the manager owns the default checkpoint location so `--resume`
    // plumbing needs no extra flags on restart
    let ckpt_dir = if a.get("ckpt-dir").is_empty() {
        run_dir.join("ckpt")
    } else {
        PathBuf::from(a.get("ckpt-dir"))
    };
    let mut chaos = parse_chaos(a.get("chaos-kill"))?;
    if let Some(ChaosKill { when: ChaosWhen::Ckpt, .. }) = chaos {
        anyhow::ensure!(
            cadence.enabled(),
            "--chaos-kill ...@ckpt needs checkpointing on \
             (--ckpt-every-steps or --ckpt-every-secs)"
        );
    }
    if let Some(ChaosKill { target: ChaosTarget::Worker(w), .. }) = chaos {
        anyhow::ensure!(
            w < p_workers,
            "--chaos-kill worker{w} out of range ({p_workers} workers)"
        );
    }
    let restart_policy = a.get("restart-policy").to_string();
    anyhow::ensure!(
        restart_policy == "none" || restart_policy == "cluster",
        "--restart-policy must be none|cluster, got '{restart_policy}'"
    );
    let max_restarts = a.get_u64("max-restarts")?;

    println!(
        "cluster: {} workers + 1 server on {}, run dir {}",
        p_workers, net.addr, run_dir.display(),
    );

    let exe = std::env::current_exe()?;
    let server_report = run_dir.join("server.json");
    let worker_reports: Vec<PathBuf> = (0..p_workers)
        .map(|w| run_dir.join(format!("worker{w}.json")))
        .collect();
    let addr_file = run_dir.join("server.addr");
    let timeout_s = a.get_u64("timeout-s")?;
    let deadline = Instant::now() + Duration::from_secs(timeout_s.max(1));

    let mut attempt = 0u64;
    let bound_addr = loop {
        attempt += 1;
        // resume only on respawn: a fresh run must not silently pick up
        // generations left in a reused run directory
        let resume = attempt > 1;
        let outcome = run_attempt(RunAttempt {
            exe: &exe,
            cfg_path: &cfg_path,
            a: &a,
            requested_addr: &net.addr,
            addr_file: &addr_file,
            server_report: &server_report,
            worker_reports: &worker_reports,
            cadence,
            ckpt_dir: &ckpt_dir,
            resume,
            chaos: &mut chaos,
            deadline,
        })?;
        match outcome {
            (Attempt::Done, addr) => break addr,
            (Attempt::Crashed(why), _) => {
                let restarts_used = attempt - 1;
                anyhow::ensure!(
                    restart_policy == "cluster",
                    "{why} (all nodes killed)"
                );
                anyhow::ensure!(
                    restarts_used < max_restarts,
                    "{why}; restart budget exhausted \
                     ({max_restarts} restarts)"
                );
                println!(
                    "cluster: {why}; respawning all roles with --resume \
                     {} (restart {}/{max_restarts})",
                    ckpt_dir.display(),
                    restarts_used + 1,
                );
            }
        }
    };

    // ---- collect reports, check the accounting identity ----
    let server = Json::parse_file(&server_report)?;
    println!(
        "cluster done: {} updates applied, final objective {:.4}, \
         {} misroutes",
        server.get("applied_updates").as_f64().unwrap_or(f64::NAN),
        server.get("final_objective").as_f64().unwrap_or(f64::NAN),
        server.get("misroutes").as_f64().unwrap_or(f64::NAN),
    );
    let steps = cfg.optim.steps as f64;
    let mut workers = Vec::new();
    for (w, path) in worker_reports.iter().enumerate() {
        let r = Json::parse_file(path)?;
        let start = r.get("start_step").as_f64().unwrap_or(f64::NAN);
        let sent = r.get("grads_sent").as_f64().unwrap_or(f64::NAN);
        let dropped = r.get("grads_dropped").as_f64().unwrap_or(f64::NAN);
        println!(
            "  worker {w}: resumed {start} + sent {sent} + dropped \
             {dropped} (= {steps} steps: {})",
            if start + sent + dropped == steps { "ok" } else { "MISMATCH" },
        );
        anyhow::ensure!(
            start + sent + dropped == steps,
            "worker {w} accounting identity broken: {start} resumed + \
             {sent} sent + {dropped} dropped != {steps} steps"
        );
        workers.push(r);
    }
    let combined = Json::obj(vec![
        ("addr", Json::Str(bound_addr)),
        ("config", Json::Str(cfg_path.display().to_string())),
        ("attempts", Json::Num(attempt as f64)),
        ("server", server),
        ("workers", Json::Arr(workers)),
    ]);
    let combined_path = run_dir.join("cluster.json");
    std::fs::write(&combined_path, combined.to_string_pretty())?;
    println!("combined report: {}", combined_path.display());
    Ok(())
}

/// Everything one spawn-and-supervise round needs.
struct RunAttempt<'a> {
    exe: &'a Path,
    cfg_path: &'a Path,
    a: &'a Args,
    requested_addr: &'a str,
    addr_file: &'a Path,
    server_report: &'a Path,
    worker_reports: &'a [PathBuf],
    cadence: CheckpointConfig,
    ckpt_dir: &'a Path,
    resume: bool,
    chaos: &'a mut Option<ChaosKill>,
    deadline: Instant,
}

/// Spawn the server, learn its bound address, spawn the workers, then
/// supervise until everyone exits or something dies. Returns the
/// attempt verdict plus the address the server actually bound.
fn run_attempt(r: RunAttempt<'_>) -> anyhow::Result<(Attempt, String)> {
    // stale addr file from a previous attempt must not be readable
    // before the new server publishes its (new) port
    let _ = std::fs::remove_file(r.addr_file);

    let mut children: Vec<(ChaosTarget, String, Child)> = Vec::new();
    let mut sc = node_command(
        r.exe, "server", r.cfg_path, r.requested_addr, r.a,
    );
    sc.arg("--report").arg(r.server_report)
        .arg("--addr-file").arg(r.addr_file);
    if r.cadence.enabled() {
        sc.arg("--ckpt-dir").arg(r.ckpt_dir)
            .arg("--ckpt-every-steps")
            .arg(r.cadence.every_steps.to_string())
            .arg("--ckpt-every-secs")
            .arg(r.cadence.every_secs.to_string());
    }
    if r.resume {
        sc.arg("--resume").arg(r.ckpt_dir);
    }
    if !r.a.get("save-model").is_empty() {
        sc.arg("--save-model").arg(r.a.get("save-model"));
    }
    children.push((ChaosTarget::Server, "server".into(), sc.spawn()?));

    // the server writes the addr file only after its listener is up;
    // waiting on it (instead of pre-binding the port in the manager)
    // closes the old resolve-then-rebind race
    let addr = match wait_addr_file(r.addr_file, &mut children[0], r.deadline)
    {
        Ok(addr) => addr,
        Err(e) => {
            kill_all(&mut children);
            return Ok((Attempt::Crashed(e.to_string()), String::new()));
        }
    };

    for (w, report) in r.worker_reports.iter().enumerate() {
        let mut wc = node_command(
            r.exe, "worker", r.cfg_path, &addr, r.a,
        );
        wc.arg("--worker-id").arg(w.to_string())
            .arg("--engine").arg(r.a.get("engine"))
            .arg("--report").arg(report);
        if r.resume {
            wc.arg("--resume").arg(r.ckpt_dir);
        }
        children.push((
            ChaosTarget::Worker(w),
            format!("worker {w}"),
            wc.spawn()?,
        ));
    }

    let verdict = supervise(
        &mut children,
        r.deadline,
        r.chaos,
        r.ckpt_dir,
    )?;
    Ok((verdict, addr))
}

/// Poll for the server's addr file while checking the server child is
/// still alive (a bind failure must surface, not hang the manager).
fn wait_addr_file(
    path: &Path,
    server: &mut (ChaosTarget, String, Child),
    deadline: Instant,
) -> anyhow::Result<String> {
    loop {
        if let Ok(s) = std::fs::read_to_string(path) {
            let s = s.trim().to_string();
            if !s.is_empty() {
                return Ok(s);
            }
        }
        if let Some(status) = server.2.try_wait()? {
            anyhow::bail!(
                "server exited with {status} before publishing its \
                 address"
            );
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "timed out waiting for the server address file {}",
            path.display()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Base `dmlps node` invocation. The seed travels inside the saved
/// config file — `load_config` leaves a config's seed alone unless
/// `--seed` is explicitly given, so the children need no extra flag.
fn node_command(
    exe: &Path,
    role: &str,
    cfg_path: &Path,
    addr: &str,
    a: &Args,
) -> Command {
    let mut c = Command::new(exe);
    c.arg("node")
        .arg("--role").arg(role)
        .arg("--config").arg(cfg_path)
        .arg("--addr").arg(addr)
        .arg("--connect-attempts").arg(a.get("connect-attempts"))
        .arg("--backoff-ms").arg(a.get("backoff-ms"))
        .arg("--max-backoff-ms").arg(a.get("max-backoff-ms"));
    c
}

fn kill_all(children: &mut [(ChaosTarget, String, Child)]) {
    for (_, _, child) in children.iter_mut() {
        let _ = child.kill();
        let _ = child.wait();
    }
}

/// Whether a pending chaos kill should fire now.
fn chaos_due(
    chaos: &Option<ChaosKill>,
    started: Instant,
    ckpt_dir: &Path,
) -> bool {
    match chaos {
        None => false,
        Some(ChaosKill { when: ChaosWhen::Secs(s), .. }) => {
            started.elapsed().as_secs_f64() >= *s
        }
        // MANIFEST.json only appears once a full generation is durable,
        // so firing on it kills the process with real restorable state
        Some(ChaosKill { when: ChaosWhen::Ckpt, .. }) => {
            ckpt_dir.join("MANIFEST.json").exists()
        }
    }
}

/// Poll every child until all exit cleanly. A node death (including a
/// chaos kill) downs the whole cluster and reports `Crashed` so the
/// restart policy can respawn it; only the manager-wide deadline is a
/// hard error.
fn supervise(
    children: &mut [(ChaosTarget, String, Child)],
    deadline: Instant,
    chaos: &mut Option<ChaosKill>,
    ckpt_dir: &Path,
) -> anyhow::Result<Attempt> {
    let started = Instant::now();
    let mut done = vec![false; children.len()];
    let mut failure: Option<String> = None;
    while !done.iter().all(|&d| d) {
        if Instant::now() > deadline {
            kill_all(children);
            anyhow::bail!("cluster run exceeded --timeout-s");
        }
        if chaos_due(chaos, started, ckpt_dir) {
            let target = chaos.take().expect("chaos checked Some").target;
            for (who, name, child) in children.iter_mut() {
                if *who == target {
                    println!("cluster: chaos kill -> SIGKILL {name}");
                    let _ = child.kill();
                }
            }
        }
        for (i, (_, name, child)) in children.iter_mut().enumerate() {
            if done[i] {
                continue;
            }
            match child.try_wait()? {
                Some(status) if status.success() => done[i] = true,
                Some(status) => {
                    failure = Some(format!("{name} exited with {status}"));
                    break;
                }
                None => {}
            }
        }
        if failure.is_some() {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    match failure {
        Some(why) => {
            kill_all(children);
            Ok(Attempt::Crashed(why))
        }
        None => Ok(Attempt::Done),
    }
}
