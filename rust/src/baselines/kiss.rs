//! KISS metric learning (Köstinger et al., CVPR 2012).
//!
//! "Keep It Simple and Straightforward": a one-shot metric from the
//! likelihood-ratio test between the similar-pair and dissimilar-pair
//! difference distributions (both modeled as zero-mean Gaussians):
//!
//! ```text
//! M = Σ_S⁻¹ − Σ_D⁻¹
//! ```
//!
//! No iterations — "very fast" (paper: 2 minutes on MNIST) — but, as the
//! paper observes, markedly worse AP than optimized methods. Covariances
//! are computed after PCA so they are invertible (the paper reduces MNIST
//! to 600 dims for exactly this reason, §5.4).

use super::LearnedMetric;
use crate::data::{Dataset, PairSet};
use crate::linalg::chol::inverse_spd;
use crate::linalg::pca::Pca;
use crate::linalg::Mat;

#[derive(Clone, Copy, Debug)]
pub struct KissConfig {
    /// PCA target dimension (paper: 600 for MNIST).
    pub pca_dim: usize,
    /// Covariance regularizer (added to the diagonal).
    pub ridge: f32,
    /// Clip M back to PSD (the raw difference of inverses is generally
    /// indefinite; KISSME clips it to keep a valid metric).
    pub project_psd: bool,
}

impl Default for KissConfig {
    fn default() -> Self {
        KissConfig { pca_dim: 64, ridge: 1e-4, project_psd: true }
    }
}

pub struct Kiss {
    pub cfg: KissConfig,
}

impl Kiss {
    pub fn new(cfg: KissConfig) -> Self {
        Kiss { cfg }
    }

    pub fn fit(&self, train: &Dataset, pairs: &PairSet) -> LearnedMetric {
        let pca_dim = self.cfg.pca_dim.min(train.dim());
        let pca = Pca::fit(&train.x, pca_dim);

        let cov = |set: &[crate::data::Pair]| -> Mat {
            let mut c = Mat::zeros(pca_dim, pca_dim);
            let mut diff = vec![0.0f32; train.dim()];
            for p in set {
                train.diff_into(p.i as usize, p.j as usize, &mut diff);
                let z = pca.components.matvec(&diff);
                // c += z zᵀ
                for i in 0..pca_dim {
                    let zi = z[i];
                    if zi == 0.0 {
                        continue;
                    }
                    let row = &mut c.data[i * pca_dim..(i + 1) * pca_dim];
                    for (cv, &zj) in row.iter_mut().zip(&z) {
                        *cv += zi * zj;
                    }
                }
            }
            c.scale_inplace(1.0 / set.len().max(1) as f32);
            for i in 0..pca_dim {
                *c.at_mut(i, i) += self.cfg.ridge;
            }
            c
        };

        let cov_s = cov(&pairs.similar);
        let cov_d = cov(&pairs.dissimilar);
        let inv_s = inverse_spd(&cov_s).expect("Σ_S not invertible");
        let inv_d = inverse_spd(&cov_d).expect("Σ_D not invertible");
        let mut m = inv_s;
        m.axpy_inplace(-1.0, &inv_d);
        m.symmetrize_inplace();
        if self.cfg.project_psd {
            m = crate::linalg::eigen::project_psd(&m);
        }
        LearnedMetric::PcaM { pca, m }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::util::rng::Pcg32;

    fn problem() -> (Dataset, PairSet, Dataset, PairSet) {
        let spec = SyntheticSpec::tiny();
        let mut rng = Pcg32::new(0);
        let train = spec.generate_with(&mut rng, 400);
        let test = spec.generate_with(&mut rng, 200);
        let mut rng2 = Pcg32::new(1);
        let pairs = PairSet::sample(&train, 400, 400, &mut rng2);
        let test_pairs = PairSet::sample(&test, 200, 200, &mut rng2);
        (train, pairs, test, test_pairs)
    }

    #[test]
    fn one_shot_fit_produces_usable_metric() {
        let (train, pairs, test, test_pairs) = problem();
        let kiss = Kiss::new(KissConfig { pca_dim: 12, ..Default::default() });
        let t0 = std::time::Instant::now();
        let metric = kiss.fit(&train, &pairs);
        let fit_s = t0.elapsed().as_secs_f64();
        let ap = metric.ap(&test, &test_pairs);
        let eu = LearnedMetric::Euclidean.ap(&test, &test_pairs);
        // KISS is fast and at least roughly competitive with Euclidean
        assert!(fit_s < 10.0);
        assert!(ap > eu - 0.1, "kiss {ap} vs euclid {eu}");
    }

    #[test]
    fn pca_dim_capped_at_input_dim() {
        let (train, pairs, _, _) = problem();
        let kiss =
            Kiss::new(KissConfig { pca_dim: 10_000, ..Default::default() });
        let metric = kiss.fit(&train, &pairs);
        let LearnedMetric::PcaM { m, .. } = &metric else { panic!() };
        assert_eq!(m.rows, train.dim());
    }

    #[test]
    fn psd_projection_keeps_distances_nonnegative() {
        let (train, pairs, test, test_pairs) = problem();
        let kiss = Kiss::new(KissConfig { pca_dim: 12, ..Default::default() });
        let metric = kiss.fit(&train, &pairs);
        let (sim, dis) = metric.score(&test, &test_pairs);
        assert!(sim.iter().chain(dis.iter()).all(|&v| v > -1e-3));
    }
}
