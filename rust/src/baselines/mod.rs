//! Single-machine baselines the paper compares against in §5.4:
//!
//! | method | reference | character |
//! |---|---|---|
//! | Xing2002 | Xing et al., NIPS 2002 | original SDP formulation: projected gradient with O(d³) eigen-projection per iteration — the cost the paper's reformulation removes |
//! | ITML | Davis et al., ICML 2007 | information-theoretic: cyclic Bregman projections, O(d²) per pair |
//! | KISS | Köstinger et al., CVPR 2012 | one-shot likelihood-ratio metric from pair-difference covariances (after PCA) |
//! | Euclidean | — | identity metric |
//!
//! All are implemented from scratch on the `linalg` substrate and exposed
//! through a common [`LearnedMetric`] so the evaluation pipeline treats
//! every method (including ours) identically.

mod itml;
mod kiss;
mod xing2002;

pub use itml::{Itml, ItmlConfig};
pub use kiss::{Kiss, KissConfig};
pub use xing2002::{Xing2002, Xing2002Config};

use crate::data::{Dataset, PairSet};
use crate::linalg::pca::Pca;
use crate::linalg::Mat;

/// A learned Mahalanobis metric, possibly living in a PCA-reduced space.
pub enum LearnedMetric {
    /// distance(δ) = δᵀ M δ in the input space.
    FullM(Mat),
    /// distance computed in a PCA-projected space.
    PcaM { pca: Pca, m: Mat },
    /// identity metric (Euclidean).
    Euclidean,
}

impl LearnedMetric {
    /// Score a pair set: returns (similar_dists, dissimilar_dists).
    pub fn score(
        &self,
        ds: &Dataset,
        pairs: &PairSet,
    ) -> (Vec<f32>, Vec<f32>) {
        match self {
            LearnedMetric::FullM(m) => {
                crate::eval::score_pairs_mahalanobis(m, ds, pairs)
            }
            LearnedMetric::Euclidean => {
                crate::eval::score_pairs_euclidean(ds, pairs)
            }
            LearnedMetric::PcaM { pca, m } => {
                let d = pca.components.rows;
                let mut diff = vec![0.0f32; ds.dim()];
                let mut score = |set: &[crate::data::Pair]| -> Vec<f32> {
                    set.iter()
                        .map(|p| {
                            ds.diff_into(
                                p.i as usize,
                                p.j as usize,
                                &mut diff,
                            );
                            // PCA is linear: project the difference
                            // directly (mean cancels in x - y).
                            let z = pca.components.matvec(&diff);
                            debug_assert_eq!(z.len(), d);
                            let mz = m.matvec(&z);
                            crate::linalg::dot(&z, &mz)
                        })
                        .collect()
                };
                let sim = score(&pairs.similar);
                let dis = score(&pairs.dissimilar);
                (sim, dis)
            }
        }
    }

    /// Average precision on a held-out pair set.
    pub fn ap(&self, ds: &Dataset, pairs: &PairSet) -> f64 {
        let (sim, dis) = self.score(ds, pairs);
        crate::eval::average_precision(&sim, &dis)
    }
}

/// (elapsed seconds, test AP) trace recorded while a method trains —
/// the raw series behind Fig 4a.
pub type ApTrace = Vec<(f64, f64)>;

/// Materialized pair differences (rows) for baseline fitting: baselines
/// operate on far fewer pairs than the distributed path, so dense
/// materialization is fine here.
pub fn pair_diffs(ds: &Dataset, pairs: &[crate::data::Pair]) -> Mat {
    let d = ds.dim();
    let mut out = Mat::zeros(pairs.len(), d);
    for (r, p) in pairs.iter().enumerate() {
        ds.diff_into(p.i as usize, p.j as usize, out.row_mut(r));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::util::rng::Pcg32;

    #[test]
    fn euclidean_metric_scores_match_eval() {
        let ds = SyntheticSpec::tiny().generate(0);
        let mut rng = Pcg32::new(0);
        let pairs = PairSet::sample(&ds, 40, 40, &mut rng);
        let m = LearnedMetric::Euclidean;
        let (s1, _) = m.score(&ds, &pairs);
        let (s2, _) = crate::eval::score_pairs_euclidean(&ds, &pairs);
        assert_eq!(s1, s2);
    }

    #[test]
    fn identity_fullm_equals_euclidean_ap() {
        let ds = SyntheticSpec::tiny().generate(1);
        let mut rng = Pcg32::new(1);
        let pairs = PairSet::sample(&ds, 100, 100, &mut rng);
        let full = LearnedMetric::FullM(Mat::eye(ds.dim()));
        let eu = LearnedMetric::Euclidean;
        assert!((full.ap(&ds, &pairs) - eu.ap(&ds, &pairs)).abs() < 1e-9);
    }

    #[test]
    fn pair_diffs_shape() {
        let ds = SyntheticSpec::tiny().generate(2);
        let mut rng = Pcg32::new(2);
        let pairs = PairSet::sample(&ds, 17, 5, &mut rng);
        let diffs = pair_diffs(&ds, &pairs.similar);
        assert_eq!((diffs.rows, diffs.cols), (17, ds.dim()));
    }
}
