//! ITML — Information-Theoretic Metric Learning (Davis et al., 2007).
//!
//! Minimizes the LogDet divergence to a prior M₀ subject to
//! dist ≤ u for similar pairs and dist ≥ l for dissimilar pairs, via
//! cyclic Bregman projections. Each projection is the classic rank-one
//! update
//!
//! ```text
//! M ← M + β · (M δ)(M δ)ᵀ
//! ```
//!
//! with β from the slack-variable recurrence — **O(d²) per pair**, the
//! complexity the paper quotes for ITML in §5.4. Updating one pair at a
//! time also explains the non-monotone precision curve the paper observes
//! (single-pair updates have high variance; there is no clean way to
//! mini-batch the projections).

use super::{ApTrace, LearnedMetric};
use crate::data::{Dataset, PairSet};
use crate::linalg::Mat;
use crate::metrics::Stopwatch;

#[derive(Clone, Copy, Debug)]
pub struct ItmlConfig {
    /// Slack tradeoff γ (paper §5.4 uses 0.001).
    pub gamma: f32,
    /// Distance targets: similar pairs ≤ u, dissimilar ≥ l. When None,
    /// set from the 5th / 95th percentiles of Euclidean pair distances
    /// (the authors' recipe).
    pub u: Option<f32>,
    pub l: Option<f32>,
    /// Sweeps over the constraint set.
    pub sweeps: usize,
    pub probe_every_pairs: usize,
    pub max_seconds: f64,
}

impl Default for ItmlConfig {
    fn default() -> Self {
        ItmlConfig {
            // slack tradeoff; the paper's §5.4 quotes 0.001 on MATLAB-
            // normalized MNIST — on our raw-scale features γ=1 puts the
            // slack term on the same footing (γ/ξ comparable to 1/p)
            gamma: 1.0,
            u: None,
            l: None,
            sweeps: 3,
            probe_every_pairs: 200,
            max_seconds: 600.0,
        }
    }
}

pub struct Itml {
    pub cfg: ItmlConfig,
}

impl Itml {
    pub fn new(cfg: ItmlConfig) -> Self {
        Itml { cfg }
    }

    pub fn fit_traced(
        &self,
        train: &Dataset,
        pairs: &PairSet,
        test: &Dataset,
        test_pairs: &PairSet,
    ) -> (LearnedMetric, ApTrace) {
        let d = train.dim();
        let watch = Stopwatch::start();
        let mut trace = ApTrace::new();

        // distance targets from Euclidean percentiles
        let (u, l) = self.targets(train, pairs);

        let mut m = Mat::eye(d);
        // dual variables + per-constraint slack targets (Davis Alg. 1:
        // λ init 0; slack ξ init to u for similar, l for dissimilar)
        let n_sim = pairs.similar.len();
        let n_dis = pairs.dissimilar.len();
        let mut lambda = vec![0.0f32; n_sim + n_dis];
        let mut xi: Vec<f32> = (0..n_sim + n_dis)
            .map(|ci| if ci < n_sim { u } else { l })
            .collect();
        let gamma = self.cfg.gamma;
        let mut diff = vec![0.0f32; d];
        let mut processed = 0usize;
        'outer: for _sweep in 0..self.cfg.sweeps {
            for ci in 0..(n_sim + n_dis) {
                let (pair, is_sim) = if ci < n_sim {
                    (pairs.similar[ci], true)
                } else {
                    (pairs.dissimilar[ci - n_sim], false)
                };
                train.diff_into(
                    pair.i as usize,
                    pair.j as usize,
                    &mut diff,
                );
                let md = m.matvec(&diff); // O(d²)
                let p = crate::linalg::dot(&diff, &md).max(1e-12);
                let delta: f32 = if is_sim { 1.0 } else { -1.0 };
                // Bregman projection with slack (Davis et al., Alg. 1):
                //   α  = min(λ, δ/2 (1/p − γ/ξ))
                //   λ ← λ − α
                //   β  = δα / (1 − δαp)
                //   ξ ← γξ / (γ + δαξ)
                //   M ← M + β (Mδ)(Mδ)ᵀ
                let alpha = lambda[ci].min(
                    0.5 * delta * (1.0 / p - gamma / xi[ci].max(1e-12)),
                );
                if alpha == 0.0 {
                    processed += 1;
                    continue;
                }
                lambda[ci] -= alpha;
                let denom = 1.0 - delta * alpha * p;
                if denom.abs() < 1e-12 {
                    processed += 1;
                    continue;
                }
                let beta = delta * alpha / denom;
                xi[ci] = gamma * xi[ci]
                    / (gamma + delta * alpha * xi[ci]);
                // M ← M + β (Mδ)(Mδ)ᵀ  (rank-one, O(d²))
                for i in 0..d {
                    let bi = beta * md[i];
                    if bi == 0.0 {
                        continue;
                    }
                    let row = &mut m.data[i * d..(i + 1) * d];
                    for (mv, &mdj) in row.iter_mut().zip(&md) {
                        *mv += bi * mdj;
                    }
                }
                processed += 1;
                if processed % self.cfg.probe_every_pairs == 0 {
                    let metric = LearnedMetric::FullM(m.clone());
                    trace.push((
                        watch.elapsed_s(),
                        metric.ap(test, test_pairs),
                    ));
                    if watch.elapsed_s() > self.cfg.max_seconds {
                        break 'outer;
                    }
                }
            }
        }
        let metric = LearnedMetric::FullM(m.clone());
        trace.push((watch.elapsed_s(), metric.ap(test, test_pairs)));
        (LearnedMetric::FullM(m), trace)
    }

    pub fn fit(&self, train: &Dataset, pairs: &PairSet) -> LearnedMetric {
        let (m, _) = self.fit_traced(train, pairs, train, pairs);
        m
    }

    fn targets(&self, train: &Dataset, pairs: &PairSet) -> (f32, f32) {
        if let (Some(u), Some(l)) = (self.cfg.u, self.cfg.l) {
            return (u, l);
        }
        let (sim, dis) = crate::eval::score_pairs_euclidean(train, pairs);
        let mut all: Vec<f64> = sim
            .iter()
            .chain(dis.iter())
            .map(|&x| x as f64)
            .collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let u = self
            .cfg
            .u
            .unwrap_or(crate::util::stats::percentile(&all, 5.0) as f32);
        let l = self
            .cfg
            .l
            .unwrap_or(crate::util::stats::percentile(&all, 95.0) as f32);
        (u.max(1e-6), l.max(u * 1.5))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::linalg::eigen::min_eigenvalue;
    use crate::util::rng::Pcg32;

    fn problem() -> (Dataset, PairSet, Dataset, PairSet) {
        let spec = SyntheticSpec::tiny();
        let mut rng = Pcg32::new(0);
        let train = spec.generate_with(&mut rng, 300);
        let test = spec.generate_with(&mut rng, 200);
        let mut rng2 = Pcg32::new(1);
        let pairs = PairSet::sample(&train, 200, 200, &mut rng2);
        let test_pairs = PairSet::sample(&test, 150, 150, &mut rng2);
        (train, pairs, test, test_pairs)
    }

    #[test]
    fn stays_psd_through_updates() {
        let (train, pairs, test, test_pairs) = problem();
        let itml = Itml::new(ItmlConfig { sweeps: 1, ..Default::default() });
        let (metric, _) =
            itml.fit_traced(&train, &pairs, &test, &test_pairs);
        let LearnedMetric::FullM(m) = &metric else { panic!() };
        // Bregman projections preserve positive definiteness
        assert!(min_eigenvalue(m) > -1e-3);
    }

    #[test]
    fn improves_over_euclidean() {
        let (train, pairs, test, test_pairs) = problem();
        let eu_ap = LearnedMetric::Euclidean.ap(&test, &test_pairs);
        let itml = Itml::new(ItmlConfig { sweeps: 2, ..Default::default() });
        let (metric, trace) =
            itml.fit_traced(&train, &pairs, &test, &test_pairs);
        let ap = metric.ap(&test, &test_pairs);
        assert!(ap > eu_ap - 0.05, "itml {ap} vs euclidean {eu_ap}");
        assert!(!trace.is_empty());
    }

    #[test]
    fn targets_ordered() {
        let (train, pairs, _, _) = problem();
        let itml = Itml::new(ItmlConfig::default());
        let (u, l) = itml.targets(&train, &pairs);
        assert!(u > 0.0 && l > u);
    }
}
