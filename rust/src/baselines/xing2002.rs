//! Xing et al. (2002): the original SDP formulation of DML.
//!
//! We implement the standard practical form of the original algorithm
//! (gradient + iterated projection):
//!
//! ```text
//! max_M   g(M) = Σ_D sqrt(δᵀ M δ)
//! s.t.    f(M) = Σ_S δᵀ M δ ≤ 1,      M ⪰ 0
//! ```
//!
//! by projected gradient ascent — gradient step on g, then alternating
//! projection onto {f(M) ≤ 1} (a scaling step for this linear constraint)
//! and the PSD cone (eigendecomposition, **O(d³) per iteration** — this
//! is precisely the cost the paper's L-factorized reformulation removes,
//! and why this baseline's Fig-4a curve is orders of magnitude slower).

use super::{ApTrace, LearnedMetric};
use crate::data::{Dataset, PairSet};
use crate::linalg::eigen::project_psd;
use crate::linalg::Mat;
use crate::metrics::Stopwatch;

#[derive(Clone, Copy, Debug)]
pub struct Xing2002Config {
    pub iters: usize,
    pub lr: f32,
    /// Evaluate the AP trace every `probe_every` iterations.
    pub probe_every: usize,
    /// Hard wall-clock budget (the method is slow by design).
    pub max_seconds: f64,
}

impl Default for Xing2002Config {
    fn default() -> Self {
        Xing2002Config {
            iters: 100,
            lr: 0.1,
            probe_every: 5,
            max_seconds: 600.0,
        }
    }
}

pub struct Xing2002 {
    pub cfg: Xing2002Config,
}

impl Xing2002 {
    pub fn new(cfg: Xing2002Config) -> Self {
        Xing2002 { cfg }
    }

    /// Fit on train pairs; records (time, AP-on-test) after every probe.
    pub fn fit_traced(
        &self,
        train: &Dataset,
        pairs: &PairSet,
        test: &Dataset,
        test_pairs: &PairSet,
    ) -> (LearnedMetric, ApTrace) {
        let d = train.dim();
        let sim = super::pair_diffs(train, &pairs.similar);
        let dis = super::pair_diffs(train, &pairs.dissimilar);
        let watch = Stopwatch::start();
        let mut trace = ApTrace::new();

        let mut m = Mat::eye(d);
        normalize_sim_constraint(&mut m, &sim);
        for it in 0..self.cfg.iters {
            // ascent direction: ∇ Σ_D sqrt(δᵀMδ) = Σ_D δδᵀ / (2 sqrt(..))
            let mut grad = Mat::zeros(d, d);
            for r in 0..dis.rows {
                let delta = dis.row(r);
                let md = m.matvec(delta);
                let dist = crate::linalg::dot(delta, &md).max(1e-12);
                let w = 0.5 / dist.sqrt();
                // grad += w * δ δᵀ (rank-one accumulate)
                for i in 0..d {
                    let wi = w * delta[i];
                    if wi == 0.0 {
                        continue;
                    }
                    let row = &mut grad.data[i * d..(i + 1) * d];
                    for (g, &dj) in row.iter_mut().zip(delta) {
                        *g += wi * dj;
                    }
                }
            }
            // normalized ascent step (the reference implementation steps
            // along ∇/‖∇‖ scaled by ‖M‖ so progress is scale-free; raw
            // gradients here span ~5 orders of magnitude across configs)
            let gnorm = grad.fro_norm().max(1e-20);
            let step = self.cfg.lr * m.fro_norm().max(1e-12) / gnorm
                / (1.0 + 0.1 * it as f32);
            m.axpy_inplace(step, &grad);
            // alternating projections: similar-sum ball, then PSD cone
            normalize_sim_constraint(&mut m, &sim);
            m = project_psd(&m); // O(d³)
            normalize_sim_constraint(&mut m, &sim);

            if it % self.cfg.probe_every == 0
                || it + 1 == self.cfg.iters
                || watch.elapsed_s() > self.cfg.max_seconds
            {
                let metric = LearnedMetric::FullM(m.clone());
                trace.push((
                    watch.elapsed_s(),
                    metric.ap(test, test_pairs),
                ));
            }
            if watch.elapsed_s() > self.cfg.max_seconds {
                break;
            }
        }
        (LearnedMetric::FullM(m), trace)
    }

    pub fn fit(
        &self,
        train: &Dataset,
        pairs: &PairSet,
    ) -> LearnedMetric {
        // trace against the train pairs (cheap) when no test set given
        let (m, _) = self.fit_traced(train, pairs, train, pairs);
        m
    }
}

/// Project onto {Σ_S δᵀMδ ≤ 1}: for this linear constraint the projection
/// along M is a rescale when violated (Xing et al.'s iterative projection
/// treats it the same way).
fn normalize_sim_constraint(m: &mut Mat, sim: &Mat) {
    let mut total = 0.0f64;
    for r in 0..sim.rows {
        let delta = sim.row(r);
        let md = m.matvec(delta);
        total += crate::linalg::dot(delta, &md) as f64;
    }
    if total > 1.0 {
        m.scale_inplace((1.0 / total) as f32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::linalg::eigen::min_eigenvalue;
    use crate::util::rng::Pcg32;

    fn small_problem() -> (Dataset, PairSet, Dataset, PairSet) {
        let spec = SyntheticSpec::tiny();
        let mut rng = Pcg32::new(0);
        let train = spec.generate_with(&mut rng, 300);
        let test = spec.generate_with(&mut rng, 200);
        let mut rng2 = Pcg32::new(1);
        let pairs = PairSet::sample(&train, 150, 150, &mut rng2);
        let test_pairs = PairSet::sample(&test, 150, 150, &mut rng2);
        (train, pairs, test, test_pairs)
    }

    #[test]
    fn result_is_psd_and_constraint_feasible() {
        let (train, pairs, test, test_pairs) = small_problem();
        let x = Xing2002::new(Xing2002Config {
            iters: 10,
            ..Default::default()
        });
        let (metric, trace) =
            x.fit_traced(&train, &pairs, &test, &test_pairs);
        let LearnedMetric::FullM(m) = &metric else { panic!() };
        assert!(min_eigenvalue(m) > -1e-3, "not PSD");
        let sim = super::super::pair_diffs(&train, &pairs.similar);
        let mut total = 0.0f64;
        for r in 0..sim.rows {
            let delta = sim.row(r);
            let md = m.matvec(delta);
            total += crate::linalg::dot(delta, &md) as f64;
        }
        assert!(total <= 1.01, "constraint violated: {total}");
        assert!(!trace.is_empty());
    }

    #[test]
    fn not_catastrophic_on_separated_data() {
        // Xing2002's first-order ascent is slow on anisotropic data
        // (the paper gives it 24 h); at unit-test budget we only require
        // it not to be catastrophically below the Euclidean baseline.
        let (train, pairs, test, test_pairs) = small_problem();
        let x = Xing2002::new(Xing2002Config {
            iters: 20,
            ..Default::default()
        });
        let (metric, _) = x.fit_traced(&train, &pairs, &test, &test_pairs);
        let ap = metric.ap(&test, &test_pairs);
        let eu = crate::baselines::LearnedMetric::Euclidean
            .ap(&test, &test_pairs);
        assert!(ap > eu - 0.1, "ap={ap} euclid={eu}");
    }

    #[test]
    fn respects_time_budget() {
        let (train, pairs, test, test_pairs) = small_problem();
        let x = Xing2002::new(Xing2002Config {
            iters: 100_000,
            max_seconds: 0.3,
            probe_every: 1,
            ..Default::default()
        });
        let t0 = std::time::Instant::now();
        let _ = x.fit_traced(&train, &pairs, &test, &test_pairs);
        assert!(t0.elapsed().as_secs_f64() < 5.0);
    }
}
