//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), experiment
//! configs, and metric dumps. Implements the full JSON grammar (strings
//! with escapes, numbers, nesting) with precise error positions; it does
//! not aim for serde-level ergonomics, just correctness.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ------------------------------------------------------------------
    // accessors
    // ------------------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null on any miss.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|v| v.get(i)).unwrap_or(&NULL)
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------------------------
    // construction helpers
    // ------------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ------------------------------------------------------------------
    // parse / serialize
    // ------------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?)
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    /// Single-line serialization — one NDJSON record per call site.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        let mut line = 1;
        let mut col = 1;
        for &c in &self.b[..self.pos.min(self.b.len())] {
            if c == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        ParseError { pos: self.pos, line, col, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        self.ws();
        let mut out = Vec::new();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                    self.ws();
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        self.ws();
        let mut out = BTreeMap::new();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                    self.ws();
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // (surrogate pairs unsupported; BMP only)
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = Json::parse(t).unwrap();
            let v2 = Json::parse(&v.to_string_pretty()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#)
            .unwrap();
        assert_eq!(v.get("a").idx(0).as_f64(), Some(1.0));
        assert!(v.get("a").idx(2).get("b").is_null());
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
    }

    #[test]
    fn roundtrip_complex() {
        let v = Json::obj(vec![
            ("name", Json::Str("dml \"quoted\"".into())),
            ("xs", Json::arr_f64(&[1.0, 2.5, -3.0])),
            ("nested", Json::obj(vec![("k", Json::Num(600.0))])),
            ("flag", Json::Bool(true)),
            ("nothing", Json::Null),
        ]);
        let text = v.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn error_positions() {
        let e = Json::parse("{\n  \"a\": }").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.msg.contains("expected a JSON value"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn compact_is_single_line_and_roundtrips() {
        let v = Json::obj(vec![
            ("a", Json::arr_f64(&[1.0, 2.5])),
            ("b", Json::obj(vec![("c", Json::Str("x\ny".into()))])),
        ]);
        let s = v.to_string_compact();
        assert!(!s.contains('\n'), "{s}");
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::Num(600.0).to_string_pretty(), "600");
        assert_eq!(Json::Num(0.5).to_string_pretty(), "0.5");
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "format": "hlo-text/1",
          "entries": [{"variant": "mnist", "function": "step",
                       "file": "mnist.step.hlo.txt",
                       "inputs": [{"shape": [600, 780], "dtype": "float32"}]}]
        }"#;
        let v = Json::parse(text).unwrap();
        let e = v.get("entries").idx(0);
        assert_eq!(e.get("function").as_str(), Some("step"));
        assert_eq!(
            e.get("inputs").idx(0).get("shape").idx(1).as_usize(),
            Some(780)
        );
    }
}
