//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Provides warmup, adaptive iteration counts, and robust summary
//! statistics, and prints aligned markdown tables so `cargo bench` output
//! can be pasted straight into EXPERIMENTS.md.
//!
//! ```no_run
//! use dmlps::util::bench::Bench;
//! let mut b = Bench::new("hot path");
//! b.bench("native step", || { /* work */ });
//! b.report();
//! ```

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

/// One measured benchmark row.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub std: Duration,
    /// Optional user-supplied throughput denominator (e.g. FLOPs/iter).
    pub work_per_iter: Option<f64>,
}

impl Measurement {
    /// work units per second, if `work_per_iter` was supplied.
    pub fn throughput(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / self.mean.as_secs_f64())
    }

    /// Machine-readable form (seconds for times, work units/s for
    /// throughput) — the payload of `BENCH_*.json` perf baselines.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_s", Json::Num(self.mean.as_secs_f64())),
            ("median_s", Json::Num(self.median.as_secs_f64())),
            ("p95_s", Json::Num(self.p95.as_secs_f64())),
            ("std_s", Json::Num(self.std.as_secs_f64())),
            ("work_per_iter", match self.work_per_iter {
                Some(w) => Json::Num(w),
                None => Json::Null,
            }),
            ("throughput_per_s", match self.throughput() {
                Some(t) => Json::Num(t),
                None => Json::Null,
            }),
        ])
    }
}

/// Benchmark group: collects measurements, prints one table.
pub struct Bench {
    title: String,
    warmup: Duration,
    target_time: Duration,
    max_iters: u64,
    rows: Vec<Measurement>,
}

impl Bench {
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            warmup: Duration::from_millis(200),
            target_time: Duration::from_secs(2),
            max_iters: 1_000_000,
            rows: Vec::new(),
        }
    }

    /// Tune for slow end-to-end benches: short warmup, few iterations.
    pub fn heavy(title: &str) -> Self {
        let mut b = Self::new(title);
        b.warmup = Duration::from_millis(0);
        b.target_time = Duration::from_millis(500);
        b.max_iters = 20;
        b
    }

    pub fn with_target_time(mut self, t: Duration) -> Self {
        self.target_time = t;
        self
    }

    /// Measure `f`, auto-picking an iteration count to fill target_time.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &Measurement {
        self.bench_with_work(name, None, f)
    }

    /// Measure with a throughput denominator (e.g. FLOPs or bytes/iter).
    pub fn bench_with_work<F: FnMut()>(
        &mut self,
        name: &str,
        work_per_iter: Option<f64>,
        mut f: F,
    ) -> &Measurement {
        // Warmup phase: run until the warmup budget is spent.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup && warm_iters < 1000 {
            f();
            warm_iters += 1;
        }
        // Calibrate: time one call to pick the sample count.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let iters = ((self.target_time.as_secs_f64() / once.as_secs_f64())
            as u64)
            .clamp(3, self.max_iters);

        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(stats::mean(&samples)),
            median: Duration::from_secs_f64(stats::median(&samples)),
            p95: Duration::from_secs_f64(stats::percentile(&samples, 95.0)),
            std: Duration::from_secs_f64(
                variance_of(&samples).sqrt(),
            ),
            work_per_iter,
        };
        self.rows.push(m);
        self.rows.last().unwrap()
    }

    /// Record an externally-measured duration series under a name
    /// (used by end-to-end drivers that time whole runs themselves).
    pub fn record(&mut self, name: &str, samples_sec: &[f64]) {
        assert!(!samples_sec.is_empty());
        self.rows.push(Measurement {
            name: name.to_string(),
            iters: samples_sec.len() as u64,
            mean: Duration::from_secs_f64(stats::mean(samples_sec)),
            median: Duration::from_secs_f64(stats::median(samples_sec)),
            p95: Duration::from_secs_f64(stats::percentile(samples_sec, 95.0)),
            std: Duration::from_secs_f64(variance_of(samples_sec).sqrt()),
            work_per_iter: None,
        });
    }

    pub fn rows(&self) -> &[Measurement] {
        &self.rows
    }

    /// The whole group as JSON (`{"group": title, "rows": [...]}`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("group", Json::Str(self.title.clone())),
            ("rows", Json::Arr(
                self.rows.iter().map(Measurement::to_json).collect(),
            )),
        ])
    }

    /// Print the group as a markdown table.
    pub fn report(&self) {
        println!("\n## {}", self.title);
        println!(
            "| {:<40} | {:>10} | {:>12} | {:>12} | {:>12} | {:>14} |",
            "benchmark", "iters", "mean", "median", "p95", "throughput"
        );
        println!(
            "|{}|{}|{}|{}|{}|{}|",
            "-".repeat(42),
            "-".repeat(12),
            "-".repeat(14),
            "-".repeat(14),
            "-".repeat(14),
            "-".repeat(16)
        );
        for r in &self.rows {
            let tp = r
                .throughput()
                .map(|t| format_throughput(t))
                .unwrap_or_else(|| "-".to_string());
            println!(
                "| {:<40} | {:>10} | {:>12} | {:>12} | {:>12} | {:>14} |",
                r.name,
                r.iters,
                format_dur(r.mean),
                format_dur(r.median),
                format_dur(r.p95),
                tp
            );
        }
    }
}

fn variance_of(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = stats::mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn format_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

pub fn format_throughput(t: f64) -> String {
    if t >= 1e12 {
        format!("{:.2} T/s", t / 1e12)
    } else if t >= 1e9 {
        format!("{:.2} G/s", t / 1e9)
    } else if t >= 1e6 {
        format!("{:.2} M/s", t / 1e6)
    } else if t >= 1e3 {
        format!("{:.2} K/s", t / 1e3)
    } else {
        format!("{t:.2} /s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bench::new("test").with_target_time(Duration::from_millis(20));
        let m = b.bench("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(m.iters >= 3);
        assert!(m.mean.as_nanos() > 0);
        assert!(m.p95 >= m.median || m.iters < 10);
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bench::new("t").with_target_time(Duration::from_millis(10));
        let m = b.bench_with_work("w", Some(1e6), || {
            std::thread::sleep(Duration::from_micros(100));
        });
        let tp = m.throughput().unwrap();
        assert!(tp > 1e8 && tp < 1.2e10, "tp={tp}");
    }

    #[test]
    fn record_external_samples() {
        let mut b = Bench::new("r");
        b.record("ext", &[0.1, 0.2, 0.3]);
        let m = &b.rows()[0];
        assert_eq!(m.iters, 3);
        assert!((m.mean.as_secs_f64() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn json_payload_has_throughput() {
        let mut b = Bench::new("j").with_target_time(Duration::from_millis(5));
        b.bench_with_work("w", Some(100.0), || {
            std::hint::black_box(1 + 1);
        });
        let j = b.to_json();
        assert_eq!(j.get("group").as_str().unwrap(), "j");
        let row = j.get("rows").idx(0);
        assert_eq!(row.get("name").as_str().unwrap(), "w");
        assert!(row.get("throughput_per_s").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(format_dur(Duration::from_secs(2)), "2.000 s");
        assert_eq!(format_dur(Duration::from_millis(5)), "5.000 ms");
        assert!(format_throughput(2.5e9).contains("G/s"));
    }
}
