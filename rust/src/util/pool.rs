//! Dependency-free scoped thread pool — the compute substrate under the
//! tiled GEMM kernels, the sharded native engine, and the eval scans.
//!
//! Design, in the spirit of crossbeam/rayon but at ~1% of the surface:
//!
//! * A [`ThreadPool`] of `threads` total lanes spawns `threads − 1`
//!   persistent workers; the calling thread is always the remaining lane,
//!   so a 1-thread pool runs everything inline with zero overhead.
//! * [`ThreadPool::scope_run`] executes a batch of borrowing closures and
//!   **blocks until every one has settled**, which is what makes handing
//!   non-`'static` borrows to the workers sound (the borrows cannot
//!   outlive the call).
//! * While waiting, the scoping thread *helps*: it drains jobs from the
//!   shared queue instead of sleeping. Nested `scope_run` calls (a shard
//!   task that itself uses the pool) therefore cannot deadlock — any
//!   waiting lane makes progress on whatever work exists.
//! * Panics inside tasks are caught at the task boundary, the remaining
//!   tasks still run, and the scope call re-panics once everything has
//!   settled — the pool itself stays usable (see the panic-safety test).
//!
//! Determinism note: splitting work over `p` lanes fixes the reduction
//! grouping, so results are bit-reproducible for a fixed thread count;
//! across different thread counts, float sums may differ at rounding
//! level (the engine's property tests bound this against an f64 oracle).

use std::collections::VecDeque;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// A queued unit of work. Jobs are always `scope_run` wrappers, which
/// catch panics internally — a popped job never unwinds into its runner.
type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    /// (pending jobs, shutdown flag)
    jobs: Mutex<(VecDeque<Job>, bool)>,
    ready: Condvar,
}

impl Queue {
    fn push_all(&self, jobs: impl Iterator<Item = Job>) {
        let mut g = self.jobs.lock().unwrap();
        for j in jobs {
            g.0.push_back(j);
        }
        drop(g);
        self.ready.notify_all();
    }

    fn try_pop(&self) -> Option<Job> {
        self.jobs.lock().unwrap().0.pop_front()
    }
}

fn worker_loop(q: Arc<Queue>) {
    loop {
        let job = {
            let mut g = q.jobs.lock().unwrap();
            loop {
                if let Some(j) = g.0.pop_front() {
                    break Some(j);
                }
                if g.1 {
                    break None;
                }
                g = q.ready.wait(g).unwrap();
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

/// Completion barrier for one `scope_run` call.
struct ScopeSync {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl ScopeSync {
    fn settle_one(&self, panicked: bool) {
        if panicked {
            self.panicked.store(true, Ordering::SeqCst);
        }
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.done.notify_all();
        }
    }
}

/// A fixed-size pool of persistent worker threads plus the caller's lane.
pub struct ThreadPool {
    queue: Arc<Queue>,
    handles: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Pool with `threads` total lanes (clamped to ≥ 1). `threads − 1`
    /// OS threads are spawned; the caller is the last lane.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let queue = Arc::new(Queue {
            jobs: Mutex::new((VecDeque::new(), false)),
            ready: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|i| {
                let q = queue.clone();
                std::thread::Builder::new()
                    .name(format!("dmlps-pool-{i}"))
                    .spawn(move || worker_loop(q))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { queue, handles, threads }
    }

    /// Total parallel lanes (workers + calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every task to completion, using the calling thread plus the
    /// workers. Blocks until all tasks have settled; if any task
    /// panicked, re-panics here (after the barrier, so borrows stay
    /// sound and the pool stays usable).
    pub fn scope_run<'s>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 's>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        if self.handles.is_empty() || n == 1 {
            // No workers (or nothing to share): run inline. Panics
            // propagate directly — there are no outstanding borrows.
            for t in tasks {
                t();
            }
            return;
        }
        let sync = Arc::new(ScopeSync {
            remaining: Mutex::new(n),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let mut tasks = tasks.into_iter();
        let first = tasks.next().unwrap();
        self.queue.push_all(tasks.map(|task| {
            // SAFETY: the borrows inside `task` live for 's, and this
            // function does not return until `remaining` hits zero —
            // i.e. until every wrapper below has finished running. The
            // queue can outlive 's only with an empty backlog.
            let task: Box<dyn FnOnce() + Send + 'static> =
                unsafe { std::mem::transmute(task) };
            let s = sync.clone();
            Box::new(move || {
                let panicked =
                    catch_unwind(AssertUnwindSafe(task)).is_err();
                s.settle_one(panicked);
            }) as Job
        }));
        // The caller's lane runs the first task itself…
        let panicked = catch_unwind(AssertUnwindSafe(first)).is_err();
        sync.settle_one(panicked);
        // …then helps drain the queue until this scope has settled.
        loop {
            if *sync.remaining.lock().unwrap() == 0 {
                break;
            }
            match self.queue.try_pop() {
                Some(job) => job(),
                None => {
                    let r = sync.remaining.lock().unwrap();
                    if *r == 0 {
                        break;
                    }
                    // Short timed wait: our tasks may be running on
                    // workers (notify wakes us) or sitting behind other
                    // scopes' jobs (the timeout re-polls the queue).
                    let _ = sync
                        .done
                        .wait_timeout(r, Duration::from_micros(200))
                        .unwrap();
                }
            }
        }
        if sync.panicked.load(Ordering::SeqCst) {
            panic!("thread-pool task panicked (see stderr for the task's panic message)");
        }
    }

    /// Split `0..n` into up to `threads()` balanced contiguous ranges and
    /// run `f` on each in parallel.
    pub fn for_each_range<F>(&self, n: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let parts = self.threads.min(n);
        if parts <= 1 {
            f(0..n);
            return;
        }
        let fref = &f;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..parts)
            .map(|i| {
                Box::new(move || fref(balanced_range(n, parts, i)))
                    as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        self.scope_run(tasks);
    }

    /// Run `f(i, &mut items[i])` for every item, one task per item.
    pub fn for_each_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        if items.is_empty() {
            return;
        }
        let fref = &f;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = items
            .iter_mut()
            .enumerate()
            .map(|(i, item)| {
                Box::new(move || fref(i, item))
                    as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        self.scope_run(tasks);
    }

    /// Split `items` into `chunk_len`-sized pieces and run
    /// `f(start_index, chunk)` on each in parallel.
    pub fn for_each_chunk<T, F>(&self, items: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        if items.is_empty() {
            return;
        }
        let chunk_len = chunk_len.max(1);
        let fref = &f;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = items
            .chunks_mut(chunk_len)
            .enumerate()
            .map(|(i, c)| {
                Box::new(move || fref(i * chunk_len, c))
                    as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        self.scope_run(tasks);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut g = self.queue.jobs.lock().unwrap();
            g.1 = true;
        }
        self.queue.ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The `idx`-th of `parts` balanced contiguous sub-ranges of `0..n`
/// (the first `n % parts` ranges are one element longer).
pub fn balanced_range(n: usize, parts: usize, idx: usize) -> Range<usize> {
    let parts = parts.max(1);
    debug_assert!(idx < parts);
    let base = n / parts;
    let rem = n % parts;
    let lo = idx * base + idx.min(rem);
    let hi = lo + base + usize::from(idx < rem);
    lo..hi
}

/// Default lane count: `DMLPS_THREADS` env var if set (and > 0), else the
/// machine's available parallelism.
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("DMLPS_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
    })
}

/// The process-wide shared pool (sized by [`default_threads`]), used by
/// the `Mat` matmul wrappers and the eval scans. Engines that need a
/// specific width own their own pool instead.
pub fn global() -> Arc<ThreadPool> {
    static POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
    POOL.get_or_init(|| Arc::new(ThreadPool::new(default_threads())))
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn balanced_ranges_cover_exactly() {
        for n in [0usize, 1, 2, 5, 7, 16, 100, 101] {
            for parts in [1usize, 2, 3, 4, 7, 16] {
                let mut seen = vec![false; n];
                let mut lens = Vec::new();
                for i in 0..parts {
                    let r = balanced_range(n, parts, i);
                    lens.push(r.len());
                    for x in r {
                        assert!(!seen[x], "overlap at {x} (n={n} p={parts})");
                        seen[x] = true;
                    }
                }
                assert!(seen.iter().all(|&s| s), "gap (n={n} p={parts})");
                let (mn, mx) = (
                    lens.iter().min().unwrap(),
                    lens.iter().max().unwrap(),
                );
                assert!(mx - mn <= 1, "unbalanced {lens:?}");
            }
        }
    }

    #[test]
    fn parallel_sum_matches_serial() {
        for threads in [1usize, 2, 4, 7] {
            let pool = ThreadPool::new(threads);
            let n = 10_000usize;
            let total = AtomicUsize::new(0);
            pool.for_each_range(n, |r| {
                let s: usize = r.sum();
                total.fetch_add(s, Ordering::Relaxed);
            });
            assert_eq!(total.load(Ordering::Relaxed), n * (n - 1) / 2);
        }
    }

    #[test]
    fn for_each_mut_touches_every_item_once() {
        let pool = ThreadPool::new(4);
        // differing shard counts against the same pool (reuse)
        for len in [1usize, 3, 4, 9, 64] {
            let mut items = vec![0u32; len];
            pool.for_each_mut(&mut items, |i, v| {
                *v += i as u32 + 1;
            });
            for (i, v) in items.iter().enumerate() {
                assert_eq!(*v, i as u32 + 1);
            }
        }
    }

    #[test]
    fn for_each_chunk_offsets_are_right() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0usize; 103];
        pool.for_each_chunk(&mut data, 10, |start, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = start + i;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.for_each_range(8, |r| {
                if r.contains(&3) {
                    panic!("boom in shard");
                }
            });
        }));
        assert!(res.is_err(), "panic must propagate to the scope caller");
        // the pool must remain fully usable afterwards
        let counter = AtomicUsize::new(0);
        pool.for_each_range(100, |r| {
            counter.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        let pool = ThreadPool::new(2);
        let total = AtomicUsize::new(0);
        pool.for_each_range(4, |outer| {
            for _ in outer {
                // nested use of the same pool from inside a task
                pool.for_each_range(50, |inner| {
                    total.fetch_add(inner.len(), Ordering::Relaxed);
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut v = vec![0u8; 16];
        pool.for_each_chunk(&mut v, 4, |_, c| c.fill(1));
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
        assert!(global().threads() >= 1);
    }
}
