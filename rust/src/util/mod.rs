//! From-scratch utility substrates.
//!
//! The offline vendor set ships only `xla` + `anyhow`, so everything a
//! normal project would pull from crates.io — PRNG, JSON, CLI parsing,
//! benchmarking, property testing, statistics, a scoped thread pool — is
//! implemented here as small, well-tested modules.

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
