//! Deterministic PRNG: PCG32 (O'Neill 2014) + distribution helpers.
//!
//! Every stochastic component in the repo (data generation, pair
//! sampling, SGD minibatch selection, simulator jitter, property tests)
//! draws from this generator, keyed by an explicit seed, so every
//! experiment is bit-reproducible.

/// PCG-XSH-RR 64/32: 64-bit state/stream, 32-bit output.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seeded generator on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Seeded generator on an explicit stream (distinct streams are
    /// statistically independent — used to give each worker its own RNG).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator (split).
    pub fn split(&mut self) -> Self {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Self::with_stream(seed, stream)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Unbiased uniform integer in [0, n) (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n <= u32::MAX as usize);
        self.below(n as u32) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller (cached second deviate omitted for
    /// simplicity; the hot path batches through `fill_gaussian`).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a slice with N(mu, sigma²) f32 samples (pairwise Box–Muller).
    pub fn fill_gaussian(&mut self, out: &mut [f32], mu: f32, sigma: f32) {
        let mut i = 0;
        while i + 1 < out.len() {
            let (a, b) = self.gaussian_pair();
            out[i] = mu + sigma * a as f32;
            out[i + 1] = mu + sigma * b as f32;
            i += 2;
        }
        if i < out.len() {
            out[i] = mu + sigma * self.gaussian() as f32;
        }
    }

    #[inline]
    fn gaussian_pair(&mut self) -> (f64, f64) {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                let t = 2.0 * std::f64::consts::PI * u2;
                return (r * t.cos(), r * t.sin());
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized nonnegative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Exponential with rate `lambda` (used by the cluster simulator's
    /// jitter model).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// k distinct indices out of [0, n) (partial Fisher–Yates; O(n) memory
    /// only when k is large relative to n, else rejection sampling).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.index(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.index(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 2);
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg32::with_stream(1, 10);
        let mut b = Pcg32::with_stream(1, 11);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 2);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Pcg32::new(3);
        for _ in 0..10_000 {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut rng = Pcg32::new(4);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.below(10) as usize] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.1).abs() < 0.01, "p={p}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg32::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(6);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_distinct_properties() {
        let mut rng = Pcg32::new(7);
        for &(n, k) in &[(10, 10), (100, 5), (1000, 100), (5, 0)] {
            let s = rng.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg32::new(8);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.2, "ratio={ratio}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg32::new(9);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| rng.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn split_children_independent() {
        let mut parent = Pcg32::new(10);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..32).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 2);
    }
}
