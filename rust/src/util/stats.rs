//! Streaming and batch statistics: Welford accumulator, percentiles,
//! histograms. Backs the bench harness, the metrics recorder, and the
//! simulator's timing summaries.

/// Online mean/variance (Welford) with min/max tracking.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for n < 2.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample via linear interpolation (like numpy's default).
/// `q` in [0, 100]. Sorts a copy; fine for bench-sized samples.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = q / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Fixed-bin histogram over [lo, hi); values outside clamp to edge bins.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Self { lo, hi, bins: vec![0; nbins] }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let i = ((t * n as f64) as isize).clamp(0, n as isize - 1) as usize;
        self.bins[i] += 1;
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((w.mean() - m).abs() < 1e-12);
        assert!((w.var() - v).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 16.0);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentile_endpoints_and_interp() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn histogram_clamps_outliers() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(-5.0);
        h.push(0.5);
        h.push(9.99);
        h.push(100.0);
        assert_eq!(h.bins()[0], 2);
        assert_eq!(h.bins()[9], 2);
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn welford_single_value() {
        let mut w = Welford::new();
        w.push(42.0);
        assert_eq!(w.mean(), 42.0);
        assert_eq!(w.var(), 0.0);
    }
}
