//! Property-testing helper (proptest is not in the offline vendor set).
//!
//! `forall` runs a property over N randomly generated cases from an
//! explicit seed; on failure it retries with progressively "smaller"
//! regenerated cases (shrink-lite: re-draw with a shrunken size hint) and
//! reports the smallest failing case's seed so the exact case can be
//! replayed in a debugger.
//!
//! ```no_run
//! use dmlps::util::check::{forall, Gen};
//! forall("sum is commutative", 100, |g| {
//!     let a = g.usize_in(0, 1000);
//!     let b = g.usize_in(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Pcg32;

/// Case generator handed to properties: wraps the PRNG with a size hint
/// that shrinks on failure retries.
pub struct Gen {
    rng: Pcg32,
    /// 1.0 = full size, shrinks toward 0 on failure reproduction.
    pub size: f64,
    pub case_seed: u64,
}

impl Gen {
    fn new(case_seed: u64, size: f64) -> Self {
        Self { rng: Pcg32::new(case_seed), size, case_seed }
    }

    /// Integer in [lo, hi], scaled down by the shrink size.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.size).ceil() as usize;
        lo + self.rng.index(span.max(1).min(hi - lo + 1))
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, lo + (hi - lo) * self.size)
    }

    pub fn f32_unit(&mut self) -> f32 {
        self.rng.f32()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn gaussian_f32(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.rng.gaussian() as f32
    }

    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        let mut v = vec![0.0; len];
        self.rng.fill_gaussian(&mut v, 0.0, scale);
        v
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `prop` over `cases` generated cases. Panics (with the failing case
/// seed and shrink info) if any case fails. The property signals failure
/// by panicking (e.g. via assert!).
pub fn forall<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u32,
    prop: F,
) {
    forall_seeded(name, 0xD31A5EED, cases, prop)
}

pub fn forall_seeded<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    seed: u64,
    cases: u32,
    prop: F,
) {
    let mut master = Pcg32::new(seed);
    for case in 0..cases {
        let case_seed = master.next_u64();
        let failed = run_case(&prop, case_seed, 1.0);
        if let Some(msg) = failed {
            // Shrink-lite: re-run the same seed with smaller size hints and
            // report the smallest size that still fails.
            let mut smallest = (1.0, msg);
            for &size in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                if let Some(m) = run_case(&prop, case_seed, size) {
                    smallest = (size, m);
                }
            }
            panic!(
                "property '{name}' failed at case {case} \
                 (replay: seed={case_seed:#x}, size={}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

fn run_case<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    prop: &F,
    case_seed: u64,
    size: f64,
) -> Option<String> {
    let result = std::panic::catch_unwind(|| {
        let mut g = Gen::new(case_seed, size);
        prop(&mut g);
    });
    match result {
        Ok(()) => None,
        Err(e) => Some(panic_message(&e)),
    }
}

fn panic_message(e: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("reverse twice is identity", 50, |g| {
            let n = g.usize_in(0, 50);
            let v: Vec<f32> = g.vec_f32(n, 1.0);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        // suppress the panic backtraces from inner catch_unwind runs
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let result = std::panic::catch_unwind(|| {
            forall("always fails", 10, |g| {
                let x = g.usize_in(0, 100);
                assert!(x > 1_000_000, "x was {x}");
            });
        });
        std::panic::set_hook(prev);
        if let Err(e) = result {
            std::panic::resume_unwind(e);
        }
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(1, 1.0);
        for _ in 0..1000 {
            let x = g.usize_in(5, 10);
            assert!((5..=10).contains(&x));
            let y = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&y));
        }
    }

    #[test]
    fn shrunk_gen_produces_smaller() {
        let mut big = Gen::new(2, 1.0);
        let mut small = Gen::new(2, 0.01);
        let bigs: Vec<usize> = (0..100).map(|_| big.usize_in(0, 10_000)).collect();
        let smalls: Vec<usize> =
            (0..100).map(|_| small.usize_in(0, 10_000)).collect();
        let bmax = *bigs.iter().max().unwrap();
        let smax = *smalls.iter().max().unwrap();
        assert!(smax <= bmax / 10, "smax={smax} bmax={bmax}");
    }
}
