//! Declarative CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positionals, defaults,
//! required args, and auto-generated `--help`. Used by the `dmlps` binary
//! and every bench/example that takes parameters.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct ArgSpec {
    name: String,
    help: String,
    default: Option<String>,
    required: bool,
    is_flag: bool,
}

/// Builder for a command's argument set.
pub struct ArgParser {
    command: String,
    about: String,
    specs: Vec<ArgSpec>,
}

/// Parsed argument values.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl ArgParser {
    pub fn new(command: &str, about: &str) -> Self {
        Self { command: command.into(), about: about.into(), specs: Vec::new() }
    }

    /// Optional `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(ArgSpec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            required: false,
            is_flag: false,
        });
        self
    }

    /// Required `--name <value>`.
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.specs.push(ArgSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            required: true,
            is_flag: false,
        });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(ArgSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            required: false,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.command, self.about);
        for spec in &self.specs {
            let left = if spec.is_flag {
                format!("  --{}", spec.name)
            } else if let Some(d) = &spec.default {
                format!("  --{} <v> (default {})", spec.name, d)
            } else {
                format!("  --{} <v> (required)", spec.name)
            };
            s.push_str(&format!("{left:<44} {}\n", spec.help));
        }
        s
    }

    /// Parse a raw token list (excluding argv[0]).
    pub fn parse(&self, tokens: &[String]) -> anyhow::Result<Args> {
        let mut out = Args::default();
        for spec in &self.specs {
            if let Some(d) = &spec.default {
                out.values.insert(spec.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if tok == "--help" || tok == "-h" {
                anyhow::bail!("{}", self.usage());
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown option --{name}\n\n{}",
                            self.usage()
                        )
                    })?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        anyhow::bail!("flag --{name} takes no value");
                    }
                    out.flags.push(name);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| {
                                    anyhow::anyhow!("--{name} needs a value")
                                })?
                        }
                    };
                    out.values.insert(name, val);
                }
            } else {
                out.positionals.push(tok.clone());
            }
            i += 1;
        }
        for spec in &self.specs {
            if spec.required && !out.values.contains_key(&spec.name) {
                anyhow::bail!(
                    "missing required --{}\n\n{}",
                    spec.name,
                    self.usage()
                );
            }
        }
        Ok(out)
    }

    /// Parse from the process environment (skipping argv[0] and, for
    /// `cargo bench`-invoked binaries, a possible `--bench` token).
    pub fn parse_env(&self) -> anyhow::Result<Args> {
        let tokens: Vec<String> = std::env::args()
            .skip(1)
            .filter(|t| t != "--bench")
            .collect();
        self.parse(&tokens)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("argument --{name} not declared/set"))
    }

    pub fn get_usize(&self, name: &str) -> anyhow::Result<usize> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> anyhow::Result<f64> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }

    pub fn get_u64(&self, name: &str) -> anyhow::Result<u64> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Comma-separated usize list, e.g. `--cores 16,32,64`.
    pub fn get_usize_list(&self, name: &str) -> anyhow::Result<Vec<usize>> {
        self.get(name)
            .split(',')
            .map(|t| {
                t.trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("--{name} '{t}': {e}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|t| t.to_string()).collect()
    }

    fn parser() -> ArgParser {
        ArgParser::new("test", "a test")
            .opt("steps", "100", "number of steps")
            .opt("lr", "0.05", "learning rate")
            .req("dataset", "dataset name")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parser()
            .parse(&toks(&["--dataset", "mnist", "--steps=250"]))
            .unwrap();
        assert_eq!(a.get("dataset"), "mnist");
        assert_eq!(a.get_usize("steps").unwrap(), 250);
        assert_eq!(a.get_f64("lr").unwrap(), 0.05);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = parser()
            .parse(&toks(&["pos1", "--dataset", "x", "--verbose", "pos2"]))
            .unwrap();
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positionals, vec!["pos1", "pos2"]);
    }

    #[test]
    fn missing_required_errors() {
        let e = parser().parse(&toks(&["--steps", "5"])).unwrap_err();
        assert!(e.to_string().contains("missing required --dataset"));
    }

    #[test]
    fn unknown_option_errors() {
        let e = parser()
            .parse(&toks(&["--dataset", "x", "--nope", "1"]))
            .unwrap_err();
        assert!(e.to_string().contains("unknown option --nope"));
    }

    #[test]
    fn value_missing_errors() {
        let e = parser().parse(&toks(&["--dataset"])).unwrap_err();
        assert!(e.to_string().contains("needs a value"));
    }

    #[test]
    fn usize_list() {
        let p = ArgParser::new("t", "t").opt("cores", "1,2,4", "core counts");
        let a = p.parse(&toks(&[])).unwrap();
        assert_eq!(a.get_usize_list("cores").unwrap(), vec![1, 2, 4]);
    }

    #[test]
    fn flag_with_value_rejected() {
        let e = parser()
            .parse(&toks(&["--dataset", "x", "--verbose=yes"]))
            .unwrap_err();
        assert!(e.to_string().contains("takes no value"));
    }
}
