//! Full-objective evaluation over (a sample of) the pair sets.
//!
//! The paper's convergence figures (Fig 2) plot the *global* objective
//! value over time. Evaluating all 200M pairs each probe would dwarf
//! training, so — like the authors must have — we evaluate on a fixed
//! random subsample and keep it constant across probes so curves are
//! comparable.

use super::{Engine, MinibatchRef};
use crate::data::{Dataset, PairSet};
use crate::linalg::Mat;
use crate::util::rng::Pcg32;

/// Objective on an explicit batch of pair differences.
pub fn objective_on_batch(
    engine: &mut dyn Engine,
    l: &Mat,
    batch: &MinibatchRef<'_>,
    lambda: f32,
) -> f32 {
    let mut g = Mat::zeros(l.rows, l.cols);
    engine
        .loss_grad(l, batch, lambda, &mut g)
        .expect("objective evaluation failed")
}

/// Deterministic subsample of the pair sets for objective probes.
pub struct ObjectiveProbe {
    ds_buf: Vec<f32>,
    dd_buf: Vec<f32>,
    bs: usize,
    bd: usize,
    d: usize,
}

impl ObjectiveProbe {
    /// Materialize `n_sim`+`n_dis` fixed pair differences (seeded).
    pub fn new(
        ds: &Dataset,
        pairs: &PairSet,
        n_sim: usize,
        n_dis: usize,
        seed: u64,
    ) -> Self {
        let mut rng = Pcg32::with_stream(seed, 0x0B7);
        let d = ds.dim();
        let n_sim = n_sim.min(pairs.similar.len());
        let n_dis = n_dis.min(pairs.dissimilar.len());
        let mut ds_buf = vec![0.0f32; n_sim * d];
        let sim_idx = rng.sample_distinct(pairs.similar.len(), n_sim);
        for (r, &pi) in sim_idx.iter().enumerate() {
            let p = pairs.similar[pi];
            ds.diff_into(p.i as usize, p.j as usize,
                         &mut ds_buf[r * d..(r + 1) * d]);
        }
        let mut dd_buf = vec![0.0f32; n_dis * d];
        let dis_idx = rng.sample_distinct(pairs.dissimilar.len(), n_dis);
        for (r, &pi) in dis_idx.iter().enumerate() {
            let p = pairs.dissimilar[pi];
            ds.diff_into(p.i as usize, p.j as usize,
                         &mut dd_buf[r * d..(r + 1) * d]);
        }
        ObjectiveProbe { ds_buf, dd_buf, bs: n_sim, bd: n_dis, d }
    }

    /// Streaming-mode analogue of [`ObjectiveProbe::new`]: materialize
    /// a fixed `n_sim`+`n_dis` probe batch by drawing from a pair
    /// stream. Deterministic when the stream is (probes stay
    /// comparable across a run because the batch is drawn once).
    pub fn from_stream(
        ds: &Dataset,
        stream: &mut dyn crate::data::PairStream,
        n_sim: usize,
        n_dis: usize,
    ) -> Self {
        let d = ds.dim();
        let mut ds_buf = vec![0.0f32; n_sim * d];
        for r in 0..n_sim {
            let p = stream.next_similar();
            ds.diff_into(p.i as usize, p.j as usize,
                         &mut ds_buf[r * d..(r + 1) * d]);
        }
        let mut dd_buf = vec![0.0f32; n_dis * d];
        for r in 0..n_dis {
            let p = stream.next_dissimilar();
            ds.diff_into(p.i as usize, p.j as usize,
                         &mut dd_buf[r * d..(r + 1) * d]);
        }
        ObjectiveProbe { ds_buf, dd_buf, bs: n_sim, bd: n_dis, d }
    }

    /// Evaluate the objective at `l`.
    pub fn eval(&self, engine: &mut dyn Engine, l: &Mat, lambda: f32) -> f32 {
        let batch = MinibatchRef::new(
            &self.ds_buf, &self.dd_buf, self.bs, self.bd, self.d,
        );
        objective_on_batch(engine, l, &batch, lambda)
    }
}

/// Objective over the *entire* pair sets (exact; for small configs/tests).
pub fn full_objective(
    engine: &mut dyn Engine,
    l: &Mat,
    ds: &Dataset,
    pairs: &PairSet,
    lambda: f32,
) -> f32 {
    let probe = ObjectiveProbe::new(
        ds,
        pairs,
        pairs.similar.len(),
        pairs.dissimilar.len(),
        0,
    );
    probe.eval(engine, l, lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::dml::{DmlProblem, NativeEngine};

    #[test]
    fn probe_is_deterministic() {
        let ds = SyntheticSpec::tiny().generate(0);
        let mut rng = Pcg32::new(1);
        let pairs = PairSet::sample(&ds, 200, 200, &mut rng);
        let problem = DmlProblem::new(ds.dim(), 8, 1.0);
        let l = problem.init_l(0.5, 7);
        let mut eng = NativeEngine::new();
        let p1 = ObjectiveProbe::new(&ds, &pairs, 50, 50, 3);
        let p2 = ObjectiveProbe::new(&ds, &pairs, 50, 50, 3);
        assert_eq!(p1.eval(&mut eng, &l, 1.0), p2.eval(&mut eng, &l, 1.0));
    }

    #[test]
    fn subsample_approximates_full() {
        let ds = SyntheticSpec::tiny().generate(2);
        let mut rng = Pcg32::new(2);
        let pairs = PairSet::sample(&ds, 2000, 2000, &mut rng);
        let problem = DmlProblem::new(ds.dim(), 8, 1.0);
        let l = problem.init_l(0.5, 8);
        let mut eng = NativeEngine::new();
        let full = full_objective(&mut eng, &l, &ds, &pairs, 1.0);
        let probe = ObjectiveProbe::new(&ds, &pairs, 500, 500, 4);
        let approx = probe.eval(&mut eng, &l, 1.0);
        assert!(
            (full - approx).abs() < 0.15 * full.abs().max(1.0),
            "full={full} approx={approx}"
        );
    }

    #[test]
    fn stream_probe_is_deterministic_and_matches_materialized_math() {
        use crate::data::ImplicitPairSampler;
        let ds = std::sync::Arc::new(SyntheticSpec::tiny().generate(4));
        let problem = DmlProblem::new(ds.dim(), 8, 1.0);
        let l = problem.init_l(0.5, 9);
        let mut eng = NativeEngine::new();
        let mut s1 =
            ImplicitPairSampler::new(ds.clone(), 6, 0, 1, 0.0, 0.0)
                .unwrap();
        let mut s2 =
            ImplicitPairSampler::new(ds.clone(), 6, 0, 1, 0.0, 0.0)
                .unwrap();
        let p1 = ObjectiveProbe::from_stream(&ds, &mut s1, 40, 40);
        let p2 = ObjectiveProbe::from_stream(&ds, &mut s2, 40, 40);
        assert_eq!(p1.eval(&mut eng, &l, 1.0), p2.eval(&mut eng, &l, 1.0));
        assert!(p1.eval(&mut eng, &l, 1.0).is_finite());
    }

    #[test]
    fn probe_caps_at_available_pairs() {
        let ds = SyntheticSpec::tiny().generate(3);
        let mut rng = Pcg32::new(3);
        let pairs = PairSet::sample(&ds, 20, 20, &mut rng);
        let probe = ObjectiveProbe::new(&ds, &pairs, 1000, 1000, 5);
        assert_eq!(probe.bs, 20);
        assert_eq!(probe.bd, 20);
    }
}
