//! The DML problem (paper Eq. 4) and the engine abstraction.
//!
//! An [`Engine`] computes the minibatch objective/gradient and pair
//! distances for a fixed problem shape. Two implementations:
//!
//! * [`NativeEngine`] — pure-Rust blocked matmuls (the L3-optimized CPU
//!   hot path; also the reference the runtime tests compare against).
//! * [`runtime::XlaEngine`](crate::runtime::XlaEngine) — executes the
//!   AOT-compiled JAX/Pallas artifacts via PJRT; the production path.
//!
//! The objective (mean-normalized Eq. 4; see `python/compile/kernels/ref.py`
//! for the identical Python oracle):
//!
//! ```text
//! f(L) = mean_S ‖LΔ‖² + λ · mean_D max(0, 1 − ‖LΔ‖²)
//! ```

mod native;
mod objective;
mod optimizer;

pub use native::NativeEngine;
pub use objective::{full_objective, objective_on_batch, ObjectiveProbe};
pub use optimizer::LrSchedule;

use crate::linalg::Mat;

/// A borrowed minibatch of pair-difference rows.
///
/// `ds`/`dd` are row-major (bs × d) / (bd × d) — exactly the layout the
/// minibatch iterator fills and the layout both engines consume with zero
/// copies.
pub struct MinibatchRef<'a> {
    pub ds: &'a [f32],
    pub dd: &'a [f32],
    pub bs: usize,
    pub bd: usize,
    pub d: usize,
}

impl<'a> MinibatchRef<'a> {
    pub fn new(
        ds: &'a [f32],
        dd: &'a [f32],
        bs: usize,
        bd: usize,
        d: usize,
    ) -> Self {
        assert_eq!(ds.len(), bs * d, "similar buffer shape");
        assert_eq!(dd.len(), bd * d, "dissimilar buffer shape");
        MinibatchRef { ds, dd, bs, bd, d }
    }

    pub fn from_iter(it: &'a crate::data::MinibatchIter<'a>) -> Self {
        let (bs, bd, d) = it.shapes();
        Self::new(&it.ds_buf, &it.dd_buf, bs, bd, d)
    }
}

/// Problem description shared by engines and the parameter server.
#[derive(Clone, Copy, Debug)]
pub struct DmlProblem {
    pub d: usize,
    pub k: usize,
    pub lambda: f32,
}

impl DmlProblem {
    pub fn new(d: usize, k: usize, lambda: f32) -> Self {
        assert!(k <= d, "factorization requires k <= d");
        DmlProblem { d, k, lambda }
    }

    /// Initial L: scaled rectangular identity plus small noise — full rank
    /// by construction, scale chosen so initial distances are O(1).
    pub fn init_l(&self, init_scale: f32, seed: u64) -> Mat {
        let mut l = Mat::scaled_eye(self.k, self.d, init_scale);
        let mut rng = crate::util::rng::Pcg32::with_stream(seed, 0x111);
        let mut noise = vec![0.0f32; self.k * self.d];
        rng.fill_gaussian(&mut noise, 0.0, init_scale / (self.d as f32).sqrt());
        for (a, b) in l.data.iter_mut().zip(&noise) {
            *a += b;
        }
        l
    }

    /// FLOPs of one minibatch loss+grad (4 b×k×d matmuls, 2 flops/MAC).
    pub fn step_flops(&self, bs: usize, bd: usize) -> f64 {
        4.0 * (bs + bd) as f64 / 2.0 * self.k as f64 * self.d as f64 * 2.0
    }
}

/// Thread-safe engine constructor. The XLA engine wraps a PJRT client
/// (`Rc`-based, not `Send`), so worker threads each build their own
/// engine inside the thread via one of these factories.
pub type EngineFactory = std::sync::Arc<
    dyn Fn() -> anyhow::Result<Box<dyn Engine>> + Send + Sync,
>;

/// Factory for the native engine (always available) on the shared global
/// pool. Callers that need a specific width (the PS workers, via
/// `WorkerConfig::threads`) resize it afterwards with
/// [`Engine::set_threads`].
pub fn native_factory() -> EngineFactory {
    std::sync::Arc::new(|| Ok(Box::new(NativeEngine::new()) as Box<dyn Engine>))
}

/// Resolve an engine factory by name: "native", "xla", or "auto"
/// (xla when the runtime is compiled in and artifacts are present, else
/// native). Per-worker compute width is applied by the worker itself:
/// the distributed executor copies `cluster.threads_per_worker` into
/// `WorkerConfig::threads` and each worker calls [`Engine::set_threads`].
pub fn engine_factory(
    name: &str,
    cfg: &crate::config::ExperimentConfig,
) -> anyhow::Result<EngineFactory> {
    match name {
        "native" => Ok(native_factory()),
        "xla" => {
            anyhow::ensure!(
                cfg!(feature = "xla"),
                "this binary was built without the XLA/PJRT runtime \
                 (rebuild with `--features xla`)"
            );
            let variant = cfg.artifact_variant.clone().ok_or_else(|| {
                anyhow::anyhow!("config has no artifact variant for xla")
            })?;
            anyhow::ensure!(
                crate::runtime::artifacts_available(),
                "artifacts not built (run `make artifacts`)"
            );
            Ok(crate::runtime::xla_factory(&variant))
        }
        "auto" => {
            if cfg!(feature = "xla")
                && crate::runtime::artifacts_available()
                && cfg.artifact_variant.is_some()
            {
                engine_factory("xla", cfg)
            } else {
                engine_factory("native", cfg)
            }
        }
        other => anyhow::bail!("unknown engine '{other}' (native|xla|auto)"),
    }
}

/// Gradient/step/eval backend for one problem shape.
///
/// Not `Send`: the PJRT-backed implementation holds `Rc` handles. Use an
/// [`EngineFactory`] to construct engines inside worker threads.
pub trait Engine {
    fn name(&self) -> &'static str;

    /// Resize the engine's compute parallelism, if it has any (`0` =
    /// machine default). The native engine rebuilds its thread pool;
    /// backends without host-side parallelism ignore this.
    fn set_threads(&mut self, _threads: usize) {}

    /// Compute objective and gradient on a minibatch; writes the gradient
    /// into `g` (shape k × d) and returns the loss.
    fn loss_grad(
        &mut self,
        l: &Mat,
        batch: &MinibatchRef<'_>,
        lambda: f32,
        g: &mut Mat,
    ) -> anyhow::Result<f32>;

    /// Fused SGD step `L ← L − lr·∇f(L)`; returns the (pre-step) loss.
    /// Default: loss_grad + axpy. The XLA engine overrides this with the
    /// donated-buffer fused artifact.
    fn step(
        &mut self,
        l: &mut Mat,
        batch: &MinibatchRef<'_>,
        lambda: f32,
        lr: f32,
    ) -> anyhow::Result<f32> {
        let mut g = Mat::zeros(l.rows, l.cols);
        let loss = self.loss_grad(l, batch, lambda, &mut g)?;
        l.axpy_inplace(-lr, &g);
        Ok(loss)
    }

    /// Squared Mahalanobis distances ‖LΔ‖² for rows of `diffs` (b × d).
    fn pair_dist(&mut self, l: &Mat, diffs: &Mat)
        -> anyhow::Result<Vec<f32>>;
}
