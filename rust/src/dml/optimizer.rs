//! Learning-rate schedules for the SGD loop.
//!
//! The paper uses plain SGD; a 1/(1+decay·t) schedule is the standard
//! robbins-monro choice for hinge objectives and what our presets use.

/// lr_t = lr0 / (1 + decay · t)
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub lr0: f32,
    pub decay: f32,
}

impl LrSchedule {
    pub fn new(lr0: f32, decay: f32) -> Self {
        assert!(lr0 > 0.0 && decay >= 0.0);
        LrSchedule { lr0, decay }
    }

    pub fn constant(lr0: f32) -> Self {
        Self::new(lr0, 0.0)
    }

    #[inline]
    pub fn at(&self, step: usize) -> f32 {
        self.lr0 / (1.0 + self.decay * step as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_schedule() {
        let s = LrSchedule::constant(0.1);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(1000), 0.1);
    }

    #[test]
    fn decay_monotone() {
        let s = LrSchedule::new(0.1, 0.01);
        assert_eq!(s.at(0), 0.1);
        assert!(s.at(10) < s.at(5));
        assert!((s.at(100) - 0.1 / 2.0).abs() < 1e-6);
    }
}
