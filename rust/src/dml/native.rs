//! Native CPU engine: the pure-Rust hot path.
//!
//! Mirrors the Pallas kernel's dataflow (project → hinge → outer-product)
//! with cache-blocked matmuls and reusable scratch buffers — the steady
//! state allocates nothing. Serves three roles: reference implementation
//! for runtime tests, fallback when artifacts are absent, and the subject
//! of the L3 performance pass (see EXPERIMENTS.md §Perf).

use super::{Engine, MinibatchRef};
use crate::linalg::{self, Mat};

pub struct NativeEngine {
    /// Scratch projections, reused across calls (resized on shape change).
    zs: Mat,
    zd: Mat,
}

impl NativeEngine {
    pub fn new() -> Self {
        NativeEngine { zs: Mat::zeros(0, 0), zd: Mat::zeros(0, 0) }
    }

    fn ensure_scratch(&mut self, bs: usize, bd: usize, k: usize) {
        if self.zs.rows != bs || self.zs.cols != k {
            self.zs = Mat::zeros(bs, k);
        }
        if self.zd.rows != bd || self.zd.cols != k {
            self.zd = Mat::zeros(bd, k);
        }
    }

    /// Z = D Lᵀ where D is a borrowed (b × d) row-major buffer.
    fn project_into(l: &Mat, diffs: &[f32], b: usize, z: &mut Mat) {
        let d = l.cols;
        let k = l.rows;
        debug_assert_eq!(z.rows, b);
        debug_assert_eq!(z.cols, k);
        for r in 0..b {
            let drow = &diffs[r * d..(r + 1) * d];
            let zrow = &mut z.data[r * k..(r + 1) * k];
            for (j, zv) in zrow.iter_mut().enumerate() {
                *zv = linalg::dot(drow, l.row(j));
            }
        }
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn loss_grad(
        &mut self,
        l: &Mat,
        batch: &MinibatchRef<'_>,
        lambda: f32,
        g: &mut Mat,
    ) -> anyhow::Result<f32> {
        let (bs, bd, d, k) = (batch.bs, batch.bd, batch.d, l.rows);
        anyhow::ensure!(l.cols == d, "L dim mismatch");
        anyhow::ensure!(
            g.rows == k && g.cols == d,
            "gradient buffer shape mismatch"
        );
        self.ensure_scratch(bs, bd, k);

        // 1) project: Zs = Ds Lᵀ, Zd = Dd Lᵀ           (2 MXU-shaped GEMMs)
        Self::project_into(l, batch.ds, bs, &mut self.zs);
        Self::project_into(l, batch.dd, bd, &mut self.zd);

        // 2) hinge + loss                                (VPU-shaped pass)
        let mut loss_sim = 0.0f64;
        for r in 0..bs {
            let zrow = &self.zs.data[r * k..(r + 1) * k];
            loss_sim += zrow.iter().map(|z| (z * z) as f64).sum::<f64>();
        }
        loss_sim /= bs as f64;

        let mut loss_dis = 0.0f64;
        // scale rows of Zs by 2/bs and rows of Zd by w_i * (−2λ/bd) so the
        // two outer products below fold all scaling in.
        let s_sim = 2.0 / bs as f32;
        for v in &mut self.zs.data {
            *v *= s_sim;
        }
        let s_dis = -2.0 * lambda / bd as f32;
        for r in 0..bd {
            let zrow = &mut self.zd.data[r * k..(r + 1) * k];
            let dist: f32 = zrow.iter().map(|z| z * z).sum();
            let hinge = (1.0 - dist).max(0.0);
            loss_dis += hinge as f64;
            let w = if dist < 1.0 { s_dis } else { 0.0 };
            for v in zrow.iter_mut() {
                *v *= w;
            }
        }
        loss_dis /= bd as f64;
        let loss = loss_sim + lambda as f64 * loss_dis;

        // 3) gradient outer products: G = Zsᵀ Ds + Zdᵀ Dd (2 GEMMs)
        let ds_mat = MatRef { data: batch.ds, rows: bs, cols: d };
        let dd_mat = MatRef { data: batch.dd, rows: bd, cols: d };
        at_b_into(&self.zs, ds_mat, g, 0.0);
        at_b_into(&self.zd, dd_mat, g, 1.0);

        Ok(loss as f32)
    }

    fn pair_dist(
        &mut self,
        l: &Mat,
        diffs: &Mat,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(l.cols == diffs.cols, "dim mismatch");
        let k = l.rows;
        let mut out = Vec::with_capacity(diffs.rows);
        let mut zrow = vec![0.0f32; k];
        for r in 0..diffs.rows {
            let drow = diffs.row(r);
            for (j, zv) in zrow.iter_mut().enumerate() {
                *zv = linalg::dot(drow, l.row(j));
            }
            out.push(zrow.iter().map(|z| z * z).sum());
        }
        Ok(out)
    }
}

/// Borrowed row-major matrix view (avoids copying minibatch buffers into
/// `Mat`s on the hot path).
#[derive(Clone, Copy)]
struct MatRef<'a> {
    data: &'a [f32],
    rows: usize,
    cols: usize,
}

/// C = beta*C + Aᵀ·B with A owned (b × m) and B borrowed (b × n):
/// saxpy per (A-row, B-row) pair, vectorizable along n.
fn at_b_into(a: &Mat, b: MatRef<'_>, c: &mut Mat, beta: f32) {
    assert_eq!(a.rows, b.rows);
    assert_eq!((c.rows, c.cols), (a.cols, b.cols));
    if beta == 0.0 {
        c.data.fill(0.0);
    }
    let (m, n) = (a.cols, b.cols);
    for r in 0..a.rows {
        let arow = &a.data[r * m..(r + 1) * m];
        let brow = &b.data[r * n..(r + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // hinge-inactive rows were zeroed — skip them
            }
            let crow = &mut c.data[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Straight-line scalar reference (no blocking, f64 accumulation).
    fn ref_loss_grad(
        l: &Mat,
        batch: &MinibatchRef<'_>,
        lambda: f32,
    ) -> (f32, Mat) {
        let (bs, bd, d, k) = (batch.bs, batch.bd, batch.d, l.rows);
        let mut g = vec![0.0f64; k * d];
        let mut loss_sim = 0.0f64;
        for r in 0..bs {
            let delta = &batch.ds[r * d..(r + 1) * d];
            let z: Vec<f64> = (0..k)
                .map(|j| {
                    l.row(j)
                        .iter()
                        .zip(delta)
                        .map(|(a, b)| (*a as f64) * (*b as f64))
                        .sum()
                })
                .collect();
            loss_sim += z.iter().map(|v| v * v).sum::<f64>();
            for j in 0..k {
                for c in 0..d {
                    g[j * d + c] +=
                        2.0 / bs as f64 * z[j] * delta[c] as f64;
                }
            }
        }
        loss_sim /= bs as f64;
        let mut loss_dis = 0.0f64;
        for r in 0..bd {
            let delta = &batch.dd[r * d..(r + 1) * d];
            let z: Vec<f64> = (0..k)
                .map(|j| {
                    l.row(j)
                        .iter()
                        .zip(delta)
                        .map(|(a, b)| (*a as f64) * (*b as f64))
                        .sum()
                })
                .collect();
            let dist: f64 = z.iter().map(|v| v * v).sum();
            loss_dis += (1.0 - dist).max(0.0);
            if dist < 1.0 {
                for j in 0..k {
                    for c in 0..d {
                        g[j * d + c] -= 2.0 * lambda as f64 / bd as f64
                            * z[j]
                            * delta[c] as f64;
                    }
                }
            }
        }
        loss_dis /= bd as f64;
        let loss = (loss_sim + lambda as f64 * loss_dis) as f32;
        let gm = Mat::from_vec(k, d, g.iter().map(|&v| v as f32).collect());
        (loss, gm)
    }

    fn rand_batch(
        rng: &mut Pcg32,
        bs: usize,
        bd: usize,
        d: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut ds = vec![0.0f32; bs * d];
        let mut dd = vec![0.0f32; bd * d];
        rng.fill_gaussian(&mut ds, 0.0, 1.0);
        rng.fill_gaussian(&mut dd, 0.0, 1.0);
        (ds, dd)
    }

    #[test]
    fn matches_scalar_reference() {
        let mut rng = Pcg32::new(0);
        for &(k, d, bs, bd) in
            &[(2, 4, 1, 1), (8, 16, 4, 6), (20, 33, 7, 9), (60, 78, 10, 10)]
        {
            let mut l = Mat::zeros(k, d);
            rng.fill_gaussian(&mut l.data, 0.0, 0.3 / (d as f32).sqrt());
            let (ds, dd) = rand_batch(&mut rng, bs, bd, d);
            let batch = MinibatchRef::new(&ds, &dd, bs, bd, d);
            let mut eng = NativeEngine::new();
            let mut g = Mat::zeros(k, d);
            let loss = eng.loss_grad(&l, &batch, 1.0, &mut g).unwrap();
            let (rloss, rg) = ref_loss_grad(&l, &batch, 1.0);
            assert!(
                (loss - rloss).abs() < 1e-4 * (1.0 + rloss.abs()),
                "loss {loss} vs {rloss} (k={k},d={d})"
            );
            assert!(g.max_abs_diff(&rg) < 1e-3, "grad (k={k},d={d})");
        }
    }

    #[test]
    fn lambda_scales_hinge_term() {
        let mut rng = Pcg32::new(1);
        let (k, d, bs, bd) = (4, 8, 3, 3);
        let mut l = Mat::zeros(k, d);
        rng.fill_gaussian(&mut l.data, 0.0, 0.05);
        let (ds, dd) = rand_batch(&mut rng, bs, bd, d);
        let batch = MinibatchRef::new(&ds, &dd, bs, bd, d);
        let mut eng = NativeEngine::new();
        let mut g = Mat::zeros(k, d);
        let l1 = eng.loss_grad(&l, &batch, 1.0, &mut g).unwrap();
        let l2 = eng.loss_grad(&l, &batch, 2.0, &mut g).unwrap();
        // with tiny L the hinge is ~fully active: loss ≈ sim + λ·~1
        assert!(l2 > l1 + 0.5, "{l1} {l2}");
    }

    #[test]
    fn step_reduces_fixed_batch_loss() {
        let mut rng = Pcg32::new(2);
        let (k, d, bs, bd) = (8, 16, 8, 8);
        let mut l = Mat::zeros(k, d);
        rng.fill_gaussian(&mut l.data, 0.0, 0.2);
        let (ds, dd) = rand_batch(&mut rng, bs, bd, d);
        let mut eng = NativeEngine::new();
        let mut losses = Vec::new();
        for _ in 0..30 {
            let batch = MinibatchRef::new(&ds, &dd, bs, bd, d);
            losses.push(eng.step(&mut l, &batch, 1.0, 0.03).unwrap());
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.8),
            "{losses:?}"
        );
    }

    #[test]
    fn pair_dist_matches_projection() {
        let mut rng = Pcg32::new(3);
        let (k, d, b) = (5, 12, 9);
        let mut l = Mat::zeros(k, d);
        rng.fill_gaussian(&mut l.data, 0.0, 0.5);
        let mut diffs = Mat::zeros(b, d);
        rng.fill_gaussian(&mut diffs.data, 0.0, 1.0);
        let mut eng = NativeEngine::new();
        let got = eng.pair_dist(&l, &diffs).unwrap();
        let z = diffs.matmul_bt(&l);
        for r in 0..b {
            let want: f32 = z.row(r).iter().map(|v| v * v).sum();
            assert!((got[r] - want).abs() < 1e-4 * (1.0 + want));
        }
    }

    #[test]
    fn scratch_reuse_across_shapes() {
        // engine must survive alternating shapes (server + eval traffic)
        let mut rng = Pcg32::new(4);
        let mut eng = NativeEngine::new();
        for &(k, d, bs, bd) in &[(4, 8, 2, 2), (6, 10, 3, 5), (4, 8, 2, 2)] {
            let mut l = Mat::zeros(k, d);
            rng.fill_gaussian(&mut l.data, 0.0, 0.2);
            let (ds, dd) = rand_batch(&mut rng, bs, bd, d);
            let batch = MinibatchRef::new(&ds, &dd, bs, bd, d);
            let mut g = Mat::zeros(k, d);
            let loss = eng.loss_grad(&l, &batch, 1.0, &mut g).unwrap();
            assert!(loss.is_finite());
        }
    }
}
