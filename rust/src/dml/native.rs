//! Native CPU engine: the pure-Rust multicore hot path.
//!
//! Mirrors the Pallas kernel's dataflow (project → hinge → outer-product)
//! but sharded across a scoped thread pool the way the paper's worker
//! model assumes a machine saturates its C cores: the minibatch rows are
//! split into per-thread shards; each shard projects its row block
//! through the packed GEMM microkernel, applies the hinge/scaling pass,
//! and accumulates a private k×d partial gradient; a tree reduction then
//! merges the partials (and the f64 partial losses) in a fixed order.
//!
//! Consequences: one `loss_grad` call genuinely uses all lanes of its
//! pool; results are bit-reproducible for a fixed thread count (the
//! shard split and merge order are deterministic), and match the scalar
//! f64 reference within float tolerance at every thread count (see the
//! property tests below). Steady state allocates nothing — all shard
//! scratch is reused across calls.

use std::sync::Arc;

use super::{Engine, MinibatchRef};
use crate::linalg::gemm::{gemm_into, KMajor};
use crate::linalg::{simd, Mat};
use crate::util::pool::{balanced_range, ThreadPool};

/// Per-shard scratch: projections for this shard's row block, a private
/// partial gradient, and partial loss terms.
struct ShardScratch {
    /// Projections of this shard's similar rows: (shard bs × k).
    zs: Mat,
    /// Projections of this shard's dissimilar rows: (shard bd × k).
    zd: Mat,
    /// Partial gradient: (k × d).
    g: Mat,
    loss_sim: f64,
    loss_dis: f64,
}

/// Raw shard-array pointer for the pairwise tree-reduction step; each
/// reduction task touches a disjoint (dst, src) index pair.
#[derive(Clone, Copy)]
struct RawShards(*mut ShardScratch);
unsafe impl Send for RawShards {}
unsafe impl Sync for RawShards {}

pub struct NativeEngine {
    pool: Arc<ThreadPool>,
    shards: Vec<ShardScratch>,
    /// (bs, bd, d, k) the shard scratch is currently sized for.
    shape: (usize, usize, usize, usize),
}

impl NativeEngine {
    /// Engine on the process-wide shared pool (all cores by default;
    /// override with `DMLPS_THREADS` or [`NativeEngine::with_threads`]).
    pub fn new() -> Self {
        Self::with_pool(crate::util::pool::global())
    }

    /// Engine with a private pool of exactly `threads` lanes.
    pub fn with_threads(threads: usize) -> Self {
        Self::with_pool(Arc::new(ThreadPool::new(threads)))
    }

    pub fn with_pool(pool: Arc<ThreadPool>) -> Self {
        NativeEngine { pool, shards: Vec::new(), shape: (0, 0, 0, 0) }
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    fn ensure_scratch(&mut self, bs: usize, bd: usize, d: usize, k: usize) {
        let n = self.pool.threads().min(bs.max(bd)).max(1);
        if self.shards.len() == n && self.shape == (bs, bd, d, k) {
            return;
        }
        self.shards.clear();
        for i in 0..n {
            let rs = balanced_range(bs, n, i).len();
            let rd = balanced_range(bd, n, i).len();
            self.shards.push(ShardScratch {
                zs: Mat::zeros(rs, k),
                zd: Mat::zeros(rd, k),
                g: Mat::zeros(k, d),
                loss_sim: 0.0,
                loss_dis: 0.0,
            });
        }
        self.shape = (bs, bd, d, k);
    }

    /// Merge shard partials pairwise (stride-doubling tree), each level's
    /// disjoint pairs running in parallel; shard 0 ends up with the sum.
    /// The merge order is a function of the shard count alone, so results
    /// are deterministic for a fixed thread count.
    fn tree_reduce(&mut self) {
        let n = self.shards.len();
        let base = RawShards(self.shards.as_mut_ptr());
        let pool = self.pool.clone();
        let mut stride = 1;
        while stride < n {
            let mut pairs: Vec<(usize, usize)> = (0..n)
                .step_by(2 * stride)
                .filter(|&i| i + stride < n)
                .map(|i| (i, i + stride))
                .collect();
            pool.for_each_mut(&mut pairs, |_, &mut (i, j)| {
                // SAFETY: within one level, every shard index appears in
                // at most one (i, j) pair and i ≠ j, so the &mut and &
                // below never alias; the barrier between levels orders
                // the cross-level accesses.
                let (dst, src) = unsafe {
                    (&mut *base.0.add(i), &*base.0.add(j))
                };
                for (a, b) in dst.g.data.iter_mut().zip(&src.g.data) {
                    *a += *b;
                }
                dst.loss_sim += src.loss_sim;
                dst.loss_dis += src.loss_dis;
            });
            stride *= 2;
        }
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn set_threads(&mut self, threads: usize) {
        let threads = if threads == 0 {
            crate::util::pool::default_threads()
        } else {
            threads
        };
        if threads != self.pool.threads() {
            self.pool = Arc::new(ThreadPool::new(threads));
            self.shards.clear();
            self.shape = (0, 0, 0, 0);
        }
    }

    fn loss_grad(
        &mut self,
        l: &Mat,
        batch: &MinibatchRef<'_>,
        lambda: f32,
        g: &mut Mat,
    ) -> anyhow::Result<f32> {
        let (bs, bd, d, k) = (batch.bs, batch.bd, batch.d, l.rows);
        anyhow::ensure!(l.cols == d, "L dim mismatch");
        anyhow::ensure!(
            g.rows == k && g.cols == d,
            "gradient buffer shape mismatch"
        );
        self.ensure_scratch(bs, bd, d, k);
        let n_shards = self.shards.len();
        // fold the mean/λ scaling into the projected rows so the shard
        // outer products need no post-scaling (same trick as the seed)
        let s_sim = 2.0 / bs as f32;
        let s_dis = -2.0 * lambda / bd as f32;
        let pool = self.pool.clone();
        pool.for_each_mut(&mut self.shards, |i, sh| {
            let rs = balanced_range(bs, n_shards, i);
            let rd = balanced_range(bd, n_shards, i);
            let (nrs, nrd) = (rs.len(), rd.len());
            let ds = &batch.ds[rs.start * d..rs.end * d];
            let dd = &batch.dd[rd.start * d..rd.end * d];

            // 1) project this shard's rows: Z = Δ Lᵀ    (2 packed GEMMs)
            gemm_into(
                KMajor::cols_k(ds, nrs, d),
                KMajor::cols_k(&l.data, k, d),
                &mut sh.zs.data,
                0.0,
                None,
            );
            gemm_into(
                KMajor::cols_k(dd, nrd, d),
                KMajor::cols_k(&l.data, k, d),
                &mut sh.zd.data,
                0.0,
                None,
            );

            // 2) hinge + loss partials, scaling rows in place. The
            // per-row squared distances dispatch through the SIMD
            // layer; the scalar path is bit-identical to the historical
            // inline loops (see linalg::simd's determinism contract).
            sh.loss_sim = 0.0;
            for r in 0..nrs {
                let zrow = &mut sh.zs.data[r * k..(r + 1) * k];
                sh.loss_sim += simd::sqnorm_f64(zrow);
                for v in zrow.iter_mut() {
                    *v *= s_sim;
                }
            }
            sh.loss_dis = 0.0;
            for r in 0..nrd {
                let zrow = &mut sh.zd.data[r * k..(r + 1) * k];
                let dist: f32 = simd::sqnorm(zrow);
                let hinge = (1.0 - dist).max(0.0);
                sh.loss_dis += hinge as f64;
                let w = if dist < 1.0 { s_dis } else { 0.0 };
                for v in zrow.iter_mut() {
                    *v *= w;
                }
            }

            // 3) partial gradient: G = Zsᵀ Δs + Zdᵀ Δd  (2 packed GEMMs)
            gemm_into(
                KMajor::rows_k(&sh.zs.data, nrs, k),
                KMajor::rows_k(ds, nrs, d),
                &mut sh.g.data,
                0.0,
                None,
            );
            gemm_into(
                KMajor::rows_k(&sh.zd.data, nrd, k),
                KMajor::rows_k(dd, nrd, d),
                &mut sh.g.data,
                1.0,
                None,
            );
        });

        // 4) merge shard partials (parallel pairwise tree)
        self.tree_reduce();
        let sh0 = &self.shards[0];
        g.data.copy_from_slice(&sh0.g.data);
        let loss = sh0.loss_sim / bs as f64
            + lambda as f64 * (sh0.loss_dis / bd as f64);
        Ok(loss as f32)
    }

    fn pair_dist(
        &mut self,
        l: &Mat,
        diffs: &Mat,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(l.cols == diffs.cols, "dim mismatch");
        let (k, rows) = (l.rows, diffs.rows);
        let mut out = vec![0.0f32; rows];
        let chunk = rows.div_ceil(self.pool.threads()).max(1);
        let pool = self.pool.clone();
        pool.for_each_chunk(&mut out, chunk, |start, o| {
            for (idx, ov) in o.iter_mut().enumerate() {
                let drow = diffs.row(start + idx);
                let mut acc = 0.0f32;
                for j in 0..k {
                    // dispatches to the 8-lane FMA dot when SIMD is
                    // active; the scalar path is linalg::dot, exactly
                    // what this loop always called
                    let z = simd::dot(drow, l.row(j));
                    acc += z * z;
                }
                *ov = acc;
            }
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Straight-line scalar reference (no blocking, f64 accumulation).
    fn ref_loss_grad(
        l: &Mat,
        batch: &MinibatchRef<'_>,
        lambda: f32,
    ) -> (f32, Mat) {
        let (bs, bd, d, k) = (batch.bs, batch.bd, batch.d, l.rows);
        let mut g = vec![0.0f64; k * d];
        let mut loss_sim = 0.0f64;
        for r in 0..bs {
            let delta = &batch.ds[r * d..(r + 1) * d];
            let z: Vec<f64> = (0..k)
                .map(|j| {
                    l.row(j)
                        .iter()
                        .zip(delta)
                        .map(|(a, b)| (*a as f64) * (*b as f64))
                        .sum()
                })
                .collect();
            loss_sim += z.iter().map(|v| v * v).sum::<f64>();
            for j in 0..k {
                for c in 0..d {
                    g[j * d + c] +=
                        2.0 / bs as f64 * z[j] * delta[c] as f64;
                }
            }
        }
        loss_sim /= bs as f64;
        let mut loss_dis = 0.0f64;
        for r in 0..bd {
            let delta = &batch.dd[r * d..(r + 1) * d];
            let z: Vec<f64> = (0..k)
                .map(|j| {
                    l.row(j)
                        .iter()
                        .zip(delta)
                        .map(|(a, b)| (*a as f64) * (*b as f64))
                        .sum()
                })
                .collect();
            let dist: f64 = z.iter().map(|v| v * v).sum();
            loss_dis += (1.0 - dist).max(0.0);
            if dist < 1.0 {
                for j in 0..k {
                    for c in 0..d {
                        g[j * d + c] -= 2.0 * lambda as f64 / bd as f64
                            * z[j]
                            * delta[c] as f64;
                    }
                }
            }
        }
        loss_dis /= bd as f64;
        let loss = (loss_sim + lambda as f64 * loss_dis) as f32;
        let gm = Mat::from_vec(k, d, g.iter().map(|&v| v as f32).collect());
        (loss, gm)
    }

    fn rand_batch(
        rng: &mut Pcg32,
        bs: usize,
        bd: usize,
        d: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut ds = vec![0.0f32; bs * d];
        let mut dd = vec![0.0f32; bd * d];
        rng.fill_gaussian(&mut ds, 0.0, 1.0);
        rng.fill_gaussian(&mut dd, 0.0, 1.0);
        (ds, dd)
    }

    fn assert_matches_ref(eng: &mut NativeEngine, k: usize, d: usize,
                          bs: usize, bd: usize, seed: u64) {
        let mut rng = Pcg32::new(seed);
        let mut l = Mat::zeros(k, d);
        rng.fill_gaussian(&mut l.data, 0.0, 0.3 / (d as f32).sqrt());
        let (ds, dd) = rand_batch(&mut rng, bs, bd, d);
        let batch = MinibatchRef::new(&ds, &dd, bs, bd, d);
        let mut g = Mat::zeros(k, d);
        let loss = eng.loss_grad(&l, &batch, 1.0, &mut g).unwrap();
        let (rloss, rg) = ref_loss_grad(&l, &batch, 1.0);
        assert!(
            (loss - rloss).abs() < 1e-4 * (1.0 + rloss.abs()),
            "loss {loss} vs {rloss} (k={k},d={d},threads={})",
            eng.threads()
        );
        assert!(
            g.max_abs_diff(&rg) < 1e-3,
            "grad (k={k},d={d},threads={})",
            eng.threads()
        );
    }

    #[test]
    fn matches_scalar_reference() {
        for &(k, d, bs, bd) in
            &[(2, 4, 1, 1), (8, 16, 4, 6), (20, 33, 7, 9), (60, 78, 10, 10)]
        {
            let mut eng = NativeEngine::new();
            assert_matches_ref(&mut eng, k, d, bs, bd, 0);
        }
    }

    #[test]
    fn parallel_matches_reference_across_thread_counts() {
        // the issue's acceptance shapes: odd sizes, non-multiple-of-tile,
        // shard counts both below and above the row counts
        for &threads in &[1usize, 2, 4] {
            for &(k, d, bs, bd) in &[
                (60, 78, 10, 10),
                (33, 77, 7, 5),
                (8, 16, 1, 9),
                (5, 13, 2, 2),
            ] {
                let mut eng = NativeEngine::with_threads(threads);
                assert_eq!(eng.threads(), threads);
                assert_matches_ref(&mut eng, k, d, bs, bd, 7 + threads as u64);
            }
        }
    }

    #[test]
    fn set_threads_rebuilds_pool_and_stays_correct() {
        let mut eng = NativeEngine::with_threads(2);
        assert_matches_ref(&mut eng, 20, 33, 7, 9, 1);
        eng.set_threads(3);
        assert_eq!(eng.threads(), 3);
        assert_matches_ref(&mut eng, 20, 33, 7, 9, 2);
        eng.set_threads(0); // 0 = machine default
        assert!(eng.threads() >= 1);
        assert_matches_ref(&mut eng, 20, 33, 7, 9, 3);
    }

    #[test]
    fn pair_dist_is_thread_count_invariant() {
        let mut rng = Pcg32::new(8);
        let (k, d, b) = (17, 29, 23);
        let mut l = Mat::zeros(k, d);
        rng.fill_gaussian(&mut l.data, 0.0, 0.5);
        let mut diffs = Mat::zeros(b, d);
        rng.fill_gaussian(&mut diffs.data, 0.0, 1.0);
        let want = NativeEngine::with_threads(1)
            .pair_dist(&l, &diffs)
            .unwrap();
        for threads in [2usize, 4] {
            let got = NativeEngine::with_threads(threads)
                .pair_dist(&l, &diffs)
                .unwrap();
            assert_eq!(got, want, "pair_dist must not depend on threads");
        }
    }

    #[test]
    fn lambda_scales_hinge_term() {
        let mut rng = Pcg32::new(1);
        let (k, d, bs, bd) = (4, 8, 3, 3);
        let mut l = Mat::zeros(k, d);
        rng.fill_gaussian(&mut l.data, 0.0, 0.05);
        let (ds, dd) = rand_batch(&mut rng, bs, bd, d);
        let batch = MinibatchRef::new(&ds, &dd, bs, bd, d);
        let mut eng = NativeEngine::new();
        let mut g = Mat::zeros(k, d);
        let l1 = eng.loss_grad(&l, &batch, 1.0, &mut g).unwrap();
        let l2 = eng.loss_grad(&l, &batch, 2.0, &mut g).unwrap();
        // with tiny L the hinge is ~fully active: loss ≈ sim + λ·~1
        assert!(l2 > l1 + 0.5, "{l1} {l2}");
    }

    #[test]
    fn step_reduces_fixed_batch_loss() {
        let mut rng = Pcg32::new(2);
        let (k, d, bs, bd) = (8, 16, 8, 8);
        let mut l = Mat::zeros(k, d);
        rng.fill_gaussian(&mut l.data, 0.0, 0.2);
        let (ds, dd) = rand_batch(&mut rng, bs, bd, d);
        let mut eng = NativeEngine::new();
        let mut losses = Vec::new();
        for _ in 0..30 {
            let batch = MinibatchRef::new(&ds, &dd, bs, bd, d);
            losses.push(eng.step(&mut l, &batch, 1.0, 0.03).unwrap());
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.8),
            "{losses:?}"
        );
    }

    #[test]
    fn pair_dist_matches_projection() {
        let mut rng = Pcg32::new(3);
        let (k, d, b) = (5, 12, 9);
        let mut l = Mat::zeros(k, d);
        rng.fill_gaussian(&mut l.data, 0.0, 0.5);
        let mut diffs = Mat::zeros(b, d);
        rng.fill_gaussian(&mut diffs.data, 0.0, 1.0);
        let mut eng = NativeEngine::new();
        let got = eng.pair_dist(&l, &diffs).unwrap();
        let z = diffs.matmul_bt(&l);
        for r in 0..b {
            let want: f32 = z.row(r).iter().map(|v| v * v).sum();
            assert!((got[r] - want).abs() < 1e-4 * (1.0 + want));
        }
    }

    #[test]
    fn scratch_reuse_across_shapes() {
        // engine must survive alternating shapes (server + eval traffic)
        let mut rng = Pcg32::new(4);
        let mut eng = NativeEngine::new();
        for &(k, d, bs, bd) in &[(4, 8, 2, 2), (6, 10, 3, 5), (4, 8, 2, 2)] {
            let mut l = Mat::zeros(k, d);
            rng.fill_gaussian(&mut l.data, 0.0, 0.2);
            let (ds, dd) = rand_batch(&mut rng, bs, bd, d);
            let batch = MinibatchRef::new(&ds, &dd, bs, bd, d);
            let mut g = Mat::zeros(k, d);
            let loss = eng.loss_grad(&l, &batch, 1.0, &mut g).unwrap();
            assert!(loss.is_finite());
        }
    }

    #[test]
    fn fixed_thread_count_is_deterministic() {
        let mut rng = Pcg32::new(5);
        let (k, d, bs, bd) = (24, 37, 9, 11);
        let mut l = Mat::zeros(k, d);
        rng.fill_gaussian(&mut l.data, 0.0, 0.2);
        let (ds, dd) = rand_batch(&mut rng, bs, bd, d);
        let mut run = |eng: &mut NativeEngine| {
            let batch = MinibatchRef::new(&ds, &dd, bs, bd, d);
            let mut g = Mat::zeros(k, d);
            let loss = eng.loss_grad(&l, &batch, 1.0, &mut g).unwrap();
            (loss, g)
        };
        let mut e1 = NativeEngine::with_threads(3);
        let (l1, g1) = run(&mut e1);
        let (l2, g2) = run(&mut e1); // scratch reuse path
        let mut e2 = NativeEngine::with_threads(3);
        let (l3, g3) = run(&mut e2); // fresh engine, same width
        assert_eq!(l1, l2);
        assert_eq!(g1.data, g2.data);
        assert_eq!(l1, l3);
        assert_eq!(g1.data, g3.data);
    }
}
