//! Binary framing for the PS wire protocol.
//!
//! Every message crossing a socket is one length-prefixed frame:
//!
//! ```text
//! u32le body_len | u8 kind | header fields | payload
//! ```
//!
//! Header fields are fixed-width little-endian; the payload is the
//! [`SliceEncoding`] serialized *exactly* as
//! [`SliceEncoding::encoded_bytes`] accounts it (Dense = 4·n, Int8 =
//! 4 + n, TopK = gaps + 4·nnz, TopKInt8 = 4 + gaps + nnz), so the wire
//! telemetry the in-memory transport already reports is byte-true on a
//! real socket with no new math. The self-describing length fields
//! (`u8` tag + `u32` counts) that let the receiver size its buffers are
//! *framing overhead*, counted by [`encoding_overhead`] and excluded
//! from payload accounting — mirroring how in-memory telemetry excludes
//! header fields.
//!
//! Decoding is split in two layers, and the split matters once frames
//! arrive off a network instead of a typed channel:
//!
//! * **structural** ([`decode_frame`]) — unknown kind/tag, truncated or
//!   trailing bytes, oversized lengths. A structural error means the
//!   stream can no longer be trusted to be in sync, so callers drop the
//!   connection.
//! * **semantic** ([`validate_to_server`] / [`validate_to_worker`]) —
//!   shard id in range, slice length matching the [`ShardPlan`], gap
//!   coordinates strictly increasing and in range. A semantic error
//!   rejects the one message (the frame boundary is still sound).
//!   Validation runs *before* the message reaches the fold/splice
//!   machinery, whose `decode_into` is entitled to panic on bad input.

use super::messages::{ShardPlan, SliceEncoding, ToServer, ToWorker};

/// Wire protocol version, checked in the Hello/HelloAck handshake.
pub const PROTOCOL_VERSION: u16 = 1;

/// Hard cap on one frame's body. Far above any real slice (the paper's
/// largest shard is ~860 MB of f32 across *all* shards); a length field
/// beyond this is treated as a corrupt stream, not an allocation order.
pub const MAX_FRAME_BYTES: usize = 1 << 28;

/// Frame kind bytes (worker→server: 0x0_, server→worker: 0x1_,
/// handshake: 0x2_).
pub const KIND_GRAD: u8 = 0x01;
pub const KIND_DONE: u8 = 0x02;
pub const KIND_PARAM: u8 = 0x11;
pub const KIND_HELLO: u8 = 0x21;
pub const KIND_HELLO_ACK: u8 = 0x22;

const TAG_DENSE: u8 = 0;
const TAG_INT8: u8 = 1;
const TAG_TOPK: u8 = 2;
const TAG_TOPK_INT8: u8 = 3;

/// A decoded frame body.
#[derive(Debug)]
pub enum Frame {
    ToServer(ToServer),
    ToWorker(ToWorker),
    /// Worker → server handshake: identity plus the topology the worker
    /// was configured with, so a mis-deployed node fails loudly at
    /// connect time instead of corrupting a run.
    Hello { protocol: u16, worker: u32, shards: u32, k: u32, d: u32 },
    /// Server → worker handshake reply (echoes the server's topology).
    HelloAck { protocol: u16, shards: u32, k: u32, d: u32 },
}

/// Why a frame was refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// Structural: the byte stream is not a well-formed frame. The
    /// connection carrying it can no longer be trusted to be in sync.
    Malformed(String),
    /// Semantic: well-formed frame whose content contradicts the shard
    /// plan (bad shard id, wrong slice length, out-of-range coordinate).
    /// The stream is still framed correctly; only this message is bad.
    Invalid(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Malformed(m) => write!(f, "malformed frame: {m}"),
            FrameError::Invalid(m) => write!(f, "invalid message: {m}"),
        }
    }
}

impl std::error::Error for FrameError {}

fn malformed(msg: impl Into<String>) -> FrameError {
    FrameError::Malformed(msg.into())
}

fn invalid(msg: impl Into<String>) -> FrameError {
    FrameError::Invalid(msg.into())
}

// ---------------------------------------------------------------------
// little-endian primitives
// ---------------------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        if self.buf.len() - self.pos < n {
            return Err(malformed(format!(
                "truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, FrameError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, FrameError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn done(&self) -> Result<(), FrameError> {
        if self.pos != self.buf.len() {
            return Err(malformed(format!(
                "{} trailing bytes after frame body",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }

    /// A count field used to size an allocation of `elem_size`-byte
    /// elements. Checked against the bytes *actually remaining in this
    /// frame*, not just the global frame cap: `Vec::with_capacity`
    /// allocates eagerly, so without the remaining-bytes check a 5-byte
    /// malformed Dense frame could claim 2^28 elements and demand a
    /// 1 GiB allocation before the first truncation error fired.
    fn count(
        &mut self,
        what: &str,
        elem_size: usize,
    ) -> Result<usize, FrameError> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME_BYTES {
            return Err(malformed(format!("{what} count {n} exceeds cap")));
        }
        let need = n.saturating_mul(elem_size);
        let remaining = self.buf.len() - self.pos;
        if need > remaining {
            return Err(malformed(format!(
                "{what} count {n} needs {need} bytes, \
                 {remaining} remain in frame"
            )));
        }
        Ok(n)
    }
}

// ---------------------------------------------------------------------
// SliceEncoding serialization
// ---------------------------------------------------------------------

/// Append the wire form of an encoding: a `u8` tag, self-describing
/// `u32` length fields, then the payload bytes exactly as
/// [`SliceEncoding::encoded_bytes`] accounts them.
pub fn encode_encoding(enc: &SliceEncoding, out: &mut Vec<u8>) {
    match enc {
        SliceEncoding::Dense(v) => {
            out.push(TAG_DENSE);
            put_u32(out, v.len() as u32);
            for &x in v {
                put_f32(out, x);
            }
        }
        SliceEncoding::Int8 { scale, q } => {
            out.push(TAG_INT8);
            put_u32(out, q.len() as u32);
            put_f32(out, *scale);
            out.extend(q.iter().map(|&b| b as u8));
        }
        SliceEncoding::TopK { gaps, vals } => {
            out.push(TAG_TOPK);
            put_u32(out, vals.len() as u32);
            put_u32(out, gaps.len() as u32);
            out.extend_from_slice(gaps);
            for &x in vals {
                put_f32(out, x);
            }
        }
        SliceEncoding::TopKInt8 { scale, gaps, vals } => {
            out.push(TAG_TOPK_INT8);
            put_u32(out, vals.len() as u32);
            put_u32(out, gaps.len() as u32);
            put_f32(out, *scale);
            out.extend_from_slice(gaps);
            out.extend(vals.iter().map(|&b| b as u8));
        }
    }
}

/// Framing overhead [`encode_encoding`] adds beyond the payload: the tag
/// byte plus the `u32` length fields. `wire size == overhead +
/// encoded_bytes()`, which the frame goldens assert per variant.
pub fn encoding_overhead(enc: &SliceEncoding) -> u64 {
    match enc {
        SliceEncoding::Dense(_) | SliceEncoding::Int8 { .. } => 1 + 4,
        SliceEncoding::TopK { .. } | SliceEncoding::TopKInt8 { .. } => {
            1 + 4 + 4
        }
    }
}

fn decode_encoding(r: &mut Reader<'_>) -> Result<SliceEncoding, FrameError> {
    match r.u8()? {
        TAG_DENSE => {
            let n = r.count("dense", 4)?;
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(r.f32()?);
            }
            Ok(SliceEncoding::Dense(v))
        }
        TAG_INT8 => {
            let n = r.count("int8", 1)?;
            let scale = r.f32()?;
            let q = r.take(n)?.iter().map(|&b| b as i8).collect();
            Ok(SliceEncoding::Int8 { scale, q })
        }
        TAG_TOPK => {
            let nnz = r.count("topk vals", 4)?;
            let glen = r.count("topk gaps", 1)?;
            let gaps = r.take(glen)?.to_vec();
            let mut vals = Vec::with_capacity(nnz);
            for _ in 0..nnz {
                vals.push(r.f32()?);
            }
            Ok(SliceEncoding::TopK { gaps, vals })
        }
        TAG_TOPK_INT8 => {
            let nnz = r.count("topk_int8 vals", 1)?;
            let glen = r.count("topk_int8 gaps", 1)?;
            let scale = r.f32()?;
            let gaps = r.take(glen)?.to_vec();
            let vals = r.take(nnz)?.iter().map(|&b| b as i8).collect();
            Ok(SliceEncoding::TopKInt8 { scale, gaps, vals })
        }
        t => Err(malformed(format!("unknown encoding tag 0x{t:02x}"))),
    }
}

// ---------------------------------------------------------------------
// frames
// ---------------------------------------------------------------------

/// Append one length-prefixed frame for a worker→server message.
pub fn encode_to_server(msg: &ToServer, out: &mut Vec<u8>) {
    with_length_prefix(out, |body| match msg {
        ToServer::Grad { worker, shard, step, grad, loss } => {
            body.push(KIND_GRAD);
            put_u32(body, *worker as u32);
            put_u32(body, *shard as u32);
            put_u64(body, *step);
            put_f32(body, *loss);
            encode_encoding(grad, body);
        }
        ToServer::Done { worker } => {
            body.push(KIND_DONE);
            put_u32(body, *worker as u32);
        }
    });
}

/// Append one length-prefixed frame for a server→worker message.
pub fn encode_to_worker(msg: &ToWorker, out: &mut Vec<u8>) {
    with_length_prefix(out, |body| match msg {
        ToWorker::Param { shard, version, clock, data } => {
            body.push(KIND_PARAM);
            put_u32(body, *shard as u32);
            put_u64(body, *version);
            put_u64(body, *clock);
            encode_encoding(data, body);
        }
    });
}

/// Append one length-prefixed handshake frame.
pub fn encode_handshake(f: &Frame, out: &mut Vec<u8>) {
    with_length_prefix(out, |body| match f {
        Frame::Hello { protocol, worker, shards, k, d } => {
            body.push(KIND_HELLO);
            put_u16(body, *protocol);
            put_u32(body, *worker);
            put_u32(body, *shards);
            put_u32(body, *k);
            put_u32(body, *d);
        }
        Frame::HelloAck { protocol, shards, k, d } => {
            body.push(KIND_HELLO_ACK);
            put_u16(body, *protocol);
            put_u32(body, *shards);
            put_u32(body, *k);
            put_u32(body, *d);
        }
        _ => unreachable!("encode_handshake takes handshake frames only"),
    });
}

/// Reserve a `u32` length slot, fill the body, patch the length.
fn with_length_prefix(out: &mut Vec<u8>, fill: impl FnOnce(&mut Vec<u8>)) {
    let at = out.len();
    put_u32(out, 0);
    fill(out);
    let body_len = (out.len() - at - 4) as u32;
    out[at..at + 4].copy_from_slice(&body_len.to_le_bytes());
}

/// Decode one frame *body* (the bytes after the `u32` length prefix).
/// Structural errors only; run the semantic validators before handing
/// the message to the fold/splice machinery.
pub fn decode_frame(body: &[u8]) -> Result<Frame, FrameError> {
    let mut r = Reader::new(body);
    let frame = match r.u8()? {
        KIND_GRAD => {
            let worker = r.u32()? as usize;
            let shard = r.u32()? as usize;
            let step = r.u64()?;
            let loss = r.f32()?;
            let grad = decode_encoding(&mut r)?;
            Frame::ToServer(ToServer::Grad { worker, shard, step, grad, loss })
        }
        KIND_DONE => {
            Frame::ToServer(ToServer::Done { worker: r.u32()? as usize })
        }
        KIND_PARAM => {
            let shard = r.u32()? as usize;
            let version = r.u64()?;
            let clock = r.u64()?;
            let data = decode_encoding(&mut r)?;
            Frame::ToWorker(ToWorker::Param { shard, version, clock, data })
        }
        KIND_HELLO => Frame::Hello {
            protocol: r.u16()?,
            worker: r.u32()?,
            shards: r.u32()?,
            k: r.u32()?,
            d: r.u32()?,
        },
        KIND_HELLO_ACK => Frame::HelloAck {
            protocol: r.u16()?,
            shards: r.u32()?,
            k: r.u32()?,
            d: r.u32()?,
        },
        kind => return Err(malformed(format!("unknown kind 0x{kind:02x}"))),
    };
    r.done()?;
    Ok(frame)
}

// ---------------------------------------------------------------------
// semantic validation against the shard plan
// ---------------------------------------------------------------------

/// Checked LEB128 walk of a gap stream: returns the decoded coordinate
/// count, requiring strictly increasing indices below `limit` and no
/// trailing/overlong bytes.
fn walk_gaps(gaps: &[u8], limit: usize) -> Result<usize, FrameError> {
    let mut pos = 0usize;
    let mut idx: u64 = 0;
    let mut count = 0usize;
    while pos < gaps.len() {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            let b = *gaps
                .get(pos)
                .ok_or_else(|| invalid("truncated varint in gap stream"))?;
            pos += 1;
            v |= u64::from(b & 0x7F) << shift;
            if b & 0x80 == 0 {
                break;
            }
            shift += 7;
            if shift >= 32 {
                return Err(invalid("overlong varint in gap stream"));
            }
        }
        if count == 0 {
            idx = v;
        } else {
            if v == 0 {
                return Err(invalid("zero gap (indices must increase)"));
            }
            idx += v;
        }
        if idx >= limit as u64 {
            return Err(invalid(format!(
                "coordinate {idx} out of range (slice len {limit})"
            )));
        }
        count += 1;
    }
    Ok(count)
}

/// Validate an encoding against the slice length shard `s` owns.
fn validate_encoding(
    plan: &ShardPlan,
    shard: usize,
    enc: &SliceEncoding,
) -> Result<(), FrameError> {
    let want = plan.len(shard);
    match enc {
        SliceEncoding::Dense(v) => {
            if v.len() != want {
                return Err(invalid(format!(
                    "dense slice len {} != shard {shard} len {want}",
                    v.len()
                )));
            }
        }
        SliceEncoding::Int8 { q, .. } => {
            if q.len() != want {
                return Err(invalid(format!(
                    "int8 slice len {} != shard {shard} len {want}",
                    q.len()
                )));
            }
        }
        SliceEncoding::TopK { gaps, vals } => {
            let n = walk_gaps(gaps, want)?;
            if n != vals.len() {
                return Err(invalid(format!(
                    "topk coordinate count {n} != value count {}",
                    vals.len()
                )));
            }
        }
        SliceEncoding::TopKInt8 { gaps, vals, .. } => {
            let n = walk_gaps(gaps, want)?;
            if n != vals.len() {
                return Err(invalid(format!(
                    "topk_int8 coordinate count {n} != value count {}",
                    vals.len()
                )));
            }
        }
    }
    Ok(())
}

/// Validate a worker→server message against the topology. Rejecting
/// here keeps a corrupt shard id or mis-sized slice out of the fold
/// path entirely (the in-memory path's `route()` misroute counter is
/// the second line of defense).
pub fn validate_to_server(
    plan: &ShardPlan,
    workers: usize,
    msg: &ToServer,
) -> Result<(), FrameError> {
    match msg {
        ToServer::Grad { worker, shard, grad, .. } => {
            if *worker >= workers {
                return Err(invalid(format!(
                    "worker id {worker} out of range ({workers} workers)"
                )));
            }
            if *shard >= plan.shards() {
                return Err(invalid(format!(
                    "shard id {shard} out of range ({} shards)",
                    plan.shards()
                )));
            }
            validate_encoding(plan, *shard, grad)
        }
        ToServer::Done { worker } => {
            if *worker >= workers {
                return Err(invalid(format!(
                    "worker id {worker} out of range ({workers} workers)"
                )));
            }
            Ok(())
        }
    }
}

/// Validate a server→worker message against the topology.
pub fn validate_to_worker(
    plan: &ShardPlan,
    msg: &ToWorker,
) -> Result<(), FrameError> {
    match msg {
        ToWorker::Param { shard, data, .. } => {
            if *shard >= plan.shards() {
                return Err(invalid(format!(
                    "shard id {shard} out of range ({} shards)",
                    plan.shards()
                )));
            }
            validate_encoding(plan, *shard, data)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strip_prefix(buf: &[u8]) -> &[u8] {
        let len =
            u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
        assert_eq!(len, buf.len() - 4, "length prefix covers the body");
        &buf[4..]
    }

    #[test]
    fn grad_frame_roundtrips_bitwise() {
        let msg = ToServer::Grad {
            worker: 3,
            shard: 1,
            step: 77,
            grad: SliceEncoding::Dense(vec![1.5, -2.25, 0.0, f32::MIN]),
            loss: 0.625,
        };
        let mut buf = Vec::new();
        encode_to_server(&msg, &mut buf);
        match decode_frame(strip_prefix(&buf)).unwrap() {
            Frame::ToServer(ToServer::Grad {
                worker, shard, step, grad, loss,
            }) => {
                assert_eq!((worker, shard, step), (3, 1, 77));
                assert_eq!(loss.to_bits(), 0.625f32.to_bits());
                match grad {
                    SliceEncoding::Dense(v) => {
                        let bits: Vec<u32> =
                            v.iter().map(|x| x.to_bits()).collect();
                        assert_eq!(bits, vec![
                            1.5f32.to_bits(),
                            (-2.25f32).to_bits(),
                            0.0f32.to_bits(),
                            f32::MIN.to_bits(),
                        ]);
                    }
                    other => panic!("wrong encoding: {other:?}"),
                }
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn payload_length_equals_encoded_bytes_every_variant() {
        let variants = [
            SliceEncoding::Dense(vec![1.0, 2.0, 3.0]),
            SliceEncoding::Int8 { scale: 0.5, q: vec![1, -2, 3, -4] },
            SliceEncoding::TopK {
                gaps: vec![0, 2, 1],
                vals: vec![5.0, -6.0, 7.0],
            },
            SliceEncoding::TopKInt8 {
                scale: 0.25,
                gaps: vec![1, 1],
                vals: vec![9, -9],
            },
        ];
        for enc in &variants {
            let mut buf = Vec::new();
            encode_encoding(enc, &mut buf);
            assert_eq!(
                buf.len() as u64,
                encoding_overhead(enc) + enc.encoded_bytes(),
                "wire bytes must be overhead + encoded_bytes: {enc:?}"
            );
        }
    }

    #[test]
    fn handshake_roundtrips() {
        let hello = Frame::Hello {
            protocol: PROTOCOL_VERSION,
            worker: 2,
            shards: 4,
            k: 8,
            d: 16,
        };
        let mut buf = Vec::new();
        encode_handshake(&hello, &mut buf);
        match decode_frame(strip_prefix(&buf)).unwrap() {
            Frame::Hello { protocol, worker, shards, k, d } => {
                assert_eq!(
                    (protocol, worker, shards, k, d),
                    (PROTOCOL_VERSION, 2, 4, 8, 16)
                );
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn unknown_kind_and_trailing_bytes_are_malformed() {
        assert!(matches!(
            decode_frame(&[0x7F]),
            Err(FrameError::Malformed(_))
        ));
        let msg = ToServer::Done { worker: 0 };
        let mut buf = Vec::new();
        encode_to_server(&msg, &mut buf);
        let mut body = strip_prefix(&buf).to_vec();
        body.push(0xAA);
        assert!(matches!(
            decode_frame(&body),
            Err(FrameError::Malformed(_))
        ));
    }

    #[test]
    fn truncated_frame_is_malformed() {
        let msg = ToServer::Grad {
            worker: 0,
            shard: 0,
            step: 1,
            grad: SliceEncoding::Dense(vec![1.0, 2.0]),
            loss: 0.0,
        };
        let mut buf = Vec::new();
        encode_to_server(&msg, &mut buf);
        let body = strip_prefix(&buf);
        for cut in 1..body.len() {
            assert!(
                matches!(
                    decode_frame(&body[..cut]),
                    Err(FrameError::Malformed(_))
                ),
                "cut at {cut} must be malformed"
            );
        }
    }

    /// The allocation-bomb regression, per tag: a tiny frame whose
    /// count field claims (just under) the 2^28 cap must be rejected as
    /// malformed by the remaining-bytes check *before* any
    /// `Vec::with_capacity` — not die trying to allocate gigabytes.
    #[test]
    fn huge_count_in_tiny_frame_is_malformed_per_tag() {
        let mut head = vec![KIND_GRAD];
        put_u32(&mut head, 0); // worker
        put_u32(&mut head, 0); // shard
        put_u64(&mut head, 0); // step
        put_f32(&mut head, 0.0); // loss
        let huge = (MAX_FRAME_BYTES - 1) as u32; // passes the cap check
        for tag in [TAG_DENSE, TAG_INT8, TAG_TOPK, TAG_TOPK_INT8] {
            let mut body = head.clone();
            body.push(tag);
            put_u32(&mut body, huge);
            let err = decode_frame(&body)
                .expect_err("huge count in tiny frame must fail");
            assert!(
                matches!(&err, FrameError::Malformed(m)
                    if m.contains("remain in frame")),
                "tag {tag}: want remaining-bytes malformed, got {err:?}"
            );
        }
        // the second count field (gap stream length) is guarded too
        let mut body = head.clone();
        body.push(TAG_TOPK);
        put_u32(&mut body, 0); // nnz = 0, passes
        put_u32(&mut body, huge); // glen huge
        assert!(matches!(
            decode_frame(&body),
            Err(FrameError::Malformed(_))
        ));
    }

    #[test]
    fn validation_rejects_bad_shard_and_length() {
        let plan = ShardPlan::new(8, 4, 2); // shard len = 16 elements
        let bad_shard = ToServer::Grad {
            worker: 0,
            shard: 9,
            step: 0,
            grad: SliceEncoding::Dense(vec![0.0; 16]),
            loss: 0.0,
        };
        assert!(matches!(
            validate_to_server(&plan, 2, &bad_shard),
            Err(FrameError::Invalid(_))
        ));
        let bad_len = ToServer::Grad {
            worker: 0,
            shard: 0,
            step: 0,
            grad: SliceEncoding::Dense(vec![0.0; 15]),
            loss: 0.0,
        };
        assert!(matches!(
            validate_to_server(&plan, 2, &bad_len),
            Err(FrameError::Invalid(_))
        ));
        let ok = ToServer::Grad {
            worker: 1,
            shard: 1,
            step: 0,
            grad: SliceEncoding::Dense(vec![0.0; 16]),
            loss: 0.0,
        };
        assert!(validate_to_server(&plan, 2, &ok).is_ok());
    }

    #[test]
    fn validation_rejects_out_of_range_coordinates() {
        let plan = ShardPlan::new(4, 4, 2); // shard len = 8
        let enc = SliceEncoding::TopK {
            gaps: vec![7, 1], // indices 7, 8 — 8 is out of range
            vals: vec![1.0, 2.0],
        };
        let msg = ToServer::Grad {
            worker: 0,
            shard: 0,
            step: 0,
            grad: enc,
            loss: 0.0,
        };
        assert!(matches!(
            validate_to_server(&plan, 1, &msg),
            Err(FrameError::Invalid(_))
        ));
        let ok = ToServer::Grad {
            worker: 0,
            shard: 0,
            step: 0,
            grad: SliceEncoding::TopK {
                gaps: vec![6, 1], // indices 6, 7 — in range
                vals: vec![1.0, 2.0],
            },
            loss: 0.0,
        };
        assert!(validate_to_server(&plan, 1, &ok).is_ok());
    }

    #[test]
    fn validation_rejects_zero_gap() {
        let plan = ShardPlan::new(4, 4, 1);
        let msg = ToServer::Grad {
            worker: 0,
            shard: 0,
            step: 0,
            grad: SliceEncoding::TopK {
                gaps: vec![3, 0], // duplicate index — gaps must be >= 1
                vals: vec![1.0, 2.0],
            },
            loss: 0.0,
        };
        assert!(matches!(
            validate_to_server(&plan, 1, &msg),
            Err(FrameError::Invalid(_))
        ));
    }

    #[test]
    fn param_validation_mirrors_grad_validation() {
        let plan = ShardPlan::new(8, 4, 2);
        let ok = ToWorker::Param {
            shard: 0,
            version: 1,
            clock: 1,
            data: SliceEncoding::Dense(vec![0.0; 16]),
        };
        assert!(validate_to_worker(&plan, &ok).is_ok());
        let bad = ToWorker::Param {
            shard: 5,
            version: 1,
            clock: 1,
            data: SliceEncoding::Dense(vec![0.0; 16]),
        };
        assert!(matches!(
            validate_to_worker(&plan, &bad),
            Err(FrameError::Invalid(_))
        ));
    }
}
