//! Message types exchanged between workers and the central server.
//!
//! The paper's protocol (§4.1): workers push gradient updates ΔL_p; the
//! server aggregates them into the global L and pushes fresh parameters
//! back. Messages carry dense f32 payloads (the full k×d matrix), which
//! is exactly the communication volume the paper's scalability analysis
//! assumes.

/// Worker → server.
pub enum ToServer {
    /// A gradient update computed on one minibatch.
    Grad {
        worker: usize,
        /// The worker's local step index this gradient belongs to.
        step: u64,
        /// Row-major k×d gradient.
        grad: Vec<f32>,
        /// Minibatch loss at the worker's local parameters (telemetry).
        loss: f32,
    },
    /// Worker finished its step budget.
    Done { worker: usize },
}

/// Server → worker.
pub enum ToWorker {
    /// Fresh global parameters.
    Param {
        /// Number of gradient updates applied to the global L so far.
        version: u64,
        /// SSP clock: min over workers of applied-update counts.
        clock: u64,
        /// Row-major k×d parameters.
        data: Vec<f32>,
    },
}

impl std::fmt::Debug for ToServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ToServer::Grad { worker, step, loss, grad } => f
                .debug_struct("Grad")
                .field("worker", worker)
                .field("step", step)
                .field("loss", loss)
                .field("len", &grad.len())
                .finish(),
            ToServer::Done { worker } => {
                f.debug_struct("Done").field("worker", worker).finish()
            }
        }
    }
}

impl std::fmt::Debug for ToWorker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ToWorker::Param { version, clock, data } => f
                .debug_struct("Param")
                .field("version", version)
                .field("clock", clock)
                .field("len", &data.len())
                .finish(),
        }
    }
}
