//! Message types exchanged between workers and the sharded server, plus
//! the static [`ShardPlan`] both sides agree on.
//!
//! The paper's protocol (§4.1) ships full k×d matrices: workers push
//! gradient updates ΔL_p, the server pushes fresh parameters back. With
//! the server sharded into S row-range shards, every message carries only
//! one shard's row-slice — communication per message drops S× and shard
//! servers fold gradients independently. `server_shards = 1` degenerates
//! to the paper's single-server protocol exactly (one shard owning all of
//! L, whole-matrix messages).

/// Static partition of L's rows into contiguous per-shard slices.
///
/// Shard `s` owns rows `rows(s)` of the k×d matrix; in row-major storage
/// that is one contiguous element range (`offset(s) .. offset(s)+len(s)`),
/// so slicing a gradient or reassembling a parameter copy is a cheap
/// contiguous copy, never a gather. Workers and all server shards are
/// constructed from the same plan, so shard ids in messages are
/// meaningful on both sides without negotiation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// Rows of L.
    pub k: usize,
    /// Columns of L (feature dimension).
    pub d: usize,
    /// Row boundaries; shard `s` owns rows `bounds[s]..bounds[s+1]`.
    bounds: Vec<usize>,
}

impl ShardPlan {
    /// Balanced contiguous partition. `shards` is clamped to `[1, k]`
    /// so no shard is ever empty; the first `k % shards` shards get one
    /// extra row.
    pub fn new(k: usize, d: usize, shards: usize) -> ShardPlan {
        assert!(k > 0 && d > 0, "empty parameter matrix");
        let s = shards.clamp(1, k);
        let base = k / s;
        let rem = k % s;
        let mut bounds = Vec::with_capacity(s + 1);
        bounds.push(0);
        let mut r = 0;
        for i in 0..s {
            r += base + usize::from(i < rem);
            bounds.push(r);
        }
        ShardPlan { k, d, bounds }
    }

    pub fn shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Row range owned by shard `s`.
    pub fn rows(&self, s: usize) -> std::ops::Range<usize> {
        self.bounds[s]..self.bounds[s + 1]
    }

    /// Element offset of shard `s`'s slice in row-major k×d storage.
    pub fn offset(&self, s: usize) -> usize {
        self.bounds[s] * self.d
    }

    /// Element count of shard `s`'s slice.
    pub fn len(&self, s: usize) -> usize {
        (self.bounds[s + 1] - self.bounds[s]) * self.d
    }

    /// Row count owned by shard `s` (the checkpoint codec writes each
    /// slice as a `shard_rows(s) × d` matrix).
    pub fn shard_rows(&self, s: usize) -> usize {
        self.bounds[s + 1] - self.bounds[s]
    }

    /// Whether shard `s` owns no elements. Always false for plans built
    /// by [`ShardPlan::new`] (shard count is clamped to `[1, k]`), but
    /// paired with [`ShardPlan::len`] for a complete API.
    pub fn is_empty(&self, s: usize) -> bool {
        self.len(s) == 0
    }

    /// Shard `s`'s slice of a row-major k×d buffer.
    pub fn slice<'a>(&self, data: &'a [f32], s: usize) -> &'a [f32] {
        &data[self.offset(s)..self.offset(s) + self.len(s)]
    }

    /// Mutable variant of [`ShardPlan::slice`].
    pub fn slice_mut<'a>(
        &self,
        data: &'a mut [f32],
        s: usize,
    ) -> &'a mut [f32] {
        let o = self.offset(s);
        let n = self.len(s);
        &mut data[o..o + n]
    }
}

/// Encoded payload of one shard slice on the wire — the unit both
/// [`ToServer::Grad`] and [`ToWorker::Param`] carry. Every variant is
/// self-describing (the receiver needs no out-of-band mode agreement)
/// and decodes to a dense f32 slice via
/// [`super::compress::decode_into`].
///
/// [`SliceEncoding::encoded_bytes`] is the *exact* wire size of the
/// payload as it would serialize — the byte-accounting truth used by
/// `WorkerStats`/`ServerResult` telemetry and `BENCH_wire.json`. It
/// counts payload only: message header fields (worker/shard/step/
/// version/clock/loss) are topology-constant and excluded, which keeps
/// the numbers comparable with `BENCH_ps.json`'s per-message payload
/// sizes.
#[derive(Clone)]
pub enum SliceEncoding {
    /// Uncompressed f32 values — the PR-2/PR-3 protocol verbatim.
    Dense(Vec<f32>),
    /// Stochastic int8 quantization: one shared f32 scale, one i8 per
    /// coordinate (`x ≈ q · scale`).
    Int8 { scale: f32, q: Vec<i8> },
    /// Top-k sparse, f32 values. Coordinates travel as LEB128 varint
    /// gaps: the first entry is the first index, each later entry is
    /// `idx[j] − idx[j−1]` (≥ 1, indices strictly increase).
    TopK { gaps: Vec<u8>, vals: Vec<f32> },
    /// Top-k sparse with int8 values and a per-slice scale; same gap
    /// coordinate stream as [`SliceEncoding::TopK`].
    TopKInt8 { scale: f32, gaps: Vec<u8>, vals: Vec<i8> },
}

impl SliceEncoding {
    /// Exact serialized payload size in bytes.
    pub fn encoded_bytes(&self) -> u64 {
        match self {
            SliceEncoding::Dense(v) => 4 * v.len() as u64,
            SliceEncoding::Int8 { q, .. } => 4 + q.len() as u64,
            SliceEncoding::TopK { gaps, vals } => {
                gaps.len() as u64 + 4 * vals.len() as u64
            }
            SliceEncoding::TopKInt8 { gaps, vals, .. } => {
                4 + gaps.len() as u64 + vals.len() as u64
            }
        }
    }

    /// Non-zero coordinates carried (= slice length for dense forms).
    pub fn nnz(&self) -> usize {
        match self {
            SliceEncoding::Dense(v) => v.len(),
            SliceEncoding::Int8 { q, .. } => q.len(),
            SliceEncoding::TopK { vals, .. } => vals.len(),
            SliceEncoding::TopKInt8 { vals, .. } => vals.len(),
        }
    }
}

impl std::fmt::Debug for SliceEncoding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let tag = match self {
            SliceEncoding::Dense(_) => "dense",
            SliceEncoding::Int8 { .. } => "int8",
            SliceEncoding::TopK { .. } => "topk",
            SliceEncoding::TopKInt8 { .. } => "topk_int8",
        };
        f.debug_struct("SliceEncoding")
            .field("tag", &tag)
            .field("nnz", &self.nnz())
            .field("bytes", &self.encoded_bytes())
            .finish()
    }
}

/// Worker → server.
pub enum ToServer {
    /// One shard-slice of a gradient computed on one minibatch. A worker
    /// step fans out into `shards()` of these, all sharing one transport
    /// fate (a dropped step loses every slice, so shard parameters never
    /// desynchronize within a step).
    Grad {
        worker: usize,
        /// Which shard's row-slice this carries.
        shard: usize,
        /// The worker's local step index this gradient belongs to.
        step: u64,
        /// Encoded row-major slice of the k×d gradient (rows
        /// `plan.rows(shard)`); `Dense` under `compression.mode=none`.
        grad: SliceEncoding,
        /// Minibatch loss at the worker's local parameters (telemetry;
        /// identical across the step's slices, counted once per shard).
        loss: f32,
    },
    /// Worker finished its step budget (routed to every shard).
    Done { worker: usize },
}

/// Server → worker.
pub enum ToWorker {
    /// Fresh parameters for one shard. Versioned per shard; workers keep
    /// the freshest version of each slice independently.
    Param {
        /// Which shard's row-slice this carries.
        shard: usize,
        /// Number of gradient slices this shard has applied so far.
        version: u64,
        /// This shard's SSP clock: min over unfinished workers of
        /// applied-slice counts. Workers gate on the min across shards.
        clock: u64,
        /// Encoded row-major slice of the k×d parameters (rows
        /// `plan.rows(shard)`). `Dense` except under the int8
        /// compression modes (parameters are absolute state: top-k
        /// sparsification never applies to them).
        data: SliceEncoding,
    },
}

impl std::fmt::Debug for ToServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ToServer::Grad { worker, shard, step, loss, grad } => f
                .debug_struct("Grad")
                .field("worker", worker)
                .field("shard", shard)
                .field("step", step)
                .field("loss", loss)
                .field("grad", grad)
                .finish(),
            ToServer::Done { worker } => {
                f.debug_struct("Done").field("worker", worker).finish()
            }
        }
    }
}

impl std::fmt::Debug for ToWorker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ToWorker::Param { shard, version, clock, data } => f
                .debug_struct("Param")
                .field("shard", shard)
                .field("version", version)
                .field("clock", clock)
                .field("data", data)
                .finish(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_plan_covers_all_rows_balanced() {
        for k in [1usize, 2, 5, 8, 13, 600] {
            for shards in [1usize, 2, 3, 4, 16] {
                let plan = ShardPlan::new(k, 7, shards);
                assert_eq!(plan.shards(), shards.clamp(1, k));
                let mut next = 0;
                let mut sizes = Vec::new();
                for s in 0..plan.shards() {
                    let r = plan.rows(s);
                    assert_eq!(r.start, next, "contiguous at shard {s}");
                    assert!(r.end > r.start, "non-empty shard {s}");
                    sizes.push(r.end - r.start);
                    next = r.end;
                }
                assert_eq!(next, k, "k={k} shards={shards}");
                let (min, max) = (
                    *sizes.iter().min().unwrap(),
                    *sizes.iter().max().unwrap(),
                );
                assert!(max - min <= 1, "balanced: {sizes:?}");
            }
        }
    }

    #[test]
    fn shard_plan_slices_roundtrip() {
        let (k, d) = (13, 5);
        let plan = ShardPlan::new(k, d, 4);
        let data: Vec<f32> = (0..k * d).map(|i| i as f32).collect();
        let mut rebuilt = vec![0.0f32; k * d];
        for s in 0..plan.shards() {
            let src = plan.slice(&data, s).to_vec();
            assert_eq!(src.len(), plan.len(s));
            plan.slice_mut(&mut rebuilt, s).copy_from_slice(&src);
        }
        assert_eq!(rebuilt, data);
    }

    #[test]
    fn encoded_bytes_is_exact_per_variant() {
        assert_eq!(SliceEncoding::Dense(vec![0.0; 10]).encoded_bytes(), 40);
        assert_eq!(
            SliceEncoding::Int8 { scale: 1.0, q: vec![0; 10] }
                .encoded_bytes(),
            4 + 10
        );
        assert_eq!(
            SliceEncoding::TopK {
                gaps: vec![0; 3],
                vals: vec![0.0; 3],
            }
            .encoded_bytes(),
            3 + 12
        );
        assert_eq!(
            SliceEncoding::TopKInt8 {
                scale: 1.0,
                gaps: vec![0; 3],
                vals: vec![0; 3],
            }
            .encoded_bytes(),
            4 + 3 + 3
        );
    }

    #[test]
    fn shard_plan_offsets_are_row_aligned() {
        let plan = ShardPlan::new(10, 3, 4);
        for s in 0..plan.shards() {
            assert_eq!(plan.offset(s) % plan.d, 0);
            assert_eq!(plan.offset(s), plan.rows(s).start * plan.d);
            assert_eq!(plan.shard_rows(s), plan.rows(s).len());
            assert_eq!(plan.len(s), (plan.rows(s).len()) * plan.d);
        }
    }
}
