//! L3: the paper's distributed system — an asynchronous parameter server
//! for distance metric learning, with the parameter space sharded.
//!
//! Topology (paper Fig. 1, extended): the global L is row-partitioned
//! into S server shards ([`ShardPlan`]), each with its own update thread,
//! queues, and learning-rate clock; P workers each hold a local copy L_p
//! and a shard of the pair sets. Workers compute minibatch gradients,
//! split them into per-shard row slices on push, and reassemble their
//! local copy from versioned per-shard `Param` slices (freshest wins) on
//! pull. The SSP consistency gate operates on the min-over-shards clock.
//! With `server_shards = 1` this is exactly the paper's single central
//! server. All threads are "best-effort" and coordinate only through
//! message queues (§4.2).
//!
//! The orchestration entry point is
//! [`Session::train_distributed`](crate::session::Session::train_distributed);
//! [`run_training`] remains as a deprecated shim over it.

pub mod checkpoint;
mod compress;
pub mod frame;
mod messages;
pub mod net;
mod server;
mod transport;
mod worker;

pub use checkpoint::{Checkpoint, CheckpointSpec, WorkerResume};
pub use compress::{decode_into, encode_param, keep_count, Compressor};
pub use messages::{ShardPlan, SliceEncoding, ToServer, ToWorker};
pub use server::{ProbeFn, Server, ServerConfig, ServerResult};
pub use transport::{
    drain, Drained, FaultSpec, FaultySender, MemoryTransport, Transport,
    TransportStats,
};
pub use worker::{Worker, WorkerConfig, WorkerStats};

use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::data::{Dataset, PairSet};
use crate::dml::EngineFactory;
use crate::linalg::Mat;
use crate::metrics::Curve;

/// Everything a finished distributed run reports.
pub struct TrainResult {
    pub l: Mat,
    pub curve: Curve,
    /// Logical full-gradient updates folded into the global L.
    pub applied_updates: u64,
    /// Per-shard slice applications summed over shards
    /// (= `applied_updates × server_shards`).
    pub slice_updates: u64,
    /// Broadcast rounds summed over shards (upper bound on param
    /// traffic; the comm thread collapses to freshest-per-shard).
    pub broadcasts: u64,
    /// Physical parameter slice messages shipped to workers.
    pub param_msgs: u64,
    /// Server shard count the run actually used (the config knob clamped
    /// to the row count).
    pub server_shards: usize,
    /// Mean worker-reported minibatch loss over the server's last
    /// telemetry window.
    pub last_loss: f32,
    /// Encoded payload bytes of gradient slices the server folded
    /// (wire size as received).
    pub grad_bytes_received: u64,
    /// Encoded payload bytes of parameter slices shipped to workers.
    pub param_bytes_sent: u64,
    /// Gradient messages the server's router skipped for naming a shard
    /// outside the plan (see [`ServerResult::misroutes`]). Zero on every
    /// healthy run.
    pub misroutes: u64,
    pub worker_stats: Vec<WorkerStats>,
    pub wall_s: f64,
}

/// Options beyond the experiment config (fault injection, probe cadence,
/// checkpointing). Like [`crate::config::NetConfig`], these describe how
/// a particular run is supervised, not what is learned — they stay out
/// of the experiment JSON and its digest.
#[derive(Clone)]
pub struct RunOptions {
    pub faults: FaultSpec,
    /// Curve-probe cadence in applied updates.
    pub probe_every: u64,
    /// Probe sample sizes (similar, dissimilar).
    pub probe_pairs: (usize, usize),
    /// Periodic sharded checkpointing of server state (None = off).
    pub checkpoint: Option<CheckpointSpec>,
    /// Resume from the newest consistent checkpoint in this run
    /// directory. An empty/never-written directory means a fresh start,
    /// so restart supervisors can pass it unconditionally.
    pub resume_from: Option<std::path::PathBuf>,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            faults: FaultSpec::perfect(),
            probe_every: 20,
            probe_pairs: (200, 200),
            checkpoint: None,
            resume_from: None,
        }
    }
}

/// Run distributed DML training with the threaded parameter server.
///
/// * `engines` — factory each worker's computing thread uses; pass
///   [`crate::dml::native_factory`] or [`crate::runtime::xla_factory`].
///
/// Deprecated shim: the orchestration lives in
/// [`crate::session`]; this delegates to exactly the code
/// [`Session::train_distributed`](crate::session::Session::train_distributed)
/// runs (the `api_session` golden tests pin the two bit-identical).
#[deprecated(
    since = "0.2.0",
    note = "use session::Session::from_config(cfg)\
            .pair_source(dataset, pairs).train_distributed()"
)]
pub fn run_training(
    cfg: &ExperimentConfig,
    dataset: Arc<Dataset>,
    pairs: &PairSet,
    engines: EngineFactory,
    opts: &RunOptions,
) -> anyhow::Result<TrainResult> {
    crate::session::run_distributed(cfg, dataset, pairs, engines, opts,
                                    None)
}
