//! Socket transport: the PS wire protocol on real TCP / Unix sockets.
//!
//! Design rule: **endpoints stay mpsc channel halves.** The server and
//! worker machinery (and `FaultySender`, whose `sent + dropped == steps`
//! identity must hold on every backend) are written against
//! `Sender`/`Receiver`; this module bridges those channels to sockets
//! with one reader + one writer thread per connection, so not a line of
//! the fold/gate/fault logic changes between in-memory and socket runs.
//!
//! Per connection:
//!
//! * the **writer** thread drains its channel, encodes frames
//!   ([`super::frame`]) into a buffered stream, and flushes whenever the
//!   channel runs empty. When the channel disconnects (the machinery
//!   dropped its sender — i.e. the run is over) it performs the linger
//!   flush: drain every queued message, flush the buffer, then
//!   `shutdown(Write)` so the peer sees a clean EOF after the last
//!   frame. mpsc guarantees queued messages survive sender drop, so no
//!   tail frame is lost.
//! * the **reader** thread length-decodes frames, runs the structural
//!   *and* semantic validators, and forwards good messages into its
//!   channel. A structural error (stream out of sync) drops the
//!   connection; a semantic error (corrupt shard id, mis-sized slice)
//!   rejects that one message and keeps reading. Either way the bad
//!   bytes never reach `decode_into`, which is entitled to panic on
//!   hostile input. Rejections are counted in [`TransportStats`].
//!
//! Connection setup is a bounded retry-with-backoff ([`connect_retry`])
//! followed by a `Hello`/`HelloAck` handshake that cross-checks
//! protocol version and `(shards, k, d)` topology, so a mis-deployed
//! node fails at connect time with a message naming both sides.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::frame::{
    decode_frame, encode_handshake, encode_to_server, encode_to_worker,
    validate_to_server, validate_to_worker, Frame, FrameError,
    MAX_FRAME_BYTES, PROTOCOL_VERSION,
};
use super::messages::{ShardPlan, ToServer, ToWorker};
use super::transport::{Transport, TransportStats};

// ---------------------------------------------------------------------
// addresses, streams, listeners
// ---------------------------------------------------------------------

/// A transport address: `host:port` for TCP, `unix:/path` for a Unix
/// domain socket.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum NetAddr {
    Tcp(String),
    #[cfg(unix)]
    Unix(std::path::PathBuf),
}

impl NetAddr {
    pub fn parse(s: &str) -> Result<NetAddr> {
        if let Some(path) = s.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                return Ok(NetAddr::Unix(std::path::PathBuf::from(path)));
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                bail!("unix: addresses are not supported on this platform");
            }
        }
        if !s.contains(':') {
            bail!("TCP address {s:?} must be host:port");
        }
        Ok(NetAddr::Tcp(s.to_string()))
    }
}

impl std::fmt::Display for NetAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetAddr::Tcp(s) => write!(f, "{s}"),
            #[cfg(unix)]
            NetAddr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// A connected stream over either socket family.
pub enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    /// Clone the underlying socket so one half can read while the
    /// other writes (used by the PS loop and the serving front end).
    pub fn try_clone(&self) -> Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => {
                Stream::Tcp(s.try_clone().context("clone tcp stream")?)
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                Stream::Unix(s.try_clone().context("clone unix stream")?)
            }
        })
    }

    /// Half-close the write side, letting the peer's blocking read
    /// observe EOF while our own reads keep draining.
    pub fn shutdown_write(&self) {
        let _ = match self {
            Stream::Tcp(s) => s.shutdown(Shutdown::Write),
            #[cfg(unix)]
            Stream::Unix(s) => s.shutdown(Shutdown::Write),
        };
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// A bound listener over either socket family.
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    pub fn bind(addr: &NetAddr) -> Result<Listener> {
        Ok(match addr {
            NetAddr::Tcp(a) => Listener::Tcp(
                TcpListener::bind(a).with_context(|| format!("bind {a}"))?,
            ),
            #[cfg(unix)]
            NetAddr::Unix(p) => {
                // A previous run's socket file would make bind fail with
                // AddrInUse even though nobody is listening.
                let _ = std::fs::remove_file(p);
                Listener::Unix(
                    UnixListener::bind(p)
                        .with_context(|| format!("bind unix:{}", p.display()))?,
                )
            }
        })
    }

    /// The actual bound address — resolves port 0 to the kernel-chosen
    /// port, which is how tests get collision-free listeners.
    pub fn local_addr(&self) -> Result<NetAddr> {
        Ok(match self {
            Listener::Tcp(l) => {
                NetAddr::Tcp(l.local_addr().context("local_addr")?.to_string())
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let a = l.local_addr().context("local_addr")?;
                let p = a
                    .as_pathname()
                    .context("unix listener has no pathname")?;
                NetAddr::Unix(p.to_path_buf())
            }
        })
    }

    /// Block for the next inbound connection. The PS layer wraps this
    /// in `accept_workers`; the serving front end drives it directly.
    pub fn accept(&self) -> Result<Stream> {
        Ok(match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept().context("accept")?;
                s.set_nodelay(true).ok();
                Stream::Tcp(s)
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept().context("accept")?;
                Stream::Unix(s)
            }
        })
    }
}

// ---------------------------------------------------------------------
// bounded connect retry
// ---------------------------------------------------------------------

/// Bounded retry-with-backoff for connection setup. Workers race the
/// server to start; a refused connection within the window is normal,
/// not fatal — but the bound keeps a dead server from hanging a node
/// forever.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total connect attempts before giving up (>= 1).
    pub attempts: u32,
    /// Sleep before the second attempt; doubles per retry.
    pub initial_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        // 30 attempts × 20 ms doubling capped at 1 s ≈ 25 s window:
        // generous for a slow-starting server process, bounded enough
        // that a misconfigured address fails within the minute.
        RetryPolicy {
            attempts: 30,
            initial_backoff: Duration::from_millis(20),
            max_backoff: Duration::from_secs(1),
        }
    }
}

/// Connect with bounded exponential backoff.
pub fn connect_retry(addr: &NetAddr, policy: RetryPolicy) -> Result<Stream> {
    let attempts = policy.attempts.max(1);
    let mut backoff = policy.initial_backoff;
    let mut last_err = None;
    for attempt in 0..attempts {
        if attempt > 0 {
            thread::sleep(backoff);
            backoff = (backoff * 2).min(policy.max_backoff);
        }
        match addr {
            NetAddr::Tcp(a) => match TcpStream::connect(a) {
                Ok(s) => {
                    s.set_nodelay(true).ok();
                    return Ok(Stream::Tcp(s));
                }
                Err(e) => last_err = Some(e),
            },
            #[cfg(unix)]
            NetAddr::Unix(p) => match UnixStream::connect(p) {
                Ok(s) => return Ok(Stream::Unix(s)),
                Err(e) => last_err = Some(e),
            },
        }
    }
    Err(anyhow::Error::new(last_err.expect("attempts >= 1")).context(
        format!("connect to {addr} failed after {attempts} attempts"),
    ))
}

// ---------------------------------------------------------------------
// framed stream I/O
// ---------------------------------------------------------------------

/// Read one length-prefixed frame body. `Ok(None)` is a clean EOF at a
/// frame boundary; EOF mid-frame is an error.
fn read_frame(r: &mut impl Read, body: &mut Vec<u8>) -> Result<Option<()>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Ok(None)
        }
        Err(e) => return Err(e).context("read frame length"),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME_BYTES {
        bail!("frame length {len} exceeds cap {MAX_FRAME_BYTES}");
    }
    body.resize(len, 0);
    r.read_exact(body).context("read frame body")?;
    Ok(Some(()))
}

fn write_all_counted(
    w: &mut impl Write,
    buf: &[u8],
    stats: &Counters,
) -> std::io::Result<()> {
    w.write_all(buf)?;
    stats.bytes_sent.fetch_add(buf.len() as u64, Ordering::Relaxed);
    stats.frames_sent.fetch_add(1, Ordering::Relaxed);
    Ok(())
}

/// Shared wire counters, read out as [`TransportStats`] on join.
/// Bytes include the 4-byte length prefixes and frame headers — these
/// are wire-level totals, distinct from the payload-exact
/// `encoded_bytes()` telemetry the PS machinery reports.
#[derive(Default)]
struct Counters {
    frames_sent: AtomicU64,
    frames_received: AtomicU64,
    bytes_sent: AtomicU64,
    bytes_received: AtomicU64,
    rejected_frames: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> TransportStats {
        TransportStats {
            frames_sent: self.frames_sent.load(Ordering::Relaxed),
            frames_received: self.frames_received.load(Ordering::Relaxed),
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            bytes_received: self.bytes_received.load(Ordering::Relaxed),
            rejected_frames: self.rejected_frames.load(Ordering::Relaxed),
        }
    }
}

fn read_handshake_frame(stream: &mut Stream) -> Result<Frame> {
    let mut body = Vec::new();
    match read_frame(stream, &mut body)? {
        Some(()) => decode_frame(&body).map_err(anyhow::Error::new),
        None => bail!("peer closed connection during handshake"),
    }
}

// ---------------------------------------------------------------------
// server side
// ---------------------------------------------------------------------

/// A bound, not-yet-accepting server endpoint. Two-phase so callers can
/// learn the kernel-chosen port (`local_addr`) before workers connect.
pub struct NetServer {
    listener: Listener,
}

impl NetServer {
    pub fn bind(addr: &NetAddr) -> Result<NetServer> {
        Ok(NetServer { listener: Listener::bind(addr)? })
    }

    pub fn local_addr(&self) -> Result<NetAddr> {
        self.listener.local_addr()
    }

    /// Accept and handshake exactly `workers` connections, then bridge
    /// each to channel endpoints. Blocks until every worker has said
    /// `Hello`; duplicate or out-of-range worker ids and topology
    /// mismatches abort with context (the manager surfaces the error
    /// and kills the run rather than training on a wrong topology).
    pub fn accept_workers(
        self,
        plan: &ShardPlan,
        workers: usize,
    ) -> Result<NetServerTransport> {
        let counters = Arc::new(Counters::default());
        let (from_workers_tx, from_workers_rx) = channel::<ToServer>();
        let mut to_worker_txs: Vec<Option<Sender<ToWorker>>> =
            (0..workers).map(|_| None).collect();
        let mut handles = Vec::new();

        for _ in 0..workers {
            let mut stream = self.listener.accept()?;
            let worker = match read_handshake_frame(&mut stream)? {
                Frame::Hello { protocol, worker, shards, k, d } => {
                    if protocol != PROTOCOL_VERSION {
                        bail!(
                            "protocol mismatch: worker {worker} speaks v{protocol}, server v{PROTOCOL_VERSION}"
                        );
                    }
                    let (ps, pk, pd) =
                        (plan.shards() as u32, plan.k as u32, plan.d as u32);
                    if (shards, k, d) != (ps, pk, pd) {
                        bail!(
                            "topology mismatch: worker {worker} configured (shards={shards}, k={k}, d={d}), server (shards={ps}, k={pk}, d={pd})"
                        );
                    }
                    worker as usize
                }
                other => bail!("expected Hello, got {other:?}"),
            };
            if worker >= workers {
                bail!("worker id {worker} out of range ({workers} workers)");
            }
            if to_worker_txs[worker].is_some() {
                bail!("worker id {worker} connected twice");
            }

            let mut ack = Vec::new();
            encode_handshake(
                &Frame::HelloAck {
                    protocol: PROTOCOL_VERSION,
                    shards: plan.shards() as u32,
                    k: plan.k as u32,
                    d: plan.d as u32,
                },
                &mut ack,
            );
            stream.write_all(&ack).context("send HelloAck")?;
            stream.flush().context("flush HelloAck")?;

            let (tx, rx) = channel::<ToWorker>();
            to_worker_txs[worker] = Some(tx);
            let read_half = stream.try_clone()?;
            handles.push(spawn_reader_to_server(
                read_half,
                from_workers_tx.clone(),
                plan.clone(),
                workers,
                worker,
                Arc::clone(&counters),
            ));
            handles.push(spawn_writer_to_worker(
                stream,
                rx,
                Arc::clone(&counters),
            ));
        }
        // The reader threads hold the live clones; dropping the master
        // sender means the server sees disconnect once all workers EOF,
        // exactly like the in-memory run dropping its `to_server_tx`.
        drop(from_workers_tx);

        Ok(NetServerTransport {
            endpoints: Some((
                from_workers_rx,
                to_worker_txs
                    .into_iter()
                    .map(|t| t.expect("every worker slot filled"))
                    .collect(),
            )),
            handles,
            counters,
        })
    }
}

/// Server-side [`Transport`]: hands the bridged channel endpoints to
/// `Server::spawn`, joins the socket threads on `finish`.
pub struct NetServerTransport {
    endpoints: Option<(Receiver<ToServer>, Vec<Sender<ToWorker>>)>,
    handles: Vec<JoinHandle<()>>,
    counters: Arc<Counters>,
}

impl Transport for NetServerTransport {
    fn name(&self) -> &'static str {
        "socket-server"
    }

    fn server_endpoints(
        &mut self,
    ) -> Result<(Receiver<ToServer>, Vec<Sender<ToWorker>>)> {
        self.endpoints
            .take()
            .context("server endpoints already taken")
    }

    fn worker_endpoints(
        &mut self,
        worker: usize,
    ) -> Result<(Sender<ToServer>, Receiver<ToWorker>)> {
        bail!("socket server transport has no local worker {worker} endpoints")
    }

    fn finish(&mut self) -> TransportStats {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.counters.snapshot()
    }
}

fn spawn_reader_to_server(
    mut stream: Stream,
    tx: Sender<ToServer>,
    plan: ShardPlan,
    workers: usize,
    worker: usize,
    counters: Arc<Counters>,
) -> JoinHandle<()> {
    thread::Builder::new()
        .name(format!("net-read-w{worker}"))
        .spawn(move || {
            let mut body = Vec::new();
            loop {
                match read_frame(&mut stream, &mut body) {
                    Ok(Some(())) => {}
                    Ok(None) => break, // clean EOF: worker done
                    Err(e) => {
                        counters
                            .rejected_frames
                            .fetch_add(1, Ordering::Relaxed);
                        eprintln!("[net] worker {worker} stream broken: {e:#}");
                        break;
                    }
                }
                counters
                    .bytes_received
                    .fetch_add(4 + body.len() as u64, Ordering::Relaxed);
                let msg = match decode_frame(&body) {
                    Ok(Frame::ToServer(m)) => m,
                    Ok(other) => {
                        // Structurally valid but nonsensical direction:
                        // the stream is out of protocol, drop it.
                        counters
                            .rejected_frames
                            .fetch_add(1, Ordering::Relaxed);
                        eprintln!(
                            "[net] worker {worker} sent unexpected frame {other:?}; closing"
                        );
                        break;
                    }
                    Err(e @ FrameError::Malformed(_)) => {
                        counters
                            .rejected_frames
                            .fetch_add(1, Ordering::Relaxed);
                        eprintln!(
                            "[net] worker {worker} stream corrupt: {e}; closing"
                        );
                        break;
                    }
                    Err(e) => {
                        counters
                            .rejected_frames
                            .fetch_add(1, Ordering::Relaxed);
                        eprintln!("[net] worker {worker}: {e}; closing");
                        break;
                    }
                };
                if let Err(e) = validate_to_server(&plan, workers, &msg) {
                    // Framing is still sound — reject the message, keep
                    // the connection. Never let it reach decode_into.
                    counters
                        .rejected_frames
                        .fetch_add(1, Ordering::Relaxed);
                    eprintln!("[net] worker {worker}: rejected message: {e}");
                    continue;
                }
                counters.frames_received.fetch_add(1, Ordering::Relaxed);
                if tx.send(msg).is_err() {
                    break; // server machinery gone
                }
            }
        })
        .expect("spawn net reader")
}

fn spawn_writer_to_worker(
    stream: Stream,
    rx: Receiver<ToWorker>,
    counters: Arc<Counters>,
) -> JoinHandle<()> {
    thread::Builder::new()
        .name("net-write-param".to_string())
        .spawn(move || {
            let shutdown_handle =
                stream.try_clone().expect("clone for shutdown");
            let mut w = std::io::BufWriter::new(stream);
            let mut buf = Vec::new();
            // recv() drains messages queued before the sender dropped,
            // so the Disconnected arm *is* the linger flush.
            while let Ok(msg) = rx.recv() {
                buf.clear();
                encode_to_worker(&msg, &mut buf);
                if write_all_counted(&mut w, &buf, &counters).is_err() {
                    return; // worker hung up; nothing to flush to
                }
                loop {
                    match rx.try_recv() {
                        Ok(m) => {
                            buf.clear();
                            encode_to_worker(&m, &mut buf);
                            if write_all_counted(&mut w, &buf, &counters)
                                .is_err()
                            {
                                return;
                            }
                        }
                        Err(TryRecvError::Empty) => {
                            let _ = w.flush();
                            break;
                        }
                        Err(TryRecvError::Disconnected) => {
                            let _ = w.flush();
                            shutdown_handle.shutdown_write();
                            return;
                        }
                    }
                }
            }
            let _ = w.flush();
            shutdown_handle.shutdown_write();
        })
        .expect("spawn net writer")
}

// ---------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------

/// Worker-side [`Transport`]: connects (with retry), handshakes, and
/// bridges the socket to the channel endpoints `Worker::spawn` expects.
pub struct NetWorkerTransport {
    worker: usize,
    endpoints: Option<(Sender<ToServer>, Receiver<ToWorker>)>,
    handles: Vec<JoinHandle<()>>,
    counters: Arc<Counters>,
}

impl NetWorkerTransport {
    pub fn connect(
        addr: &NetAddr,
        worker: usize,
        plan: &ShardPlan,
        policy: RetryPolicy,
    ) -> Result<NetWorkerTransport> {
        let mut stream = connect_retry(addr, policy)?;

        let mut hello = Vec::new();
        encode_handshake(
            &Frame::Hello {
                protocol: PROTOCOL_VERSION,
                worker: worker as u32,
                shards: plan.shards() as u32,
                k: plan.k as u32,
                d: plan.d as u32,
            },
            &mut hello,
        );
        stream.write_all(&hello).context("send Hello")?;
        stream.flush().context("flush Hello")?;
        match read_handshake_frame(&mut stream)? {
            Frame::HelloAck { protocol, shards, k, d } => {
                if protocol != PROTOCOL_VERSION {
                    bail!(
                        "protocol mismatch: server speaks v{protocol}, worker v{PROTOCOL_VERSION}"
                    );
                }
                let (ps, pk, pd) =
                    (plan.shards() as u32, plan.k as u32, plan.d as u32);
                if (shards, k, d) != (ps, pk, pd) {
                    bail!(
                        "topology mismatch: server (shards={shards}, k={k}, d={d}), worker configured (shards={ps}, k={pk}, d={pd})"
                    );
                }
            }
            other => bail!("expected HelloAck, got {other:?}"),
        }

        let counters = Arc::new(Counters::default());
        let (to_server_tx, to_server_rx) = channel::<ToServer>();
        let (from_server_tx, from_server_rx) = channel::<ToWorker>();
        let read_half = stream.try_clone()?;
        let handles = vec![
            spawn_writer_to_server(
                stream,
                to_server_rx,
                Arc::clone(&counters),
            ),
            spawn_reader_to_worker(
                read_half,
                from_server_tx,
                plan.clone(),
                worker,
                Arc::clone(&counters),
            ),
        ];
        Ok(NetWorkerTransport {
            worker,
            endpoints: Some((to_server_tx, from_server_rx)),
            handles,
            counters,
        })
    }
}

impl Transport for NetWorkerTransport {
    fn name(&self) -> &'static str {
        "socket-worker"
    }

    fn server_endpoints(
        &mut self,
    ) -> Result<(Receiver<ToServer>, Vec<Sender<ToWorker>>)> {
        bail!("socket worker transport has no server endpoints")
    }

    fn worker_endpoints(
        &mut self,
        worker: usize,
    ) -> Result<(Sender<ToServer>, Receiver<ToWorker>)> {
        if worker != self.worker {
            bail!(
                "this node is worker {}, asked for endpoints of worker {worker}",
                self.worker
            );
        }
        self.endpoints.take().context("worker endpoints already taken")
    }

    fn finish(&mut self) -> TransportStats {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.counters.snapshot()
    }
}

fn spawn_writer_to_server(
    stream: Stream,
    rx: Receiver<ToServer>,
    counters: Arc<Counters>,
) -> JoinHandle<()> {
    thread::Builder::new()
        .name("net-write-grad".to_string())
        .spawn(move || {
            let shutdown_handle =
                stream.try_clone().expect("clone for shutdown");
            let mut w = std::io::BufWriter::new(stream);
            let mut buf = Vec::new();
            while let Ok(msg) = rx.recv() {
                buf.clear();
                encode_to_server(&msg, &mut buf);
                if write_all_counted(&mut w, &buf, &counters).is_err() {
                    return;
                }
                loop {
                    match rx.try_recv() {
                        Ok(m) => {
                            buf.clear();
                            encode_to_server(&m, &mut buf);
                            if write_all_counted(&mut w, &buf, &counters)
                                .is_err()
                            {
                                return;
                            }
                        }
                        Err(TryRecvError::Empty) => {
                            let _ = w.flush();
                            break;
                        }
                        Err(TryRecvError::Disconnected) => {
                            // Linger flush: the comm thread is done and
                            // dropped its FaultySender; everything it
                            // queued (including Done) is already drained
                            // by the recv loop above.
                            let _ = w.flush();
                            shutdown_handle.shutdown_write();
                            return;
                        }
                    }
                }
            }
            let _ = w.flush();
            shutdown_handle.shutdown_write();
        })
        .expect("spawn net writer")
}

fn spawn_reader_to_worker(
    mut stream: Stream,
    tx: Sender<ToWorker>,
    plan: ShardPlan,
    worker: usize,
    counters: Arc<Counters>,
) -> JoinHandle<()> {
    thread::Builder::new()
        .name(format!("net-read-param-w{worker}"))
        .spawn(move || {
            let mut body = Vec::new();
            loop {
                match read_frame(&mut stream, &mut body) {
                    Ok(Some(())) => {}
                    Ok(None) => break, // clean EOF: server done
                    Err(e) => {
                        counters
                            .rejected_frames
                            .fetch_add(1, Ordering::Relaxed);
                        eprintln!("[net] server stream broken: {e:#}");
                        break;
                    }
                }
                counters
                    .bytes_received
                    .fetch_add(4 + body.len() as u64, Ordering::Relaxed);
                let msg = match decode_frame(&body) {
                    Ok(Frame::ToWorker(m)) => m,
                    Ok(other) => {
                        counters
                            .rejected_frames
                            .fetch_add(1, Ordering::Relaxed);
                        eprintln!(
                            "[net] server sent unexpected frame {other:?}; closing"
                        );
                        break;
                    }
                    Err(e @ FrameError::Malformed(_)) => {
                        counters
                            .rejected_frames
                            .fetch_add(1, Ordering::Relaxed);
                        eprintln!("[net] server stream corrupt: {e}; closing");
                        break;
                    }
                    Err(e) => {
                        counters
                            .rejected_frames
                            .fetch_add(1, Ordering::Relaxed);
                        eprintln!("[net] server frame: {e}; closing");
                        break;
                    }
                };
                if let Err(e) = validate_to_worker(&plan, &msg) {
                    counters
                        .rejected_frames
                        .fetch_add(1, Ordering::Relaxed);
                    eprintln!("[net] rejected param message: {e}");
                    continue;
                }
                counters.frames_received.fetch_add(1, Ordering::Relaxed);
                if tx.send(msg).is_err() {
                    break; // worker machinery gone
                }
            }
        })
        .expect("spawn net reader")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::messages::SliceEncoding;

    fn loopback() -> NetAddr {
        NetAddr::Tcp("127.0.0.1:0".to_string())
    }

    #[test]
    fn addr_parse_forms() {
        assert_eq!(
            NetAddr::parse("127.0.0.1:4000").unwrap(),
            NetAddr::Tcp("127.0.0.1:4000".to_string())
        );
        assert!(NetAddr::parse("no-port").is_err());
        #[cfg(unix)]
        assert_eq!(
            NetAddr::parse("unix:/tmp/x.sock").unwrap(),
            NetAddr::Unix(std::path::PathBuf::from("/tmp/x.sock"))
        );
    }

    /// A full socket bridge: grads flow worker→server, params flow
    /// back, Done tears everything down, and both sides join cleanly.
    #[test]
    fn bridge_round_trip_over_tcp() {
        let plan = ShardPlan::new(4, 4, 2);
        let server = NetServer::bind(&loopback()).unwrap();
        let addr = server.local_addr().unwrap();

        let wplan = plan.clone();
        let worker = thread::spawn(move || {
            let mut t = NetWorkerTransport::connect(
                &addr,
                0,
                &wplan,
                RetryPolicy::default(),
            )
            .unwrap();
            let (tx, rx) = t.worker_endpoints(0).unwrap();
            tx.send(ToServer::Grad {
                worker: 0,
                shard: 1,
                step: 0,
                grad: SliceEncoding::Dense(vec![1.0; wplan.len(1)]),
                loss: 0.5,
            })
            .unwrap();
            let param = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            match &param {
                ToWorker::Param { shard, version, .. } => {
                    assert_eq!((*shard, *version), (1, 7));
                }
            }
            tx.send(ToServer::Done { worker: 0 }).unwrap();
            drop(tx);
            t.finish()
        });

        let mut t = server.accept_workers(&plan, 1).unwrap();
        let (from_workers, to_workers) = t.server_endpoints().unwrap();
        match from_workers.recv_timeout(Duration::from_secs(5)).unwrap() {
            ToServer::Grad { worker, shard, step, loss, grad } => {
                assert_eq!((worker, shard, step), (0, 1, 0));
                assert_eq!(loss, 0.5);
                assert_eq!(grad.encoded_bytes(), 4 * plan.len(1) as u64);
            }
            other => panic!("expected grad, got {other:?}"),
        }
        to_workers[0]
            .send(ToWorker::Param {
                shard: 1,
                version: 7,
                clock: 7,
                data: SliceEncoding::Dense(vec![2.0; plan.len(1)]),
            })
            .unwrap();
        match from_workers.recv_timeout(Duration::from_secs(5)).unwrap() {
            ToServer::Done { worker } => assert_eq!(worker, 0),
            other => panic!("expected done, got {other:?}"),
        }
        drop(to_workers);
        let wstats = worker.join().unwrap();
        let sstats = t.finish();
        assert_eq!(wstats.frames_sent, 2); // grad + done
        assert_eq!(wstats.frames_received, 1); // param
        assert_eq!(sstats.frames_received, 2);
        assert_eq!(sstats.frames_sent, 1);
        assert_eq!(wstats.rejected_frames, 0);
        assert_eq!(sstats.rejected_frames, 0);
    }

    /// Corrupt shard id in an otherwise well-framed message: the server
    /// bridge must reject it (never forwarding to the fold path) and
    /// keep the connection alive for subsequent good frames.
    #[test]
    fn corrupt_shard_id_is_rejected_not_forwarded() {
        let plan = ShardPlan::new(4, 4, 2);
        let server = NetServer::bind(&loopback()).unwrap();
        let addr = server.local_addr().unwrap();

        let wplan = plan.clone();
        let client = thread::spawn(move || {
            let mut stream =
                connect_retry(&addr, RetryPolicy::default()).unwrap();
            let mut hello = Vec::new();
            encode_handshake(
                &Frame::Hello {
                    protocol: PROTOCOL_VERSION,
                    worker: 0,
                    shards: wplan.shards() as u32,
                    k: wplan.k as u32,
                    d: wplan.d as u32,
                },
                &mut hello,
            );
            stream.write_all(&hello).unwrap();
            read_handshake_frame(&mut stream).unwrap();
            // shard 9 of 2: well-framed, semantically corrupt
            let mut buf = Vec::new();
            encode_to_server(
                &ToServer::Grad {
                    worker: 0,
                    shard: 9,
                    step: 0,
                    grad: SliceEncoding::Dense(vec![0.0; 8]),
                    loss: 0.0,
                },
                &mut buf,
            );
            encode_to_server(&ToServer::Done { worker: 0 }, &mut buf);
            stream.write_all(&buf).unwrap();
            stream.flush().unwrap();
            stream.shutdown_write();
            // drain until server closes
            let mut sink = Vec::new();
            let _ = stream.read_to_end(&mut sink);
        });

        let mut t = server.accept_workers(&plan, 1).unwrap();
        let (from_workers, to_workers) = t.server_endpoints().unwrap();
        // Only Done arrives: the corrupt grad was rejected at the edge.
        match from_workers.recv_timeout(Duration::from_secs(5)).unwrap() {
            ToServer::Done { worker } => assert_eq!(worker, 0),
            other => panic!("corrupt frame leaked through: {other:?}"),
        }
        assert!(from_workers.recv_timeout(Duration::from_millis(200)).is_err());
        drop(to_workers);
        client.join().unwrap();
        let stats = t.finish();
        assert_eq!(stats.rejected_frames, 1);
        assert_eq!(stats.frames_received, 1);
    }

    /// A structurally corrupt stream (garbage length prefix) drops the
    /// connection rather than wedging the reader.
    #[test]
    fn oversized_length_prefix_drops_connection() {
        let plan = ShardPlan::new(4, 4, 1);
        let server = NetServer::bind(&loopback()).unwrap();
        let addr = server.local_addr().unwrap();

        let wplan = plan.clone();
        let client = thread::spawn(move || {
            let mut stream =
                connect_retry(&addr, RetryPolicy::default()).unwrap();
            let mut hello = Vec::new();
            encode_handshake(
                &Frame::Hello {
                    protocol: PROTOCOL_VERSION,
                    worker: 0,
                    shards: 1,
                    k: wplan.k as u32,
                    d: wplan.d as u32,
                },
                &mut hello,
            );
            stream.write_all(&hello).unwrap();
            read_handshake_frame(&mut stream).unwrap();
            stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
            stream.flush().unwrap();
            let mut sink = Vec::new();
            let _ = stream.read_to_end(&mut sink);
        });

        let mut t = server.accept_workers(&plan, 1).unwrap();
        let (from_workers, to_workers) = t.server_endpoints().unwrap();
        // Reader drops the stream; channel reports disconnect.
        assert!(from_workers.recv_timeout(Duration::from_secs(5)).is_err());
        drop(to_workers);
        client.join().unwrap();
        let stats = t.finish();
        assert_eq!(stats.rejected_frames, 1);
    }

    #[test]
    fn topology_mismatch_fails_handshake() {
        let plan = ShardPlan::new(4, 4, 2);
        let server = NetServer::bind(&loopback()).unwrap();
        let addr = server.local_addr().unwrap();
        let wrong = ShardPlan::new(4, 4, 3); // 3 shards vs server's 2
        let client = thread::spawn(move || {
            NetWorkerTransport::connect(
                &addr,
                0,
                &wrong,
                RetryPolicy::default(),
            )
        });
        assert!(server.accept_workers(&plan, 1).is_err());
        // The worker either sees the topology error from HelloAck (if
        // the server's bail happened after the ack — impossible here) or
        // a closed connection; both are Err.
        assert!(client.join().unwrap().is_err());
    }
}
