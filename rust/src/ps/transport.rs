//! Transport between workers and the server.
//!
//! The server and worker machinery speak `std::sync::mpsc` endpoints on
//! every backend; the [`Transport`] trait only decides what those
//! endpoints are wired to. [`MemoryTransport`] connects them directly
//! (the fast/test path — threads in one process, bit-identical to the
//! pre-socket tree), while [`super::net`] bridges them to TCP or Unix
//! sockets for real multi-process runs. Because the endpoints are the
//! same type either way, [`FaultySender`] wraps both unchanged and the
//! `sent + dropped == steps` accounting identity holds on both.
//!
//! The optional fault model (message drops, injected latency) lets
//! tests exercise the protocol under degraded conditions and benches
//! study sensitivity to communication cost.
//!
//! Latency is injected at *delivery* time, not send time: a delayed
//! message parks in a per-sender in-flight queue and is handed to the
//! channel once its deadline passes (on the next [`FaultySender::send`] or
//! [`FaultySender::pump`]). The sender never blocks, so a laggy link to
//! one worker cannot stall the comm thread that serves every other link —
//! with the server sharded, a blocking sleep here would serialize all
//! shards' traffic through one nap.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::messages::{ToServer, ToWorker};
use crate::util::rng::Pcg32;

/// Wire-level counters a [`Transport`] reports on [`Transport::finish`].
/// All zero for the in-memory backend (there is no wire); for the
/// socket backend, bytes include length prefixes and frame headers —
/// deliberately distinct from the payload-exact `encoded_bytes()`
/// telemetry the PS machinery itself reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportStats {
    pub frames_sent: u64,
    pub frames_received: u64,
    pub bytes_sent: u64,
    pub bytes_received: u64,
    /// Frames refused at the edge: structurally corrupt streams or
    /// semantically invalid messages (bad shard id, mis-sized slice).
    pub rejected_frames: u64,
}

/// What connects the PS endpoints. Implementations hand out mpsc
/// channel halves — `Server::spawn` takes the server side, each
/// `Worker::spawn` a worker side — and own whatever machinery moves
/// messages between them.
///
/// Each endpoint set can be taken once; taking a side this node does
/// not host (e.g. server endpoints from a worker-node transport) is an
/// error, not a panic, so a mis-wired deployment fails with context.
pub trait Transport {
    /// Backend name for logs and run telemetry.
    fn name(&self) -> &'static str;

    /// The server's endpoints: the shared worker→server receiver plus
    /// one parameter-broadcast sender per worker.
    fn server_endpoints(
        &mut self,
    ) -> Result<(Receiver<ToServer>, Vec<Sender<ToWorker>>)>;

    /// Worker `w`'s endpoints: its gradient sender and parameter
    /// receiver.
    fn worker_endpoints(
        &mut self,
        worker: usize,
    ) -> Result<(Sender<ToServer>, Receiver<ToWorker>)>;

    /// Tear down after both sides have joined; returns wire telemetry.
    fn finish(&mut self) -> TransportStats;
}

/// The in-memory backend: endpoints are directly-connected channels,
/// exactly the wiring the pre-socket tree hard-coded in
/// `run_distributed`. Hosts both sides in one process.
pub struct MemoryTransport {
    to_server_tx: Option<Sender<ToServer>>,
    to_server_rx: Option<Receiver<ToServer>>,
    to_worker_txs: Option<Vec<Sender<ToWorker>>>,
    to_worker_rxs: Vec<Option<Receiver<ToWorker>>>,
}

impl MemoryTransport {
    pub fn new(workers: usize) -> MemoryTransport {
        let (to_server_tx, to_server_rx) = channel();
        let mut txs = Vec::with_capacity(workers);
        let mut rxs = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(Some(rx));
        }
        MemoryTransport {
            to_server_tx: Some(to_server_tx),
            to_server_rx: Some(to_server_rx),
            to_worker_txs: Some(txs),
            to_worker_rxs: rxs,
        }
    }

    /// Drop the master worker→server sender. Call after every worker
    /// has taken its endpoints: from then on the server sees disconnect
    /// exactly when the last worker's sender drops (the shutdown signal
    /// the comm loop's hung-up fallback relies on).
    pub fn seal(&mut self) {
        self.to_server_tx = None;
    }
}

impl Transport for MemoryTransport {
    fn name(&self) -> &'static str {
        "memory"
    }

    fn server_endpoints(
        &mut self,
    ) -> Result<(Receiver<ToServer>, Vec<Sender<ToWorker>>)> {
        let rx = self
            .to_server_rx
            .take()
            .context("server endpoints already taken")?;
        let txs = self
            .to_worker_txs
            .take()
            .context("server endpoints already taken")?;
        Ok((rx, txs))
    }

    fn worker_endpoints(
        &mut self,
        worker: usize,
    ) -> Result<(Sender<ToServer>, Receiver<ToWorker>)> {
        let tx = self
            .to_server_tx
            .as_ref()
            .context("transport already sealed")?
            .clone();
        let rx = self
            .to_worker_rxs
            .get_mut(worker)
            .with_context(|| format!("no worker {worker} in transport"))?
            .take()
            .with_context(|| {
                format!("worker {worker} endpoints already taken")
            })?;
        Ok((tx, rx))
    }

    fn finish(&mut self) -> TransportStats {
        TransportStats::default()
    }
}

/// Fault/latency injection parameters (all zero = perfect transport).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultSpec {
    /// Probability a *gradient* push is silently dropped. The drop is
    /// decided once per worker step: all shard-slices of the step share
    /// the fate, so a lossy link loses whole updates, never half of one.
    pub drop_grad_prob: f64,
    /// Probability a *parameter* slice broadcast to one worker is
    /// dropped (decided per slice per worker; a stale shard just waits
    /// for the next broadcast).
    pub drop_param_prob: f64,
    /// Latency added to every delivered message (delivery-time).
    pub latency: Duration,
}

impl FaultSpec {
    pub fn perfect() -> FaultSpec {
        FaultSpec::default()
    }

    pub fn is_perfect(&self) -> bool {
        self.drop_grad_prob == 0.0
            && self.drop_param_prob == 0.0
            && self.latency.is_zero()
    }
}

/// Sender wrapper that applies the fault model.
///
/// **Accounting contract** (the telemetry and the benches rely on it):
///
/// * `stats()` counts *logical* sends: a [`FaultySender::send_group`] of
///   S physical slices is one send (or one drop), and control messages
///   sent via [`FaultySender::send_reliable`] are not counted at all —
///   so a worker's `sent + dropped` equals its step count exactly.
/// * `bytes_sent()` counts *encoded payload* bytes of the physical
///   slice messages the transport accepted (post drop-gate): a dropped
///   group contributes zero bytes, and control/`Done` messages are
///   excluded, mirroring `stats()`. Callers pass the payload size with
///   [`FaultySender::send_group_bytes`] / [`FaultySender::send_bytes`]
///   because the payload type is opaque here. Header fields are not
///   bytes — `BENCH_wire.json` ratios therefore compare directly with
///   `BENCH_ps.json`'s per-message payload sizes.
pub struct FaultySender<T> {
    tx: Sender<T>,
    drop_prob: f64,
    latency: Duration,
    rng: Pcg32,
    sent: u64,
    dropped: u64,
    bytes_sent: u64,
    /// Messages in flight: FIFO of (delivery deadline, payload). All
    /// deadlines share the same fixed latency, so the front is always
    /// the earliest.
    inflight: VecDeque<(Instant, T)>,
}

impl<T> FaultySender<T> {
    pub fn new(tx: Sender<T>, drop_prob: f64, latency: Duration,
               seed: u64) -> Self {
        FaultySender {
            tx,
            drop_prob,
            latency,
            rng: Pcg32::with_stream(seed, 0xFA017),
            sent: 0,
            dropped: 0,
            bytes_sent: 0,
            inflight: VecDeque::new(),
        }
    }

    /// Send one message through the fault model. Returns Ok even when
    /// the message is dropped (that's the point); Err only when the peer
    /// hung up.
    pub fn send(&mut self, msg: T) -> Result<(), ()> {
        self.send_group(std::iter::once(msg))
    }

    /// [`FaultySender::send`] with payload-byte accounting: `bytes` is
    /// added to `bytes_sent()` iff the message survives the drop gate.
    pub fn send_bytes(&mut self, msg: T, bytes: u64) -> Result<(), ()> {
        self.send_group_bytes(std::iter::once(msg), bytes)
    }

    /// Send a group of physical messages that share one transport fate:
    /// one drop decision and one `sent`/`dropped` count for the whole
    /// group. Used for the per-shard slices of a single gradient step.
    pub fn send_group<I>(&mut self, msgs: I) -> Result<(), ()>
    where
        I: IntoIterator<Item = T>,
    {
        self.send_group_bytes(msgs, 0)
    }

    /// [`FaultySender::send_group`] with payload-byte accounting:
    /// `payload_bytes` is the summed encoded size of the group's
    /// messages, added to `bytes_sent()` iff the group survives the
    /// drop gate (the byte counter and `stats()` always agree on which
    /// messages exist).
    pub fn send_group_bytes<I>(
        &mut self,
        msgs: I,
        payload_bytes: u64,
    ) -> Result<(), ()>
    where
        I: IntoIterator<Item = T>,
    {
        if self.drop_prob > 0.0 && self.rng.f64() < self.drop_prob {
            self.dropped += 1;
            return self.pump();
        }
        // count only after the transport accepted the group, so a
        // hung-up peer doesn't inflate the sent telemetry
        self.enqueue(msgs)?;
        self.sent += 1;
        self.bytes_sent += payload_bytes;
        self.pump()
    }

    /// Send bypassing the drop model (control messages like `Done` model
    /// a reliable control plane). Still subject to latency, and ordered
    /// after earlier in-flight messages. Not counted in `stats()`.
    pub fn send_reliable(&mut self, msg: T) -> Result<(), ()> {
        self.enqueue(std::iter::once(msg))?;
        self.pump()
    }

    fn enqueue<I>(&mut self, msgs: I) -> Result<(), ()>
    where
        I: IntoIterator<Item = T>,
    {
        if self.latency.is_zero() && self.inflight.is_empty() {
            // fast path: perfect-latency transport never touches the queue
            for m in msgs {
                self.tx.send(m).map_err(|_| ())?;
            }
            return Ok(());
        }
        let due = Instant::now() + self.latency;
        for m in msgs {
            self.inflight.push_back((due, m));
        }
        Ok(())
    }

    /// Deliver every in-flight message whose latency has elapsed. Call
    /// from the owning comm loop each iteration so deliveries happen even
    /// when nothing new is being sent.
    pub fn pump(&mut self) -> Result<(), ()> {
        while !self.inflight.is_empty() {
            let due = self.inflight.front().unwrap().0;
            if due > Instant::now() {
                break;
            }
            let (_, m) = self.inflight.pop_front().unwrap();
            self.tx.send(m).map_err(|_| ())?;
        }
        Ok(())
    }

    /// Wait out remaining latencies and deliver everything still in
    /// flight (shutdown path; delivery order is preserved).
    pub fn flush_blocking(&mut self) {
        while let Some((due, m)) = self.inflight.pop_front() {
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
            if self.tx.send(m).is_err() {
                self.inflight.clear();
                return;
            }
        }
    }

    /// (logical sends, logical drops) — see the type docs.
    pub fn stats(&self) -> (u64, u64) {
        (self.sent, self.dropped)
    }

    /// Encoded payload bytes accepted by the transport (post drop-gate;
    /// control messages excluded) — see the type docs.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }
}

/// One [`drain`] result: the batch plus whether the channel's senders
/// are gone. Disconnect travels *with* the batch it interrupted — the
/// old `Result<Vec<T>, _>` shape could only signal disconnect on an
/// empty read, so a partial batch silently swallowed it and the caller
/// burned one more full timeout before noticing.
#[derive(Debug)]
pub struct Drained<T> {
    pub msgs: Vec<T>,
    /// True once every sender has hung up. Any messages queued before
    /// the last sender dropped are still in `msgs` (mpsc delivers them
    /// first), so process the batch, then react to the flag.
    pub disconnected: bool,
}

/// Drain up to `max` pending messages without blocking; first waits up to
/// `timeout` for one message. The shard update threads' dequeue pattern.
pub fn drain<T>(rx: &Receiver<T>, max: usize, timeout: Duration) -> Drained<T> {
    let mut out = Vec::new();
    match rx.recv_timeout(timeout) {
        Ok(m) => out.push(m),
        Err(RecvTimeoutError::Timeout) => {
            return Drained { msgs: out, disconnected: false }
        }
        Err(RecvTimeoutError::Disconnected) => {
            return Drained { msgs: out, disconnected: true }
        }
    }
    while out.len() < max {
        match rx.try_recv() {
            Ok(m) => out.push(m),
            Err(std::sync::mpsc::TryRecvError::Empty) => break,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                return Drained { msgs: out, disconnected: true }
            }
        }
    }
    Drained { msgs: out, disconnected: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn perfect_sender_delivers_everything() {
        let (tx, rx) = channel();
        let mut s = FaultySender::new(tx, 0.0, Duration::ZERO, 0);
        for i in 0..100 {
            s.send(i).unwrap();
        }
        let got: Vec<i32> = rx.try_iter().collect();
        assert_eq!(got.len(), 100);
        assert_eq!(s.stats(), (100, 0));
    }

    #[test]
    fn lossy_sender_drops_roughly_p() {
        let (tx, rx) = channel();
        let mut s = FaultySender::new(tx, 0.3, Duration::ZERO, 1);
        for i in 0..10_000 {
            s.send(i).unwrap();
        }
        let got = rx.try_iter().count();
        let (sent, dropped) = s.stats();
        assert_eq!(sent as usize, got);
        assert_eq!(sent + dropped, 10_000);
        let rate = dropped as f64 / 10_000.0;
        assert!((rate - 0.3).abs() < 0.03, "rate={rate}");
    }

    #[test]
    fn send_to_hungup_peer_errors() {
        let (tx, rx) = channel::<i32>();
        drop(rx);
        let mut s = FaultySender::new(tx, 0.0, Duration::ZERO, 2);
        assert!(s.send(1).is_err());
    }

    #[test]
    fn group_shares_one_fate() {
        let (tx, rx) = channel();
        let mut s = FaultySender::new(tx, 0.4, Duration::ZERO, 3);
        let groups = 2_000usize;
        for g in 0..groups {
            s.send_group((0..4).map(|i| (g, i))).unwrap();
        }
        let got: Vec<(usize, usize)> = rx.try_iter().collect();
        let (sent, dropped) = s.stats();
        assert_eq!(sent + dropped, groups as u64);
        // delivered count is exactly 4 × logical sends: no partial groups
        assert_eq!(got.len() as u64, 4 * sent);
        for chunk in got.chunks(4) {
            assert!(chunk.iter().all(|&(g, _)| g == chunk[0].0));
            assert_eq!(
                chunk.iter().map(|&(_, i)| i).collect::<Vec<_>>(),
                vec![0, 1, 2, 3]
            );
        }
        assert!(dropped > 0, "fault injection inactive");
    }

    #[test]
    fn latency_does_not_block_sender() {
        let (tx, rx) = channel();
        let lat = Duration::from_millis(300);
        let mut s = FaultySender::new(tx, 0.0, lat, 4);
        let t0 = Instant::now();
        for i in 0..5 {
            s.send(i).unwrap();
        }
        // delivery-time latency: the sends return immediately. A
        // blocking sender would take ≥ 5 × 300 ms; the 4× bound plus
        // the elapsed guard below keep this stable on stalled CI
        // runners while still catching a regression to send-time sleeps.
        assert!(
            t0.elapsed() < lat * 4,
            "sender blocked: {:?}",
            t0.elapsed()
        );
        if t0.elapsed() < lat {
            assert_eq!(rx.try_iter().count(), 0, "delivered early");
        }
        std::thread::sleep(lat + Duration::from_millis(20));
        s.pump().unwrap();
        let got: Vec<i32> = rx.try_iter().collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4], "order preserved");
    }

    #[test]
    fn flush_blocking_delivers_in_flight() {
        let (tx, rx) = channel();
        let mut s =
            FaultySender::new(tx, 0.0, Duration::from_millis(15), 5);
        for i in 0..3 {
            s.send(i).unwrap();
        }
        s.send_reliable(99).unwrap();
        s.flush_blocking();
        let got: Vec<i32> = rx.try_iter().collect();
        assert_eq!(got, vec![0, 1, 2, 99]);
    }

    #[test]
    fn reliable_sends_are_ordered_and_uncounted() {
        let (tx, rx) = channel();
        let mut s = FaultySender::new(tx, 0.0, Duration::ZERO, 6);
        s.send(1).unwrap();
        s.send_reliable(2).unwrap();
        s.send(3).unwrap();
        assert_eq!(rx.try_iter().collect::<Vec<i32>>(), vec![1, 2, 3]);
        assert_eq!(s.stats(), (2, 0), "control messages not counted");
    }

    #[test]
    fn byte_accounting_agrees_with_message_accounting() {
        // The contract the wire telemetry rests on: bytes are counted
        // per *accepted* group (same drop gate as `sent`), and control
        // messages contribute neither messages nor bytes.
        let (tx, rx) = channel();
        let mut s = FaultySender::new(tx, 0.4, Duration::ZERO, 11);
        let group_bytes = 400u64;
        for g in 0..2_000usize {
            s.send_group_bytes((0..4).map(|i| (g, i)), group_bytes)
                .unwrap();
        }
        s.send_reliable((usize::MAX, 0)).unwrap(); // control: uncounted
        let (sent, dropped) = s.stats();
        assert!(dropped > 0, "fault injection inactive");
        assert_eq!(s.bytes_sent(), sent * group_bytes,
                   "bytes must track accepted groups exactly");
        // physical deliveries: 4 slices per accepted group + 1 control
        assert_eq!(rx.try_iter().count() as u64, 4 * sent + 1);
    }

    #[test]
    fn drain_batches_available_messages() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let d = drain(&rx, 4, Duration::from_millis(10));
        assert_eq!(d.msgs, vec![0, 1, 2, 3]);
        assert!(!d.disconnected, "live sender reported as gone");
        let d = drain(&rx, 100, Duration::from_millis(10));
        assert_eq!(d.msgs.len(), 6);
        assert!(!d.disconnected);
    }

    #[test]
    fn drain_times_out_empty() {
        let (_tx, rx) = channel::<i32>();
        let d = drain(&rx, 4, Duration::from_millis(5));
        assert!(d.msgs.is_empty());
        assert!(!d.disconnected, "timeout is not disconnect");
    }

    #[test]
    fn drain_detects_disconnect_when_empty() {
        let (tx, rx) = channel::<i32>();
        drop(tx);
        let d = drain(&rx, 4, Duration::from_millis(5));
        assert!(d.msgs.is_empty());
        assert!(d.disconnected);
    }

    /// The bug this shape fixes: messages queued before the sender
    /// dropped must arrive in the same call that reports the
    /// disconnect, not mask it for another 20 ms timeout round.
    #[test]
    fn drain_surfaces_disconnect_with_partial_batch() {
        let (tx, rx) = channel::<i32>();
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let d = drain(&rx, 10, Duration::from_millis(5));
        assert_eq!(d.msgs, vec![0, 1, 2], "queued messages not lost");
        assert!(
            d.disconnected,
            "disconnect masked by the partial batch (the old Err(_)=>break bug)"
        );
    }

    /// A batch cut short by `max` (channel still has messages) must NOT
    /// claim disconnect even if the sender is already gone — the
    /// remaining messages still need draining first; the next call
    /// reports it.
    #[test]
    fn drain_full_batch_defers_disconnect_to_next_call() {
        let (tx, rx) = channel::<i32>();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let d = drain(&rx, 3, Duration::from_millis(5));
        assert_eq!(d.msgs, vec![0, 1, 2]);
        assert!(!d.disconnected, "max-limited batch must not skip messages");
        let d = drain(&rx, 3, Duration::from_millis(5));
        assert_eq!(d.msgs, vec![3, 4]);
        assert!(d.disconnected);
    }

    #[test]
    fn memory_transport_wires_both_sides() {
        let mut t = MemoryTransport::new(2);
        assert_eq!(t.name(), "memory");
        let (from_workers, to_workers) = t.server_endpoints().unwrap();
        assert!(t.server_endpoints().is_err(), "server side taken twice");
        let (tx0, rx0) = t.worker_endpoints(0).unwrap();
        let (tx1, _rx1) = t.worker_endpoints(1).unwrap();
        assert!(t.worker_endpoints(1).is_err(), "worker side taken twice");
        assert!(t.worker_endpoints(9).is_err(), "out-of-range worker");
        t.seal();
        assert!(t.worker_endpoints(0).is_err(), "sealed transport");

        tx0.send(ToServer::Done { worker: 0 }).unwrap();
        tx1.send(ToServer::Done { worker: 1 }).unwrap();
        let mut seen: Vec<usize> = (0..2)
            .map(|_| match from_workers.recv().unwrap() {
                ToServer::Done { worker } => worker,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1]);

        to_workers[0]
            .send(ToWorker::Param {
                shard: 0,
                version: 1,
                clock: 1,
                data: super::super::messages::SliceEncoding::Dense(vec![0.0]),
            })
            .unwrap();
        assert!(rx0.recv().is_ok());
        // after seal + all worker senders dropped, server sees disconnect
        drop(tx0);
        drop(tx1);
        let d = drain(&from_workers, 4, Duration::from_millis(5));
        assert!(d.disconnected);
        assert_eq!(t.finish(), TransportStats::default());
    }
}
