//! In-process transport between workers and the server.
//!
//! On the paper's cluster this is the network; here it is `std::sync::mpsc`
//! channels wrapped with an optional fault model (message drops, injected
//! latency) so tests can exercise the protocol under degraded conditions
//! and benches can study sensitivity to communication cost.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use crate::util::rng::Pcg32;

/// Fault/latency injection parameters (all zero = perfect transport).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultSpec {
    /// Probability a *gradient* message is silently dropped.
    pub drop_grad_prob: f64,
    /// Probability a *parameter* broadcast to one worker is dropped.
    pub drop_param_prob: f64,
    /// Fixed latency added to every delivered message.
    pub latency: Duration,
}

impl FaultSpec {
    pub fn perfect() -> FaultSpec {
        FaultSpec::default()
    }

    pub fn is_perfect(&self) -> bool {
        self.drop_grad_prob == 0.0
            && self.drop_param_prob == 0.0
            && self.latency.is_zero()
    }
}

/// Sender wrapper that applies the fault model.
pub struct FaultySender<T> {
    tx: Sender<T>,
    drop_prob: f64,
    latency: Duration,
    rng: Pcg32,
    sent: u64,
    dropped: u64,
}

impl<T> FaultySender<T> {
    pub fn new(tx: Sender<T>, drop_prob: f64, latency: Duration,
               seed: u64) -> Self {
        FaultySender {
            tx,
            drop_prob,
            latency,
            rng: Pcg32::with_stream(seed, 0xFA017),
            sent: 0,
            dropped: 0,
        }
    }

    /// Send through the fault model. Returns Ok even when the message is
    /// dropped (that's the point); Err only when the peer hung up.
    pub fn send(&mut self, msg: T) -> Result<(), ()> {
        if self.drop_prob > 0.0 && self.rng.f64() < self.drop_prob {
            self.dropped += 1;
            return Ok(());
        }
        if !self.latency.is_zero() {
            // Injected latency models serialization + wire time. The
            // sender blocks, which matches a synchronous send over a
            // socket with a small kernel buffer.
            std::thread::sleep(self.latency);
        }
        self.sent += 1;
        self.tx.send(msg).map_err(|_| ())
    }

    /// Send bypassing the fault model (control messages like `Done`
    /// model a reliable control plane).
    pub fn send_reliable(&mut self, msg: T) -> Result<(), ()> {
        self.sent += 1;
        self.tx.send(msg).map_err(|_| ())
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.sent, self.dropped)
    }
}

/// Drain up to `max` pending messages without blocking; first waits up to
/// `timeout` for one message. The server comm thread's dequeue pattern.
pub fn drain<T>(
    rx: &Receiver<T>,
    max: usize,
    timeout: Duration,
) -> Result<Vec<T>, RecvTimeoutError> {
    let mut out = Vec::new();
    match rx.recv_timeout(timeout) {
        Ok(m) => out.push(m),
        Err(RecvTimeoutError::Timeout) => return Ok(out),
        Err(e) => return Err(e),
    }
    while out.len() < max {
        match rx.try_recv() {
            Ok(m) => out.push(m),
            Err(_) => break,
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn perfect_sender_delivers_everything() {
        let (tx, rx) = channel();
        let mut s = FaultySender::new(tx, 0.0, Duration::ZERO, 0);
        for i in 0..100 {
            s.send(i).unwrap();
        }
        let got: Vec<i32> = rx.try_iter().collect();
        assert_eq!(got.len(), 100);
        assert_eq!(s.stats(), (100, 0));
    }

    #[test]
    fn lossy_sender_drops_roughly_p() {
        let (tx, rx) = channel();
        let mut s = FaultySender::new(tx, 0.3, Duration::ZERO, 1);
        for i in 0..10_000 {
            s.send(i).unwrap();
        }
        let got = rx.try_iter().count();
        let (sent, dropped) = s.stats();
        assert_eq!(sent as usize, got);
        assert_eq!(sent + dropped, 10_000);
        let rate = dropped as f64 / 10_000.0;
        assert!((rate - 0.3).abs() < 0.03, "rate={rate}");
    }

    #[test]
    fn send_to_hungup_peer_errors() {
        let (tx, rx) = channel::<i32>();
        drop(rx);
        let mut s = FaultySender::new(tx, 0.0, Duration::ZERO, 2);
        assert!(s.send(1).is_err());
    }

    #[test]
    fn drain_batches_available_messages() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let batch = drain(&rx, 4, Duration::from_millis(10)).unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        let batch = drain(&rx, 100, Duration::from_millis(10)).unwrap();
        assert_eq!(batch.len(), 6);
    }

    #[test]
    fn drain_times_out_empty() {
        let (_tx, rx) = channel::<i32>();
        let batch = drain(&rx, 4, Duration::from_millis(5)).unwrap();
        assert!(batch.is_empty());
    }

    #[test]
    fn drain_detects_disconnect() {
        let (tx, rx) = channel::<i32>();
        drop(tx);
        assert!(drain(&rx, 4, Duration::from_millis(5)).is_err());
    }
}
