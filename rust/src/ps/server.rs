//! The sharded parameter server (paper §4.2 server side, partitioned).
//!
//! The paper's server is two threads and two queues: a communication
//! thread feeding an inbound queue and draining an outbound queue, and an
//! update thread folding gradients into the global L. Here the parameter
//! space itself is partitioned: L's rows are split into S shards
//! ([`super::ShardPlan`]), and each shard gets its *own* update thread,
//! inbound queue, and learning-rate clock, so gradient folds for
//! different row ranges run in parallel and every message carries only a
//! shard's row-slice. With S = 1 this is exactly the paper's single
//! server.
//!
//! Threads:
//!
//! * **communication thread** (one) — routes gradient slices from workers
//!   to the owning shard's inbound queue, fans `Done` out to every shard,
//!   and broadcasts fresh parameter slices (freshest version per shard
//!   wins) to all workers through the fault model.
//! * **shard update threads** (S) — each drains its inbound queue in
//!   batches, applies `slice ← slice − lr(applied_s)·g_s`, tracks its own
//!   per-worker counts and SSP clock, and emits versioned `Param` slices.
//! * **probe thread** (one) — reassembles a full L from the slice
//!   snapshots the shards publish and records the objective curve at the
//!   configured cadence; keeps objective evaluation off every hot path.
//!
//! All coordination is through channels — no locks between threads,
//! matching the paper's "best-effort, coordinated indirectly by the
//! message queues" design (§4.2).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::time::Duration;

use super::checkpoint::{Checkpoint, CheckpointSpec, CkptMsg, ShardSnapshot};
use super::compress::{decode_into, encode_param};
use super::messages::{ShardPlan, ToServer, ToWorker};
use super::transport::{drain, FaultSpec, FaultySender};
use crate::config::{CheckpointConfig, CompressionConfig};
use crate::dml::LrSchedule;
use crate::linalg::Mat;
use crate::metrics::{Curve, Stopwatch};

/// A probe called periodically with the reassembled L to record the
/// global objective (must be `Send`; engines are created inside the
/// probe thread).
pub type ProbeFn = Box<dyn FnMut(&Mat, u64, f64, &mut Curve) + Send>;

pub struct ServerConfig {
    pub workers: usize,
    /// Max gradient messages folded per shard per dequeue round.
    pub server_batch: usize,
    pub lr: LrSchedule,
    /// Server-side lr multiplier. With P workers pushing independent
    /// gradient streams, 1/P makes the global step size invariant to P
    /// (gradient averaging) — without it ASP's effective lr grows with
    /// the worker count and diverges once staleness is non-trivial.
    pub lr_scale: f32,
    /// Record a curve point every `probe_every` applied (logical)
    /// updates.
    pub probe_every: u64,
    pub faults: FaultSpec,
    pub seed: u64,
    /// Wire compression: shards decode gradient slices before folding
    /// (any mode decodes — the wire format is self-describing) and
    /// encode parameter broadcasts per this mode.
    pub compression: CompressionConfig,
    /// Optional run-event sink: shard update threads report every
    /// parameter broadcast round through it (`None` = no reporting,
    /// byte-identical to the historical protocol).
    pub events: Option<Arc<dyn crate::session::EventSink>>,
    /// Periodic sharded checkpointing: shard threads snapshot through a
    /// dedicated writer thread at this cadence (None = off, zero work on
    /// the update path).
    pub checkpoint: Option<CheckpointSpec>,
    /// Re-enter the protocol from a loaded checkpoint: per-shard clocks,
    /// per-worker counts, and telemetry counters resume where the
    /// snapshot left them (the slices in it also overwrite `l0`).
    pub resume: Option<Arc<Checkpoint>>,
}

/// What the server hands back after shutdown.
pub struct ServerResult {
    pub l: Mat,
    pub curve: Curve,
    /// Logical full-gradient updates folded into L: the per-shard slice
    /// applies summed over shards, divided by the shard count. Slices of
    /// one step share one transport fate, so this is exact.
    pub applied_updates: u64,
    /// Raw per-shard slice applications summed over shards
    /// (= `applied_updates × shards`).
    pub slice_updates: u64,
    /// Broadcast rounds summed over shards. The comm thread collapses
    /// queued rounds to the freshest slice per shard before sending, so
    /// this is an upper bound on wire traffic — see `param_msgs`.
    pub broadcasts: u64,
    /// Physical parameter slice messages actually shipped to workers
    /// (per worker, per shard, post drop-gate).
    pub param_msgs: u64,
    /// Mean worker-reported minibatch loss over the last window,
    /// averaged across shards.
    pub last_loss: f32,
    /// Encoded payload bytes of the gradient slices the shards folded
    /// (wire size as received, before decoding).
    pub grad_bytes_received: u64,
    /// Encoded payload bytes of the parameter slices actually shipped
    /// to workers (post drop-gate; pairs with `param_msgs`).
    pub param_bytes_sent: u64,
    /// Gradient messages naming a shard outside the plan, counted and
    /// skipped by the comm thread's `route()`. Always zero with
    /// well-behaved workers; non-zero means a corrupt or mis-built
    /// message got past the transport edge, and the per-worker
    /// accounting identity may no longer balance against folds.
    pub misroutes: u64,
}

/// What one shard's update thread hands back.
struct ShardOutcome {
    slice: Vec<f32>,
    applied: u64,
    broadcasts: u64,
    grad_bytes: u64,
    last_loss: f32,
    saw_loss: bool,
}

/// Slice snapshots flowing from shard update threads to the probe thread.
enum ProbeMsg {
    Snapshot { shard: usize, applied: u64, data: Vec<f32> },
    ShardDone { shard: usize },
}

/// Handle to the running server threads.
pub struct Server {
    shard_handles: Vec<std::thread::JoinHandle<ShardOutcome>>,
    probe_handle: std::thread::JoinHandle<Curve>,
    /// Returns (param slice messages shipped, encoded param bytes,
    /// misrouted gradient messages).
    comm_handle: std::thread::JoinHandle<(u64, u64, u64)>,
    /// Checkpoint writer (when checkpointing is on); returns the last
    /// generation written.
    ckpt_handle: Option<std::thread::JoinHandle<u64>>,
    plan: ShardPlan,
}

impl Server {
    /// Spawn the server threads. `from_workers` is the shared
    /// worker→server channel; `to_workers[w]` sends parameter slices to
    /// worker w.
    pub fn spawn(
        cfg: ServerConfig,
        plan: ShardPlan,
        mut l0: Mat,
        from_workers: Receiver<ToServer>,
        to_workers: Vec<Sender<ToWorker>>,
        mut probe: ProbeFn,
    ) -> Server {
        let shard_count = plan.shards();
        let workers = cfg.workers;
        let server_batch = cfg.server_batch.max(1);
        let probe_every = cfg.probe_every.max(1);
        let shards_done = Arc::new(AtomicUsize::new(0));

        // Resuming: the checkpointed slices are the parameters, whatever
        // the caller passed as l0 (they normally match — callers build
        // l0 from the same checkpoint — but the snapshot is the truth).
        if let Some(c) = &cfg.resume {
            for s in 0..shard_count {
                plan.slice_mut(&mut l0.data, s)
                    .copy_from_slice(&c.shards[s].data);
            }
        }

        // Checkpoint writer thread: same off-hot-path shape as the probe
        // thread — bounded channel, best-effort snapshots, a dedicated
        // thread doing the disk work.
        let (ckpt_tx, ckpt_handle) = match cfg.checkpoint.clone() {
            Some(spec) => {
                let (tx, rx) =
                    sync_channel::<CkptMsg>(4 * shard_count + 8);
                let wplan = plan.clone();
                // resumed runs number new generations after the one
                // they loaded, so a restart never rewrites history
                let start_gen =
                    cfg.resume.as_ref().map_or(0, |c| c.gen);
                let handle = std::thread::Builder::new()
                    .name("ps-server-ckpt".into())
                    .spawn(move || {
                        super::checkpoint::run_writer(
                            spec, wplan, workers, start_gen, rx,
                        )
                    })
                    .expect("spawn checkpoint writer thread");
                (Some(tx), Some(handle))
            }
            None => (None, None),
        };
        let cadence = cfg
            .checkpoint
            .as_ref()
            .map(|s| s.cadence)
            .unwrap_or_default();

        // Queues: one inbound per shard, one shared outbound, one probe.
        let mut inbound_txs = Vec::with_capacity(shard_count);
        let mut inbound_rxs = Vec::with_capacity(shard_count);
        for _ in 0..shard_count {
            let (tx, rx) = channel::<ToServer>();
            inbound_txs.push(tx);
            inbound_rxs.push(rx);
        }
        let (outbound_tx, outbound_rx) = channel::<ToWorker>();
        // Bounded: periodic snapshots are best-effort telemetry and are
        // dropped (try_send) when the probe lags, so a slow objective
        // evaluation can never balloon memory with queued slices.
        let (probe_tx, probe_rx) =
            sync_channel::<ProbeMsg>(4 * shard_count + 8);

        // ---------------------- shard update threads ----------------------
        let mut shard_handles = Vec::with_capacity(shard_count);
        for (s, inbound_rx) in inbound_rxs.into_iter().enumerate() {
            let slice0 = plan.slice(&l0.data, s).to_vec();
            let outbound_tx = outbound_tx.clone();
            let probe_tx = probe_tx.clone();
            let ckpt_tx = ckpt_tx.clone();
            let shards_done = shards_done.clone();
            let lr = cfg.lr;
            let lr_scale = cfg.lr_scale;
            let compression = cfg.compression;
            let seed = cfg.seed;
            let events = cfg.events.clone();
            let init = cfg.resume.as_ref().map(|c| c.shards[s].clone());
            let handle = std::thread::Builder::new()
                .name(format!("ps-server-shard{s}"))
                .spawn(move || {
                    let outcome = run_shard(
                        s,
                        slice0,
                        workers,
                        server_batch,
                        lr,
                        lr_scale,
                        probe_every,
                        compression,
                        seed,
                        events,
                        init,
                        ckpt_tx.map(|tx| (tx, cadence)),
                        &inbound_rx,
                        &outbound_tx,
                        &probe_tx,
                    );
                    shards_done.fetch_add(1, Ordering::SeqCst);
                    outcome
                })
                .expect("spawn shard update thread");
            shard_handles.push(handle);
        }
        drop(outbound_tx); // comm sees disconnect once all shards exit
        drop(probe_tx); // probe sees disconnect once all shards exit
        drop(ckpt_tx); // writer sees disconnect once all shards exit

        // -------------------------- probe thread --------------------------
        let probe_plan = plan.clone();
        let probe_handle = std::thread::Builder::new()
            .name("ps-server-probe".into())
            .spawn(move || {
                let mut l = l0;
                let mut curve = Curve::new("server");
                let shard_count = probe_plan.shards() as u64;
                let mut applied = vec![0u64; probe_plan.shards()];
                let mut done = vec![false; probe_plan.shards()];
                let mut next_probe = probe_every;
                let watch = Stopwatch::start();
                // initial probe (t=0 point on every convergence curve)
                probe(&l, 0, 0.0, &mut curve);
                loop {
                    match probe_rx.recv() {
                        Ok(ProbeMsg::Snapshot { shard, applied: a, data }) => {
                            probe_plan
                                .slice_mut(&mut l.data, shard)
                                .copy_from_slice(&data);
                            applied[shard] = applied[shard].max(a);
                            let logical =
                                applied.iter().sum::<u64>() / shard_count;
                            if logical >= next_probe {
                                probe(
                                    &l,
                                    logical,
                                    watch.elapsed_s(),
                                    &mut curve,
                                );
                                next_probe = (logical / probe_every + 1)
                                    * probe_every;
                            }
                        }
                        Ok(ProbeMsg::ShardDone { shard }) => {
                            done[shard] = true;
                            if done.iter().all(|&f| f) {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                }
                // final probe on the fully assembled final L
                let logical = applied.iter().sum::<u64>() / shard_count;
                probe(&l, logical, watch.elapsed_s(), &mut curve);
                curve
            })
            .expect("spawn server probe thread");

        // ----------------------- communication thread ---------------------
        let comm_done = shards_done;
        let faults = cfg.faults;
        let seed = cfg.seed;
        let comm_handle = std::thread::Builder::new()
            .name("ps-server-comm".into())
            .spawn(move || -> (u64, u64, u64) {
                let mut senders: Vec<FaultySender<ToWorker>> = to_workers
                    .into_iter()
                    .enumerate()
                    .map(|(w, tx)| {
                        FaultySender::new(
                            tx,
                            faults.drop_param_prob,
                            faults.latency,
                            // `<<` binds tighter than `^`, so these
                            // parens are what the expression always
                            // computed — written out for clippy's
                            // `precedence` lint.
                            seed ^ ((w as u64) << 8),
                        )
                    })
                    .collect();
                let mut misroutes = 0u64;
                // reused across iterations: freshest pending Param per
                // shard (no steady-state allocation in the poll loop)
                let mut latest: Vec<Option<ToWorker>> =
                    (0..inbound_txs.len()).map(|_| None).collect();
                loop {
                    // inbound direction: workers → shard update threads.
                    // Move a bounded batch per iteration so slice traffic
                    // (S messages per step) doesn't starve the outbound
                    // direction.
                    match from_workers.recv_timeout(Duration::from_millis(1))
                    {
                        Ok(msg) => {
                            route(&inbound_txs, msg, &mut misroutes);
                            for _ in 0..256 {
                                match from_workers.try_recv() {
                                    Ok(m) => route(
                                        &inbound_txs,
                                        m,
                                        &mut misroutes,
                                    ),
                                    Err(_) => break,
                                }
                            }
                        }
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                        Err(_) => break, // all workers hung up
                    }
                    // outbound direction: shard update threads → workers.
                    broadcast_freshest(
                        &outbound_rx,
                        &mut latest,
                        &mut senders,
                    );
                    // deliver any latency-delayed messages that came due
                    for snd in senders.iter_mut() {
                        let _ = snd.pump();
                    }
                    if comm_done.load(Ordering::SeqCst)
                        == inbound_txs.len()
                    {
                        // all shards exited: flush remaining control
                        // messages, ship final Param slices queued since
                        // this iteration's drain, flush in-flight, leave
                        while let Ok(msg) = from_workers.try_recv() {
                            route(&inbound_txs, msg, &mut misroutes);
                        }
                        broadcast_freshest(
                            &outbound_rx,
                            &mut latest,
                            &mut senders,
                        );
                        for snd in senders.iter_mut() {
                            snd.flush_blocking();
                        }
                        break;
                    }
                }
                // physical param messages + encoded bytes shipped (post
                // drop-gate), summed over workers — the benches'
                // message/byte-count truth
                (
                    senders.iter().map(|s| s.stats().0).sum(),
                    senders.iter().map(|s| s.bytes_sent()).sum(),
                    misroutes,
                )
            })
            .expect("spawn server comm thread");

        Server {
            shard_handles,
            probe_handle,
            comm_handle,
            ckpt_handle,
            plan,
        }
    }

    /// Join all threads and return the final state.
    pub fn join(self) -> ServerResult {
        let outcomes: Vec<ShardOutcome> = self
            .shard_handles
            .into_iter()
            .map(|h| h.join().expect("server shard panicked"))
            .collect();
        let (param_msgs, param_bytes_sent, misroutes) =
            self.comm_handle.join().expect("server comm panicked");
        // writer drains the final snapshots, so the run-end generation
        // is on disk before join() returns
        if let Some(h) = self.ckpt_handle {
            let _ = h.join();
        }
        let curve = self.probe_handle.join().expect("server probe panicked");

        let mut l = Mat::zeros(self.plan.k, self.plan.d);
        for (s, o) in outcomes.iter().enumerate() {
            self.plan.slice_mut(&mut l.data, s).copy_from_slice(&o.slice);
        }
        let slice_updates: u64 = outcomes.iter().map(|o| o.applied).sum();
        let applied_updates = slice_updates / self.plan.shards() as u64;
        let broadcasts: u64 = outcomes.iter().map(|o| o.broadcasts).sum();
        let grad_bytes_received: u64 =
            outcomes.iter().map(|o| o.grad_bytes).sum();
        let (mut acc, mut n) = (0.0f64, 0u32);
        for o in &outcomes {
            if o.saw_loss {
                acc += o.last_loss as f64;
                n += 1;
            }
        }
        let last_loss = if n > 0 { (acc / n as f64) as f32 } else { 0.0 };
        ServerResult {
            l,
            curve,
            applied_updates,
            slice_updates,
            broadcasts,
            param_msgs,
            last_loss,
            grad_bytes_received,
            param_bytes_sent,
            misroutes,
        }
    }
}

/// Drain the shards' outbound queue, collapse to the freshest parameter
/// slice per shard (versions supersede), and broadcast those slices to
/// every worker through the fault model. `latest` is the caller's reused
/// scratch (left all-`None` on return).
fn broadcast_freshest(
    outbound_rx: &Receiver<ToWorker>,
    latest: &mut [Option<ToWorker>],
    senders: &mut [FaultySender<ToWorker>],
) {
    let mut any = false;
    while let Ok(p) = outbound_rx.try_recv() {
        let s = match &p {
            ToWorker::Param { shard, .. } => *shard,
        };
        latest[s] = Some(p);
        any = true;
    }
    if !any {
        return;
    }
    for slot in latest.iter_mut() {
        if let Some(ToWorker::Param { shard, version, clock, data }) =
            slot.take()
        {
            let bytes = data.encoded_bytes();
            for snd in senders.iter_mut() {
                let _ = snd.send_bytes(
                    ToWorker::Param {
                        shard,
                        version,
                        clock,
                        data: data.clone(),
                    },
                    bytes,
                );
            }
        }
    }
}

/// How many misroutes are logged individually before the log throttles
/// to every 1024th (a corrupt peer could otherwise flood stderr).
const MISROUTE_LOG_HEAD: u64 = 8;

/// Route one worker message to the owning shard (`Done` fans out to all).
/// Send errors mean the shard already exited, which only happens after it
/// saw every worker finish — safe to ignore.
///
/// A `Grad` naming a shard outside the plan is counted in `misroutes`
/// and skipped — never folded, never silently vanished. The socket
/// backend already rejects such frames at decode time, so this firing
/// means either an in-process caller built a bad message or a corrupt
/// one slipped an edge; the count surfaces in `ServerResult::misroutes`
/// so the accounting-identity checks can tell "dropped by fault model"
/// from "lost to misrouting".
fn route(inbound: &[Sender<ToServer>], msg: ToServer, misroutes: &mut u64) {
    let target = match &msg {
        ToServer::Grad { shard, .. } => Some(*shard),
        ToServer::Done { .. } => None,
    };
    match target {
        Some(s) if s < inbound.len() => {
            let _ = inbound[s].send(msg);
        }
        Some(s) => {
            *misroutes += 1;
            if *misroutes <= MISROUTE_LOG_HEAD || *misroutes % 1024 == 0 {
                if let ToServer::Grad { worker, step, .. } = &msg {
                    eprintln!(
                        "[ps-server] misroute #{}: grad from worker {worker} step {step} names shard {s} of {}; skipped",
                        *misroutes,
                        inbound.len()
                    );
                }
            }
        }
        None => {
            if let ToServer::Done { worker } = msg {
                for tx in inbound {
                    let _ = tx.send(ToServer::Done { worker });
                }
            }
        }
    }
}

/// One shard's update loop: decode and fold gradient slices into the
/// owned row range with this shard's own lr clock, maintain per-worker
/// counts and the shard SSP clock, publish versioned (encoded) `Param`
/// slices and (raw f32) probe snapshots.
#[allow(clippy::too_many_arguments)]
fn run_shard(
    shard: usize,
    mut slice: Vec<f32>,
    workers: usize,
    server_batch: usize,
    lr: LrSchedule,
    lr_scale: f32,
    probe_every: u64,
    compression: CompressionConfig,
    seed: u64,
    events: Option<Arc<dyn crate::session::EventSink>>,
    init: Option<ShardSnapshot>,
    ckpt: Option<(SyncSender<CkptMsg>, CheckpointConfig)>,
    inbound_rx: &Receiver<ToServer>,
    outbound_tx: &Sender<ToWorker>,
    probe_tx: &SyncSender<ProbeMsg>,
) -> ShardOutcome {
    // Resuming re-enters the protocol exactly where the snapshot left
    // it: the lr clock keeps its schedule position, per-worker counts
    // keep the SSP clock monotone, and the telemetry counters keep the
    // whole-run totals honest across the restart.
    let (
        mut counts,
        mut finished,
        mut applied,
        mut broadcasts,
        mut grad_bytes,
        mut last_loss,
        mut saw_loss,
    ) = match init {
        Some(s) => (
            s.counts,
            s.finished,
            s.applied,
            s.broadcasts,
            s.grad_bytes,
            s.last_loss,
            s.saw_loss,
        ),
        None => (vec![0u64; workers], vec![false; workers], 0, 0, 0, 0.0, false),
    };
    let mut loss_acc = 0.0f64;
    let mut loss_n = 0u64;
    let mut ckpt_last = std::time::Instant::now();
    // reused decode scratch: every wire encoding lands here as dense
    // f32 before folding (the Dense arm is a plain copy, so mode=none
    // folds the exact bits the worker computed)
    let mut dec = vec![0.0f32; slice.len()];
    loop {
        let drained =
            drain(inbound_rx, server_batch, Duration::from_millis(20));
        if drained.msgs.is_empty() {
            // disconnect surfaces immediately now (the old shape hid it
            // behind a partial batch for one extra timeout round)
            if drained.disconnected || finished.iter().all(|&f| f) {
                break;
            }
            continue;
        }
        let mut applied_this_round = false;
        for msg in drained.msgs {
            match msg {
                ToServer::Grad { worker, grad, loss, .. } => {
                    grad_bytes += grad.encoded_bytes();
                    decode_into(&grad, &mut dec);
                    // slice ← slice − lr_t · g_s  (per-shard lr clock)
                    let lr_t = lr.at(applied as usize) * lr_scale;
                    for (a, gv) in slice.iter_mut().zip(&dec) {
                        *a -= lr_t * gv;
                    }
                    applied += 1;
                    counts[worker] += 1;
                    loss_acc += loss as f64;
                    loss_n += 1;
                    applied_this_round = true;
                    if applied % probe_every == 0 {
                        // best-effort: skip the snapshot if the probe
                        // thread is behind (curve just loses a point)
                        let _ = probe_tx.try_send(ProbeMsg::Snapshot {
                            shard,
                            applied,
                            data: slice.clone(),
                        });
                        last_loss =
                            (loss_acc / loss_n.max(1) as f64) as f32;
                        saw_loss = true;
                        loss_acc = 0.0;
                        loss_n = 0;
                    }
                    if let Some((tx, cad)) = &ckpt {
                        let step_due = cad.every_steps > 0
                            && applied % cad.every_steps == 0;
                        let time_due = cad.every_secs > 0.0
                            && ckpt_last.elapsed().as_secs_f64()
                                >= cad.every_secs;
                        if step_due || time_due {
                            // best-effort like the probe: a lagging
                            // writer delays a checkpoint, never a fold
                            let _ = tx.try_send(CkptMsg::Snapshot(
                                ShardSnapshot {
                                    shard,
                                    applied,
                                    counts: counts.clone(),
                                    finished: finished.clone(),
                                    broadcasts,
                                    grad_bytes,
                                    last_loss,
                                    saw_loss,
                                    data: slice.clone(),
                                },
                            ));
                            ckpt_last = std::time::Instant::now();
                        }
                    }
                }
                ToServer::Done { worker } => {
                    finished[worker] = true;
                }
            }
        }
        if applied_this_round {
            // SSP clock: min over unfinished workers' applied counts;
            // finished workers stop holding the clock back.
            let clock = counts
                .iter()
                .zip(&finished)
                .map(|(&c, &f)| if f { u64::MAX } else { c })
                .min()
                .unwrap_or(0);
            let clock = if clock == u64::MAX {
                *counts.iter().max().unwrap_or(&0)
            } else {
                clock
            };
            broadcasts += 1;
            // encoded once per broadcast round, keyed by
            // (shard, version) so reruns are reproducible
            let data = encode_param(
                compression.mode,
                seed,
                shard,
                applied,
                &slice,
            );
            if let Some(sink) = &events {
                sink.on_broadcast(&crate::session::BroadcastEvent {
                    shard,
                    version: applied,
                    clock,
                    encoded_bytes: data.encoded_bytes(),
                });
            }
            let _ = outbound_tx.send(ToWorker::Param {
                shard,
                version: applied,
                clock,
                data,
            });
        }
        // process the batch first, *then* act on a disconnect: any
        // messages the comm thread routed before dying were folded and
        // broadcast above, bit-identical to the pre-fix ordering.
        if drained.disconnected || finished.iter().all(|&f| f) {
            break;
        }
    }
    // fold the tail window into the loss telemetry, then hand the probe
    // thread the final slice so the last curve point sees the final L
    if loss_n > 0 {
        last_loss = (loss_acc / loss_n as f64) as f32;
        saw_loss = true;
    }
    let _ = probe_tx.send(ProbeMsg::Snapshot {
        shard,
        applied,
        data: slice.clone(),
    });
    let _ = probe_tx.send(ProbeMsg::ShardDone { shard });
    // final checkpoint snapshot is blocking (like the probe's): the
    // run-end generation must not be lost to a busy writer
    if let Some((tx, _)) = &ckpt {
        let _ = tx.send(CkptMsg::Snapshot(ShardSnapshot {
            shard,
            applied,
            counts: counts.clone(),
            finished: finished.clone(),
            broadcasts,
            grad_bytes,
            last_loss,
            saw_loss,
            data: slice.clone(),
        }));
        let _ = tx.send(CkptMsg::ShardDone { shard });
    }
    ShardOutcome {
        slice,
        applied,
        broadcasts,
        grad_bytes,
        last_loss,
        saw_loss,
    }
}
