//! The central parameter server (paper §4.2, server side).
//!
//! Two threads, two queues — exactly the paper's design:
//!
//! * **communication thread** — receives gradient messages from workers
//!   and puts them on the *inbound* queue; takes fresh parameters off the
//!   *outbound* queue and broadcasts them to all workers.
//! * **update thread** — takes a batch of gradient updates off the
//!   inbound queue, applies them to the global parameter L, and puts the
//!   updated parameter on the outbound queue.
//!
//! Threads run "best-effort … coordinated indirectly by the message
//! queues" (§4.2) — no shared locks between them, only channels.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use super::messages::{ToServer, ToWorker};
use super::transport::{drain, FaultSpec, FaultySender};
use crate::dml::LrSchedule;
use crate::linalg::Mat;
use crate::metrics::{Curve, Stopwatch};

/// A probe the update thread calls periodically to record the global
/// objective (must be `Send`; engines are created inside the thread).
pub type ProbeFn = Box<dyn FnMut(&Mat, u64, f64, &mut Curve) + Send>;

pub struct ServerConfig {
    pub workers: usize,
    /// Max gradient messages folded per update-thread dequeue round.
    pub server_batch: usize,
    pub lr: LrSchedule,
    /// Server-side lr multiplier. With P workers pushing independent
    /// gradient streams, 1/P makes the global step size invariant to P
    /// (gradient averaging) — without it ASP's effective lr grows with
    /// the worker count and diverges once staleness is non-trivial.
    pub lr_scale: f32,
    /// Record a curve point every `probe_every` applied updates.
    pub probe_every: u64,
    pub faults: FaultSpec,
    pub seed: u64,
}

/// What the server hands back after shutdown.
pub struct ServerResult {
    pub l: Mat,
    pub curve: Curve,
    pub applied_updates: u64,
    pub broadcasts: u64,
    /// Mean worker-reported minibatch loss over the last probe window.
    pub last_loss: f32,
}

/// Handle to the running server threads.
pub struct Server {
    update_handle: std::thread::JoinHandle<ServerResult>,
    comm_handle: std::thread::JoinHandle<()>,
}

impl Server {
    /// Spawn the server. `from_workers` is the shared worker→server
    /// channel; `to_workers[w]` sends parameters to worker w.
    pub fn spawn(
        cfg: ServerConfig,
        l0: Mat,
        from_workers: Receiver<ToServer>,
        to_workers: Vec<Sender<ToWorker>>,
        mut probe: ProbeFn,
    ) -> Server {
        // The two §4.2 queues between comm and update threads:
        let (inbound_tx, inbound_rx) = channel::<ToServer>();
        let (outbound_tx, outbound_rx) = channel::<ToWorker>();
        let done = Arc::new(AtomicBool::new(false));

        // ------------------------- update thread -------------------------
        let update_done = done.clone();
        let workers = cfg.workers;
        let server_batch = cfg.server_batch.max(1);
        let lr = cfg.lr;
        let lr_scale = cfg.lr_scale;
        let probe_every = cfg.probe_every.max(1);
        let update_handle = std::thread::Builder::new()
            .name("ps-server-update".into())
            .spawn(move || {
                let mut l = l0;
                let mut curve = Curve::new("server");
                let clock_counts = vec![0u64; workers];
                let mut counts = clock_counts;
                let mut applied = 0u64;
                let mut broadcasts = 0u64;
                let mut finished = vec![false; workers];
                let mut loss_acc = 0.0f64;
                let mut loss_n = 0u64;
                let mut last_loss = 0.0f32;
                let watch = Stopwatch::start();
                // initial probe (t=0 point on every convergence curve)
                probe(&l, 0, 0.0, &mut curve);
                loop {
                    let batch = match drain(
                        &inbound_rx,
                        server_batch,
                        Duration::from_millis(20),
                    ) {
                        Ok(b) => b,
                        Err(_) => break, // comm thread gone
                    };
                    if batch.is_empty() {
                        if finished.iter().all(|&f| f) {
                            break;
                        }
                        continue;
                    }
                    let mut applied_this_round = false;
                    for msg in batch {
                        match msg {
                            ToServer::Grad { worker, grad, loss, .. } => {
                                // L ← L − lr_t · ΔL_p  (server-side SGD)
                                let lr_t =
                                    lr.at(applied as usize) * lr_scale;
                                for (a, gv) in
                                    l.data.iter_mut().zip(&grad)
                                {
                                    *a -= lr_t * gv;
                                }
                                applied += 1;
                                counts[worker] += 1;
                                loss_acc += loss as f64;
                                loss_n += 1;
                                applied_this_round = true;
                                if applied % probe_every == 0 {
                                    probe(
                                        &l,
                                        applied,
                                        watch.elapsed_s(),
                                        &mut curve,
                                    );
                                    last_loss = (loss_acc
                                        / loss_n.max(1) as f64)
                                        as f32;
                                    loss_acc = 0.0;
                                    loss_n = 0;
                                }
                            }
                            ToServer::Done { worker } => {
                                finished[worker] = true;
                            }
                        }
                    }
                    if applied_this_round {
                        let clock = counts
                            .iter()
                            .zip(&finished)
                            .map(|(&c, &f)| if f { u64::MAX } else { c })
                            .min()
                            .unwrap_or(0);
                        let clock = if clock == u64::MAX {
                            *counts.iter().max().unwrap_or(&0)
                        } else {
                            clock
                        };
                        broadcasts += 1;
                        // put fresh parameters on the outbound queue
                        let _ = outbound_tx.send(ToWorker::Param {
                            version: applied,
                            clock,
                            data: l.data.clone(),
                        });
                    }
                    if finished.iter().all(|&f| f) {
                        break;
                    }
                }
                // final probe
                probe(&l, applied, watch.elapsed_s(), &mut curve);
                update_done.store(true, Ordering::SeqCst);
                ServerResult {
                    l,
                    curve,
                    applied_updates: applied,
                    broadcasts,
                    last_loss,
                }
            })
            .expect("spawn server update thread");

        // ----------------------- communication thread --------------------
        let comm_done = done;
        let faults = cfg.faults;
        let seed = cfg.seed;
        let comm_handle = std::thread::Builder::new()
            .name("ps-server-comm".into())
            .spawn(move || {
                let mut senders: Vec<FaultySender<ToWorker>> = to_workers
                    .into_iter()
                    .enumerate()
                    .map(|(w, tx)| {
                        FaultySender::new(
                            tx,
                            faults.drop_param_prob,
                            faults.latency,
                            seed ^ (w as u64) << 8,
                        )
                    })
                    .collect();
                loop {
                    // inbound direction: workers → update thread
                    match from_workers.recv_timeout(Duration::from_millis(5))
                    {
                        Ok(msg) => {
                            if inbound_tx.send(msg).is_err() {
                                break; // update thread exited
                            }
                        }
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                        Err(_) => break, // all workers hung up
                    }
                    // outbound direction: update thread → workers.
                    // Collapse to the freshest parameter if several are
                    // queued (later params supersede earlier ones).
                    let mut latest: Option<ToWorker> = None;
                    while let Ok(p) = outbound_rx.try_recv() {
                        latest = Some(p);
                    }
                    if let Some(ToWorker::Param { version, clock, data }) =
                        latest
                    {
                        for s in senders.iter_mut() {
                            let _ = s.send(ToWorker::Param {
                                version,
                                clock,
                                data: data.clone(),
                            });
                        }
                    }
                    if comm_done.load(Ordering::SeqCst) {
                        // flush any remaining inbound Done messages
                        while let Ok(msg) = from_workers.try_recv() {
                            let _ = inbound_tx.send(msg);
                        }
                        break;
                    }
                }
            })
            .expect("spawn server comm thread");

        Server { update_handle, comm_handle }
    }

    /// Join both threads and return the final state.
    pub fn join(self) -> ServerResult {
        let result = self.update_handle.join().expect("server update panicked");
        self.comm_handle.join().expect("server comm panicked");
        result
    }
}
