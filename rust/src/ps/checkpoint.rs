//! `DMLPSCKPT`: periodic sharded checkpoints of parameter-server state,
//! and restart-from-checkpoint — the elasticity layer.
//!
//! The paper's 15-hour, 256-core runs only make sense if a run survives
//! losing a process. Each server shard periodically snapshots its
//! parameter slice *plus* the protocol state needed to re-enter the run
//! (lr clock, per-worker applied counts, finished flags, telemetry
//! counters); a dedicated writer thread — the same off-hot-path pattern
//! as the probe thread — assembles per-shard snapshots into numbered
//! *generations* on disk:
//!
//! ```text
//! <ckpt-dir>/
//!   MANIFEST.json            { version, latest_gen, shards, workers, k, d }
//!   gen00000003/shard0.ckpt  versioned DMLPSCKPT codec (below)
//!   gen00000003/shard1.ckpt
//! ```
//!
//! Every file is written crash-atomically
//! ([`crate::linalg::io::atomic_write`]: temp in target dir + fsync +
//! rename), and `MANIFEST.json` is only updated *after* every shard file
//! of a generation is durable — so "newest consistent checkpoint" is
//! simply whatever the manifest names, no matter when the process died.
//!
//! Per-shard file layout (all little-endian):
//!
//! ```text
//! 9 B  magic    b"DMLPSCKPT"
//! 4 B  u32      codec version (currently 1)
//! 8 B  u64      shard index
//! 8 B  u64      shard count
//! 8 B  u64      k (rows of L)
//! 8 B  u64      d (cols of L)
//! 8 B  u64      worker count
//! 8 B  u64      applied (this shard's lr clock: slice updates folded)
//! 8 B  u64      broadcasts
//! 8 B  u64      grad_bytes (encoded gradient payload bytes folded)
//! 4 B  f32      last_loss
//! 1 B  u8       saw_loss
//! 8 B ×workers  per-worker applied-slice counts (SSP clock inputs)
//! 1 B ×workers  per-worker finished flags
//! ...           the shard's row-slice via `linalg::io::write_mat`
//!               (`DMLPSMAT` framing, shard_rows × d)
//! ```
//!
//! On the restore side, [`load_latest`] returns the newest consistent
//! [`Checkpoint`]; the server re-enters the protocol at each shard's
//! recorded clock, and worker `w` resumes at step
//! `min over shards of counts[s][w]` — the largest step every shard has
//! fully absorbed. Shards ahead of that step simply re-fold the few
//! replayed gradients (at-least-once semantics; the counts stay
//! monotone, so SSP clocks and the accounting identity remain intact).
//! Because pair `t` of worker `w` is a pure function of `(seed, w, t)`,
//! re-deriving the pair stream position is plain replay arithmetic.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::mpsc::Receiver;

use super::messages::ShardPlan;
use crate::config::CheckpointConfig;
use crate::linalg::io::{atomic_write, read_mat, write_mat};
use crate::linalg::Mat;
use crate::util::json::Json;

const CKPT_MAGIC: &[u8; 9] = b"DMLPSCKPT";
const CKPT_VERSION: u32 = 1;
/// Sanity caps on header-claimed topology, so a corrupt checkpoint
/// header cannot demand absurd allocations (the slice payload is
/// separately capped by `read_mat`).
const MAX_TOPOLOGY: u64 = 1 << 20;

/// Where and how often the server checkpoints.
#[derive(Clone, Debug)]
pub struct CheckpointSpec {
    /// Run directory the generations and manifest live in.
    pub dir: PathBuf,
    /// Cadence knobs (CLI-flag plumbing; see
    /// [`CheckpointConfig`]'s rationale for staying out of the
    /// experiment JSON).
    pub cadence: CheckpointConfig,
}

/// One shard's complete state at a checkpoint instant.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardSnapshot {
    pub shard: usize,
    /// This shard's lr clock: slice updates folded so far.
    pub applied: u64,
    /// Per-worker applied-slice counts (the SSP clock inputs).
    pub counts: Vec<u64>,
    /// Per-worker finished flags (`Done` seen).
    pub finished: Vec<bool>,
    pub broadcasts: u64,
    pub grad_bytes: u64,
    pub last_loss: f32,
    pub saw_loss: bool,
    /// Raw f32 row-slice of L this shard owns (`plan.len(shard)`).
    pub data: Vec<f32>,
}

impl ShardSnapshot {
    /// This shard's SSP clock at the snapshot: min over unfinished
    /// workers' counts (the same formula the update loop broadcasts).
    pub fn clock(&self) -> u64 {
        let clock = self
            .counts
            .iter()
            .zip(&self.finished)
            .map(|(&c, &f)| if f { u64::MAX } else { c })
            .min()
            .unwrap_or(0);
        if clock == u64::MAX {
            *self.counts.iter().max().unwrap_or(&0)
        } else {
            clock
        }
    }
}

/// Everything a resumed worker needs to re-enter the protocol.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerResume {
    /// First local step to execute (earlier steps are replayed through
    /// the pair stream and discarded — pure `(seed, w, t)` arithmetic).
    pub start_step: u64,
    /// Initial per-shard server clocks, so the SSP gate starts from the
    /// checkpointed clocks instead of waiting for progress the server
    /// already made.
    pub clocks: Vec<u64>,
    /// Initial per-shard parameter versions (freshest-wins splicing).
    pub versions: Vec<u64>,
}

/// A fully loaded consistent checkpoint generation.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub gen: u64,
    pub k: usize,
    pub d: usize,
    pub workers: usize,
    /// One snapshot per shard, in shard order.
    pub shards: Vec<ShardSnapshot>,
}

impl Checkpoint {
    /// Fail loudly if this checkpoint was taken under a different
    /// topology than the run being resumed.
    pub fn validate_for(
        &self,
        plan: &ShardPlan,
        workers: usize,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.k == plan.k
                && self.d == plan.d
                && self.shards.len() == plan.shards(),
            "checkpoint topology {}x{} / {} shards does not match \
             run topology {}x{} / {} shards",
            self.k,
            self.d,
            self.shards.len(),
            plan.k,
            plan.d,
            plan.shards()
        );
        anyhow::ensure!(
            self.workers == workers,
            "checkpoint was taken with {} workers, run has {workers}",
            self.workers
        );
        for (s, snap) in self.shards.iter().enumerate() {
            anyhow::ensure!(
                snap.data.len() == plan.len(s),
                "shard {s} slice has {} elements, plan owns {}",
                snap.data.len(),
                plan.len(s)
            );
        }
        Ok(())
    }

    /// Reassemble the full L from the per-shard slices.
    pub fn l(&self, plan: &ShardPlan) -> Mat {
        let mut l = Mat::zeros(plan.k, plan.d);
        for (s, snap) in self.shards.iter().enumerate() {
            plan.slice_mut(&mut l.data, s).copy_from_slice(&snap.data);
        }
        l
    }

    /// The step worker `w` resumes at: the largest step every shard has
    /// fully absorbed. Shards that counted further simply re-fold the
    /// replayed steps (counts stay monotone).
    pub fn resume_step(&self, w: usize) -> u64 {
        self.shards
            .iter()
            .map(|s| s.counts.get(w).copied().unwrap_or(0))
            .min()
            .unwrap_or(0)
    }

    /// The resume bundle for worker `w`.
    pub fn worker_resume(&self, w: usize) -> WorkerResume {
        WorkerResume {
            start_step: self.resume_step(w),
            clocks: self.shards.iter().map(ShardSnapshot::clock).collect(),
            versions: self.shards.iter().map(|s| s.applied).collect(),
        }
    }
}

// ---------------------------------------------------------------------
// codec
// ---------------------------------------------------------------------

fn put_u64<W: Write>(w: &mut W, v: u64) -> anyhow::Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

/// Write one shard snapshot in the `DMLPSCKPT` framing.
pub fn write_shard<W: Write>(
    w: &mut W,
    plan: &ShardPlan,
    workers: usize,
    snap: &ShardSnapshot,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        snap.counts.len() == workers && snap.finished.len() == workers,
        "snapshot worker vectors sized {}/{}, expected {workers}",
        snap.counts.len(),
        snap.finished.len()
    );
    anyhow::ensure!(
        snap.data.len() == plan.len(snap.shard),
        "snapshot slice has {} elements, shard {} owns {}",
        snap.data.len(),
        snap.shard,
        plan.len(snap.shard)
    );
    w.write_all(CKPT_MAGIC)?;
    w.write_all(&CKPT_VERSION.to_le_bytes())?;
    put_u64(w, snap.shard as u64)?;
    put_u64(w, plan.shards() as u64)?;
    put_u64(w, plan.k as u64)?;
    put_u64(w, plan.d as u64)?;
    put_u64(w, workers as u64)?;
    put_u64(w, snap.applied)?;
    put_u64(w, snap.broadcasts)?;
    put_u64(w, snap.grad_bytes)?;
    w.write_all(&snap.last_loss.to_le_bytes())?;
    w.write_all(&[u8::from(snap.saw_loss)])?;
    for &c in &snap.counts {
        put_u64(w, c)?;
    }
    for &f in &snap.finished {
        w.write_all(&[u8::from(f)])?;
    }
    // the slice payload rides the DMLPSMAT codec — one matrix format
    // across the whole crate, sharing read_mat's corrupt-header caps
    let m = Mat {
        rows: plan.shard_rows(snap.shard),
        cols: plan.d,
        data: snap.data.clone(),
    };
    write_mat(w, &m)
}

/// A parsed shard file: the snapshot plus the topology header it claims.
pub struct ShardFile {
    pub shards: usize,
    pub k: usize,
    pub d: usize,
    pub workers: usize,
    pub snap: ShardSnapshot,
}

/// Read one `DMLPSCKPT`-framed shard snapshot.
pub fn read_shard<R: Read>(r: &mut R) -> anyhow::Result<ShardFile> {
    let mut magic = [0u8; 9];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == CKPT_MAGIC, "not a DMLPSCKPT shard file");
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let version = u32::from_le_bytes(b4);
    anyhow::ensure!(
        version == CKPT_VERSION,
        "unsupported checkpoint version {version} \
         (this build reads version {CKPT_VERSION})"
    );
    let mut b8 = [0u8; 8];
    let mut u64f = |r: &mut R| -> anyhow::Result<u64> {
        r.read_exact(&mut b8)?;
        Ok(u64::from_le_bytes(b8))
    };
    let shard = u64f(r)?;
    let shards = u64f(r)?;
    let k = u64f(r)?;
    let d = u64f(r)?;
    let workers = u64f(r)?;
    anyhow::ensure!(
        shards > 0
            && shards <= MAX_TOPOLOGY
            && workers > 0
            && workers <= MAX_TOPOLOGY
            && shard < shards,
        "corrupt checkpoint topology header \
         (shard {shard} of {shards}, {workers} workers)"
    );
    let applied = u64f(r)?;
    let broadcasts = u64f(r)?;
    let grad_bytes = u64f(r)?;
    r.read_exact(&mut b4)?;
    let last_loss = f32::from_le_bytes(b4);
    let mut b1 = [0u8; 1];
    r.read_exact(&mut b1)?;
    let saw_loss = b1[0] != 0;
    let mut counts = Vec::with_capacity(workers as usize);
    for _ in 0..workers {
        counts.push(u64f(r)?);
    }
    let mut finished = Vec::with_capacity(workers as usize);
    for _ in 0..workers {
        r.read_exact(&mut b1)?;
        finished.push(b1[0] != 0);
    }
    let m = read_mat(r)?;
    anyhow::ensure!(
        m.cols == d as usize,
        "shard slice payload is {}x{}, header says d={d}",
        m.rows,
        m.cols
    );
    Ok(ShardFile {
        shards: shards as usize,
        k: k as usize,
        d: d as usize,
        workers: workers as usize,
        snap: ShardSnapshot {
            shard: shard as usize,
            applied,
            counts,
            finished,
            broadcasts,
            grad_bytes,
            last_loss,
            saw_loss,
            data: m.data,
        },
    })
}

// ---------------------------------------------------------------------
// run directory: generations + manifest
// ---------------------------------------------------------------------

fn gen_dir(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("gen{gen:08}"))
}

fn manifest_path(dir: &Path) -> PathBuf {
    dir.join("MANIFEST.json")
}

/// Write one complete generation: every shard file first (each
/// crash-atomic), then the manifest naming it — so the manifest never
/// points at a partially written generation. Prunes generations older
/// than the previous one afterwards.
pub fn write_generation(
    dir: &Path,
    plan: &ShardPlan,
    workers: usize,
    gen: u64,
    snaps: &[&ShardSnapshot],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        snaps.len() == plan.shards(),
        "generation needs {} shard snapshots, got {}",
        plan.shards(),
        snaps.len()
    );
    let gdir = gen_dir(dir, gen);
    std::fs::create_dir_all(&gdir)?;
    for (s, snap) in snaps.iter().enumerate() {
        anyhow::ensure!(
            snap.shard == s,
            "snapshot {} out of order at slot {s}",
            snap.shard
        );
        atomic_write(&gdir.join(format!("shard{s}.ckpt")), |w| {
            write_shard(w, plan, workers, snap)
        })?;
    }
    let manifest = Json::obj(vec![
        ("version", Json::Num(1.0)),
        ("latest_gen", Json::Num(gen as f64)),
        ("shards", Json::Num(plan.shards() as f64)),
        ("workers", Json::Num(workers as f64)),
        ("k", Json::Num(plan.k as f64)),
        ("d", Json::Num(plan.d as f64)),
    ]);
    atomic_write(&manifest_path(dir), |w| {
        w.write_all(manifest.to_string_pretty().as_bytes())?;
        Ok(())
    })?;
    prune_old(dir, gen);
    Ok(())
}

/// Best-effort removal of generation directories older than `gen - 1`
/// (the current and previous generations are kept, so a reader of the
/// old manifest never races a delete).
fn prune_old(dir: &Path, gen: u64) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    for e in rd.flatten() {
        let name = e.file_name().to_string_lossy().into_owned();
        if let Some(g) = name
            .strip_prefix("gen")
            .and_then(|s| s.parse::<u64>().ok())
        {
            if g + 1 < gen {
                let _ = std::fs::remove_dir_all(e.path());
            }
        }
    }
}

/// Load the newest consistent checkpoint from a run directory.
///
/// `Ok(None)` means nothing was checkpointed yet (no manifest) — the
/// caller starts fresh; that is what lets `--resume` be passed
/// unconditionally on a restart. A manifest naming a generation whose
/// shard files are missing or corrupt is an error: the state existed
/// and cannot be trusted, so failing loudly beats silently retraining.
pub fn load_latest(dir: &Path) -> anyhow::Result<Option<Checkpoint>> {
    let mpath = manifest_path(dir);
    if !mpath.exists() {
        return Ok(None);
    }
    let j = Json::parse_file(&mpath)?;
    let version = j.get("version").as_usize().unwrap_or(0);
    anyhow::ensure!(
        version == 1,
        "unsupported checkpoint manifest version {version}"
    );
    let need = |k: &str| -> anyhow::Result<usize> {
        j.get(k).as_usize().ok_or_else(|| {
            anyhow::anyhow!("checkpoint manifest missing '{k}'")
        })
    };
    let gen = need("latest_gen")? as u64;
    let shards = need("shards")?;
    let workers = need("workers")?;
    let k = need("k")?;
    let d = need("d")?;
    anyhow::ensure!(
        shards > 0 && shards as u64 <= MAX_TOPOLOGY,
        "corrupt manifest shard count {shards}"
    );
    let gdir = gen_dir(dir, gen);
    let mut snaps = Vec::with_capacity(shards);
    for s in 0..shards {
        let path = gdir.join(format!("shard{s}.ckpt"));
        let mut f = std::io::BufReader::new(
            std::fs::File::open(&path).map_err(|e| {
                anyhow::anyhow!(
                    "checkpoint gen {gen} shard file {} unreadable: {e}",
                    path.display()
                )
            })?,
        );
        let sf = read_shard(&mut f)?;
        anyhow::ensure!(
            sf.shards == shards
                && sf.workers == workers
                && sf.k == k
                && sf.d == d
                && sf.snap.shard == s,
            "shard file {} disagrees with manifest topology",
            path.display()
        );
        snaps.push(sf.snap);
    }
    Ok(Some(Checkpoint { gen, k, d, workers, shards: snaps }))
}

// ---------------------------------------------------------------------
// writer thread (the probe-thread pattern, for durability)
// ---------------------------------------------------------------------

/// Messages from shard update threads to the checkpoint writer thread.
/// Snapshots are best-effort (`try_send` on a bounded channel): a
/// lagging writer loses a checkpoint opportunity, never stalls a fold.
pub enum CkptMsg {
    Snapshot(ShardSnapshot),
    ShardDone { shard: usize },
}

/// The checkpoint writer loop (runs on its own `ps-server-ckpt`
/// thread). Collects the freshest snapshot per shard and writes a new
/// generation whenever every live shard has advanced past what the last
/// generation recorded — one complete, consistent-by-construction
/// generation per cadence boundary. Returns the last generation written.
pub(crate) fn run_writer(
    spec: CheckpointSpec,
    plan: ShardPlan,
    workers: usize,
    start_gen: u64,
    rx: Receiver<CkptMsg>,
) -> u64 {
    let shards = plan.shards();
    let mut latest: Vec<Option<ShardSnapshot>> =
        (0..shards).map(|_| None).collect();
    // applied count each shard had in the last written generation
    let mut written: Vec<Option<u64>> = vec![None; shards];
    let mut done = vec![false; shards];
    let mut gen = start_gen;
    loop {
        match rx.recv() {
            Ok(CkptMsg::Snapshot(s)) => {
                let i = s.shard;
                if i < shards {
                    latest[i] = Some(s);
                }
            }
            Ok(CkptMsg::ShardDone { shard }) => {
                if shard < shards {
                    done[shard] = true;
                }
            }
            Err(_) => break, // all shards hung up
        }
        let ready = latest.iter().all(|o| o.is_some());
        // at least one shard moved past the last written generation…
        let any_new = latest.iter().zip(&written).any(|(o, w)| match (o, w)
        {
            (Some(s), Some(a)) => s.applied > *a,
            (Some(_), None) => true,
            _ => false,
        });
        // …and every shard still running has too (done shards are
        // frozen at their final snapshot and exempt)
        let all_fresh = latest.iter().zip(&written).zip(&done).all(
            |((o, w), &dn)| {
                dn || match (o, w) {
                    (Some(s), Some(a)) => s.applied > *a,
                    (Some(_), None) => true,
                    _ => false,
                }
            },
        );
        if ready && any_new && all_fresh {
            let snaps: Vec<&ShardSnapshot> =
                latest.iter().map(|o| o.as_ref().unwrap()).collect();
            match write_generation(&spec.dir, &plan, workers, gen + 1, &snaps)
            {
                Ok(()) => {
                    gen += 1;
                    for (w, o) in written.iter_mut().zip(&latest) {
                        *w = Some(o.as_ref().unwrap().applied);
                    }
                }
                Err(e) => {
                    // checkpointing is best-effort durability: log and
                    // keep training rather than killing the run
                    eprintln!("[ps-ckpt] generation write failed: {e:#}");
                }
            }
        }
        if done.iter().all(|&f| f) {
            break;
        }
    }
    gen
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(shard: usize, plan: &ShardPlan, applied: u64) -> ShardSnapshot {
        ShardSnapshot {
            shard,
            applied,
            counts: vec![applied / 2, applied - applied / 2],
            finished: vec![false, false],
            broadcasts: applied / 3,
            grad_bytes: 64 * applied,
            last_loss: 0.5,
            saw_loss: applied > 0,
            data: (0..plan.len(shard))
                .map(|i| (i as f32) + applied as f32)
                .collect(),
        }
    }

    #[test]
    fn shard_codec_roundtrips() {
        let plan = ShardPlan::new(8, 4, 2);
        let s = snap(1, &plan, 17);
        let mut buf: Vec<u8> = Vec::new();
        write_shard(&mut buf, &plan, 2, &s).unwrap();
        let sf = read_shard(&mut std::io::Cursor::new(buf)).unwrap();
        assert_eq!(sf.shards, 2);
        assert_eq!((sf.k, sf.d, sf.workers), (8, 4, 2));
        assert_eq!(sf.snap, s);
    }

    #[test]
    fn shard_codec_rejects_garbage_and_truncation() {
        let plan = ShardPlan::new(8, 4, 2);
        let s = snap(0, &plan, 5);
        let mut buf: Vec<u8> = Vec::new();
        write_shard(&mut buf, &plan, 2, &s).unwrap();
        assert!(read_shard(&mut std::io::Cursor::new(b"nope".to_vec()))
            .is_err());
        for cut in [1, 9, 13, 40, buf.len() - 1] {
            assert!(
                read_shard(&mut std::io::Cursor::new(buf[..cut].to_vec()))
                    .is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn generation_roundtrip_and_resume_math() {
        let dir = std::env::temp_dir().join("dmlps_ckpt_gen_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let plan = ShardPlan::new(8, 4, 2);
        let mut s0 = snap(0, &plan, 10);
        let mut s1 = snap(1, &plan, 12);
        // shard 0 absorbed steps (4, 6); shard 1 absorbed (5, 7)
        s0.counts = vec![4, 6];
        s1.counts = vec![5, 7];
        write_generation(&dir, &plan, 2, 3, &[&s0, &s1]).unwrap();
        let c = load_latest(&dir).unwrap().expect("manifest written");
        assert_eq!(c.gen, 3);
        c.validate_for(&plan, 2).unwrap();
        // worker resumes at the min over shards of its counts
        assert_eq!(c.resume_step(0), 4);
        assert_eq!(c.resume_step(1), 6);
        let r = c.worker_resume(0);
        assert_eq!(r.start_step, 4);
        assert_eq!(r.versions, vec![10, 12]);
        // shard clocks: min over unfinished counts
        assert_eq!(r.clocks, vec![4, 5]);
        // reassembled L carries each shard's slice
        let l = c.l(&plan);
        assert_eq!(plan.slice(&l.data, 0), &s0.data[..]);
        assert_eq!(plan.slice(&l.data, 1), &s1.data[..]);
        // topology mismatches fail loudly
        assert!(c.validate_for(&plan, 3).is_err());
        assert!(c
            .validate_for(&ShardPlan::new(8, 4, 4), 2)
            .is_err());
    }

    #[test]
    fn empty_dir_resumes_fresh_and_corrupt_manifest_errors() {
        let dir = std::env::temp_dir().join("dmlps_ckpt_empty_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // no manifest → nothing to resume, start fresh
        assert!(load_latest(&dir).unwrap().is_none());
        // manifest naming a generation without shard files → loud error
        std::fs::write(
            manifest_path(&dir),
            r#"{"version": 1, "latest_gen": 9, "shards": 1,
                "workers": 1, "k": 8, "d": 4}"#,
        )
        .unwrap();
        assert!(load_latest(&dir).is_err());
    }

    #[test]
    fn finished_workers_do_not_hold_the_clock() {
        let plan = ShardPlan::new(8, 4, 1);
        let mut s = snap(0, &plan, 20);
        s.counts = vec![3, 17];
        s.finished = vec![true, false];
        assert_eq!(s.clock(), 17);
        s.finished = vec![true, true];
        assert_eq!(s.clock(), 17.max(3));
    }

    #[test]
    fn pruning_keeps_current_and_previous_generation() {
        let dir = std::env::temp_dir().join("dmlps_ckpt_prune_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let plan = ShardPlan::new(4, 4, 1);
        for gen in 1..=4 {
            let s = snap(0, &plan, 10 * gen);
            write_generation(&dir, &plan, 2, gen, &[&s]).unwrap();
        }
        assert!(!gen_dir(&dir, 1).exists());
        assert!(!gen_dir(&dir, 2).exists());
        assert!(gen_dir(&dir, 3).exists());
        assert!(gen_dir(&dir, 4).exists());
        let c = load_latest(&dir).unwrap().unwrap();
        assert_eq!(c.gen, 4);
        assert_eq!(c.shards[0].applied, 40);
    }
}
