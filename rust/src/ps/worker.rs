//! Worker machine (paper §4.2, worker side), sharded-server aware.
//!
//! Three threads per worker, exactly the paper's structure:
//!
//! * **local computing thread** — takes a minibatch of its pair shard,
//!   computes the gradient on the local parameter copy, applies it
//!   locally, and puts it on the outbound queue;
//! * **communication thread** — splits each outbound gradient into
//!   per-server-shard row slices (one transport fate per step) and ships
//!   them; moves incoming parameter slices onto the inbound queue;
//! * **remote update thread** — takes fresh parameter slices off the
//!   inbound queue and splices them into the local copy, freshest
//!   version per shard wins.
//!
//! Consistency (ASP/BSP/SSP) is enforced in the computing thread against
//! the *min over server shards* of the shard clocks: under SSP(s) a
//! worker at local step t blocks until every shard's clock reaches
//! t − s; ASP is s = ∞ (never blocks — the paper's mode); BSP is s = 0.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::compress::{decode_into, Compressor};
use super::messages::{ShardPlan, ToServer, ToWorker};
use super::transport::{FaultSpec, FaultySender};
use crate::config::{CompressionConfig, Consistency};
use crate::data::{Dataset, MinibatchIter, WorkerPairs};
use crate::dml::{EngineFactory, LrSchedule, MinibatchRef};
use crate::linalg::Mat;
use crate::util::rng::Pcg32;

pub struct WorkerConfig {
    pub id: usize,
    pub steps: usize,
    pub batch_sim: usize,
    pub batch_dis: usize,
    pub lambda: f32,
    /// Local learning rate the worker applies to its own copy between
    /// server refreshes.
    pub lr: LrSchedule,
    pub consistency: Consistency,
    pub faults: FaultSpec,
    pub seed: u64,
    /// Compute threads for this worker's engine (paper: C cores per
    /// worker machine). `0` = engine default.
    pub threads: usize,
    /// Wire compression for gradient pushes (and, symmetrically on the
    /// server, parameter broadcasts). `mode = none` is the dense f32
    /// protocol bit for bit.
    pub compression: CompressionConfig,
    /// Optional run-event sink: the computing thread reports its
    /// completion through it (`None` = no reporting).
    pub events: Option<Arc<dyn crate::session::EventSink>>,
    /// Resume after a restart: skip to `start_step` (replaying the pair
    /// stream, which is pure in `(seed, w, t)`) and seed the shard
    /// clocks/versions from the checkpoint so the SSP gate starts from
    /// the server's recorded progress instead of zero.
    pub resume: Option<super::checkpoint::WorkerResume>,
}

/// Per-worker telemetry returned on join.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub id: usize,
    pub steps_done: u64,
    /// Logical gradient pushes (one per step; a push fans out into one
    /// slice message per server shard, all sharing one fate).
    pub grads_sent: u64,
    pub grads_dropped: u64,
    /// Parameter slice messages received.
    pub params_received: u64,
    /// Total seconds the computing thread spent blocked on consistency.
    pub wait_s: f64,
    /// Max observed staleness: own step index minus the min-over-shards
    /// server clock, measured right before each gradient computation.
    /// SSP(s) guarantees this never exceeds s; BSP pins it to 0.
    pub max_staleness: u64,
    pub last_loss: f32,
    /// Resident bytes of materialized pair storage this worker held
    /// (shard size in materialized mode, 0 in streaming mode).
    pub pair_bytes: usize,
    /// Pairs drawn from this worker's pair stream.
    pub pairs_drawn: u64,
    /// Encoded payload bytes of gradient slices the transport accepted
    /// (post drop-gate; `Done` excluded — the same contract as
    /// `grads_sent`, see `FaultySender`).
    pub grad_bytes_sent: u64,
    /// Encoded payload bytes of parameter slices received.
    pub param_bytes_received: u64,
    /// First step this worker actually executed (non-zero only when
    /// resumed from a checkpoint). The per-worker accounting identity
    /// across a restart is `start_step + grads_sent + grads_dropped ==
    /// steps`: the steps before `start_step` were accounted by the
    /// incarnation the checkpoint captured.
    pub start_step: u64,
}

/// Worker-internal outbound queue entries (computing → comm thread).
/// The comm thread slices `Step` into per-shard wire messages.
enum Outbound {
    Step { step: u64, grad: Vec<f32>, loss: f32 },
    Done,
}

/// Shared state between the three worker threads.
struct Shared {
    /// Local parameter copy L_p (reassembled from shard slices).
    l: Mutex<Mat>,
    /// Latest server clock seen, per shard (for SSP gating).
    clocks: Vec<AtomicU64>,
    /// Latest parameter version seen, per shard (freshest-wins).
    versions: Vec<AtomicU64>,
    /// Signalled by the remote-update thread when new state arrives.
    cv: Condvar,
    cv_m: Mutex<()>,
    stop: AtomicBool,
    params_received: AtomicU64,
    param_bytes: AtomicU64,
}

impl Shared {
    /// The SSP gate's clock: min over server shards.
    fn min_clock(&self) -> u64 {
        self.clocks
            .iter()
            .map(|c| c.load(Ordering::SeqCst))
            .min()
            .unwrap_or(0)
    }
}

pub struct Worker {
    compute: std::thread::JoinHandle<WorkerStats>,
    remote_update: std::thread::JoinHandle<()>,
    /// Returns (grads sent, grads dropped, encoded grad bytes sent).
    comm: std::thread::JoinHandle<(u64, u64, u64)>,
    shared: Arc<Shared>,
}

impl Worker {
    /// Spawn a worker's three threads.
    ///
    /// * `plan`: the shard plan shared with the server.
    /// * `dataset`/`pairs`: this worker's pair source — a materialized
    ///   shard (paper §4.1) or an implicit `(seed, w, t)` sampler.
    /// * `to_server`: shared channel into the server's comm thread.
    /// * `from_server`: this worker's parameter channel.
    /// * `engines`: factory; the computing thread builds its engine
    ///   inside the thread (PJRT handles are not `Send`).
    pub fn spawn(
        cfg: WorkerConfig,
        plan: ShardPlan,
        l0: Mat,
        dataset: Arc<Dataset>,
        pairs: WorkerPairs,
        to_server: Sender<ToServer>,
        from_server: Receiver<ToWorker>,
        engines: EngineFactory,
    ) -> Worker {
        let shard_count = plan.shards();
        let resume = cfg.resume.clone();
        let shared = Arc::new(Shared {
            l: Mutex::new(l0),
            clocks: (0..shard_count)
                .map(|s| {
                    AtomicU64::new(
                        resume
                            .as_ref()
                            .and_then(|r| r.clocks.get(s))
                            .copied()
                            .unwrap_or(0),
                    )
                })
                .collect(),
            versions: (0..shard_count)
                .map(|s| {
                    AtomicU64::new(
                        resume
                            .as_ref()
                            .and_then(|r| r.versions.get(s))
                            .copied()
                            .unwrap_or(0),
                    )
                })
                .collect(),
            cv: Condvar::new(),
            cv_m: Mutex::new(()),
            stop: AtomicBool::new(false),
            params_received: AtomicU64::new(0),
            param_bytes: AtomicU64::new(0),
        });

        // internal queues (paper: worker-side inbound/outbound queues)
        let (outbound_tx, outbound_rx) = channel::<Outbound>();
        let (inbound_tx, inbound_rx) = channel::<ToWorker>();

        // --------------------- local computing thread ---------------------
        let c_shared = shared.clone();
        let id = cfg.id;
        let compute = std::thread::Builder::new()
            .name(format!("ps-worker{id}-compute"))
            .spawn(move || {
                let mut engine = (engines)().expect("engine construction");
                if cfg.threads > 0 {
                    // saturate this worker's configured core budget
                    engine.set_threads(cfg.threads);
                }
                // materialized mode must keep the historical per-worker
                // minibatch RNG stream; the implicit sampler ignores it
                // (its draws are pure in (seed, w, t))
                let mut iter = MinibatchIter::from_stream(
                    &dataset,
                    pairs.into_stream(Pcg32::with_stream(
                        cfg.seed,
                        0x3000 + id as u64,
                    )),
                    cfg.batch_sim,
                    cfg.batch_dis,
                );
                let staleness = match cfg.consistency {
                    Consistency::Asp => u64::MAX,
                    Consistency::Bsp => 0,
                    Consistency::Ssp { staleness } => staleness as u64,
                };
                let (k, d) = {
                    let l = c_shared.l.lock().unwrap();
                    (l.rows, l.cols)
                };
                let mut l_snap = Mat::zeros(k, d);
                let mut g = Mat::zeros(k, d);
                // Resume: re-derive the pair stream position by drawing
                // (and discarding) the minibatches the previous
                // incarnation consumed — pair t of worker w is pure in
                // (seed, w, t), so this replay is exact in both the
                // materialized and streaming modes. Replayed pairs do
                // count in `pairs_drawn` (it meters stream positions,
                // not fresh work).
                let start = cfg
                    .resume
                    .as_ref()
                    .map_or(0, |r| r.start_step)
                    .min(cfg.steps as u64);
                for _ in 0..start {
                    iter.next_batch();
                }
                let mut stats = WorkerStats {
                    id,
                    pair_bytes: iter.pair_bytes(),
                    start_step: start,
                    ..Default::default()
                };
                for step in start..cfg.steps as u64 {
                    // ---- consistency gate (SSP inequality over the
                    //      min-over-shards clock) ----
                    if staleness != u64::MAX && step > staleness {
                        let need = step - staleness;
                        let t0 = std::time::Instant::now();
                        let mut guard = c_shared.cv_m.lock().unwrap();
                        while c_shared.min_clock() < need
                            && !c_shared.stop.load(Ordering::SeqCst)
                        {
                            let (g2, _timeout) = c_shared
                                .cv
                                .wait_timeout(
                                    guard,
                                    Duration::from_millis(50),
                                )
                                .unwrap();
                            guard = g2;
                        }
                        drop(guard);
                        stats.wait_s += t0.elapsed().as_secs_f64();
                    }
                    if c_shared.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    // observed staleness at compute time (telemetry; the
                    // SSP regression tests assert its bound)
                    stats.max_staleness = stats.max_staleness.max(
                        step.saturating_sub(c_shared.min_clock()),
                    );
                    // ---- compute gradient on the local copy ----
                    iter.next_batch();
                    {
                        let l = c_shared.l.lock().unwrap();
                        l_snap.data.copy_from_slice(&l.data);
                    }
                    let batch = MinibatchRef::new(
                        &iter.ds_buf,
                        &iter.dd_buf,
                        cfg.batch_sim,
                        cfg.batch_dis,
                        d,
                    );
                    let loss = engine
                        .loss_grad(&l_snap, &batch, cfg.lambda, &mut g)
                        .expect("worker gradient");
                    stats.last_loss = loss;
                    // ---- apply locally (keeps progressing between
                    //      server refreshes) ----
                    {
                        let mut l = c_shared.l.lock().unwrap();
                        let lr_t = cfg.lr.at(step as usize);
                        for (a, gv) in l.data.iter_mut().zip(&g.data) {
                            *a -= lr_t * gv;
                        }
                    }
                    // ---- enqueue for the server ----
                    let msg = Outbound::Step {
                        step,
                        grad: g.data.clone(),
                        loss,
                    };
                    if outbound_tx.send(msg).is_err() {
                        break; // comm thread gone
                    }
                    stats.steps_done += 1;
                }
                stats.pairs_drawn = iter.pairs_drawn();
                if let Some(sink) = &cfg.events {
                    sink.on_done(&crate::session::DoneEvent {
                        worker: id,
                        steps: stats.steps_done,
                        last_loss: stats.last_loss,
                        wait_s: stats.wait_s,
                        max_staleness: stats.max_staleness,
                    });
                }
                let _ = outbound_tx.send(Outbound::Done);
                stats
            })
            .expect("spawn compute thread");

        // --------------------- remote update thread ----------------------
        let r_shared = shared.clone();
        let r_plan = plan.clone();
        let remote_update = std::thread::Builder::new()
            .name(format!("ps-worker{id}-remote-update"))
            .spawn(move || {
                loop {
                    match inbound_rx.recv_timeout(Duration::from_millis(20))
                    {
                        Ok(ToWorker::Param {
                            shard,
                            version,
                            clock,
                            data,
                        }) => {
                            r_shared
                                .params_received
                                .fetch_add(1, Ordering::Relaxed);
                            r_shared.param_bytes.fetch_add(
                                data.encoded_bytes(),
                                Ordering::Relaxed,
                            );
                            // freshest version per shard wins
                            if version
                                > r_shared.versions[shard]
                                    .load(Ordering::SeqCst)
                            {
                                {
                                    let mut l =
                                        r_shared.l.lock().unwrap();
                                    // splice the decoded slice into the
                                    // local copy (§4.1, per shard);
                                    // Dense decodes by plain copy
                                    decode_into(
                                        &data,
                                        r_plan
                                            .slice_mut(&mut l.data, shard),
                                    );
                                }
                                // The store+notify must happen under
                                // cv_m: the gate checks min_clock() and
                                // parks while holding that lock, so a
                                // notify from outside it can land in
                                // the check→park window and be lost —
                                // the gate then burns a full 50 ms
                                // wait_timeout per lost wakeup.
                                let _g =
                                    r_shared.cv_m.lock().unwrap();
                                r_shared.versions[shard]
                                    .store(version, Ordering::SeqCst);
                                r_shared.clocks[shard]
                                    .store(clock, Ordering::SeqCst);
                                r_shared.cv.notify_all();
                            }
                        }
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                            if r_shared.stop.load(Ordering::SeqCst) {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn remote-update thread");

        // ----------------------- communication thread --------------------
        let w_shared = shared.clone();
        let faults = cfg.faults;
        let seed = cfg.seed;
        let compression = cfg.compression;
        let comm = std::thread::Builder::new()
            .name(format!("ps-worker{id}-comm"))
            .spawn(move || {
                let mut to_server = FaultySender::new(
                    to_server,
                    faults.drop_grad_prob,
                    faults.latency,
                    seed ^ 0xC0,
                );
                // gradient encoder: per-shard error-feedback residuals
                // live here, on the thread that owns the outbound order
                let mut compressor =
                    Compressor::new(compression, seed, id, &plan);
                loop {
                    let mut did_work = false;
                    // outbound: gradient slices → server (one fate per
                    // step), Done over the reliable control plane
                    match outbound_rx.try_recv() {
                        Ok(msg) => {
                            let _ = ship(
                                &mut to_server,
                                &mut compressor,
                                &plan,
                                id,
                                msg,
                            );
                            did_work = true;
                        }
                        Err(std::sync::mpsc::TryRecvError::Empty) => {}
                        Err(_) => {
                            // compute thread done & channel drained
                        }
                    }
                    // inbound: parameter slices ← server. The remote-
                    // update thread can exit slightly before us during
                    // shutdown; a failed handoff then just means params
                    // are no longer needed — never skip the stop-flush
                    // below, or queued gradients and Done would be lost.
                    match from_server.try_recv() {
                        Ok(msg) => {
                            if inbound_tx.send(msg).is_ok() {
                                did_work = true;
                            }
                        }
                        Err(std::sync::mpsc::TryRecvError::Empty) => {}
                        Err(_) => {
                            // server comm thread exited
                        }
                    }
                    // deliver latency-delayed slices that came due
                    let _ = to_server.pump();
                    if w_shared.stop.load(Ordering::SeqCst) {
                        // flush outbound through the same fault model,
                        // then wait out in-flight latencies and exit
                        while let Ok(msg) = outbound_rx.try_recv() {
                            let _ = ship(
                                &mut to_server,
                                &mut compressor,
                                &plan,
                                id,
                                msg,
                            );
                        }
                        to_server.flush_blocking();
                        break;
                    }
                    if !did_work {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                }
                let (sent, dropped) = to_server.stats();
                (sent, dropped, to_server.bytes_sent())
            })
            .expect("spawn comm thread");

        Worker { compute, remote_update, comm, shared }
    }

    /// Join the compute thread, then stop and join the service threads.
    pub fn join(self) -> WorkerStats {
        let mut stats = self.compute.join().expect("compute panicked");
        Self::signal_stop(&self.shared);
        let (sent, dropped, grad_bytes) =
            self.comm.join().expect("comm panicked");
        self.remote_update.join().expect("remote-update panicked");
        stats.grads_sent = sent;
        stats.grads_dropped = dropped;
        stats.grad_bytes_sent = grad_bytes;
        stats.params_received =
            self.shared.params_received.load(Ordering::Relaxed);
        stats.param_bytes_received =
            self.shared.param_bytes.load(Ordering::Relaxed);
        stats
    }

    /// Signal the worker to stop early (used by failure-injection tests).
    pub fn stop(&self) {
        Self::signal_stop(&self.shared);
    }

    /// Set the stop flag and wake the gate — under `cv_m`, for the same
    /// lost-wakeup reason as the remote-update thread's notify: a stop
    /// raised in the gate's check→park window must not strand it for a
    /// wait_timeout round.
    fn signal_stop(shared: &Shared) {
        let _g = shared.cv_m.lock().unwrap();
        shared.stop.store(true, Ordering::SeqCst);
        shared.cv.notify_all();
    }
}

/// Put one outbound entry on the wire: a `Step` becomes one *encoded*
/// gradient slice per server shard sharing a single transport fate;
/// `Done` rides the reliable control plane (never dropped, still
/// ordered). Encoding (and the error-feedback residual update) happens
/// before the group's drop decision: a transport-dropped step is lost
/// work exactly as in the dense protocol — error feedback recovers
/// compression losses, not network losses.
fn ship(
    to_server: &mut FaultySender<ToServer>,
    comp: &mut Compressor,
    plan: &ShardPlan,
    worker: usize,
    msg: Outbound,
) -> Result<(), ()> {
    match msg {
        Outbound::Step { step, grad, loss } => {
            let mut bytes = 0u64;
            let msgs: Vec<ToServer> = (0..plan.shards())
                .map(|s| {
                    let enc =
                        comp.encode_grad(s, step, plan.slice(&grad, s));
                    bytes += enc.encoded_bytes();
                    ToServer::Grad {
                        worker,
                        shard: s,
                        step,
                        grad: enc,
                        loss,
                    }
                })
                .collect();
            to_server.send_group_bytes(msgs, bytes)
        }
        Outbound::Done => {
            to_server.send_reliable(ToServer::Done { worker })
        }
    }
}
