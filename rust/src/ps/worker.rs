//! Worker machine (paper §4.2, worker side).
//!
//! Three threads per worker, exactly the paper's structure:
//!
//! * **local computing thread** — takes a minibatch of its pair shard,
//!   computes the gradient on the local parameter copy, applies it
//!   locally, and puts it on the outbound queue;
//! * **communication thread** — ships outbound gradients to the server
//!   and moves incoming parameter messages onto the inbound queue;
//! * **remote update thread** — takes fresh parameters off the inbound
//!   queue and replaces the local copy.
//!
//! Consistency (ASP/BSP/SSP) is enforced in the computing thread: under
//! SSP(s) a worker at local step t blocks until the server clock reaches
//! t − s; ASP is s = ∞ (never blocks — the paper's mode); BSP is s = 0.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::messages::{ToServer, ToWorker};
use super::transport::{FaultSpec, FaultySender};
use crate::config::Consistency;
use crate::data::{Dataset, MinibatchIter, PairShard};
use crate::dml::{EngineFactory, LrSchedule, MinibatchRef};
use crate::linalg::Mat;
use crate::util::rng::Pcg32;

pub struct WorkerConfig {
    pub id: usize,
    pub steps: usize,
    pub batch_sim: usize,
    pub batch_dis: usize,
    pub lambda: f32,
    /// Local learning rate the worker applies to its own copy between
    /// server refreshes.
    pub lr: LrSchedule,
    pub consistency: Consistency,
    pub faults: FaultSpec,
    pub seed: u64,
    /// Compute threads for this worker's engine (paper: C cores per
    /// worker machine). `0` = engine default.
    pub threads: usize,
}

/// Per-worker telemetry returned on join.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub id: usize,
    pub steps_done: u64,
    pub grads_sent: u64,
    pub grads_dropped: u64,
    pub params_received: u64,
    /// Total seconds the computing thread spent blocked on consistency.
    pub wait_s: f64,
    pub last_loss: f32,
}

/// Shared state between the three worker threads.
struct Shared {
    /// Local parameter copy L_p.
    l: Mutex<Mat>,
    /// Latest server clock seen (for SSP gating).
    clock: AtomicU64,
    /// Latest parameter version seen.
    version: AtomicU64,
    /// Signalled by the remote-update thread when new state arrives.
    cv: Condvar,
    cv_m: Mutex<()>,
    stop: AtomicBool,
    params_received: AtomicU64,
}

pub struct Worker {
    compute: std::thread::JoinHandle<WorkerStats>,
    remote_update: std::thread::JoinHandle<()>,
    comm: std::thread::JoinHandle<(u64, u64)>,
    shared: Arc<Shared>,
}

impl Worker {
    /// Spawn a worker's three threads.
    ///
    /// * `dataset`/`shard`: this worker's pair shard (paper §4.1).
    /// * `to_server`: shared channel into the server's comm thread.
    /// * `from_server`: this worker's parameter channel.
    /// * `engines`: factory; the computing thread builds its engine
    ///   inside the thread (PJRT handles are not `Send`).
    pub fn spawn(
        cfg: WorkerConfig,
        l0: Mat,
        dataset: Arc<Dataset>,
        shard: PairShard,
        to_server: Sender<ToServer>,
        from_server: Receiver<ToWorker>,
        engines: EngineFactory,
    ) -> Worker {
        let shared = Arc::new(Shared {
            l: Mutex::new(l0),
            clock: AtomicU64::new(0),
            version: AtomicU64::new(0),
            cv: Condvar::new(),
            cv_m: Mutex::new(()),
            stop: AtomicBool::new(false),
            params_received: AtomicU64::new(0),
        });

        // internal queues (paper: worker-side inbound/outbound queues)
        let (outbound_tx, outbound_rx) = channel::<ToServer>();
        let (inbound_tx, inbound_rx) = channel::<ToWorker>();

        // --------------------- local computing thread ---------------------
        let c_shared = shared.clone();
        let id = cfg.id;
        let compute = std::thread::Builder::new()
            .name(format!("ps-worker{id}-compute"))
            .spawn(move || {
                let mut engine = (engines)().expect("engine construction");
                if cfg.threads > 0 {
                    // saturate this worker's configured core budget
                    engine.set_threads(cfg.threads);
                }
                let mut iter = MinibatchIter::new(
                    &dataset,
                    &shard.pairs,
                    cfg.batch_sim,
                    cfg.batch_dis,
                    Pcg32::with_stream(cfg.seed, 0x3000 + id as u64),
                );
                let staleness = match cfg.consistency {
                    Consistency::Asp => u64::MAX,
                    Consistency::Bsp => 0,
                    Consistency::Ssp { staleness } => staleness as u64,
                };
                let (k, d) = {
                    let l = c_shared.l.lock().unwrap();
                    (l.rows, l.cols)
                };
                let mut l_snap = Mat::zeros(k, d);
                let mut g = Mat::zeros(k, d);
                let mut stats = WorkerStats { id, ..Default::default() };
                for step in 0..cfg.steps as u64 {
                    // ---- consistency gate (SSP inequality) ----
                    if staleness != u64::MAX && step > staleness {
                        let need = step - staleness;
                        let t0 = std::time::Instant::now();
                        let mut guard = c_shared.cv_m.lock().unwrap();
                        while c_shared.clock.load(Ordering::SeqCst) < need
                            && !c_shared.stop.load(Ordering::SeqCst)
                        {
                            let (g2, _timeout) = c_shared
                                .cv
                                .wait_timeout(
                                    guard,
                                    Duration::from_millis(50),
                                )
                                .unwrap();
                            guard = g2;
                        }
                        drop(guard);
                        stats.wait_s += t0.elapsed().as_secs_f64();
                    }
                    if c_shared.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    // ---- compute gradient on the local copy ----
                    iter.next_batch();
                    {
                        let l = c_shared.l.lock().unwrap();
                        l_snap.data.copy_from_slice(&l.data);
                    }
                    let batch = MinibatchRef::new(
                        &iter.ds_buf,
                        &iter.dd_buf,
                        cfg.batch_sim,
                        cfg.batch_dis,
                        d,
                    );
                    let loss = engine
                        .loss_grad(&l_snap, &batch, cfg.lambda, &mut g)
                        .expect("worker gradient");
                    stats.last_loss = loss;
                    // ---- apply locally (keeps progressing between
                    //      server refreshes) ----
                    {
                        let mut l = c_shared.l.lock().unwrap();
                        let lr_t = cfg.lr.at(step as usize);
                        for (a, gv) in l.data.iter_mut().zip(&g.data) {
                            *a -= lr_t * gv;
                        }
                    }
                    // ---- enqueue for the server ----
                    let msg = ToServer::Grad {
                        worker: id,
                        step,
                        grad: g.data.clone(),
                        loss,
                    };
                    if outbound_tx.send(msg).is_err() {
                        break; // comm thread gone
                    }
                    stats.steps_done += 1;
                }
                let _ = outbound_tx.send(ToServer::Done { worker: id });
                stats
            })
            .expect("spawn compute thread");

        // --------------------- remote update thread ----------------------
        let r_shared = shared.clone();
        let remote_update = std::thread::Builder::new()
            .name(format!("ps-worker{id}-remote-update"))
            .spawn(move || {
                loop {
                    match inbound_rx.recv_timeout(Duration::from_millis(20))
                    {
                        Ok(ToWorker::Param { version, clock, data }) => {
                            {
                                let mut l = r_shared.l.lock().unwrap();
                                // replace local copy with global L (§4.1)
                                l.data.copy_from_slice(&data);
                            }
                            r_shared
                                .version
                                .store(version, Ordering::SeqCst);
                            r_shared.clock.store(clock, Ordering::SeqCst);
                            r_shared
                                .params_received
                                .fetch_add(1, Ordering::Relaxed);
                            r_shared.cv.notify_all();
                        }
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                            if r_shared.stop.load(Ordering::SeqCst) {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn remote-update thread");

        // ----------------------- communication thread --------------------
        let w_shared = shared.clone();
        let faults = cfg.faults;
        let seed = cfg.seed;
        let comm = std::thread::Builder::new()
            .name(format!("ps-worker{id}-comm"))
            .spawn(move || {
                let mut to_server = FaultySender::new(
                    to_server,
                    faults.drop_grad_prob,
                    faults.latency,
                    seed ^ 0xC0,
                );
                loop {
                    let mut did_work = false;
                    // outbound: gradients → server
                    match outbound_rx.try_recv() {
                        Ok(msg) => {
                            let is_done =
                                matches!(msg, ToServer::Done { .. });
                            // Done must never be dropped: bypass faults.
                            if is_done {
                                // consume the faulty sender's inner tx
                                // via a clean send path
                                let _ = to_server.send_reliable(msg);
                            } else {
                                let _ = to_server.send(msg);
                            }
                            did_work = true;
                        }
                        Err(std::sync::mpsc::TryRecvError::Empty) => {}
                        Err(_) => {
                            // compute thread done & channel drained
                        }
                    }
                    // inbound: params ← server
                    match from_server.try_recv() {
                        Ok(msg) => {
                            if inbound_tx.send(msg).is_err() {
                                break;
                            }
                            did_work = true;
                        }
                        Err(std::sync::mpsc::TryRecvError::Empty) => {}
                        Err(_) => {
                            // server comm thread exited
                        }
                    }
                    if w_shared.stop.load(Ordering::SeqCst) {
                        // flush outbound then exit
                        while let Ok(msg) = outbound_rx.try_recv() {
                            let _ = to_server.send_reliable(msg);
                        }
                        break;
                    }
                    if !did_work {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                }
                to_server.stats()
            })
            .expect("spawn comm thread");

        Worker { compute, remote_update, comm, shared }
    }

    /// Join the compute thread, then stop and join the service threads.
    pub fn join(self) -> WorkerStats {
        let mut stats = self.compute.join().expect("compute panicked");
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        let (sent, dropped) = self.comm.join().expect("comm panicked");
        self.remote_update.join().expect("remote-update panicked");
        stats.grads_sent = sent;
        stats.grads_dropped = dropped;
        stats.params_received =
            self.shared.params_received.load(Ordering::Relaxed);
        stats
    }

    /// Signal the worker to stop early (used by failure-injection tests).
    pub fn stop(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
    }
}
