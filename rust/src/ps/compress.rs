//! Wire compression for PS slices: top-k sparsification and stochastic
//! int8 quantization with worker-side error feedback.
//!
//! The paper's protocol moves O(P·k·d) dense f32s per logical clock;
//! at the ImageNet shape that is gigabytes of traffic per round, and the
//! paper names communication as a first-order scaling cost. Following
//! the "move less, not just in smaller pieces" direction of Qian et al.
//! (*Towards Making High Dimensional Distance Metric Learning
//! Practical*, 2015), this module shrinks what actually crosses the
//! wire while preserving the optimizer's long-run update mass:
//!
//! * **Top-k sparsification** — keep the `ceil(keep·len)` largest-
//!   magnitude coordinates of a gradient slice; coordinates travel as
//!   LEB128 delta-varint gaps (~1 byte each at practical densities).
//! * **Stochastic int8 quantization** — values scaled by
//!   `max|x|/127` and rounded *stochastically* (`⌊y⌋ + Bernoulli(frac)`),
//!   so `E[decode(encode(x))] = x` exactly: quantization adds variance,
//!   never bias.
//! * **Error feedback** — each worker keeps one residual buffer per
//!   server shard. Every push folds the residual into the raw gradient
//!   slice before encoding and stores back whatever the encoder dropped
//!   (unsent coordinates) or rounded away (quantization error). Over a
//!   run, `Σ decode(sent_t) + residual_T = Σ grad_t` to f32 round-off:
//!   compression changes *when* mass reaches the server, never
//!   *whether*. The residual is charged at encode time — a slice the
//!   transport then drops is lost work, exactly as an uncompressed drop
//!   was (one fate per step, no retransmission).
//! * **Reproducibility** — the rounding RNG is a dedicated [`Pcg32`]
//!   stream keyed purely by `(seed, worker, shard, step)` (parameter
//!   broadcasts use a reserved worker lane keyed by `(shard, version)`),
//!   so a rerun of the same config produces bit-identical wire traffic
//!   regardless of thread interleaving.
//!
//! `mode = none` routes through [`SliceEncoding::Dense`] with no RNG
//! construction and no residual allocation — the PR-2/PR-3 protocol
//! bit for bit.

use super::messages::{ShardPlan, SliceEncoding};
use crate::config::{CompressionConfig, CompressionMode};
use crate::util::rng::Pcg32;

/// Reserved "worker" lane for parameter-broadcast quantization streams
/// (real worker ids are process-local and far smaller).
const PARAM_LANE: u64 = u64::MAX;

/// The rounding RNG for one slice: pure in `(seed, worker, shard, step)`.
fn rounding_rng(seed: u64, worker: u64, shard: u64, step: u64) -> Pcg32 {
    // step perturbs the seed (golden-ratio mix keeps nearby steps on
    // unrelated orbits); (worker, shard) select the stream
    Pcg32::with_stream(
        seed ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        0xC0DE_C000 ^ (worker << 20) ^ shard,
    )
}

/// Coordinates kept by a top-k pass: `ceil(keep · len)`, at least 1.
pub fn keep_count(keep: f32, len: usize) -> usize {
    ((keep as f64 * len as f64).ceil() as usize).clamp(1, len)
}

/// LEB128 varint append.
fn push_varint(buf: &mut Vec<u8>, mut v: u32) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(b);
            return;
        }
        buf.push(b | 0x80);
    }
}

/// LEB128 varint read at `*pos`, advancing it.
fn read_varint(buf: &[u8], pos: &mut usize) -> u32 {
    let mut v = 0u32;
    let mut shift = 0;
    loop {
        let b = buf[*pos];
        *pos += 1;
        v |= ((b & 0x7F) as u32) << shift;
        if b & 0x80 == 0 {
            return v;
        }
        shift += 7;
    }
}

/// Indices of the `n` largest-magnitude entries of `x`, ascending.
/// Total order (|x| desc, index asc on ties) via `total_cmp`, so the
/// selection is deterministic for any input.
fn select_topk(x: &[f32], n: usize) -> Vec<u32> {
    debug_assert!(n >= 1 && n <= x.len());
    let mut idx: Vec<u32> = (0..x.len() as u32).collect();
    if n < x.len() {
        idx.select_nth_unstable_by(n - 1, |&a, &b| {
            x[b as usize]
                .abs()
                .total_cmp(&x[a as usize].abs())
                .then(a.cmp(&b))
        });
        idx.truncate(n);
    }
    idx.sort_unstable();
    idx
}

/// One stochastically rounded int8 for `v` at `1/scale`. Unbiased:
/// `E[q] = v/scale` whenever `|v| ≤ 127·scale` (true by construction of
/// the per-slice scale; the clamp only absorbs f32 round-off).
fn stochastic_q(v: f32, inv_scale: f32, rng: &mut Pcg32) -> i8 {
    let y = v * inv_scale;
    let f = y.floor();
    let q = f as i32 + i32::from(rng.f32() < y - f);
    q.clamp(-127, 127) as i8
}

/// Quantize a full slice to int8 without touching the input (parameter
/// broadcasts keep no residual). Returns `(scale, q)`.
fn quantize_ref(v: &[f32], rng: &mut Pcg32) -> (f32, Vec<i8>) {
    let amax = v.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let scale = amax / 127.0;
    let mut q = vec![0i8; v.len()];
    if scale > 0.0 {
        let inv = 1.0 / scale;
        for (qi, &vi) in q.iter_mut().zip(v.iter()) {
            *qi = stochastic_q(vi, inv, rng);
        }
    }
    (scale, q)
}

/// [`quantize_ref`], additionally leaving the rounding error
/// (`v − q·scale`) behind in `v` — the gradient path's residual update.
/// Identical RNG consumption and encoding to the non-mutating variant.
fn quantize_dense(v: &mut [f32], rng: &mut Pcg32) -> (f32, Vec<i8>) {
    let (scale, q) = quantize_ref(v, rng);
    if scale > 0.0 {
        for (vi, &qi) in v.iter_mut().zip(&q) {
            *vi -= qi as f32 * scale;
        }
    }
    (scale, q)
}

/// Encode the coordinate stream of a sorted index list as varint gaps.
fn encode_gaps(idx: &[u32]) -> Vec<u8> {
    let mut gaps = Vec::with_capacity(idx.len() + 2);
    let mut prev = 0u32;
    for (j, &i) in idx.iter().enumerate() {
        push_varint(&mut gaps, if j == 0 { i } else { i - prev });
        prev = i;
    }
    gaps
}

/// Worker-side gradient encoder with per-shard error-feedback residuals.
///
/// One per worker comm thread. `encode_grad` must be called with the
/// worker's own monotone step sequence (the comm thread's outbound
/// order); residual state makes consecutive encodes of one shard
/// interdependent, which is exactly the error-feedback contract.
pub struct Compressor {
    mode: CompressionMode,
    keep: f32,
    seed: u64,
    worker: u64,
    /// One residual per server shard (empty under `mode = none`).
    residuals: Vec<Vec<f32>>,
}

impl Compressor {
    pub fn new(
        cfg: CompressionConfig,
        seed: u64,
        worker: usize,
        plan: &ShardPlan,
    ) -> Compressor {
        let residuals = if cfg.mode == CompressionMode::None {
            Vec::new()
        } else {
            (0..plan.shards()).map(|s| vec![0.0; plan.len(s)]).collect()
        };
        Compressor {
            mode: cfg.mode,
            keep: cfg.keep,
            seed,
            worker: worker as u64,
            residuals,
        }
    }

    /// Residual currently held for `shard` (test/telemetry access).
    pub fn residual(&self, shard: usize) -> &[f32] {
        &self.residuals[shard]
    }

    /// Encode one gradient slice for `shard` at local step `step`,
    /// folding the shard's residual in first and leaving the dropped/
    /// rounded mass behind in it.
    pub fn encode_grad(
        &mut self,
        shard: usize,
        step: u64,
        slice: &[f32],
    ) -> SliceEncoding {
        if self.mode == CompressionMode::None {
            return SliceEncoding::Dense(slice.to_vec());
        }
        let r = &mut self.residuals[shard];
        debug_assert_eq!(r.len(), slice.len(), "shard {shard} slice len");
        for (ri, &g) in r.iter_mut().zip(slice) {
            *ri += g;
        }
        let mut rng = rounding_rng(self.seed, self.worker, shard as u64, step);
        match self.mode {
            CompressionMode::None => unreachable!(),
            CompressionMode::Int8 => {
                let (scale, q) = quantize_dense(r, &mut rng);
                SliceEncoding::Int8 { scale, q }
            }
            CompressionMode::TopK => {
                let idx = select_topk(r, keep_count(self.keep, r.len()));
                let mut vals = Vec::with_capacity(idx.len());
                for &i in &idx {
                    // f32 values ship exactly: the kept mass leaves the
                    // residual in full
                    vals.push(std::mem::take(&mut r[i as usize]));
                }
                SliceEncoding::TopK { gaps: encode_gaps(&idx), vals }
            }
            CompressionMode::TopKInt8 => {
                let idx = select_topk(r, keep_count(self.keep, r.len()));
                // top-k keeps the largest magnitudes, so the max over
                // the kept values IS the slice max
                let amax = idx
                    .iter()
                    .map(|&i| r[i as usize].abs())
                    .fold(0.0f32, f32::max);
                let scale = amax / 127.0;
                let mut vals = Vec::with_capacity(idx.len());
                if scale > 0.0 {
                    let inv = 1.0 / scale;
                    for &i in &idx {
                        let q = stochastic_q(r[i as usize], inv, &mut rng);
                        r[i as usize] -= q as f32 * scale;
                        vals.push(q);
                    }
                } else {
                    vals.resize(idx.len(), 0);
                }
                SliceEncoding::TopKInt8 {
                    scale,
                    gaps: encode_gaps(&idx),
                    vals,
                }
            }
        }
    }
}

/// Encode one parameter-broadcast slice. Parameters are absolute state,
/// not deltas: there is no receiver-side accumulation to absorb dropped
/// mass, so only the (unbiased, bounded-error) int8 quantization ever
/// applies — `none` and `topk` broadcast dense f32. Keyed by
/// `(shard, version)` on a reserved lane, so broadcasts are reproducible
/// and independent of worker streams.
pub fn encode_param(
    mode: CompressionMode,
    seed: u64,
    shard: usize,
    version: u64,
    data: &[f32],
) -> SliceEncoding {
    if !mode.quantizes() {
        return SliceEncoding::Dense(data.to_vec());
    }
    let mut rng = rounding_rng(seed, PARAM_LANE, shard as u64, version);
    let (scale, q) = quantize_ref(data, &mut rng);
    SliceEncoding::Int8 { scale, q }
}

/// Decode any wire encoding into a dense f32 slice. The `Dense` arm is
/// a plain copy, which keeps the `mode = none` golden paths bit-exact.
pub fn decode_into(enc: &SliceEncoding, out: &mut [f32]) {
    match enc {
        SliceEncoding::Dense(v) => out.copy_from_slice(v),
        SliceEncoding::Int8 { scale, q } => {
            assert_eq!(q.len(), out.len(), "int8 slice length");
            for (o, &qi) in out.iter_mut().zip(q) {
                *o = qi as f32 * scale;
            }
        }
        SliceEncoding::TopK { gaps, vals } => {
            out.fill(0.0);
            scatter(gaps, out, vals.iter().copied());
        }
        SliceEncoding::TopKInt8 { scale, gaps, vals } => {
            out.fill(0.0);
            scatter(gaps, out, vals.iter().map(|&q| q as f32 * scale));
        }
    }
}

/// Walk a varint gap stream, writing `vals` at the decoded coordinates.
fn scatter<I: Iterator<Item = f32>>(gaps: &[u8], out: &mut [f32], vals: I) {
    let mut pos = 0usize;
    let mut idx = 0u32;
    for (j, v) in vals.enumerate() {
        let g = read_varint(gaps, &mut pos);
        idx = if j == 0 { g } else { idx + g };
        out[idx as usize] = v;
    }
    debug_assert_eq!(pos, gaps.len(), "trailing bytes in gap stream");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let vals =
            [0u32, 1, 127, 128, 300, 16_383, 16_384, 1 << 20, u32::MAX];
        for &v in &vals {
            push_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(read_varint(&buf, &mut pos), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn varint_small_gaps_are_one_byte() {
        let mut buf = Vec::new();
        for v in 0u32..128 {
            push_varint(&mut buf, v);
        }
        assert_eq!(buf.len(), 128, "gaps < 128 must cost one byte");
    }

    #[test]
    fn select_topk_picks_largest_magnitudes() {
        let x = [0.1f32, -5.0, 0.0, 3.0, -0.2, 4.0];
        assert_eq!(select_topk(&x, 3), vec![1, 3, 5]);
        assert_eq!(select_topk(&x, 1), vec![1]);
        // full selection: every index, ascending
        assert_eq!(select_topk(&x, 6), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn select_topk_ties_break_by_index() {
        let x = [1.0f32, -1.0, 1.0, -1.0];
        assert_eq!(select_topk(&x, 2), vec![0, 1]);
    }

    #[test]
    fn keep_count_is_ceil_and_clamped() {
        assert_eq!(keep_count(0.25, 100), 25);
        assert_eq!(keep_count(0.25, 101), 26);
        assert_eq!(keep_count(1.0, 7), 7);
        assert_eq!(keep_count(0.001, 10), 1, "never below one coordinate");
    }

    #[test]
    fn zero_slice_encodes_and_decodes_to_zero() {
        let plan = ShardPlan::new(4, 5, 2);
        for mode in [CompressionMode::Int8, CompressionMode::TopK,
                     CompressionMode::TopKInt8] {
            let mut c = Compressor::new(
                CompressionConfig { mode, keep: 0.5 },
                9,
                0,
                &plan,
            );
            let enc = c.encode_grad(0, 0, &vec![0.0f32; plan.len(0)]);
            let mut out = vec![1.0f32; plan.len(0)];
            decode_into(&enc, &mut out);
            assert!(out.iter().all(|&v| v == 0.0), "{mode:?}");
            assert!(c.residual(0).iter().all(|&v| v == 0.0), "{mode:?}");
        }
    }

    #[test]
    fn dense_mode_is_a_verbatim_copy() {
        let plan = ShardPlan::new(3, 4, 2);
        let mut c = Compressor::new(
            CompressionConfig::default(),
            1,
            0,
            &plan,
        );
        let x: Vec<f32> = (0..plan.len(1)).map(|i| i as f32 * 0.5).collect();
        let enc = c.encode_grad(1, 3, &x);
        assert_eq!(enc.encoded_bytes(), 4 * x.len() as u64);
        let mut out = vec![0.0f32; x.len()];
        decode_into(&enc, &mut out);
        assert_eq!(out, x, "mode=none must be bit-exact");
    }

    #[test]
    fn param_encoding_modes() {
        let data = vec![0.5f32, -1.0, 0.25, 0.0];
        for mode in [CompressionMode::None, CompressionMode::TopK] {
            match encode_param(mode, 7, 0, 1, &data) {
                SliceEncoding::Dense(v) => assert_eq!(v, data),
                other => panic!("{mode:?} must stay dense: {other:?}"),
            }
        }
        for mode in [CompressionMode::Int8, CompressionMode::TopKInt8] {
            let enc = encode_param(mode, 7, 0, 1, &data);
            assert!(matches!(enc, SliceEncoding::Int8 { .. }), "{mode:?}");
            let mut out = vec![0.0f32; data.len()];
            decode_into(&enc, &mut out);
            let scale = 1.0 / 127.0; // max|data| = 1.0
            for (o, d) in out.iter().zip(&data) {
                assert!((o - d).abs() <= scale + 1e-7, "{o} vs {d}");
            }
        }
    }

    #[test]
    fn param_encoding_is_deterministic_in_shard_and_version() {
        let data: Vec<f32> =
            (0..64).map(|i| ((i * 37 % 13) as f32 - 6.0) * 0.1).collect();
        let a = encode_param(CompressionMode::Int8, 5, 2, 9, &data);
        let b = encode_param(CompressionMode::Int8, 5, 2, 9, &data);
        let (mut da, mut db) = (vec![0.0; 64], vec![0.0; 64]);
        decode_into(&a, &mut da);
        decode_into(&b, &mut db);
        assert_eq!(da, db);
        let c = encode_param(CompressionMode::Int8, 5, 2, 10, &data);
        let mut dc = vec![0.0; 64];
        decode_into(&c, &mut dc);
        assert_ne!(da, dc, "version must key the rounding stream");
    }
}
