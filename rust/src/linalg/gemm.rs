//! Packed, register-tiled GEMM — the one microkernel every matmul shape
//! in the repo routes through.
//!
//! All three hot-path products reduce to the same *K-major* form
//! `C[i][j] = Σ_p Â(p, i) · B̂(p, j)` where Â is (K × M) and B̂ is
//! (K × N), each viewed from a row-major buffer either directly
//! ([`KMajor::rows_k`]) or transposed ([`KMajor::cols_k`]):
//!
//! * `C = A·B`   → Â = Aᵀ view, B̂ = B view      (classic matmul)
//! * `C = A·Bᵀ`  → Â = Aᵀ view, B̂ = Bᵀ view     (projection `Z = Δ Lᵀ`)
//! * `C = Aᵀ·B`  → Â = A view,  B̂ = B view      (gradient `G = Zᵀ Δ`)
//!
//! The kernel follows the BLIS decomposition: the K dimension is split
//! into panels of [`KC`]; per panel, B̂ is packed once into contiguous
//! [`NR`]-wide strips and Â is packed on the fly into [`MR`]-wide strips;
//! an MR×NR register-tile microkernel accumulates each C tile. Output row
//! strips are distributed over the thread pool; every C element is
//! written by exactly one strip task with a fixed K-order, so results
//! are **bit-identical across thread counts**.
//!
//! Two register-tile microkernels exist behind one dispatch point
//! ([`linalg::simd`](crate::linalg::simd)): the scalar reference below
//! (8-wide inner loop LLVM autovectorizes; bit-exact with the pre-SIMD
//! kernel, so goldens stay pinned to it) and an explicit AVX2+FMA
//! 8-lane tile. The backend is resolved **once per `gemm_into` call**
//! and threaded to every strip task, so one product never mixes
//! backends — results stay bit-identical across thread counts on
//! either path.
//!
//! Packing buffers are thread-locals reused across calls (take/put, so
//! nested/helping execution can never observe a borrowed buffer): the
//! steady state allocates nothing.

use std::cell::RefCell;
use std::ops::Range;

use crate::util::pool::ThreadPool;

/// Microkernel tile height (rows of C per A-strip).
pub const MR: usize = 4;
/// Microkernel tile width (columns of C per B-strip) — one 8-lane vector.
pub const NR: usize = 8;
/// K-panel depth: a packed B-strip is KC×NR f32 = 8 KiB, an A-strip
/// KC×MR = 4 KiB; tile + both strips sit comfortably in L1/L2.
pub const KC: usize = 256;

/// A K-major operand view: logically (k × m), element `(p, i)`.
#[derive(Clone, Copy)]
pub struct KMajor<'a> {
    data: &'a [f32],
    k: usize,
    m: usize,
    /// `false`: `data` is row-major (k × m) — element = `data[p*m + i]`.
    /// `true`:  `data` is row-major (m × k) — element = `data[i*k + p]`.
    trans: bool,
}

impl<'a> KMajor<'a> {
    /// View a row-major (k × m) buffer as the logical (k × m) operand.
    pub fn rows_k(data: &'a [f32], k: usize, m: usize) -> Self {
        assert_eq!(data.len(), k * m, "rows_k shape mismatch");
        KMajor { data, k, m, trans: false }
    }

    /// View a row-major (m × k) buffer as its transpose (k × m).
    pub fn cols_k(data: &'a [f32], m: usize, k: usize) -> Self {
        assert_eq!(data.len(), m * k, "cols_k shape mismatch");
        KMajor { data, k, m, trans: true }
    }
}

/// Pack columns `[i0, i0+h)` of `a` over depth `[p0, p1)` into a
/// zero-padded (p1−p0) × MR strip: `out[q*MR + r] = a(p0+q, i0+r)`.
fn pack_a(a: &KMajor<'_>, p0: usize, p1: usize, i0: usize, h: usize, out: &mut [f32]) {
    let kc = p1 - p0;
    debug_assert!(h >= 1 && h <= MR);
    debug_assert!(out.len() >= kc * MR);
    if h < MR {
        out[..kc * MR].fill(0.0);
    }
    if a.trans {
        // element (p, i) = data[i*k + p]: sequential reads per source row
        for r in 0..h {
            let row = &a.data[(i0 + r) * a.k..(i0 + r) * a.k + a.k];
            for (q, p) in (p0..p1).enumerate() {
                out[q * MR + r] = row[p];
            }
        }
    } else {
        // element (p, i) = data[p*m + i]: contiguous h-wide copies
        for (q, p) in (p0..p1).enumerate() {
            let src = &a.data[p * a.m + i0..p * a.m + i0 + h];
            out[q * MR..q * MR + h].copy_from_slice(src);
        }
    }
}

/// Pack the whole `[p0, p1) × [0, n)` panel of `b` into NR-wide strips:
/// `out[s*kc*NR + q*NR + c] = b(p0+q, s*NR+c)`, zero-padded on the edge.
fn pack_b(b: &KMajor<'_>, p0: usize, p1: usize, out: &mut [f32]) {
    let n = b.m;
    let kc = p1 - p0;
    let strips = n.div_ceil(NR);
    debug_assert!(out.len() >= strips * kc * NR);
    for s in 0..strips {
        let j0 = s * NR;
        let w = (n - j0).min(NR);
        let base = s * kc * NR;
        if w < NR {
            out[base..base + kc * NR].fill(0.0);
        }
        if b.trans {
            // element (p, j) = data[j*k + p]
            for c in 0..w {
                let col = &b.data[(j0 + c) * b.k..(j0 + c) * b.k + b.k];
                for q in 0..kc {
                    out[base + q * NR + c] = col[p0 + q];
                }
            }
        } else {
            // element (p, j) = data[p*n + j]
            for q in 0..kc {
                let src = &b.data[(p0 + q) * n + j0..(p0 + q) * n + j0 + w];
                out[base + q * NR..base + q * NR + w].copy_from_slice(src);
            }
        }
    }
}

/// The scalar register tile: MR×NR accumulators, 8-wide FMA-friendly
/// inner loop. This is the bit-exact reference the golden tests pin —
/// its float order must never change.
#[inline(always)]
fn microkernel(kc: usize, apack: &[f32], bstrip: &[f32], acc: &mut [[f32; NR]; MR]) {
    for q in 0..kc {
        let a: &[f32; MR] = apack[q * MR..q * MR + MR].try_into().unwrap();
        let b: &[f32; NR] = bstrip[q * NR..q * NR + NR].try_into().unwrap();
        for r in 0..MR {
            let ar = a[r];
            for c in 0..NR {
                acc[r][c] += ar * b[c];
            }
        }
    }
}

/// One register tile through the backend chosen for this `gemm_into`
/// call: the explicit 8-lane tile when `simd`, else the scalar
/// reference above.
#[inline(always)]
fn microkernel_dispatch(
    simd: bool,
    kc: usize,
    apack: &[f32],
    bstrip: &[f32],
    acc: &mut [[f32; NR]; MR],
) {
    if !crate::linalg::simd::gemm_microkernel_simd(
        simd, kc, apack, bstrip, acc,
    ) {
        microkernel(kc, apack, bstrip, acc);
    }
}

/// Raw C pointer that may cross task boundaries. Each strip task writes a
/// disjoint row range, so concurrent use is race-free by construction.
#[derive(Clone, Copy)]
struct CPtr(*mut f32);
unsafe impl Send for CPtr {}
unsafe impl Sync for CPtr {}

/// Add the valid h×w corner of an accumulator tile into C.
///
/// SAFETY: caller guarantees rows `[i0, i0+h)` of the (m × n) buffer at
/// `cptr` are owned exclusively by this task.
unsafe fn store_tile(
    acc: &[[f32; NR]; MR],
    cptr: CPtr,
    n: usize,
    i0: usize,
    h: usize,
    j0: usize,
    w: usize,
) {
    for r in 0..h {
        let base = cptr.0.add((i0 + r) * n + j0);
        for (c, &v) in acc[r][..w].iter().enumerate() {
            *base.add(c) += v;
        }
    }
}

thread_local! {
    static APACK: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    static BPACK: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Borrow a thread-local scratch buffer of at least `len` floats. The
/// buffer is *taken* out of the slot for the duration (not held borrowed),
/// so re-entrant use on the same thread just allocates a fresh one.
fn with_scratch<R>(
    slot: &'static std::thread::LocalKey<RefCell<Vec<f32>>>,
    len: usize,
    f: impl FnOnce(&mut [f32]) -> R,
) -> R {
    let mut buf = slot.with(|c| c.take());
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
    let r = f(&mut buf[..len]);
    slot.with(|c| c.replace(buf));
    r
}

/// Process output-row strips `[s0, s1)` of C for one K-panel `[p0, p1)`.
fn run_strips(
    a: &KMajor<'_>,
    bpack: &[f32],
    cptr: CPtr,
    m: usize,
    n: usize,
    p0: usize,
    p1: usize,
    simd: bool,
    strips: Range<usize>,
) {
    let kc = p1 - p0;
    let b_strips = n.div_ceil(NR);
    with_scratch(&APACK, kc * MR, |apack| {
        for s in strips {
            let i0 = s * MR;
            let h = (m - i0).min(MR);
            pack_a(a, p0, p1, i0, h, apack);
            for sb in 0..b_strips {
                let j0 = sb * NR;
                let w = (n - j0).min(NR);
                let bstrip = &bpack[sb * kc * NR..(sb + 1) * kc * NR];
                let mut acc = [[0.0f32; NR]; MR];
                microkernel_dispatch(simd, kc, apack, bstrip, &mut acc);
                // SAFETY: strip `s` owns C rows [i0, i0+h) exclusively.
                unsafe { store_tile(&acc, cptr, n, i0, h, j0, w) };
            }
        }
    });
}

/// Problems below this MAC count stay serial: tile/pack setup and the
/// scope barrier would dominate real work.
const PAR_MIN_MACS: usize = 32 * 1024;

/// `C = beta·C + Â·B̂` over K-major views; C is (m × n) row-major.
///
/// `pool: None` (or a 1-thread pool, or a small problem) runs serially on
/// the calling thread — the path the sharded engine uses inside its own
/// parallel region.
pub fn gemm_into(
    a: KMajor<'_>,
    b: KMajor<'_>,
    c: &mut [f32],
    beta: f32,
    pool: Option<&ThreadPool>,
) {
    let (kk, m, n) = (a.k, a.m, b.m);
    assert_eq!(b.k, kk, "gemm inner-dimension mismatch");
    assert_eq!(c.len(), m * n, "gemm output shape mismatch");
    if beta == 0.0 {
        c.fill(0.0);
    } else if beta != 1.0 {
        for v in c.iter_mut() {
            *v *= beta;
        }
    }
    if m == 0 || n == 0 || kk == 0 {
        return;
    }
    let pool =
        pool.filter(|p| p.threads() > 1 && m * n * kk >= PAR_MIN_MACS);
    // resolve the microkernel backend once: every strip task of this
    // product uses the same tile, on any thread
    let simd = crate::linalg::simd::simd_active();
    let a_strips = m.div_ceil(MR);
    let b_strips = n.div_ceil(NR);
    let cptr = CPtr(c.as_mut_ptr());
    let kc_max = KC.min(kk);
    with_scratch(&BPACK, b_strips * kc_max * NR, |bpack| {
        let mut p0 = 0;
        while p0 < kk {
            let p1 = (p0 + KC).min(kk);
            let kc = p1 - p0;
            let blen = b_strips * kc * NR;
            pack_b(&b, p0, p1, &mut bpack[..blen]);
            let bp: &[f32] = &bpack[..blen];
            let aref = &a;
            match pool {
                Some(p) => p.for_each_range(a_strips, |r| {
                    run_strips(aref, bp, cptr, m, n, p0, p1, simd, r)
                }),
                None => run_strips(
                    aref, bp, cptr, m, n, p0, p1, simd, 0..a_strips,
                ),
            }
            p0 = p1;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::rng::Pcg32;

    fn randm(rng: &mut Pcg32, r: usize, c: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        rng.fill_gaussian(&mut m.data, 0.0, 1.0);
        m
    }

    fn naive_kmajor(a: &Mat, at: bool, b: &Mat, bt: bool) -> Mat {
        // computes Âᵀ·B̂ from K-major logical views built off a and b
        let (kk, m) = if at { (a.cols, a.rows) } else { (a.rows, a.cols) };
        let n = if bt { b.rows } else { b.cols };
        let av = |p: usize, i: usize| if at { a.at(i, p) } else { a.at(p, i) };
        let bv = |p: usize, j: usize| if bt { b.at(j, p) } else { b.at(p, j) };
        let mut c = Mat::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for p in 0..kk {
                    s += av(p, i) * bv(p, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn all_view_combinations_match_naive() {
        let mut rng = Pcg32::new(11);
        // (kk, m, n) shapes straddling MR/NR/KC boundaries
        for &(kk, m, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 4, 8),
            (17, 9, 23),
            (64, 33, 40),
            (257, 13, 19),
            (300, 65, 70),
        ] {
            for &(at, bt) in
                &[(false, false), (true, false), (false, true), (true, true)]
            {
                let a = if at { randm(&mut rng, m, kk) } else { randm(&mut rng, kk, m) };
                let b = if bt { randm(&mut rng, n, kk) } else { randm(&mut rng, kk, n) };
                let av = if at {
                    KMajor::cols_k(&a.data, m, kk)
                } else {
                    KMajor::rows_k(&a.data, kk, m)
                };
                let bv = if bt {
                    KMajor::cols_k(&b.data, n, kk)
                } else {
                    KMajor::rows_k(&b.data, kk, n)
                };
                let mut c = Mat::zeros(m, n);
                gemm_into(av, bv, &mut c.data, 0.0, None);
                let want = naive_kmajor(&a, at, &b, bt);
                assert!(
                    c.max_abs_diff(&want) < 1e-3 * (1.0 + kk as f32 * 0.01),
                    "(kk={kk},m={m},n={n},at={at},bt={bt})"
                );
            }
        }
    }

    #[test]
    fn beta_accumulates_and_scales() {
        let mut rng = Pcg32::new(12);
        let a = randm(&mut rng, 20, 6); // (kk × m)
        let b = randm(&mut rng, 20, 9); // (kk × n)
        let prod = naive_kmajor(&a, false, &b, false);
        let mut c = randm(&mut rng, 6, 9);
        let c0 = c.clone();
        gemm_into(
            KMajor::rows_k(&a.data, 20, 6),
            KMajor::rows_k(&b.data, 20, 9),
            &mut c.data,
            1.0,
            None,
        );
        let mut want = prod.clone();
        want.axpy_inplace(1.0, &c0);
        assert!(c.max_abs_diff(&want) < 1e-4);

        let mut c2 = c0.clone();
        gemm_into(
            KMajor::rows_k(&a.data, 20, 6),
            KMajor::rows_k(&b.data, 20, 9),
            &mut c2.data,
            0.5,
            None,
        );
        let mut want2 = c0.clone();
        want2.scale_inplace(0.5);
        want2.axpy_inplace(1.0, &prod);
        assert!(c2.max_abs_diff(&want2) < 1e-4);
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let mut rng = Pcg32::new(13);
        let (kk, m, n) = (310, 90, 77);
        let a = randm(&mut rng, m, kk);
        let b = randm(&mut rng, kk, n);
        let mut serial = Mat::zeros(m, n);
        gemm_into(
            KMajor::cols_k(&a.data, m, kk),
            KMajor::rows_k(&b.data, kk, n),
            &mut serial.data,
            0.0,
            None,
        );
        for threads in [2usize, 3, 4] {
            let pool = ThreadPool::new(threads);
            let mut par = Mat::zeros(m, n);
            gemm_into(
                KMajor::cols_k(&a.data, m, kk),
                KMajor::rows_k(&b.data, kk, n),
                &mut par.data,
                0.0,
                Some(&pool),
            );
            assert_eq!(
                serial.data, par.data,
                "strip-parallel GEMM must be bit-identical ({threads} threads)"
            );
        }
    }

    #[test]
    fn empty_dims_zero_output_on_beta_zero() {
        let a: Vec<f32> = vec![];
        let b: Vec<f32> = vec![];
        let mut c = vec![7.0f32; 12];
        // kk = 0: C must still be beta-scaled (here: zeroed)
        gemm_into(
            KMajor::rows_k(&a, 0, 3),
            KMajor::rows_k(&b, 0, 4),
            &mut c,
            0.0,
            None,
        );
        assert!(c.iter().all(|&v| v == 0.0));
    }
}
