//! Binary matrix persistence (save/load learned metrics).
//!
//! Format: `DMLPSMAT` magic, u64 LE rows, u64 LE cols, then rows·cols
//! f32 LE values. Used by `dmlps train --save-model` / `dmlps eval`,
//! and embedded as the payload codec inside
//! [`MetricModel`](crate::session::MetricModel) artifacts via
//! [`write_mat`] / [`read_mat`].

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::Mat;

const MAGIC: &[u8; 8] = b"DMLPSMAT";

/// Element cap a `DMLPSMAT` header may claim: 2^28 f32s (1 GiB of
/// payload). Far above any artifact this crate produces (the paper's
/// largest shape is k=600 × d=21504 ≈ 1.3e7 elements), low enough that
/// a corrupt 24-byte header can never demand a multi-GB allocation.
const MAX_ELEMS: u64 = 1 << 28;

/// Elements decoded per allocation step in [`read_mat`]: reading grows
/// the buffer in 256 KiB chunks as payload bytes actually arrive, so a
/// truncated file fails at EOF having allocated at most one chunk
/// beyond the bytes that exist — never the header-claimed size up
/// front.
const CHUNK_ELEMS: usize = 1 << 16;

/// Crash-atomically replace `path` with whatever `write` produces.
///
/// The contract every persisted artifact in this crate relies on
/// (models, matrices, checkpoints, manifests): a reader never observes
/// a torn file. The bytes go to a uniquely-named temp file *in the
/// target directory* (same filesystem, so the rename cannot cross
/// devices), are flushed and fsynced, and only then renamed over
/// `path` — a process killed at any instant leaves either the old
/// complete file or the new complete file, plus at worst one orphaned
/// `.tmp` sibling. On any error the temp file is removed and `path`
/// is untouched.
pub fn atomic_write<F>(path: &Path, write: F) -> anyhow::Result<()>
where
    F: FnOnce(
        &mut std::io::BufWriter<std::fs::File>,
    ) -> anyhow::Result<()>,
{
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let name = path.file_name().ok_or_else(|| {
        anyhow::anyhow!("atomic_write: no file name in {}", path.display())
    })?;
    let dir: PathBuf = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let tmp = dir.join(format!(
        ".{}.{}.{}.tmp",
        name.to_string_lossy(),
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let result = (|| -> anyhow::Result<()> {
        let f = std::fs::File::create(&tmp)?;
        let mut w = std::io::BufWriter::new(f);
        write(&mut w)?;
        let f = w.into_inner().map_err(|e| e.into_error())?;
        f.sync_all()?;
        std::fs::rename(&tmp, path)?;
        // make the rename itself durable where directory fsync is
        // supported; best-effort elsewhere
        let _ = std::fs::File::open(&dir).and_then(|d| d.sync_all());
        Ok(())
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Write one matrix in the `DMLPSMAT` framing to any byte sink.
pub fn write_mat<W: Write>(w: &mut W, m: &Mat) -> anyhow::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&(m.rows as u64).to_le_bytes())?;
    w.write_all(&(m.cols as u64).to_le_bytes())?;
    for v in &m.data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read one `DMLPSMAT`-framed matrix from any byte source.
pub fn read_mat<R: Read>(r: &mut R) -> anyhow::Result<Mat> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a DMLPSMAT payload");
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let rows = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let cols = u64::from_le_bytes(b8) as usize;
    let claimed = (rows as u64).checked_mul(cols as u64);
    anyhow::ensure!(
        claimed.is_some_and(|n| n <= MAX_ELEMS),
        "matrix too large ({rows}x{cols}, cap {MAX_ELEMS} elements)"
    );
    let total = rows * cols;
    let mut data: Vec<f32> = Vec::new();
    let mut bytes = vec![0u8; 4 * CHUNK_ELEMS.min(total.max(1))];
    while data.len() < total {
        let n = CHUNK_ELEMS.min(total - data.len());
        let b = &mut bytes[..4 * n];
        r.read_exact(b)?;
        data.reserve(n);
        for c in b.chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
    }
    Ok(Mat { rows, cols, data })
}

impl Mat {
    /// Crash-atomic save (see [`atomic_write`]): a kill mid-save never
    /// leaves a torn file where a complete one stood.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        atomic_write(path, |f| write_mat(f, self))
    }

    pub fn load(path: &Path) -> anyhow::Result<Mat> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        read_mat(&mut f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn roundtrip() {
        let mut rng = Pcg32::new(0);
        let mut m = Mat::zeros(17, 23);
        rng.fill_gaussian(&mut m.data, 0.0, 1.0);
        let path = std::env::temp_dir().join("dmlps_mat_roundtrip.bin");
        m.save(&path).unwrap();
        let m2 = Mat::load(&path).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("dmlps_mat_garbage.bin");
        std::fs::write(&path, b"not a matrix").unwrap();
        assert!(Mat::load(&path).is_err());
    }

    /// A corrupt header claiming absurd dims must fail the cap check
    /// up front — never attempt the multi-GB allocation the old
    /// `1<<33` cap allowed.
    #[test]
    fn rejects_corrupt_header_without_allocating() {
        for (rows, cols) in [
            (u64::MAX, u64::MAX),         // overflow bait
            (1u64 << 40, 1),              // huge rows
            (1, (1u64 << 28) + 1),        // one past the cap
            (1u64 << 20, 1u64 << 20),     // 4 TiB claim
        ] {
            let mut buf: Vec<u8> = Vec::new();
            buf.extend_from_slice(MAGIC);
            buf.extend_from_slice(&rows.to_le_bytes());
            buf.extend_from_slice(&cols.to_le_bytes());
            let err = read_mat(&mut std::io::Cursor::new(buf))
                .expect_err("corrupt header must be rejected");
            assert!(
                err.to_string().contains("too large"),
                "unexpected error: {err}"
            );
        }
    }

    /// A header whose claimed size passes the cap but whose payload is
    /// truncated must fail at EOF, having allocated at most one chunk
    /// beyond the bytes that exist.
    #[test]
    fn rejects_truncated_payload() {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&1000u64.to_le_bytes());
        buf.extend_from_slice(&1000u64.to_le_bytes());
        buf.extend_from_slice(&[0u8; 64]); // 16 of 1e6 values
        assert!(read_mat(&mut std::io::Cursor::new(buf)).is_err());
    }

    /// The crash-safety contract behind every persisted artifact: a
    /// torn file (what an in-place writer killed mid-save leaves) must
    /// fail to load cleanly, and an atomic save over it must restore a
    /// loadable file without littering temp files.
    #[test]
    fn atomic_save_replaces_torn_file_and_leaves_no_temp() {
        let dir =
            std::env::temp_dir().join("dmlps_atomic_save_testdir");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metric.bin");

        let mut rng = Pcg32::new(3);
        let mut m = Mat::zeros(11, 7);
        rng.fill_gaussian(&mut m.data, 0.0, 1.0);
        let mut full: Vec<u8> = Vec::new();
        write_mat(&mut full, &m).unwrap();

        // simulate a kill mid-save: only a prefix reached disk
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(Mat::load(&path).is_err(), "torn file must not parse");

        // atomic save replaces the torn file wholesale
        m.save(&path).unwrap();
        assert_eq!(Mat::load(&path).unwrap(), m);

        // and leaves no temp-file litter behind
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp litter: {leftovers:?}");

        // a failed write must leave the previous complete file intact
        let err = atomic_write(&path, |_w| {
            anyhow::bail!("simulated mid-write failure")
        });
        assert!(err.is_err());
        assert_eq!(Mat::load(&path).unwrap(), m);
    }

    #[test]
    fn stream_codec_roundtrips_in_memory() {
        let mut rng = Pcg32::new(7);
        let mut m = Mat::zeros(5, 9);
        rng.fill_gaussian(&mut m.data, 0.0, 1.0);
        let mut buf: Vec<u8> = Vec::new();
        write_mat(&mut buf, &m).unwrap();
        assert_eq!(buf.len(), 8 + 8 + 8 + 4 * 5 * 9);
        let m2 = read_mat(&mut std::io::Cursor::new(buf)).unwrap();
        assert_eq!(m, m2);
    }
}
