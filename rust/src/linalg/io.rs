//! Binary matrix persistence (save/load learned metrics).
//!
//! Format: `DMLPSMAT` magic, u64 LE rows, u64 LE cols, then rows·cols
//! f32 LE values. Used by `dmlps train --save-model` / `dmlps eval`,
//! and embedded as the payload codec inside
//! [`MetricModel`](crate::session::MetricModel) artifacts via
//! [`write_mat`] / [`read_mat`].

use std::io::{Read, Write};
use std::path::Path;

use super::Mat;

const MAGIC: &[u8; 8] = b"DMLPSMAT";

/// Write one matrix in the `DMLPSMAT` framing to any byte sink.
pub fn write_mat<W: Write>(w: &mut W, m: &Mat) -> anyhow::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&(m.rows as u64).to_le_bytes())?;
    w.write_all(&(m.cols as u64).to_le_bytes())?;
    for v in &m.data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read one `DMLPSMAT`-framed matrix from any byte source.
pub fn read_mat<R: Read>(r: &mut R) -> anyhow::Result<Mat> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a DMLPSMAT payload");
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let rows = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let cols = u64::from_le_bytes(b8) as usize;
    anyhow::ensure!(
        rows.saturating_mul(cols) < (1 << 33),
        "matrix too large ({rows}x{cols})"
    );
    let mut data = vec![0.0f32; rows * cols];
    let mut b4 = [0u8; 4];
    for v in data.iter_mut() {
        r.read_exact(&mut b4)?;
        *v = f32::from_le_bytes(b4);
    }
    Ok(Mat { rows, cols, data })
}

impl Mat {
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        write_mat(&mut f, self)
    }

    pub fn load(path: &Path) -> anyhow::Result<Mat> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        read_mat(&mut f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn roundtrip() {
        let mut rng = Pcg32::new(0);
        let mut m = Mat::zeros(17, 23);
        rng.fill_gaussian(&mut m.data, 0.0, 1.0);
        let path = std::env::temp_dir().join("dmlps_mat_roundtrip.bin");
        m.save(&path).unwrap();
        let m2 = Mat::load(&path).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("dmlps_mat_garbage.bin");
        std::fs::write(&path, b"not a matrix").unwrap();
        assert!(Mat::load(&path).is_err());
    }

    #[test]
    fn stream_codec_roundtrips_in_memory() {
        let mut rng = Pcg32::new(7);
        let mut m = Mat::zeros(5, 9);
        rng.fill_gaussian(&mut m.data, 0.0, 1.0);
        let mut buf: Vec<u8> = Vec::new();
        write_mat(&mut buf, &m).unwrap();
        assert_eq!(buf.len(), 8 + 8 + 8 + 4 * 5 * 9);
        let m2 = read_mat(&mut std::io::Cursor::new(buf)).unwrap();
        assert_eq!(m, m2);
    }
}
