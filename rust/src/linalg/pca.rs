//! PCA dimensionality reduction.
//!
//! The KISS baseline requires invertible covariance matrices, which the
//! paper obtains by reducing MNIST to 600 dimensions with PCA (§5.4). We
//! implement PCA over the sample covariance via the Jacobi eigensolver.

use super::eigen::eigh;
use super::Mat;

/// A fitted PCA transform: `project` maps (n, d) data to (n, out_dim).
pub struct Pca {
    /// (out_dim, d) — rows are principal directions (descending variance).
    pub components: Mat,
    pub mean: Vec<f32>,
    /// Eigenvalues (variances) for the kept components, descending.
    pub explained: Vec<f32>,
}

impl Pca {
    /// Fit on rows of `x` (n_samples × d), keeping `out_dim` components.
    ///
    /// Uses the d×d covariance eigendecomposition — O(d³) — which is fine
    /// for baseline-scale d (the paper applies KISS after PCA to 600 dims;
    /// our baseline configs keep d ≤ a few hundred).
    pub fn fit(x: &Mat, out_dim: usize) -> Pca {
        let (n, d) = (x.rows, x.cols);
        assert!(out_dim <= d, "out_dim {out_dim} > d {d}");
        assert!(n >= 2, "need at least 2 samples");
        // mean
        let mut mean = vec![0.0f32; d];
        for r in 0..n {
            for (m, v) in mean.iter_mut().zip(x.row(r)) {
                *m += v;
            }
        }
        for m in &mut mean {
            *m /= n as f32;
        }
        // covariance = Xcᵀ Xc / (n-1)
        let mut xc = x.clone();
        for r in 0..n {
            for (v, m) in xc.row_mut(r).iter_mut().zip(&mean) {
                *v -= m;
            }
        }
        let mut cov = xc.matmul_at(&xc);
        cov.scale_inplace(1.0 / (n - 1) as f32);
        let e = eigh(&cov);
        // take top `out_dim` eigenvectors (eigh sorts ascending)
        let mut components = Mat::zeros(out_dim, d);
        let mut explained = Vec::with_capacity(out_dim);
        for i in 0..out_dim {
            let c = d - 1 - i; // descending
            explained.push(e.values[c].max(0.0));
            for j in 0..d {
                *components.at_mut(i, j) = e.vectors.at(j, c);
            }
        }
        Pca { components, mean, explained }
    }

    /// Project rows of `x` into the PCA space: (n, out_dim).
    pub fn project(&self, x: &Mat) -> Mat {
        let mut xc = x.clone();
        for r in 0..x.rows {
            for (v, m) in xc.row_mut(r).iter_mut().zip(&self.mean) {
                *v -= m;
            }
        }
        xc.matmul_bt(&self.components)
    }

    /// Project a single vector.
    pub fn project_vec(&self, x: &[f32]) -> Vec<f32> {
        let centered: Vec<f32> =
            x.iter().zip(&self.mean).map(|(v, m)| v - m).collect();
        self.components.matvec(&centered)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Data concentrated along a known direction is recovered by PC 1.
    #[test]
    fn recovers_dominant_direction() {
        let mut rng = Pcg32::new(0);
        let d = 6;
        let n = 400;
        let dir: Vec<f32> = {
            let mut v = vec![0.0f32; d];
            rng.fill_gaussian(&mut v, 0.0, 1.0);
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            v.iter().map(|x| x / norm).collect()
        };
        let mut x = Mat::zeros(n, d);
        for r in 0..n {
            let t = rng.gaussian() as f32 * 5.0; // big variance along dir
            for c in 0..d {
                *x.at_mut(r, c) =
                    t * dir[c] + 0.1 * rng.gaussian() as f32;
            }
        }
        let pca = Pca::fit(&x, 2);
        let pc1 = pca.components.row(0);
        let cos: f32 = pc1.iter().zip(&dir).map(|(a, b)| a * b).sum();
        assert!(cos.abs() > 0.98, "cos={cos}");
        assert!(pca.explained[0] > 10.0 * pca.explained[1]);
    }

    #[test]
    fn projection_shape_and_centering() {
        let mut rng = Pcg32::new(1);
        let mut x = Mat::zeros(50, 8);
        rng.fill_gaussian(&mut x.data, 3.0, 1.0);
        let pca = Pca::fit(&x, 3);
        let p = pca.project(&x);
        assert_eq!((p.rows, p.cols), (50, 3));
        // projected data is centered
        for c in 0..3 {
            let mean: f32 =
                (0..50).map(|r| p.at(r, c)).sum::<f32>() / 50.0;
            assert!(mean.abs() < 0.1, "mean={mean}");
        }
    }

    #[test]
    fn full_dim_projection_preserves_distances() {
        let mut rng = Pcg32::new(2);
        let mut x = Mat::zeros(30, 5);
        rng.fill_gaussian(&mut x.data, 0.0, 1.0);
        let pca = Pca::fit(&x, 5);
        let p = pca.project(&x);
        // pairwise distances preserved under orthogonal transform
        for i in 0..5 {
            for j in (i + 1)..6 {
                let d_orig: f32 = x
                    .row(i)
                    .iter()
                    .zip(x.row(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                let d_proj: f32 = p
                    .row(i)
                    .iter()
                    .zip(p.row(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                assert!((d_orig - d_proj).abs() < 1e-2 * (1.0 + d_orig));
            }
        }
    }

    #[test]
    fn project_vec_matches_project() {
        let mut rng = Pcg32::new(3);
        let mut x = Mat::zeros(20, 6);
        rng.fill_gaussian(&mut x.data, 0.0, 1.0);
        let pca = Pca::fit(&x, 4);
        let p = pca.project(&x);
        let pv = pca.project_vec(x.row(7));
        for c in 0..4 {
            assert!((p.at(7, c) - pv[c]).abs() < 1e-5);
        }
    }
}
