//! Cholesky factorization, triangular solves, SPD inverse.
//!
//! Used by the KISS baseline (inverting pair-difference covariances) and
//! by tests as an independent PSD check.

use super::Mat;

/// Lower-triangular Cholesky factor of an SPD matrix: A = G Gᵀ.
/// Returns `None` if the matrix is not (numerically) positive definite.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut g = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j) as f64;
            for k in 0..j {
                s -= g.at(i, k) as f64 * g.at(j, k) as f64;
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                *g.at_mut(i, j) = (s as f32).sqrt().max(f32::MIN_POSITIVE);
            } else {
                *g.at_mut(i, j) = (s / g.at(j, j) as f64) as f32;
            }
        }
    }
    Some(g)
}

/// Solve G y = b for lower-triangular G (forward substitution).
pub fn solve_lower(g: &Mat, b: &[f32]) -> Vec<f32> {
    let n = g.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= g.at(i, k) as f64 * y[k] as f64;
        }
        y[i] = (s / g.at(i, i) as f64) as f32;
    }
    y
}

/// Solve Gᵀ x = y for lower-triangular G (back substitution).
pub fn solve_lower_t(g: &Mat, y: &[f32]) -> Vec<f32> {
    let n = g.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = y[i] as f64;
        for k in (i + 1)..n {
            s -= g.at(k, i) as f64 * x[k] as f64;
        }
        x[i] = (s / g.at(i, i) as f64) as f32;
    }
    x
}

/// Solve A x = b for SPD A via Cholesky.
pub fn solve_spd(a: &Mat, b: &[f32]) -> Option<Vec<f32>> {
    let g = cholesky(a)?;
    Some(solve_lower_t(&g, &solve_lower(&g, b)))
}

/// Inverse of an SPD matrix via Cholesky (column-by-column solves).
pub fn inverse_spd(a: &Mat) -> Option<Mat> {
    let n = a.rows;
    let g = cholesky(a)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0f32; n];
    for c in 0..n {
        e[c] = 1.0;
        let x = solve_lower_t(&g, &solve_lower(&g, &e));
        for r in 0..n {
            *inv.at_mut(r, c) = x[r];
        }
        e[c] = 0.0;
    }
    // Symmetrize to clean round-off.
    inv.symmetrize_inplace();
    Some(inv)
}

/// log-determinant of an SPD matrix (via Cholesky).
pub fn logdet_spd(a: &Mat) -> Option<f64> {
    let g = cholesky(a)?;
    Some(2.0 * (0..g.rows).map(|i| (g.at(i, i) as f64).ln()).sum::<f64>())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Random SPD matrix A = B Bᵀ + eps I.
    fn rand_spd(rng: &mut Pcg32, n: usize) -> Mat {
        let mut b = Mat::zeros(n, n);
        rng.fill_gaussian(&mut b.data, 0.0, 1.0);
        let mut a = b.matmul_bt(&b);
        for i in 0..n {
            *a.at_mut(i, i) += 0.5;
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg32::new(0);
        for &n in &[1, 2, 5, 20, 50] {
            let a = rand_spd(&mut rng, n);
            let g = cholesky(&a).expect("SPD");
            let rec = g.matmul_bt(&g);
            assert!(rec.max_abs_diff(&a) < 1e-2 * n as f32, "n={n}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1, 3
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_spd_residual_small() {
        let mut rng = Pcg32::new(1);
        let a = rand_spd(&mut rng, 12);
        let b: Vec<f32> = (0..12).map(|i| (i as f32) - 6.0).collect();
        let x = solve_spd(&a, &b).unwrap();
        let ax = a.matvec(&x);
        for i in 0..12 {
            assert!((ax[i] - b[i]).abs() < 1e-2, "{} vs {}", ax[i], b[i]);
        }
    }

    #[test]
    fn inverse_spd_gives_identity() {
        let mut rng = Pcg32::new(2);
        let a = rand_spd(&mut rng, 15);
        let inv = inverse_spd(&a).unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.max_abs_diff(&Mat::eye(15)) < 5e-2);
    }

    #[test]
    fn logdet_matches_eigen_for_diagonal() {
        let a = Mat::from_vec(3, 3,
            vec![2.0, 0.0, 0.0, 0.0, 3.0, 0.0, 0.0, 0.0, 4.0]);
        let ld = logdet_spd(&a).unwrap();
        assert!((ld - (24.0f64).ln()).abs() < 1e-5);
    }
}
