//! Dense linear algebra substrate (no external BLAS in the vendor set).
//!
//! Provides the row-major f32 [`Mat`] type whose matmuls all route
//! through the packed, register-tiled, pool-parallel [`gemm`] microkernel
//! (the same one the native DML engine builds on), plus the
//! factorizations the single-machine baselines need: Cholesky ([`chol`]),
//! Jacobi eigendecomposition ([`eigen`]), and PCA ([`pca`]).

pub mod chol;
pub mod eigen;
pub mod gemm;
pub mod io;
pub mod pca;
pub mod simd;

use self::gemm::KMajor;
use crate::util::pool;

/// Row-major dense f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

/// Tile edge for the cache-blocked transpose (32×32 f32 = 4 KiB: one
/// read tile + one write tile fit in L1 with room to spare).
const TRANS_BLK: usize = 32;

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Identity-like rectangular matrix scaled by `s` (used to init L).
    pub fn scaled_eye(rows: usize, cols: usize, s: f32) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows.min(cols) {
            m.data[i * cols + i] = s;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Cache-blocked transpose: both the reads and the writes stay within
    /// one [`TRANS_BLK`]² tile at a time, so neither side strides through
    /// memory a full row apart per element.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r0 in (0..self.rows).step_by(TRANS_BLK) {
            let r1 = (r0 + TRANS_BLK).min(self.rows);
            for c0 in (0..self.cols).step_by(TRANS_BLK) {
                let c1 = (c0 + TRANS_BLK).min(self.cols);
                for r in r0..r1 {
                    for c in c0..c1 {
                        out.data[c * self.rows + r] =
                            self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn scale_inplace(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// self += s * other
    pub fn axpy_inplace(&mut self, s: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    // ------------------------------------------------------------------
    // matmul kernels
    // ------------------------------------------------------------------

    /// C = A · B (blocked ikj; autovectorizes on the innermost j loop).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        matmul_into(self, b, &mut c, 0.0);
        c
    }

    /// C = A · Bᵀ. The DML hot path's shape (`Z = D Lᵀ`): both operands
    /// are traversed row-major, so rows dot rows — ideal locality.
    pub fn matmul_bt(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_bt shape mismatch");
        let mut c = Mat::zeros(self.rows, b.rows);
        matmul_bt_into(self, b, &mut c);
        c
    }

    /// C = Aᵀ · B (the gradient outer-product shape `G = Zᵀ D`).
    pub fn matmul_at(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "matmul_at shape mismatch");
        let mut c = Mat::zeros(self.cols, b.cols);
        matmul_at_into(self, b, &mut c, 0.0);
        c
    }

    /// y = A · x for a vector x (row dots via the 4-accumulator [`dot`]).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|r| dot(self.row(r), x)).collect()
    }

    /// Max |a - b| across entries (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Symmetrize in place: M = (M + Mᵀ)/2 (numerical hygiene for the
    /// baselines' PSD iterates).
    pub fn symmetrize_inplace(&mut self) {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        for i in 0..n {
            for j in (i + 1)..n {
                let avg = 0.5 * (self.data[i * n + j] + self.data[j * n + i]);
                self.data[i * n + j] = avg;
                self.data[j * n + i] = avg;
            }
        }
    }
}

/// C = beta·C + A·B via the packed tiled kernel, parallel over the
/// global pool.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat, beta: f32) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let p = pool::global();
    gemm::gemm_into(
        KMajor::cols_k(&a.data, a.rows, a.cols),
        KMajor::rows_k(&b.data, b.rows, b.cols),
        &mut c.data,
        beta,
        Some(&p),
    );
}

/// C = A · Bᵀ (the DML projection shape `Z = Δ Lᵀ`) via the packed tiled
/// kernel, parallel over the global pool.
pub fn matmul_bt_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.rows));
    let p = pool::global();
    gemm::gemm_into(
        KMajor::cols_k(&a.data, a.rows, a.cols),
        KMajor::cols_k(&b.data, b.rows, b.cols),
        &mut c.data,
        0.0,
        Some(&p),
    );
}

/// C = beta·C + Aᵀ·B (the gradient outer-product shape `G = Zᵀ Δ`;
/// A is (r×m), B is (r×n), C is (m×n)) via the packed tiled kernel,
/// parallel over the global pool.
pub fn matmul_at_into(a: &Mat, b: &Mat, c: &mut Mat, beta: f32) {
    assert_eq!(a.rows, b.rows);
    assert_eq!((c.rows, c.cols), (a.cols, b.cols));
    let p = pool::global();
    gemm::gemm_into(
        KMajor::rows_k(&a.data, a.rows, a.cols),
        KMajor::rows_k(&b.data, b.rows, b.cols),
        &mut c.data,
        beta,
        Some(&p),
    );
}

/// Dot product with 4 independent accumulators (breaks the fp dependency
/// chain so LLVM can vectorize + pipeline).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n4 = a.len() & !3;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut i = 0;
    while i < n4 {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut tail = 0.0f32;
    while i < a.len() {
        tail += a[i] * b[i];
        i += 1;
    }
    (s0 + s1) + (s2 + s3) + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randm(rng: &mut Pcg32, r: usize, c: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        rng.fill_gaussian(&mut m.data, 0.0, 1.0);
        m
    }

    fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg32::new(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (64, 64, 64), (65, 130, 3),
                            (100, 17, 33), (33, 300, 41), (70, 513, 9)] {
            let a = randm(&mut rng, m, k);
            let b = randm(&mut rng, k, n);
            let got = a.matmul(&b);
            let want = matmul_naive(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-3 * k as f32,
                    "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_bt_matches_transpose_path() {
        let mut rng = Pcg32::new(1);
        let a = randm(&mut rng, 10, 20);
        let b = randm(&mut rng, 15, 20);
        let got = a.matmul_bt(&b);
        let want = a.matmul(&b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn matmul_at_matches_transpose_path() {
        let mut rng = Pcg32::new(2);
        let a = randm(&mut rng, 12, 8);
        let b = randm(&mut rng, 12, 9);
        let got = a.matmul_at(&b);
        let want = a.transpose().matmul(&b);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn matmul_at_into_accumulates() {
        let mut rng = Pcg32::new(3);
        let a = randm(&mut rng, 6, 4);
        let b = randm(&mut rng, 6, 5);
        let mut c = randm(&mut rng, 4, 5);
        let c0 = c.clone();
        matmul_at_into(&a, &b, &mut c, 1.0);
        let mut want = a.transpose().matmul(&b);
        want.axpy_inplace(1.0, &c0);
        assert!(c.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg32::new(4);
        let a = randm(&mut rng, 7, 5);
        let x: Vec<f32> = (0..5).map(|i| i as f32).collect();
        let y = a.matvec(&x);
        let xm = Mat::from_vec(5, 1, x);
        let want = a.matmul(&xm);
        for i in 0..7 {
            assert!((y[i] - want.at(i, 0)).abs() < 1e-5);
        }
    }

    #[test]
    fn blocked_transpose_matches_naive() {
        let mut rng = Pcg32::new(9);
        for &(r, c) in &[(1, 1), (7, 3), (31, 33), (64, 64), (65, 130),
                         (100, 41)] {
            let a = randm(&mut rng, r, c);
            let t = a.transpose();
            assert_eq!((t.rows, t.cols), (c, r));
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t.at(j, i), a.at(i, j), "({r},{c}) @({i},{j})");
                }
            }
        }
    }

    #[test]
    fn eye_and_transpose() {
        let i = Mat::eye(4);
        assert_eq!(i.transpose(), i);
        let mut rng = Pcg32::new(5);
        let a = randm(&mut rng, 4, 4);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-6);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn symmetrize() {
        let mut m = Mat::from_vec(2, 2, vec![1.0, 2.0, 4.0, 3.0]);
        m.symmetrize_inplace();
        assert_eq!(m.at(0, 1), 3.0);
        assert_eq!(m.at(1, 0), 3.0);
    }

    #[test]
    fn dot_matches_scalar() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.01).collect();
        let b: Vec<f32> = (0..103).map(|i| 1.0 - (i as f32) * 0.005).collect();
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - want).abs() < 1e-3);
    }

    #[test]
    fn fro_norm_known() {
        let m = Mat::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-6);
    }
}
