//! Cyclic Jacobi eigendecomposition for symmetric matrices + the PSD
//! projection that makes Xing et al. (2002)'s projected gradient loop
//! possible — this is exactly the O(d³) step whose elimination is the
//! paper's algorithmic contribution, so it matters that it is real.

use super::Mat;

/// Eigendecomposition A = V diag(w) Vᵀ of a symmetric matrix.
/// `vectors` holds eigenvectors as *columns*; `values` ascending.
pub struct Eigen {
    pub values: Vec<f32>,
    pub vectors: Mat,
}

/// Cyclic Jacobi with threshold sweeps. Converges quadratically; fine for
/// the baseline dimensions (d ≤ ~1000 after PCA).
pub fn eigh(a: &Mat) -> Eigen {
    assert_eq!(a.rows, a.cols, "eigh needs a square matrix");
    let n = a.rows;
    // f64 working copy for numerical headroom.
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v: Vec<f64> = vec![0.0; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // off(A): sqrt of sum of squares of off-diagonal entries
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        if off.sqrt() < 1e-10 * (1.0 + frob(&m, n)) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                // Rotation angle (Golub & Van Loan 8.4)
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // A <- Jᵀ A J on rows/cols p, q
                for k in 0..n {
                    let akp = m[k * n + p];
                    let akq = m[k * n + q];
                    m[k * n + p] = c * akp - s * akq;
                    m[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[p * n + k];
                    let aqk = m[q * n + k];
                    m[p * n + k] = c * apk - s * aqk;
                    m[q * n + k] = s * apk + c * aqk;
                }
                // V <- V J
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract + sort ascending.
    let mut idx: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    idx.sort_by(|&a, &b| diag[a].partial_cmp(&diag[b]).unwrap());
    let values: Vec<f32> = idx.iter().map(|&i| diag[i] as f32).collect();
    let mut vectors = Mat::zeros(n, n);
    for (new_c, &old_c) in idx.iter().enumerate() {
        for r in 0..n {
            *vectors.at_mut(r, new_c) = v[r * n + old_c] as f32;
        }
    }
    Eigen { values, vectors }
}

fn frob(m: &[f64], n: usize) -> f64 {
    m.iter().take(n * n).map(|x| x * x).sum::<f64>().sqrt()
}

/// Project a symmetric matrix onto the PSD cone: clamp negative
/// eigenvalues to zero and reassemble. This is the O(d³) bottleneck of
/// the original (2002) formulation that the paper's reformulation avoids.
pub fn project_psd(a: &Mat) -> Mat {
    let n = a.rows;
    let e = eigh(a);
    // B = V diag(max(w,0)); out = B Vᵀ
    let mut b = Mat::zeros(n, n);
    for c in 0..n {
        let w = e.values[c].max(0.0);
        if w == 0.0 {
            continue;
        }
        for r in 0..n {
            *b.at_mut(r, c) = e.vectors.at(r, c) * w;
        }
    }
    let mut out = b.matmul_bt(&e.vectors);
    out.symmetrize_inplace();
    out
}

/// Smallest eigenvalue (convenience for PSD checks in tests).
pub fn min_eigenvalue(a: &Mat) -> f32 {
    eigh(a).values[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_sym(rng: &mut Pcg32, n: usize) -> Mat {
        let mut b = Mat::zeros(n, n);
        rng.fill_gaussian(&mut b.data, 0.0, 1.0);
        let mut a = b.clone();
        a.axpy_inplace(1.0, &b.transpose());
        a.scale_inplace(0.5);
        a
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let a = Mat::from_vec(3, 3,
            vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let e = eigh(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-5);
        assert!((e.values[1] - 2.0).abs() < 1e-5);
        assert!((e.values[2] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 1, 3
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = eigh(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-5);
        assert!((e.values[1] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        let mut rng = Pcg32::new(3);
        for &n in &[2, 5, 16, 40] {
            let a = rand_sym(&mut rng, n);
            let e = eigh(&a);
            // V Vᵀ = I
            let vvt = e.vectors.matmul_bt(&e.vectors);
            assert!(vvt.max_abs_diff(&Mat::eye(n)) < 1e-3, "orth n={n}");
            // V diag(w) Vᵀ = A
            let mut vd = Mat::zeros(n, n);
            for c in 0..n {
                for r in 0..n {
                    *vd.at_mut(r, c) = e.vectors.at(r, c) * e.values[c];
                }
            }
            let rec = vd.matmul_bt(&e.vectors);
            assert!(rec.max_abs_diff(&a) < 1e-2, "recon n={n}");
        }
    }

    #[test]
    fn eigenvalues_ascending() {
        let mut rng = Pcg32::new(4);
        let a = rand_sym(&mut rng, 20);
        let e = eigh(&a);
        for w in e.values.windows(2) {
            assert!(w[0] <= w[1] + 1e-6);
        }
    }

    #[test]
    fn psd_projection_properties() {
        let mut rng = Pcg32::new(5);
        let a = rand_sym(&mut rng, 12);
        let p = project_psd(&a);
        // (1) result is PSD
        assert!(min_eigenvalue(&p) > -1e-3);
        // (2) projection is idempotent
        let pp = project_psd(&p);
        assert!(pp.max_abs_diff(&p) < 1e-2);
        // (3) an already-PSD matrix is (nearly) unchanged
        let spd = {
            let mut b = Mat::zeros(8, 8);
            rng.fill_gaussian(&mut b.data, 0.0, 1.0);
            let mut s = b.matmul_bt(&b);
            for i in 0..8 {
                *s.at_mut(i, i) += 0.1;
            }
            s
        };
        assert!(project_psd(&spd).max_abs_diff(&spd) < 1e-2);
    }

    #[test]
    fn psd_projection_zeroes_negative_part() {
        // diag(2, -3): projection = diag(2, 0)
        let a = Mat::from_vec(2, 2, vec![2.0, 0.0, 0.0, -3.0]);
        let p = project_psd(&a);
        let want = Mat::from_vec(2, 2, vec![2.0, 0.0, 0.0, 0.0]);
        assert!(p.max_abs_diff(&want) < 1e-5);
    }
}
