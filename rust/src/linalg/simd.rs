//! Explicit-SIMD kernel layer: the vectorized backend behind the three
//! hot paths (packed GEMM microkernel, pair-distance scan, kNN gallery
//! scan), with runtime CPU-feature dispatch and a scalar reference that
//! stays **bit-identical** to the pre-SIMD code.
//!
//! ## Dispatch rules (in priority order)
//!
//! 1. Compile time: without the `simd` cargo feature (or off x86_64)
//!    only the scalar path exists — the vector code is not even built.
//! 2. Programmatic force ([`force_backend`]) — what the backend-sweep
//!    benches and the `prop_simd` property suite use.
//! 3. The `DMLPS_KERNEL` env var: `scalar` | `simd` | `auto` (default).
//! 4. Runtime CPU detection: `auto` (and `simd`) resolve to the vector
//!    path only when the CPU reports AVX2 + FMA; anything else falls
//!    back to scalar. A forced/env `simd` request on an unsupported CPU
//!    degrades to scalar and says so in the [`KernelReport`].
//!
//! ## Determinism contract
//!
//! * The **scalar** path is the reference: its code is byte-for-byte
//!   the pre-SIMD implementation, so every golden test pinned before
//!   this layer existed still holds with the feature off, on a non-AVX2
//!   CPU, or under `DMLPS_KERNEL=scalar`.
//! * The **SIMD** path is ε-tolerant: FMA contraction and 8-lane
//!   reassociation change float rounding, bounded by the `prop_simd`
//!   suite (≤ 4 ULP on monotone inputs at the tested shapes). Within
//!   one backend, results remain bit-reproducible run-to-run and across
//!   thread counts — lane order and reduction shape are fixed.
//! * Comparative golden tests (shim ≡ session, distributed ≡
//!   sequential, save/save byte equality) compare two code paths inside
//!   one process, which always resolve to the same backend, so they
//!   pass under either.
//!
//! The 8-lane width is [`LANES`]; the vector type is a thin wrapper
//! over `core::arch` AVX intrinsics (`__m256`), compiled only under
//! `--features simd` on x86_64.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Vector width of the SIMD path (f32 lanes per register).
pub const LANES: usize = 8;

/// Which kernel implementation actually runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelBackend {
    /// Bit-exact reference path (the pre-SIMD code, unchanged).
    Scalar,
    /// 8-lane AVX2+FMA path (ε-tolerant vs scalar).
    Simd,
}

impl KernelBackend {
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Simd => "simd",
        }
    }
}

impl std::fmt::Display for KernelBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// How the active backend was decided — surfaced through [`KernelReport`]
/// so benches and `Run` telemetry record *why* a path ran, not just which.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchDecision {
    /// Crate built without the `simd` feature (or not on x86_64):
    /// scalar is the only compiled path.
    NotCompiled,
    /// [`force_backend`] override (benches / property tests).
    Forced,
    /// `DMLPS_KERNEL` env var picked the backend.
    Env,
    /// `auto`: runtime CPU detection picked the best compiled path.
    Auto,
    /// SIMD was requested (env or force) but the CPU lacks AVX2+FMA;
    /// degraded to scalar.
    UnsupportedCpu,
}

impl DispatchDecision {
    pub fn name(self) -> &'static str {
        match self {
            DispatchDecision::NotCompiled => "not-compiled",
            DispatchDecision::Forced => "forced",
            DispatchDecision::Env => "env",
            DispatchDecision::Auto => "auto",
            DispatchDecision::UnsupportedCpu => "unsupported-cpu",
        }
    }
}

/// Snapshot of the kernel dispatch state: which backend runs, how wide
/// it is, and why it was chosen. Attached to every
/// [`Run`](crate::session::Run) and written into `BENCH_hotpath.json`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelReport {
    /// The backend kernel calls dispatch to right now.
    pub backend: KernelBackend,
    /// f32 lanes per vector op (8 on the SIMD path, 1 scalar).
    pub lanes: usize,
    /// Whether the vector path was compiled in (`simd` feature, x86_64).
    pub compiled_simd: bool,
    /// Whether the CPU reports AVX2 + FMA (always false when not
    /// compiled — detection is skipped).
    pub cpu_supported: bool,
    /// Why this backend was selected.
    pub decision: DispatchDecision,
}

impl std::fmt::Display for KernelReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} ({} lane{}, {})",
            self.backend,
            self.lanes,
            if self.lanes == 1 { "" } else { "s" },
            self.decision.name()
        )
    }
}

/// Whether the vector path exists in this build at all.
#[inline]
pub const fn simd_compiled() -> bool {
    cfg!(all(feature = "simd", target_arch = "x86_64"))
}

fn cpu_supported() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        static OK: OnceLock<bool> = OnceLock::new();
        return *OK.get_or_init(|| {
            std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
        });
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    false
}

/// Backend requested by `DMLPS_KERNEL` (`None` = auto / unset /
/// unrecognized — unknown values fall back to auto rather than abort).
fn env_request() -> Option<KernelBackend> {
    static ENV: OnceLock<Option<KernelBackend>> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("DMLPS_KERNEL") {
        Ok(v) if v.eq_ignore_ascii_case("scalar") => {
            Some(KernelBackend::Scalar)
        }
        Ok(v) if v.eq_ignore_ascii_case("simd") => Some(KernelBackend::Simd),
        _ => None,
    })
}

/// Programmatic override slot: 0 = none (env/auto), 1 = scalar, 2 = simd.
static FORCE: AtomicU8 = AtomicU8::new(0);

/// Force a backend for the current process (pass `None` to return to
/// env/auto resolution). Overrides the `DMLPS_KERNEL` env var.
///
/// Intended for benches sweeping backends and for the `prop_simd`
/// property suite; the override is process-global, so concurrent tests
/// that force different backends must serialize around it (a forced
/// `Simd` on an unsupported CPU still degrades to scalar).
pub fn force_backend(backend: Option<KernelBackend>) {
    let v = match backend {
        None => 0,
        Some(KernelBackend::Scalar) => 1,
        Some(KernelBackend::Simd) => 2,
    };
    FORCE.store(v, Ordering::Release);
}

/// The backend kernel calls dispatch to right now (cheap: one atomic
/// load on the no-override path).
#[inline]
pub fn active_backend() -> KernelBackend {
    report().backend
}

/// Full dispatch snapshot — see [`KernelReport`].
pub fn report() -> KernelReport {
    let compiled = simd_compiled();
    let cpu = cpu_supported();
    let (requested, how) = match FORCE.load(Ordering::Acquire) {
        1 => (Some(KernelBackend::Scalar), DispatchDecision::Forced),
        2 => (Some(KernelBackend::Simd), DispatchDecision::Forced),
        _ => match env_request() {
            Some(b) => (Some(b), DispatchDecision::Env),
            None => (None, DispatchDecision::Auto),
        },
    };
    let (backend, decision) = match requested {
        Some(KernelBackend::Scalar) => (KernelBackend::Scalar, how),
        Some(KernelBackend::Simd) if !compiled => {
            (KernelBackend::Scalar, DispatchDecision::NotCompiled)
        }
        Some(KernelBackend::Simd) if !cpu => {
            (KernelBackend::Scalar, DispatchDecision::UnsupportedCpu)
        }
        Some(KernelBackend::Simd) => (KernelBackend::Simd, how),
        None if compiled && cpu => {
            (KernelBackend::Simd, DispatchDecision::Auto)
        }
        None if compiled => (KernelBackend::Scalar, DispatchDecision::Auto),
        None => (KernelBackend::Scalar, DispatchDecision::NotCompiled),
    };
    KernelReport {
        backend,
        lanes: if backend == KernelBackend::Simd { LANES } else { 1 },
        compiled_simd: compiled,
        cpu_supported: cpu,
        decision,
    }
}

/// `true` iff kernel calls should take the vector path right now.
#[inline]
pub fn simd_active() -> bool {
    // fast path: no force, no env request, detection cached
    active_backend() == KernelBackend::Simd
}

// ---------------------------------------------------------------------
// Scalar reference kernels — byte-for-byte the pre-SIMD implementations
// (goldens are pinned to these; do not "improve" their float order).
// ---------------------------------------------------------------------

/// Squared Euclidean distance Σ (a−b)², sequential f32 accumulation —
/// exactly the historical `eval::nearest_k` inner loop.
#[inline]
pub fn sqdist_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Squared norm Σ x², sequential f32 accumulation — exactly the
/// historical hinge-pass `zrow.iter().map(|z| z * z).sum()`.
#[inline]
pub fn sqnorm_scalar(x: &[f32]) -> f32 {
    x.iter().map(|v| v * v).sum()
}

/// Squared norm with per-element f64 accumulation — exactly the
/// historical similar-pair loss accumulation.
#[inline]
pub fn sqnorm_f64_scalar(x: &[f32]) -> f64 {
    x.iter().map(|v| (v * v) as f64).sum()
}

// ---------------------------------------------------------------------
// Dispatching primitives: scalar path bit-exact, SIMD path ε-tolerant.
// ---------------------------------------------------------------------

/// Dot product. Scalar path is [`crate::linalg::dot`] (the historical
/// 4-accumulator kernel `NativeEngine::pair_dist` always used).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: dispatch verified AVX2+FMA before selecting this path.
        return unsafe { avx::dot(a, b) };
    }
    crate::linalg::dot(a, b)
}

/// Squared Euclidean distance Σ (a−b)².
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: dispatch verified AVX2+FMA before selecting this path.
        return unsafe { avx::sqdist(a, b) };
    }
    sqdist_scalar(a, b)
}

/// Squared norm Σ x² in f32.
#[inline]
pub fn sqnorm(x: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: dispatch verified AVX2+FMA before selecting this path.
        return unsafe { avx::sqnorm(x) };
    }
    sqnorm_scalar(x)
}

/// Squared norm accumulated toward f64 (the loss-curve accumulator).
/// The SIMD path sums 8 f32 lanes then widens once; the scalar path
/// widens per element exactly as the historical code did.
#[inline]
pub fn sqnorm_f64(x: &[f32]) -> f64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: dispatch verified AVX2+FMA before selecting this path.
        return unsafe { avx::sqnorm(x) } as f64;
    }
    sqnorm_f64_scalar(x)
}

/// The vectorized GEMM register tile: `acc[r][c] += Σ_q apack[q·MR+r] ·
/// bstrip[q·NR+c]` with NR = [`LANES`]. Returns `false` when the vector
/// path is unavailable or inactive (caller then runs the scalar
/// microkernel, keeping that code byte-identical to the reference).
#[inline(always)]
#[allow(unused_variables)]
pub(crate) fn gemm_microkernel_simd(
    simd: bool,
    kc: usize,
    apack: &[f32],
    bstrip: &[f32],
    acc: &mut [[f32; crate::linalg::gemm::NR]; crate::linalg::gemm::MR],
) -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd {
        // SAFETY: `simd` is only true after dispatch verified AVX2+FMA.
        unsafe { avx::gemm_microkernel(kc, apack, bstrip, acc) };
        return true;
    }
    false
}

// ---------------------------------------------------------------------
// AVX2 + FMA implementations (compiled only with `--features simd` on
// x86_64; entered only after runtime detection).
// ---------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx {
    use crate::linalg::gemm::{MR, NR};
    use core::arch::x86_64::*;

    /// Horizontal sum of 8 lanes with a fixed tree shape:
    /// (0+4, 1+5, 2+6, 3+7) → ((0+4)+(2+6), (1+5)+(3+7)) → total.
    #[inline(always)]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// 8-lane FMA dot product: two independent vector accumulators
    /// (breaking the FMA latency chain), scalar remainder tail.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut s0 = _mm256_setzero_ps();
        let mut s1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 2 * NR <= n {
            s0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i)),
                _mm256_loadu_ps(pb.add(i)),
                s0,
            );
            s1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + NR)),
                _mm256_loadu_ps(pb.add(i + NR)),
                s1,
            );
            i += 2 * NR;
        }
        if i + NR <= n {
            s0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i)),
                _mm256_loadu_ps(pb.add(i)),
                s0,
            );
            i += NR;
        }
        let mut acc = hsum(_mm256_add_ps(s0, s1));
        while i < n {
            acc += *pa.add(i) * *pb.add(i);
            i += 1;
        }
        acc
    }

    /// 8-lane squared distance: d = a − b, acc = fma(d, d, acc).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn sqdist(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut s0 = _mm256_setzero_ps();
        let mut s1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 2 * NR <= n {
            let d0 = _mm256_sub_ps(
                _mm256_loadu_ps(pa.add(i)),
                _mm256_loadu_ps(pb.add(i)),
            );
            let d1 = _mm256_sub_ps(
                _mm256_loadu_ps(pa.add(i + NR)),
                _mm256_loadu_ps(pb.add(i + NR)),
            );
            s0 = _mm256_fmadd_ps(d0, d0, s0);
            s1 = _mm256_fmadd_ps(d1, d1, s1);
            i += 2 * NR;
        }
        if i + NR <= n {
            let d = _mm256_sub_ps(
                _mm256_loadu_ps(pa.add(i)),
                _mm256_loadu_ps(pb.add(i)),
            );
            s0 = _mm256_fmadd_ps(d, d, s0);
            i += NR;
        }
        let mut acc = hsum(_mm256_add_ps(s0, s1));
        while i < n {
            let d = *pa.add(i) - *pb.add(i);
            acc += d * d;
            i += 1;
        }
        acc
    }

    /// 8-lane squared norm: acc = fma(x, x, acc).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn sqnorm(x: &[f32]) -> f32 {
        let n = x.len();
        let p = x.as_ptr();
        let mut s0 = _mm256_setzero_ps();
        let mut s1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 2 * NR <= n {
            let v0 = _mm256_loadu_ps(p.add(i));
            let v1 = _mm256_loadu_ps(p.add(i + NR));
            s0 = _mm256_fmadd_ps(v0, v0, s0);
            s1 = _mm256_fmadd_ps(v1, v1, s1);
            i += 2 * NR;
        }
        if i + NR <= n {
            let v = _mm256_loadu_ps(p.add(i));
            s0 = _mm256_fmadd_ps(v, v, s0);
            i += NR;
        }
        let mut acc = hsum(_mm256_add_ps(s0, s1));
        while i < n {
            acc += *p.add(i) * *p.add(i);
            i += 1;
        }
        acc
    }

    /// The MR×NR register tile on 8-lane FMA: one B vector load per
    /// depth step, MR broadcast-FMAs into MR vector accumulators. Same
    /// tile contract as the scalar microkernel (accumulates into `acc`,
    /// zero-padded edges included), different rounding (FMA).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemm_microkernel(
        kc: usize,
        apack: &[f32],
        bstrip: &[f32],
        acc: &mut [[f32; NR]; MR],
    ) {
        debug_assert!(apack.len() >= kc * MR);
        debug_assert!(bstrip.len() >= kc * NR);
        let (pa, pb) = (apack.as_ptr(), bstrip.as_ptr());
        let mut c0 = _mm256_loadu_ps(acc[0].as_ptr());
        let mut c1 = _mm256_loadu_ps(acc[1].as_ptr());
        let mut c2 = _mm256_loadu_ps(acc[2].as_ptr());
        let mut c3 = _mm256_loadu_ps(acc[3].as_ptr());
        for q in 0..kc {
            let b = _mm256_loadu_ps(pb.add(q * NR));
            c0 = _mm256_fmadd_ps(
                _mm256_set1_ps(*pa.add(q * MR)), b, c0);
            c1 = _mm256_fmadd_ps(
                _mm256_set1_ps(*pa.add(q * MR + 1)), b, c1);
            c2 = _mm256_fmadd_ps(
                _mm256_set1_ps(*pa.add(q * MR + 2)), b, c2);
            c3 = _mm256_fmadd_ps(
                _mm256_set1_ps(*pa.add(q * MR + 3)), b, c3);
        }
        _mm256_storeu_ps(acc[0].as_mut_ptr(), c0);
        _mm256_storeu_ps(acc[1].as_mut_ptr(), c1);
        _mm256_storeu_ps(acc[2].as_mut_ptr(), c2);
        _mm256_storeu_ps(acc[3].as_mut_ptr(), c3);
    }

    // The tile kernel above hard-codes 4 accumulator registers.
    const _: () = assert!(MR == 4 && NR == 8);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_primitives_match_inline_loops_bitwise() {
        let x: Vec<f32> = (0..103).map(|i| (i as f32).sin()).collect();
        let y: Vec<f32> = (0..103).map(|i| (i as f32).cos()).collect();
        let want_sqd: f32 =
            x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum();
        assert_eq!(sqdist_scalar(&x, &y).to_bits(), want_sqd.to_bits());
        let want_sqn: f32 = x.iter().map(|v| v * v).sum();
        assert_eq!(sqnorm_scalar(&x).to_bits(), want_sqn.to_bits());
        let want_sqn64: f64 = x.iter().map(|v| (v * v) as f64).sum();
        assert_eq!(
            sqnorm_f64_scalar(&x).to_bits(),
            want_sqn64.to_bits()
        );
    }

    #[test]
    fn report_is_internally_consistent() {
        let r = report();
        assert_eq!(r.compiled_simd, simd_compiled());
        match r.backend {
            KernelBackend::Simd => {
                assert_eq!(r.lanes, LANES);
                assert!(r.compiled_simd && r.cpu_supported);
            }
            KernelBackend::Scalar => assert_eq!(r.lanes, 1),
        }
        if !r.compiled_simd {
            assert!(!r.cpu_supported);
            assert_eq!(r.backend, KernelBackend::Scalar);
        }
    }

    #[test]
    fn display_formats() {
        let r = KernelReport {
            backend: KernelBackend::Scalar,
            lanes: 1,
            compiled_simd: false,
            cpu_supported: false,
            decision: DispatchDecision::NotCompiled,
        };
        assert_eq!(r.to_string(), "scalar (1 lane, not-compiled)");
    }
}
