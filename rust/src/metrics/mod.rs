//! Metrics: time-series recording (objective vs time), CSV/JSON export,
//! speedup computation — everything the paper's figures are built from.

use std::time::Instant;

use crate::util::json::Json;

/// One convergence-curve point.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    /// Seconds since run start (wall clock or simulated, per producer).
    pub time_s: f64,
    /// Global SGD step count at probe time.
    pub step: usize,
    /// Objective value.
    pub objective: f64,
}

/// A labeled convergence curve (one line in Fig 2 / Fig 4a).
#[derive(Clone, Debug, Default)]
pub struct Curve {
    pub label: String,
    pub points: Vec<CurvePoint>,
}

impl Curve {
    pub fn new(label: impl Into<String>) -> Curve {
        Curve { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, time_s: f64, step: usize, objective: f64) {
        self.points.push(CurvePoint { time_s, step, objective });
    }

    pub fn final_objective(&self) -> Option<f64> {
        self.points.last().map(|p| p.objective)
    }

    /// First time at which the objective reaches (≤) `target`.
    /// `None` if never reached.
    pub fn time_to_reach(&self, target: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.objective <= target)
            .map(|p| p.time_s)
    }

    /// Render as CSV rows `time_s,step,objective`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("time_s,step,objective\n");
        for p in &self.points {
            s.push_str(&format!("{},{},{}\n", p.time_s, p.step, p.objective));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("time_s",
             Json::arr_f64(&self.points.iter().map(|p| p.time_s)
                 .collect::<Vec<_>>())),
            ("step",
             Json::arr_usize(&self.points.iter().map(|p| p.step)
                 .collect::<Vec<_>>())),
            ("objective",
             Json::arr_f64(&self.points.iter().map(|p| p.objective)
                 .collect::<Vec<_>>())),
        ])
    }
}

/// Wall-clock stopwatch for curve recording.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Speedup table (Fig 3): time-to-target per worker/core count relative
/// to the smallest configuration.
#[derive(Clone, Debug)]
pub struct SpeedupRow {
    pub cores: usize,
    pub time_to_target_s: f64,
    pub speedup: f64,
    pub linear: f64,
}

/// Compute speedup factors from (cores, time_to_target) measurements.
/// The first row is the baseline (speedup 1); `linear` is the ideal
/// cores/base_cores line the paper plots in blue.
pub fn speedup_table(mut meas: Vec<(usize, f64)>) -> Vec<SpeedupRow> {
    assert!(!meas.is_empty());
    meas.sort_by_key(|&(c, _)| c);
    let (base_cores, base_time) = meas[0];
    meas.iter()
        .map(|&(cores, t)| SpeedupRow {
            cores,
            time_to_target_s: t,
            speedup: base_time / t,
            linear: cores as f64 / base_cores as f64,
        })
        .collect()
}

/// JSON paths (`a.b[3].c`) of every non-finite numeric leaf in a bench
/// payload, depth-first. Empty = the payload is clean.
pub fn non_finite_paths(j: &Json) -> Vec<String> {
    fn walk(j: &Json, path: &str, out: &mut Vec<String>) {
        match j {
            Json::Num(x) if !x.is_finite() => out.push(path.to_string()),
            Json::Arr(v) => {
                for (i, item) in v.iter().enumerate() {
                    walk(item, &format!("{path}[{i}]"), out);
                }
            }
            Json::Obj(m) => {
                for (k, v) in m {
                    let p = if path.is_empty() {
                        k.clone()
                    } else {
                        format!("{path}.{k}")
                    };
                    walk(v, &p, out);
                }
            }
            _ => {}
        }
    }
    let mut out = Vec::new();
    walk(j, "", &mut out);
    out
}

/// The refuse-to-write-garbage guard every `BENCH_*.json` producer
/// shares: errors (naming the offending paths) if any numeric leaf of
/// `payload` is NaN/Inf — non-finite numbers are not valid JSON, and a
/// poisoned baseline is worse than none.
pub fn finite_guard(payload: &Json) -> anyhow::Result<()> {
    let bad = non_finite_paths(payload);
    anyhow::ensure!(
        bad.is_empty(),
        "non-finite metric at {} — refusing to write the baseline",
        bad.join(", ")
    );
    Ok(())
}

/// Write a machine-readable bench baseline: resolve the output path
/// (`DMLPS_BENCH_OUT` overrides `default_path`), apply [`finite_guard`],
/// then write pretty JSON crash-atomically. Returns the path written.
pub fn write_bench_json(
    default_path: &str,
    payload: &Json,
) -> anyhow::Result<std::path::PathBuf> {
    let path = std::path::PathBuf::from(
        std::env::var("DMLPS_BENCH_OUT")
            .unwrap_or_else(|_| default_path.to_string()),
    );
    finite_guard(payload)?;
    crate::linalg::io::atomic_write(&path, |w| {
        use std::io::Write;
        w.write_all(payload.to_string_pretty().as_bytes())?;
        Ok(())
    })?;
    Ok(path)
}

/// Markdown rendering of a set of curves, sampled at up to `max_rows`
/// points (bench output stays readable).
pub fn curves_to_markdown(curves: &[Curve], max_rows: usize) -> String {
    let mut s = String::new();
    for c in curves {
        s.push_str(&format!("\n### {}\n", c.label));
        s.push_str("| time_s | step | objective |\n|---|---|---|\n");
        let stride = (c.points.len() / max_rows.max(1)).max(1);
        for p in c.points.iter().step_by(stride) {
            s.push_str(&format!(
                "| {:.3} | {} | {:.6} |\n",
                p.time_s, p.step, p.objective
            ));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(label: &str, objs: &[f64]) -> Curve {
        let mut c = Curve::new(label);
        for (i, &o) in objs.iter().enumerate() {
            c.push(i as f64, i * 10, o);
        }
        c
    }

    #[test]
    fn time_to_reach_finds_first_crossing() {
        let c = curve("x", &[5.0, 3.0, 2.0, 1.5, 1.2]);
        assert_eq!(c.time_to_reach(2.0), Some(2.0));
        assert_eq!(c.time_to_reach(1.2), Some(4.0));
        assert_eq!(c.time_to_reach(0.5), None);
    }

    #[test]
    fn speedup_table_is_relative_to_smallest() {
        let rows = speedup_table(vec![(64, 30.0), (16, 100.0), (32, 52.0)]);
        assert_eq!(rows[0].cores, 16);
        assert!((rows[0].speedup - 1.0).abs() < 1e-12);
        assert!((rows[1].speedup - 100.0 / 52.0).abs() < 1e-12);
        assert!((rows[2].linear - 4.0).abs() < 1e-12);
    }

    #[test]
    fn csv_and_json_roundtrip() {
        let c = curve("test", &[2.0, 1.0]);
        let csv = c.to_csv();
        assert!(csv.starts_with("time_s,step,objective\n"));
        assert_eq!(csv.lines().count(), 3);
        let j = c.to_json();
        assert_eq!(j.get("label").as_str(), Some("test"));
        assert_eq!(j.get("objective").idx(1).as_f64(), Some(1.0));
    }

    #[test]
    fn finite_guard_names_nested_paths() {
        let bad = Json::obj(vec![
            ("ok", Json::Num(1.0)),
            ("rows", Json::Arr(vec![
                Json::obj(vec![("qps", Json::Num(f64::NAN))]),
            ])),
            ("inf", Json::Num(f64::INFINITY)),
        ]);
        let paths = non_finite_paths(&bad);
        assert_eq!(paths, vec!["inf", "rows[0].qps"]);
        let msg = finite_guard(&bad).unwrap_err().to_string();
        assert!(msg.contains("rows[0].qps"), "{msg}");

        let clean = Json::obj(vec![
            ("x", Json::arr_f64(&[0.0, -1.5])),
            ("s", Json::Str("NaN is fine as a string".into())),
        ]);
        assert!(non_finite_paths(&clean).is_empty());
        assert!(finite_guard(&clean).is_ok());
    }

    #[test]
    fn write_bench_json_refuses_non_finite() {
        let dir = std::env::temp_dir()
            .join(format!("dmlps-metrics-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let target = dir.join("BENCH_guard_test.json");
        // DMLPS_BENCH_OUT would redirect the write; the test must not
        // mutate the process env (tests run in parallel), so skip under
        // an externally set override.
        if std::env::var("DMLPS_BENCH_OUT").is_ok() {
            return;
        }
        let bad = Json::obj(vec![("x", Json::Num(f64::NAN))]);
        assert!(
            write_bench_json(target.to_str().unwrap(), &bad).is_err()
        );
        assert!(!target.exists(), "guard must block the write");
        let ok = Json::obj(vec![("x", Json::Num(2.0))]);
        let written =
            write_bench_json(target.to_str().unwrap(), &ok).unwrap();
        let back = Json::parse_file(&written).unwrap();
        assert_eq!(back.get("x").as_f64(), Some(2.0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn markdown_sampling() {
        let c = curve("long", &vec![1.0; 100]);
        let md = curves_to_markdown(&[c], 10);
        let rows = md.lines().filter(|l| l.starts_with("| ")).count();
        assert!(rows <= 13, "{rows}");
    }
}
