//! Evaluation: pair-verification precision/recall (the paper's §5.4
//! protocol), average precision, and kNN retrieval accuracy.
//!
//! Protocol (paper): sample held-out similar/dissimilar pairs, score each
//! pair with the learned distance, predict "similar" when the distance is
//! below a threshold t, and sweep t to get a precision-recall curve; the
//! headline number is average precision.
//!
//! The heavy scans are multicore: pair scoring parallelizes inside the
//! engine's `pair_dist` (row-sharded over its pool) and the kNN scan
//! shards test queries over the global pool.

mod pr;

pub use pr::{average_precision, pr_curve, PrPoint};

use crate::data::{Dataset, ExperimentData, PairSet};
use crate::dml::Engine;
use crate::linalg::Mat;

/// AP of a learned L on the held-out test pairs (scores through the
/// factored form; materializing M = LᵀL at d=780 would be wasteful).
pub fn ap_of_l(
    engine: &mut dyn Engine,
    l: &Mat,
    data: &ExperimentData,
) -> anyhow::Result<f64> {
    let (sim, dis) = score_pairs(engine, l, &data.test, &data.test_pairs)?;
    Ok(average_precision(&sim, &dis))
}

/// AP of the Euclidean baseline on the held-out test pairs.
pub fn ap_euclidean(data: &ExperimentData) -> f64 {
    let (sim, dis) = score_pairs_euclidean(&data.test, &data.test_pairs);
    average_precision(&sim, &dis)
}

/// Distances for all pairs of a [`PairSet`] under metric L.
/// Returns (similar_dists, dissimilar_dists).
pub fn score_pairs(
    engine: &mut dyn Engine,
    l: &Mat,
    ds: &Dataset,
    pairs: &PairSet,
) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
    let score = |set: &[crate::data::Pair],
                 engine: &mut dyn Engine|
     -> anyhow::Result<Vec<f32>> {
        // materialize diffs in manageable chunks to bound memory
        const CHUNK: usize = 4096;
        let d = ds.dim();
        let mut out = Vec::with_capacity(set.len());
        let mut buf = Mat::zeros(CHUNK.min(set.len().max(1)), d);
        let mut i = 0;
        while i < set.len() {
            let n = (set.len() - i).min(CHUNK);
            if buf.rows != n {
                buf = Mat::zeros(n, d);
            }
            for (r, p) in set[i..i + n].iter().enumerate() {
                ds.diff_into(p.i as usize, p.j as usize, buf.row_mut(r));
            }
            out.extend(engine.pair_dist(l, &buf)?);
            i += n;
        }
        Ok(out)
    };
    Ok((score(&pairs.similar, engine)?, score(&pairs.dissimilar, engine)?))
}

/// Euclidean pair distances (baseline): L = I without materializing it.
pub fn score_pairs_euclidean(
    ds: &Dataset,
    pairs: &PairSet,
) -> (Vec<f32>, Vec<f32>) {
    let score = |set: &[crate::data::Pair]| -> Vec<f32> {
        set.iter()
            .map(|p| {
                ds.feature(p.i as usize)
                    .iter()
                    .zip(ds.feature(p.j as usize))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum()
            })
            .collect()
    };
    (score(&pairs.similar), score(&pairs.dissimilar))
}

/// Mahalanobis pair distances under a full M (d×d) — used by baselines
/// that learn M directly (Xing2002, ITML, KISS): dist = δᵀ M δ.
pub fn score_pairs_mahalanobis(
    m: &Mat,
    ds: &Dataset,
    pairs: &PairSet,
) -> (Vec<f32>, Vec<f32>) {
    let d = ds.dim();
    assert_eq!((m.rows, m.cols), (d, d));
    let mut diff = vec![0.0f32; d];
    let mut score = |set: &[crate::data::Pair]| -> Vec<f32> {
        set.iter()
            .map(|p| {
                ds.diff_into(p.i as usize, p.j as usize, &mut diff);
                let md = m.matvec(&diff);
                crate::linalg::dot(&diff, &md)
            })
            .collect()
    };
    let sim = score(&pairs.similar);
    let dis = score(&pairs.dissimilar);
    (sim, dis)
}

/// Gallery rows scored per selection pass: the distance loop runs
/// branch-free over one block (vectorizable, gallery rows streamed once
/// through cache) before the branchy top-k maintenance touches the
/// results. 64 rows × 4 B dists = one 256 B scratch line set.
const KNN_BLOCK: usize = 64;

/// Bounded top-k selector: a size-k binary max-heap ordered by
/// `(distance, index)` under `total_cmp`. Maintains the invariant the
/// historical full-sort loop had — the k lexicographically-smallest
/// `(dist, idx)` pairs seen so far, with a candidate admitted only when
/// its distance is *strictly* below the current worst — at O(log k) per
/// replacement instead of O(k log k).
struct TopK {
    k: usize,
    heap: Vec<(f32, usize)>,
}

#[inline]
fn knn_gt(a: (f32, usize), b: (f32, usize)) -> bool {
    a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)) == std::cmp::Ordering::Greater
}

impl TopK {
    fn new(k: usize) -> TopK {
        TopK { k, heap: Vec::with_capacity(k) }
    }

    #[inline]
    fn offer(&mut self, dist: f32, idx: usize) {
        if self.heap.len() < self.k {
            self.heap.push((dist, idx));
            let mut i = self.heap.len() - 1;
            while i > 0 {
                let p = (i - 1) / 2;
                if !knn_gt(self.heap[i], self.heap[p]) {
                    break;
                }
                self.heap.swap(i, p);
                i = p;
            }
        } else if dist < self.heap[0].0 {
            // strict `<` on distance alone — indices only arrive in
            // increasing order, so a distance tie can never displace
            // (matching the historical `dist < best[k-1].0` gate)
            self.heap[0] = (dist, idx);
            let (mut i, n) = (0, self.heap.len());
            loop {
                let (l, r) = (2 * i + 1, 2 * i + 2);
                let mut big = i;
                if l < n && knn_gt(self.heap[l], self.heap[big]) {
                    big = l;
                }
                if r < n && knn_gt(self.heap[r], self.heap[big]) {
                    big = r;
                }
                if big == i {
                    break;
                }
                self.heap.swap(i, big);
                i = big;
            }
        }
    }

    fn into_sorted(self) -> Vec<(f32, usize)> {
        let mut v = self.heap;
        v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        v
    }
}

/// The `k` rows of `gallery` nearest to `q` under squared Euclidean
/// distance, as `(distance, row index)` ascending — ties broken toward
/// the smaller index, so the result is fully deterministic. This is the
/// one kNN scan kernel: [`knn_accuracy`],
/// [`MetricModel::knn`](crate::session::MetricModel::knn), and the
/// serving layer ([`crate::serve`]) all consume it, which is what makes
/// the three provably equivalent.
///
/// `k` is clamped to the gallery size here, in the kernel — callers
/// must not pre-clamp (a `k > n` request simply returns all `n` rows
/// sorted). Centralizing the clamp keeps every call site identical and
/// stops a huge `k` from eagerly reserving a huge heap.
///
/// The scan is cache-blocked: distances for `KNN_BLOCK` gallery rows
/// are computed in one branch-free pass through the SIMD-dispatched
/// [`simd::sqdist`](crate::linalg::simd::sqdist) primitive, then folded
/// into a bounded k-size max-heap (O(n log k) total, and the common
/// no-replacement case is one comparison). On the scalar backend the
/// computed distances are bit-identical to the historical row-at-a-time
/// loop, and the selection is pinned to the old full-sort output —
/// including tie order — by the `prop_simd` regression tests.
pub fn nearest_k(gallery: &Mat, q: &[f32], k: usize) -> Vec<(f32, usize)> {
    assert_eq!(q.len(), gallery.cols, "query dim mismatch");
    let k = k.min(gallery.rows);
    if k == 0 {
        return Vec::new();
    }
    let mut top = TopK::new(k);
    let mut dists = [0.0f32; KNN_BLOCK];
    let mut j0 = 0;
    while j0 < gallery.rows {
        let n = (gallery.rows - j0).min(KNN_BLOCK);
        for (t, dv) in dists[..n].iter_mut().enumerate() {
            *dv = crate::linalg::simd::sqdist(q, gallery.row(j0 + t));
        }
        for (t, &dv) in dists[..n].iter().enumerate() {
            top.offer(dv, j0 + t);
        }
        j0 += n;
    }
    top.into_sorted()
}

/// [`nearest_k`] restricted to a subset of gallery rows — the kernel
/// behind the serving layer's cluster-pruned approximate scan. `rows`
/// must be strictly increasing (the candidate set from a coarse
/// quantizer, sorted); the returned indices are *global* gallery row
/// indices.
///
/// Candidates are offered in increasing global index, through the same
/// strict-`<` heap gate as [`nearest_k`], so when `rows` covers the
/// whole gallery the output is bit-for-bit identical to [`nearest_k`] —
/// the `nprobe = nclusters ≡ exact` contract `prop_serve` pins. `k` is
/// clamped to `rows.len()` under the same centralized-clamp rule.
pub fn nearest_k_among(
    gallery: &Mat,
    q: &[f32],
    k: usize,
    rows: &[usize],
) -> Vec<(f32, usize)> {
    assert_eq!(q.len(), gallery.cols, "query dim mismatch");
    debug_assert!(
        rows.windows(2).all(|w| w[0] < w[1]),
        "candidate rows must be strictly increasing"
    );
    let k = k.min(rows.len());
    if k == 0 {
        return Vec::new();
    }
    let mut top = TopK::new(k);
    let mut dists = [0.0f32; KNN_BLOCK];
    let mut j0 = 0;
    while j0 < rows.len() {
        let n = (rows.len() - j0).min(KNN_BLOCK);
        for (t, dv) in dists[..n].iter_mut().enumerate() {
            *dv = crate::linalg::simd::sqdist(q, gallery.row(rows[j0 + t]));
        }
        for (t, &dv) in dists[..n].iter().enumerate() {
            top.offer(dv, rows[j0 + t]);
        }
        j0 += n;
    }
    top.into_sorted()
}

/// Majority vote over neighbour labels, ties broken toward the smallest
/// class id so the result is deterministic run-to-run.
pub fn majority_label(votes: &[u32]) -> Option<u32> {
    let mut counts = std::collections::HashMap::new();
    for &c in votes {
        *counts.entry(c).or_insert(0usize) += 1;
    }
    counts
        .into_iter()
        .max_by_key(|&(c, n)| (n, std::cmp::Reverse(c)))
        .map(|(c, _)| c)
}

/// k-nearest-neighbour classification accuracy of `test` against `train`
/// under the metric L (L = None → Euclidean). The paper motivates DML
/// through exactly this task (kNN/clustering accuracy).
///
/// The O(n_test · n_train) scan shards test queries over the global
/// thread pool; per-query work is independent, so the result does not
/// depend on the thread count.
pub fn knn_accuracy(
    l: Option<&Mat>,
    train: &Dataset,
    test: &Dataset,
    k: usize,
    max_test: usize,
) -> f64 {
    // project once: in the learned space distances are Euclidean
    let (tr, te): (Mat, Mat) = match l {
        Some(l) => (train.x.matmul_bt(l), test.x.matmul_bt(l)),
        None => (train.x.clone(), test.x.clone()),
    };
    let n_test = test.n().min(max_test);
    if n_test == 0 {
        return 0.0;
    }
    let pool = crate::util::pool::global();
    let shards = pool.threads().min(n_test);
    let mut correct = vec![0usize; shards];
    pool.for_each_mut(&mut correct, |s, correct_s| {
        for i in crate::util::pool::balanced_range(n_test, shards, s) {
            let votes: Vec<u32> = nearest_k(&tr, te.row(i), k)
                .into_iter()
                .map(|(_, j)| train.labels[j])
                .collect();
            if majority_label(&votes) == Some(test.labels[i]) {
                *correct_s += 1;
            }
        }
    });
    correct.iter().sum::<usize>() as f64 / n_test as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::dml::NativeEngine;
    use crate::util::rng::Pcg32;

    #[test]
    fn euclidean_and_engine_agree_on_identity_metric() {
        let ds = SyntheticSpec::tiny().generate(0);
        let mut rng = Pcg32::new(0);
        let pairs = crate::data::PairSet::sample(&ds, 50, 50, &mut rng);
        let l = Mat::eye(ds.dim());
        let mut eng = NativeEngine::new();
        let (s1, d1) = score_pairs(&mut eng, &l, &ds, &pairs).unwrap();
        let (s2, d2) = score_pairs_euclidean(&ds, &pairs);
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b), "{a} {b}");
        }
        for (a, b) in d1.iter().zip(&d2) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b));
        }
    }

    #[test]
    fn mahalanobis_identity_equals_euclidean() {
        let ds = SyntheticSpec::tiny().generate(1);
        let mut rng = Pcg32::new(1);
        let pairs = crate::data::PairSet::sample(&ds, 30, 30, &mut rng);
        let m = Mat::eye(ds.dim());
        let (s1, _) = score_pairs_mahalanobis(&m, &ds, &pairs);
        let (s2, _) = score_pairs_euclidean(&ds, &pairs);
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b));
        }
    }

    #[test]
    fn mahalanobis_matches_factored_form() {
        // dist under M = LᵀL must equal ‖LΔ‖²
        let ds = SyntheticSpec::tiny().generate(2);
        let mut rng = Pcg32::new(2);
        let pairs = crate::data::PairSet::sample(&ds, 20, 20, &mut rng);
        let mut l = Mat::zeros(8, ds.dim());
        rng.fill_gaussian(&mut l.data, 0.0, 0.3);
        let m = l.matmul_at(&l); // M = Lᵀ·L, (d×d)
        let (s1, _) = score_pairs_mahalanobis(&m, &ds, &pairs);
        let mut eng = NativeEngine::new();
        let (s2, _) = score_pairs(&mut eng, &l, &ds, &pairs).unwrap();
        for (a, b) in s1.iter().zip(&s2) {
            assert!((a - b).abs() < 1e-2 * (1.0 + b.abs()), "{a} {b}");
        }
    }

    #[test]
    fn knn_on_separated_clusters_is_accurate() {
        let mut spec = SyntheticSpec::tiny();
        spec.separation = 6.0; // easy
        spec.signal_fraction = 1.0; // signal everywhere
        spec.noise_amp = 1.0;
        spec.outlier_prob = 0.0;
        let mut rng = Pcg32::new(3);
        let train = spec.generate_with(&mut rng, 300);
        let test = spec.generate_with(&mut rng, 100);
        let acc = knn_accuracy(None, &train, &test, 3, 100);
        assert!(acc > 0.9, "acc={acc}");
    }

    #[test]
    fn knn_respects_max_test() {
        let ds = SyntheticSpec::tiny().generate(4);
        let acc = knn_accuracy(None, &ds, &ds, 1, 10);
        // 1-NN on itself = perfect
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn nearest_k_clamps_k_to_gallery() {
        let mut g = Mat::zeros(5, 3);
        Pcg32::new(7).fill_gaussian(&mut g.data, 0.0, 1.0);
        let q = [0.1f32, -0.2, 0.3];
        let all = nearest_k(&g, &q, 5);
        // k far beyond n returns exactly the full sorted gallery
        assert_eq!(nearest_k(&g, &q, usize::MAX), all);
        assert_eq!(nearest_k(&g, &q, 0), Vec::new());
        // empty gallery: any k yields an empty result, no panic
        let empty = Mat::zeros(0, 3);
        assert_eq!(nearest_k(&empty, &q, 10), Vec::new());
    }

    #[test]
    fn nearest_k_among_full_range_matches_nearest_k_bitwise() {
        let mut g = Mat::zeros(97, 6);
        Pcg32::new(9).fill_gaussian(&mut g.data, 0.0, 1.0);
        let q: Vec<f32> = (0..6).map(|i| i as f32 * 0.25 - 0.5).collect();
        let rows: Vec<usize> = (0..g.rows).collect();
        let full = nearest_k(&g, &q, 10);
        let among = nearest_k_among(&g, &q, 10, &rows);
        assert_eq!(full.len(), among.len());
        for ((d1, i1), (d2, i2)) in full.iter().zip(&among) {
            assert_eq!((d1.to_bits(), i1), (d2.to_bits(), i2));
        }
        // subset clamp: k beyond the candidate count returns them all
        let few = [3usize, 40, 41, 90];
        assert_eq!(nearest_k_among(&g, &q, 100, &few).len(), few.len());
        assert_eq!(nearest_k_among(&g, &q, 3, &[]), Vec::new());
    }
}
