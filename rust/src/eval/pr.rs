//! Precision-recall curves and average precision for pair verification.
//!
//! Convention: a pair is *predicted similar* when its distance is below
//! the threshold; *ground-truth positive* = labeled similar. Sweeping the
//! threshold over all observed distances traces the PR curve (paper
//! Fig. 4b/4c); average precision is the standard ranked-retrieval AP
//! (area under the precision-recall steps).

/// One PR-curve point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrPoint {
    pub threshold: f32,
    pub precision: f64,
    pub recall: f64,
}

/// PR curve from similar-pair and dissimilar-pair distance scores.
/// Points are ordered by increasing threshold (recall-ascending).
pub fn pr_curve(sim_dists: &[f32], dis_dists: &[f32]) -> Vec<PrPoint> {
    assert!(!sim_dists.is_empty() && !dis_dists.is_empty());
    // Rank all scores ascending; walk thresholds between distinct values.
    let mut scored: Vec<(f32, bool)> = sim_dists
        .iter()
        .map(|&d| (d, true))
        .chain(dis_dists.iter().map(|&d| (d, false)))
        .collect();
    scored.sort_by(|a, b| a.0.total_cmp(&b.0));
    let total_pos = sim_dists.len() as f64;
    let mut tp = 0.0f64;
    let mut fp = 0.0f64;
    let mut out = Vec::with_capacity(scored.len());
    let mut i = 0;
    while i < scored.len() {
        // advance over ties so the threshold cut is well defined
        let t = scored[i].0;
        while i < scored.len() && scored[i].0 == t {
            if scored[i].1 {
                tp += 1.0;
            } else {
                fp += 1.0;
            }
            i += 1;
        }
        out.push(PrPoint {
            threshold: t,
            precision: tp / (tp + fp),
            recall: tp / total_pos,
        });
    }
    out
}

/// Average precision: mean of precision over the positive ranks
/// (standard information-retrieval AP on the distance ranking).
pub fn average_precision(sim_dists: &[f32], dis_dists: &[f32]) -> f64 {
    assert!(!sim_dists.is_empty());
    let mut scored: Vec<(f32, bool)> = sim_dists
        .iter()
        .map(|&d| (d, true))
        .chain(dis_dists.iter().map(|&d| (d, false)))
        .collect();
    // ascending distance = descending similarity confidence.
    // tie-break: dissimilar first (pessimistic, avoids inflating AP).
    scored.sort_by(|a, b| a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
    let mut tp = 0.0f64;
    let mut ap = 0.0f64;
    for (rank, &(_, is_pos)) in scored.iter().enumerate() {
        if is_pos {
            tp += 1.0;
            ap += tp / (rank as f64 + 1.0);
        }
    }
    ap / sim_dists.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation_gives_ap_one() {
        let sim = [0.1, 0.2, 0.3];
        let dis = [1.0, 2.0, 3.0];
        assert!((average_precision(&sim, &dis) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn inverted_separation_gives_low_ap() {
        let sim = [1.0, 2.0, 3.0];
        let dis = [0.1, 0.2, 0.3];
        let ap = average_precision(&sim, &dis);
        assert!(ap < 0.6, "ap={ap}");
    }

    #[test]
    fn random_scores_give_ap_near_prior() {
        // With equal counts and random scores AP ≈ positive prior = 0.5
        let mut rng = crate::util::rng::Pcg32::new(0);
        let sim: Vec<f32> = (0..2000).map(|_| rng.f32()).collect();
        let dis: Vec<f32> = (0..2000).map(|_| rng.f32()).collect();
        let ap = average_precision(&sim, &dis);
        assert!((ap - 0.5).abs() < 0.05, "ap={ap}");
    }

    #[test]
    fn pr_curve_monotone_recall_and_endpoints() {
        let mut rng = crate::util::rng::Pcg32::new(1);
        let sim: Vec<f32> = (0..500).map(|_| rng.f32() * 0.8).collect();
        let dis: Vec<f32> =
            (0..500).map(|_| 0.2 + rng.f32() * 0.8).collect();
        let curve = pr_curve(&sim, &dis);
        for w in curve.windows(2) {
            assert!(w[1].recall >= w[0].recall);
            assert!(w[1].threshold > w[0].threshold);
        }
        let last = curve.last().unwrap();
        assert!((last.recall - 1.0).abs() < 1e-12);
        assert!((last.precision - 0.5).abs() < 1e-12);
        // separated data: early points should be high precision
        assert!(curve[0].precision > 0.9);
    }

    #[test]
    fn pr_handles_ties() {
        let sim = [0.5, 0.5, 0.5];
        let dis = [0.5, 0.5, 0.5];
        let curve = pr_curve(&sim, &dis);
        assert_eq!(curve.len(), 1);
        assert!((curve[0].precision - 0.5).abs() < 1e-12);
        assert!((curve[0].recall - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ap_tie_break_is_pessimistic() {
        // one positive and one negative at the same distance:
        // pessimistic ranking puts the negative first → AP = 1/2
        let ap = average_precision(&[1.0], &[1.0]);
        assert!((ap - 0.5).abs() < 1e-12, "ap={ap}");
    }
}
