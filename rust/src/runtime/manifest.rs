//! Artifact manifest: what `python/compile/aot.py` exported.
//!
//! The manifest lets the rust side validate shapes/marshalling without
//! parsing HLO text, and lets the CLI's `inspect-artifacts` subcommand
//! describe what is available.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Shape parameters of one exported variant (mirrors
/// `python/compile/model.py::VARIANTS`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VariantShape {
    pub k: usize,
    pub d: usize,
    pub bs: usize,
    pub bd: usize,
    pub eval_batch: usize,
}

/// One exported (variant, function) HLO module.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub variant: String,
    pub function: String,
    pub file: String,
    /// Input shapes, in call order.
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes, in tuple order.
    pub outputs: Vec<Vec<usize>>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, VariantShape>,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        anyhow::ensure!(
            j.get("format").as_str() == Some("hlo-text/1"),
            "unsupported artifact format {:?}",
            j.get("format")
        );
        let mut variants = BTreeMap::new();
        if let Some(vs) = j.get("variants").as_obj() {
            for (name, v) in vs {
                variants.insert(
                    name.clone(),
                    VariantShape {
                        k: req_usize(v, "k")?,
                        d: req_usize(v, "d")?,
                        bs: req_usize(v, "bs")?,
                        bd: req_usize(v, "bd")?,
                        eval_batch: req_usize(v, "eval_batch")?,
                    },
                );
            }
        }
        let mut entries = Vec::new();
        for e in j.get("entries").as_arr().unwrap_or(&[]) {
            entries.push(ArtifactEntry {
                variant: req_str(e, "variant")?,
                function: req_str(e, "function")?,
                file: req_str(e, "file")?,
                inputs: shape_list(e.get("inputs"))?,
                outputs: shape_list(e.get("outputs"))?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), variants, entries })
    }

    pub fn entry(
        &self,
        variant: &str,
        function: &str,
    ) -> anyhow::Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.variant == variant && e.function == function)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "artifact {variant}.{function} not in manifest \
                     (have: {:?})",
                    self.entries
                        .iter()
                        .map(|e| format!("{}.{}", e.variant, e.function))
                        .collect::<Vec<_>>()
                )
            })
    }

    pub fn variant(&self, name: &str) -> anyhow::Result<VariantShape> {
        self.variants.get(name).copied().ok_or_else(|| {
            anyhow::anyhow!(
                "variant '{name}' not in manifest (have: {:?})",
                self.variants.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

fn req_usize(j: &Json, k: &str) -> anyhow::Result<usize> {
    j.get(k)
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("manifest: missing usize '{k}'"))
}

fn req_str(j: &Json, k: &str) -> anyhow::Result<String> {
    Ok(j.get(k)
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("manifest: missing str '{k}'"))?
        .to_string())
}

fn shape_list(j: &Json) -> anyhow::Result<Vec<Vec<usize>>> {
    let mut out = Vec::new();
    for item in j.as_arr().unwrap_or(&[]) {
        let shape: Option<Vec<usize>> = item
            .get("shape")
            .as_arr()
            .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect());
        out.push(shape.ok_or_else(|| {
            anyhow::anyhow!("manifest: entry missing 'shape'")
        })?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, text: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("dmlps_manifest_test");
        write_manifest(
            &dir,
            r#"{
              "format": "hlo-text/1",
              "variants": {"tiny": {"k": 8, "d": 16, "bs": 4, "bd": 4,
                                    "eval_batch": 16}},
              "entries": [{
                "variant": "tiny", "function": "step",
                "file": "tiny.step.hlo.txt",
                "inputs": [{"shape": [8, 16], "dtype": "float32"},
                           {"shape": [4, 16], "dtype": "float32"}],
                "outputs": [{"shape": [1, 1], "dtype": "float32"}]
              }]
            }"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.variant("tiny").unwrap().d, 16);
        let e = m.entry("tiny", "step").unwrap();
        assert_eq!(e.inputs[0], vec![8, 16]);
        assert_eq!(m.hlo_path(e), dir.join("tiny.step.hlo.txt"));
        assert!(m.entry("tiny", "nope").is_err());
        assert!(m.variant("nope").is_err());
    }

    #[test]
    fn rejects_unknown_format() {
        let dir = std::env::temp_dir().join("dmlps_manifest_badfmt");
        write_manifest(&dir, r#"{"format": "hlo-bin/9"}"#);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").is_file() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        // the python test suite pins these shapes too
        let mnist = m.variant("mnist").unwrap();
        assert_eq!((mnist.k, mnist.d, mnist.bs, mnist.bd),
                   (600, 780, 500, 500));
        for f in ["loss_grad", "step", "pair_dist", "apply_update"] {
            let e = m.entry("mnist", f).unwrap();
            assert!(m.hlo_path(e).is_file(), "missing {}", e.file);
        }
    }
}
