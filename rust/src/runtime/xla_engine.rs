//! The PJRT-backed [`Engine`]: executes AOT artifacts on the hot path.
//!
//! One `XlaEngine` owns compiled executables for a single shape variant
//! (`loss_grad`, `step`, `pair_dist`, `apply_update`). Executables are
//! compiled once at construction; per-call work is literal marshalling +
//! `execute`.
//!
//! PJRT client handles are `Rc`-based (not `Send`), so worker threads
//! construct their own engine via [`xla_factory`].

use anyhow::Context;

use super::manifest::{Manifest, VariantShape};
use crate::dml::{Engine, EngineFactory, MinibatchRef};
use crate::linalg::Mat;

pub struct XlaEngine {
    variant: String,
    shape: VariantShape,
    loss_grad_exe: xla::PjRtLoadedExecutable,
    step_exe: xla::PjRtLoadedExecutable,
    pair_dist_exe: xla::PjRtLoadedExecutable,
}

/// f32 slice → (rows, cols) literal.
fn lit2d(data: &[f32], rows: usize, cols: usize) -> anyhow::Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    let bytes = unsafe {
        std::slice::from_raw_parts(
            data.as_ptr() as *const u8,
            data.len() * std::mem::size_of::<f32>(),
        )
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &[rows, cols],
        bytes,
    )?)
}

fn scalar11(v: f32) -> anyhow::Result<xla::Literal> {
    lit2d(&[v], 1, 1)
}

fn first_f32(lit: &xla::Literal) -> anyhow::Result<f32> {
    Ok(lit.to_vec::<f32>()?[0])
}

impl XlaEngine {
    /// Compile all entry points of `variant` from the artifacts in `dir`.
    pub fn load(dir: &std::path::Path, variant: &str) -> anyhow::Result<XlaEngine> {
        let manifest = Manifest::load(dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let shape = manifest.variant(variant)?;
        let client = xla::PjRtClient::cpu()?;
        let compile = |function: &str| -> anyhow::Result<xla::PjRtLoadedExecutable> {
            let entry = manifest.entry(variant, function)?;
            let path = manifest.hlo_path(entry);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {variant}.{function}"))
        };
        Ok(XlaEngine {
            variant: variant.to_string(),
            shape,
            loss_grad_exe: compile("loss_grad")?,
            step_exe: compile("step")?,
            pair_dist_exe: compile("pair_dist")?,
        })
    }

    pub fn shape(&self) -> VariantShape {
        self.shape
    }

    pub fn variant(&self) -> &str {
        &self.variant
    }

    fn check_batch(&self, batch: &MinibatchRef<'_>) -> anyhow::Result<()> {
        anyhow::ensure!(
            batch.bs == self.shape.bs
                && batch.bd == self.shape.bd
                && batch.d == self.shape.d,
            "batch shape (bs={}, bd={}, d={}) does not match artifact \
             variant '{}' (bs={}, bd={}, d={}) — HLO is shape-specialized",
            batch.bs, batch.bd, batch.d,
            self.variant, self.shape.bs, self.shape.bd, self.shape.d,
        );
        Ok(())
    }
}

impl Engine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn loss_grad(
        &mut self,
        l: &Mat,
        batch: &MinibatchRef<'_>,
        lambda: f32,
        g: &mut Mat,
    ) -> anyhow::Result<f32> {
        self.check_batch(batch)?;
        anyhow::ensure!(
            l.rows == self.shape.k && l.cols == self.shape.d,
            "L shape mismatch vs variant '{}'",
            self.variant
        );
        let args = [
            lit2d(&l.data, l.rows, l.cols)?,
            lit2d(batch.ds, batch.bs, batch.d)?,
            lit2d(batch.dd, batch.bd, batch.d)?,
            scalar11(lambda)?,
        ];
        let result = self.loss_grad_exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (loss_lit, g_lit) = result.to_tuple2()?;
        let gv = g_lit.to_vec::<f32>()?;
        anyhow::ensure!(gv.len() == g.data.len(), "gradient size mismatch");
        g.data.copy_from_slice(&gv);
        first_f32(&loss_lit)
    }

    fn step(
        &mut self,
        l: &mut Mat,
        batch: &MinibatchRef<'_>,
        lambda: f32,
        lr: f32,
    ) -> anyhow::Result<f32> {
        self.check_batch(batch)?;
        let args = [
            lit2d(&l.data, l.rows, l.cols)?,
            lit2d(batch.ds, batch.bs, batch.d)?,
            lit2d(batch.dd, batch.bd, batch.d)?,
            scalar11(lambda)?,
            scalar11(lr)?,
        ];
        let result = self.step_exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (loss_lit, l_lit) = result.to_tuple2()?;
        let lv = l_lit.to_vec::<f32>()?;
        anyhow::ensure!(lv.len() == l.data.len(), "L' size mismatch");
        l.data.copy_from_slice(&lv);
        first_f32(&loss_lit)
    }

    fn pair_dist(
        &mut self,
        l: &Mat,
        diffs: &Mat,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(diffs.cols == self.shape.d, "diff dim mismatch");
        let be = self.shape.eval_batch;
        let l_lit = lit2d(&l.data, l.rows, l.cols)?;
        let mut out = Vec::with_capacity(diffs.rows);
        let mut chunk = vec![0.0f32; be * self.shape.d];
        let mut r = 0;
        while r < diffs.rows {
            let n = (diffs.rows - r).min(be);
            // pad the trailing chunk with zeros (discarded below)
            chunk.fill(0.0);
            chunk[..n * self.shape.d].copy_from_slice(
                &diffs.data[r * self.shape.d..(r + n) * self.shape.d],
            );
            let d_lit = lit2d(&chunk, be, self.shape.d)?;
            let result = self
                .pair_dist_exe
                .execute::<xla::Literal>(&[l_lit.clone(), d_lit])?[0][0]
                .to_literal_sync()?;
            let dist_lit = result.to_tuple1()?;
            let dv = dist_lit.to_vec::<f32>()?;
            out.extend_from_slice(&dv[..n]);
            r += n;
        }
        Ok(out)
    }
}

/// Engine factory for worker threads: each call loads + compiles the
/// variant's artifacts on a fresh PJRT CPU client inside the calling
/// thread.
pub fn xla_factory(variant: &str) -> EngineFactory {
    let variant = variant.to_string();
    let dir = super::artifacts_dir();
    std::sync::Arc::new(move || {
        Ok(Box::new(XlaEngine::load(&dir, &variant)?) as Box<dyn Engine>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dml::NativeEngine;
    use crate::util::rng::Pcg32;

    fn engine_or_skip(variant: &str) -> Option<XlaEngine> {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").is_file() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(XlaEngine::load(&dir, variant).expect("load artifacts"))
    }

    #[test]
    fn xla_matches_native_on_test_small() {
        let Some(mut xe) = engine_or_skip("test_small") else { return };
        let s = xe.shape();
        let mut rng = Pcg32::new(0);
        let mut l = Mat::zeros(s.k, s.d);
        rng.fill_gaussian(&mut l.data, 0.0, 0.3);
        let mut ds = vec![0.0f32; s.bs * s.d];
        let mut dd = vec![0.0f32; s.bd * s.d];
        rng.fill_gaussian(&mut ds, 0.0, 1.0);
        rng.fill_gaussian(&mut dd, 0.0, 1.0);
        let batch = MinibatchRef::new(&ds, &dd, s.bs, s.bd, s.d);

        let mut ne = NativeEngine::new();
        let mut gx = Mat::zeros(s.k, s.d);
        let mut gn = Mat::zeros(s.k, s.d);
        let lx = xe.loss_grad(&l, &batch, 1.0, &mut gx).unwrap();
        let ln = ne.loss_grad(&l, &batch, 1.0, &mut gn).unwrap();
        assert!((lx - ln).abs() < 1e-4 * (1.0 + ln.abs()),
                "loss {lx} vs {ln}");
        assert!(gx.max_abs_diff(&gn) < 1e-3);
    }

    #[test]
    fn xla_step_matches_native_step() {
        let Some(mut xe) = engine_or_skip("test_small") else { return };
        let s = xe.shape();
        let mut rng = Pcg32::new(1);
        let mut lx = Mat::zeros(s.k, s.d);
        rng.fill_gaussian(&mut lx.data, 0.0, 0.3);
        let mut ln = lx.clone();
        let mut ds = vec![0.0f32; s.bs * s.d];
        let mut dd = vec![0.0f32; s.bd * s.d];
        rng.fill_gaussian(&mut ds, 0.0, 1.0);
        rng.fill_gaussian(&mut dd, 0.0, 1.0);

        let mut ne = NativeEngine::new();
        for step in 0..5 {
            let batch = MinibatchRef::new(&ds, &dd, s.bs, s.bd, s.d);
            let fx = xe.step(&mut lx, &batch, 1.0, 0.05).unwrap();
            let batch = MinibatchRef::new(&ds, &dd, s.bs, s.bd, s.d);
            let fn_ = ne.step(&mut ln, &batch, 1.0, 0.05).unwrap();
            assert!((fx - fn_).abs() < 1e-3 * (1.0 + fn_.abs()),
                    "step {step}: {fx} vs {fn_}");
        }
        assert!(lx.max_abs_diff(&ln) < 1e-2);
    }

    #[test]
    fn pair_dist_chunks_and_pads() {
        let Some(mut xe) = engine_or_skip("test_small") else { return };
        let s = xe.shape();
        let mut rng = Pcg32::new(2);
        let mut l = Mat::zeros(s.k, s.d);
        rng.fill_gaussian(&mut l.data, 0.0, 0.5);
        // rows deliberately NOT a multiple of eval_batch
        let rows = s.eval_batch * 2 + 3;
        let mut diffs = Mat::zeros(rows, s.d);
        rng.fill_gaussian(&mut diffs.data, 0.0, 1.0);
        let got = xe.pair_dist(&l, &diffs).unwrap();
        assert_eq!(got.len(), rows);
        let want = NativeEngine::new().pair_dist(&l, &diffs).unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b), "{a} vs {b}");
        }
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let Some(mut xe) = engine_or_skip("test_small") else { return };
        let s = xe.shape();
        let l = Mat::zeros(s.k, s.d);
        let ds = vec![0.0f32; (s.bs + 1) * s.d];
        let dd = vec![0.0f32; s.bd * s.d];
        let batch = MinibatchRef::new(&ds, &dd, s.bs + 1, s.bd, s.d);
        let mut g = Mat::zeros(s.k, s.d);
        let err = xe.loss_grad(&l, &batch, 1.0, &mut g).unwrap_err();
        assert!(err.to_string().contains("shape-specialized"));
    }
}
