//! PJRT runtime: load + execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The build-time Python pipeline (`make artifacts`) lowers the L2 model
//! (which calls the L1 Pallas kernels) to **HLO text** — see
//! `python/compile/aot.py` for why text, not serialized protos. This
//! module is the production hot path: it parses the manifest, compiles
//! each needed HLO module once on the PJRT CPU client, and exposes the
//! same [`Engine`](crate::dml::Engine) interface the native engine
//! implements, so the parameter server is backend-agnostic.

mod manifest;
#[cfg(feature = "xla")]
mod xla_engine;

pub use manifest::{ArtifactEntry, Manifest, VariantShape};
#[cfg(feature = "xla")]
pub use xla_engine::{xla_factory, XlaEngine};

/// Stub factory used when the crate is built without the `xla` feature
/// (the PJRT bindings are not in the offline vendor set): constructing an
/// engine reports the missing runtime instead of linking against it.
#[cfg(not(feature = "xla"))]
pub fn xla_factory(variant: &str) -> crate::dml::EngineFactory {
    let variant = variant.to_string();
    std::sync::Arc::new(
        move || -> anyhow::Result<Box<dyn crate::dml::Engine>> {
            anyhow::bail!(
                "XLA/PJRT runtime not compiled in (rebuild with \
                 `--features xla`); cannot load artifact variant \
                 '{variant}'"
            )
        },
    )
}

/// Default artifacts directory, relative to the repo root. Overridable
/// via the `DMLPS_ARTIFACTS` environment variable (used by tests).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("DMLPS_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| find_artifacts_upward())
}

/// Walk up from CWD looking for an `artifacts/manifest.json` so binaries
/// work from the repo root, `rust/`, or a bench/test cwd.
fn find_artifacts_upward() -> std::path::PathBuf {
    let mut dir = std::env::current_dir()
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    for _ in 0..5 {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").is_file() {
            return cand;
        }
        if !dir.pop() {
            break;
        }
    }
    std::path::PathBuf::from("artifacts")
}

/// True if AOT artifacts are available (tests degrade gracefully when
/// `make artifacts` has not run).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").is_file()
}
