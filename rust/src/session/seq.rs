//! Sequential-training executor core (the paper's §5.4 single-thread
//! setting, used for the Fig 4a/4b method comparison).
//!
//! Moved here from `cli::driver::train_single_thread`, which survives
//! as a deprecated shim over this function (pinned bit-identical by the
//! `api_session` golden tests): same RNG streams, same probe seeds,
//! same update order.

use crate::baselines::ApTrace;
use crate::config::ExperimentConfig;
use crate::data::ExperimentData;
use crate::dml::{
    DmlProblem, Engine, LrSchedule, MinibatchRef, ObjectiveProbe,
};
use crate::linalg::Mat;
use crate::metrics::{Curve, Stopwatch};
use crate::util::rng::Pcg32;

use super::events::{EventSink, ProbeEvent};

/// What sequential training hands back (folded into [`super::Run`] by
/// the session, or into the legacy `SingleThreadRun` by the shim).
pub(crate) struct SeqOutcome {
    pub l: Mat,
    pub curve: Curve,
    pub ap_trace: ApTrace,
    pub wall_s: f64,
}

/// Single-threaded SGD training. Records an objective curve and an
/// AP-vs-time trace on held-out test pairs. `probe_pairs` bounds the
/// similar/dissimilar probe subsample (clamped to the materialized pair
/// counts; the historical entry point used 500/500).
pub(crate) fn run_sequential(
    cfg: &ExperimentConfig,
    data: &ExperimentData,
    engine: &mut dyn Engine,
    probe_every: usize,
    probe_pairs: (usize, usize),
    events: Option<&std::sync::Arc<dyn EventSink>>,
) -> anyhow::Result<SeqOutcome> {
    anyhow::ensure!(
        !data.pairs.similar.is_empty()
            && !data.pairs.dissimilar.is_empty(),
        "sequential training needs materialized train pairs \
         (generate data with the materialized pair mode)"
    );
    let probe_every = probe_every.max(1);
    let problem =
        DmlProblem::new(cfg.dataset.dim, cfg.model.k, cfg.optim.lambda);
    let mut l = problem.init_l(cfg.model.init_scale, cfg.seed);
    let lr = LrSchedule::new(cfg.optim.lr, cfg.optim.lr_decay);
    let probe = ObjectiveProbe::new(
        &data.train,
        &data.pairs,
        probe_pairs.0.min(data.pairs.similar.len()),
        probe_pairs.1.min(data.pairs.dissimilar.len()),
        cfg.seed ^ 0xB0B,
    );
    let (bs, bd, d) =
        (cfg.optim.batch_sim, cfg.optim.batch_dis, cfg.dataset.dim);
    let mut rng = Pcg32::with_stream(cfg.seed, 0x51);
    let mut ds_buf = vec![0.0f32; bs * d];
    let mut dd_buf = vec![0.0f32; bd * d];
    let mut curve = Curve::new("ours (single thread)");
    let mut ap_trace = ApTrace::new();
    let watch = Stopwatch::start();
    let record =
        |curve: &mut Curve, step: usize, t: f64, obj: f64| {
            curve.push(t, step, obj);
            if let Some(sink) = events {
                sink.on_probe(&ProbeEvent {
                    step: step as u64,
                    time_s: t,
                    objective: obj,
                });
            }
        };
    let obj0 = probe.eval(engine, &l, cfg.optim.lambda) as f64;
    record(&mut curve, 0, 0.0, obj0);
    for step in 0..cfg.optim.steps {
        fill_batch(&data.train, &data.pairs, &mut rng, &mut ds_buf,
                   &mut dd_buf, bs, bd);
        let batch = MinibatchRef::new(&ds_buf, &dd_buf, bs, bd, d);
        engine.step(&mut l, &batch, cfg.optim.lambda, lr.at(step))?;
        if (step + 1) % probe_every == 0 || step + 1 == cfg.optim.steps {
            let t = watch.elapsed_s();
            let obj = probe.eval(engine, &l, cfg.optim.lambda) as f64;
            record(&mut curve, step + 1, t, obj);
            ap_trace.push((t, crate::eval::ap_of_l(engine, &l, data)?));
        }
    }
    Ok(SeqOutcome { l, curve, ap_trace, wall_s: watch.elapsed_s() })
}

fn fill_batch(
    train: &crate::data::Dataset,
    pairs: &crate::data::PairSet,
    rng: &mut Pcg32,
    ds_buf: &mut [f32],
    dd_buf: &mut [f32],
    bs: usize,
    bd: usize,
) {
    let d = train.dim();
    for r in 0..bs {
        let p = pairs.similar[rng.index(pairs.similar.len())];
        train.diff_into(p.i as usize, p.j as usize,
                        &mut ds_buf[r * d..(r + 1) * d]);
    }
    for r in 0..bd {
        let p = pairs.dissimilar[rng.index(pairs.dissimilar.len())];
        train.diff_into(p.i as usize, p.j as usize,
                        &mut dd_buf[r * d..(r + 1) * d]);
    }
}
