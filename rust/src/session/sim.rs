//! Simulated-cluster executor core and its cost knobs.
//!
//! Moved here from `cli::driver` so [`Session::simulate`](super::Session)
//! is the one entry point; `cli::driver` re-exports these names for
//! compatibility.

use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::data::{partition_pairs, ExperimentData};
use crate::dml::{DmlProblem, LrSchedule};
use crate::simcluster::{
    calibrate_grad_seconds, Disruption, DmlWorkload, NetworkModel,
    SimConfig, SimResult, Simulator,
};

/// Cost knobs for a simulated run. [`Default`] derives everything from
/// the config's own (scaled) shape: `grad_seconds = 0.0` means
/// "calibrate on this machine at run time". For paper-true clocking,
/// override `grad_seconds` (FLOP-extrapolated) and `bytes_per_msg`.
#[derive(Clone, Copy, Debug)]
pub struct SimKnobs {
    /// Single-core minibatch gradient seconds; `0.0` = calibrate with
    /// [`calibrate_for`] when the session runs.
    pub grad_seconds: f64,
    /// Message payload bytes; `None` = dense f32 (`k·d·4`).
    pub bytes_per_msg: Option<f64>,
    /// Applied updates to simulate.
    pub total_updates: u64,
    /// Optional kill/restart scenario (see [`Disruption`]).
    pub disruption: Option<Disruption>,
}

impl Default for SimKnobs {
    fn default() -> Self {
        SimKnobs {
            grad_seconds: 0.0,
            bytes_per_msg: None,
            total_updates: 2_000,
            disruption: None,
        }
    }
}

/// One simulated-cluster convergence run at `machines × cores`.
///
/// `knobs.grad_seconds` should come from [`calibrate_for`] (possibly
/// FLOP-extrapolated to the paper-true shape) so the simulated clock is
/// anchored to real measured compute cost; `0.0` calibrates here.
/// Errors when the materialized pair sets cannot cover `machines`
/// workers.
pub(crate) fn run_simulated(
    cfg: &ExperimentConfig,
    data: &ExperimentData,
    machines: usize,
    cores_per_machine: usize,
    knobs: SimKnobs,
) -> anyhow::Result<SimResult> {
    let grad_seconds = if knobs.grad_seconds > 0.0 {
        knobs.grad_seconds
    } else {
        calibrate_for(cfg)
    };
    let problem =
        DmlProblem::new(cfg.dataset.dim, cfg.model.k, cfg.optim.lambda);
    let shards = partition_pairs(&data.pairs, machines, cfg.seed ^ 0xFA)?;
    let dataset = Arc::new(crate::session::clone_dataset(&data.train));
    let mut workload = DmlWorkload::new(
        problem,
        cfg.model.init_scale,
        dataset,
        shards,
        cfg.optim.batch_sim,
        cfg.optim.batch_dis,
        (500, 500),
        cfg.seed,
    );
    let n_params = (cfg.model.k * cfg.dataset.dim) as f64;
    let bytes = knobs.bytes_per_msg.unwrap_or(n_params * 4.0);
    let sim_cfg = SimConfig {
        machines,
        cores_per_machine,
        grad_seconds,
        // server-side apply: streaming axpy over the parameters at
        // ~4 GB/s effective memory bandwidth (two passes of 4 bytes)
        apply_seconds: bytes * 2.0 / 4.0e9,
        bytes_per_msg: bytes,
        network: NetworkModel::ten_gbe(),
        jitter: 0.05,
        total_updates: knobs.total_updates,
        probe_every: (knobs.total_updates / 40).max(1),
        broadcast_every: 1,
        lr: LrSchedule::new(cfg.optim.lr, cfg.optim.lr_decay),
        seed: cfg.seed,
        disruption: knobs.disruption,
    };
    Ok(Simulator::new(sim_cfg, &mut workload).run())
}

/// A dimension-scaled copy of a config for simulator numerics, plus the
/// FLOP ratio to the paper-true shape.
///
/// The simulator runs *real* gradients serially on this box, so Fig 2/3
/// sweeps use a scaled shape for the numerics while the simulated clock
/// charges each gradient the *extrapolated paper-true* cost (FLOP-ratio
/// scaling of the calibrated native step time). Convergence shape is
/// preserved (same algorithm, same staleness structure); absolute
/// objective values are those of the scaled problem — which is what we
/// compare across core counts, never against the paper's absolute values.
pub struct SimScaled {
    pub cfg: ExperimentConfig,
    /// paper-true FLOPs / scaled FLOPs per minibatch gradient.
    pub flop_ratio: f64,
    /// paper-true parameter bytes per message.
    pub paper_bytes: f64,
}

pub fn sim_scaled(preset: crate::config::Preset) -> SimScaled {
    use crate::config::{PaperShape, Preset, PAPER_SHAPES};
    let mut cfg = preset.config();
    let paper: &PaperShape = match preset {
        Preset::Mnist | Preset::Tiny => &PAPER_SHAPES[0],
        Preset::Imnet60kScaled => &PAPER_SHAPES[1],
        Preset::Imnet1mScaled => &PAPER_SHAPES[2],
    };
    // Scale to ~10 ms/grad on this box: divide d, k, batch.
    let (d, k, bs) = match preset {
        Preset::Mnist => (260, 200, 160),
        Preset::Imnet60kScaled => (512, 128, 25),
        Preset::Imnet1mScaled => (512, 64, 125),
        Preset::Tiny => (16, 8, 4),
    };
    cfg.dataset.dim = d;
    cfg.model.k = k;
    cfg.optim.batch_sim = bs;
    cfg.optim.batch_dis = bs;
    cfg.dataset.name = format!("{}_sim", cfg.dataset.name);
    cfg.artifact_variant = None;
    // keep data volume small enough for quick generation
    cfg.dataset.n_train = cfg.dataset.n_train.min(20_000);
    cfg.dataset.n_similar = cfg.dataset.n_similar.min(50_000);
    cfg.dataset.n_dissimilar = cfg.dataset.n_dissimilar.min(50_000);
    let scaled_flops = 4.0 * (2.0 * bs as f64) / 2.0 * k as f64
        * d as f64 * 2.0;
    let paper_flops = paper.step_flops();
    SimScaled {
        cfg,
        flop_ratio: paper_flops / scaled_flops,
        paper_bytes: paper.n_params() as f64 * 4.0,
    }
}

/// Calibrate per-core gradient seconds for a config on this machine.
pub fn calibrate_for(cfg: &ExperimentConfig) -> f64 {
    let problem =
        DmlProblem::new(cfg.dataset.dim, cfg.model.k, cfg.optim.lambda);
    calibrate_grad_seconds(
        &problem,
        cfg.optim.batch_sim,
        cfg.optim.batch_dis,
        5,
    )
}
