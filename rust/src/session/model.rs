//! The first-class trained-metric artifact.
//!
//! A [`MetricModel`] owns the learned projection L (k × d) plus the
//! provenance header (shape, seed, config digest) and offers everything
//! a serving path needs — project features, score pairs, run kNN
//! retrieval — without retraining and without touching the training
//! stack. It persists to a versioned binary format so a metric trained
//! once can be reloaded and served anywhere (`dmlps train --save-model`
//! / `dmlps eval --model`).
//!
//! On-disk format (all little-endian):
//!
//! ```text
//! 8 B  magic  b"DMLPSMM1"
//! 4 B  u32    header version (currently 1)
//! 8 B  u64    k (rows of L)
//! 8 B  u64    d (cols of L)
//! 8 B  u64    training seed
//! 8 B  u64    FNV-1a digest of the training config JSON
//! ...         L payload via `linalg::io` (DMLPSMAT magic, dims, f32 rows)
//! ```
//!
//! The payload reuses the `DMLPSMAT` matrix codec, so the bytes after
//! the header are exactly what `Mat::save` writes — one matrix format
//! across the whole crate.

use std::io::{Read, Write};
use std::path::Path;

use crate::config::ExperimentConfig;
use crate::data::Dataset;
use crate::linalg::io::{read_mat, write_mat};
use crate::linalg::Mat;

const MAGIC: &[u8; 8] = b"DMLPSMM1";
const FORMAT_VERSION: u32 = 1;

/// Provenance header carried by a [`MetricModel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelMeta {
    /// On-disk format version (see module docs). Real artifacts start
    /// at 1; `0` marks a wrapped legacy bare-matrix file whose
    /// provenance fields are unknown, not claims.
    pub version: u32,
    /// Rows of L.
    pub k: u64,
    /// Cols of L (the feature dimension).
    pub d: u64,
    /// Seed the metric was trained with.
    pub seed: u64,
    /// FNV-1a 64-bit digest of the training config's JSON rendering —
    /// ties a model file back to the exact experiment that produced it.
    pub config_digest: u64,
}

/// A trained Mahalanobis metric `M = LᵀL`, packaged for serving.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricModel {
    l: Mat,
    meta: ModelMeta,
}

impl MetricModel {
    /// Package a learned L with provenance from the config that
    /// produced it.
    pub fn new(l: Mat, cfg: &ExperimentConfig) -> MetricModel {
        let meta = ModelMeta {
            version: FORMAT_VERSION,
            k: l.rows as u64,
            d: l.cols as u64,
            seed: cfg.seed,
            config_digest: config_digest(cfg),
        };
        MetricModel { l, meta }
    }

    /// Rehydrate from parts (e.g. a legacy bare-`Mat` model file whose
    /// provenance is unknown).
    pub fn from_parts(l: Mat, meta: ModelMeta) -> MetricModel {
        MetricModel { l, meta }
    }

    /// The learned projection L (k × d).
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Consume the model and keep only L.
    pub fn into_l(self) -> Mat {
        self.l
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Feature dimension d the metric expects.
    pub fn dim(&self) -> usize {
        self.l.cols
    }

    /// Projected dimension k.
    pub fn k(&self) -> usize {
        self.l.rows
    }

    /// Project feature rows into the learned space: `x` (n × d) → n × k.
    /// In the projected space the learned metric is plain Euclidean —
    /// project once, then serve with any Euclidean index.
    pub fn transform(&self, x: &Mat) -> Mat {
        assert_eq!(
            x.cols, self.l.cols,
            "feature dim {} != model dim {}",
            x.cols, self.l.cols
        );
        x.matmul_bt(&self.l)
    }

    /// Project a single feature vector. Routes through the same gemm
    /// path as [`MetricModel::transform`], so a query projected alone
    /// is bit-identical to the same row projected in a batch (and to
    /// [`crate::eval::knn_accuracy`]'s projection — the kNN
    /// equivalence the `api_session` tests pin).
    pub fn transform_vec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.l.cols, "feature dim mismatch");
        let mut m = Mat::zeros(1, self.l.cols);
        m.row_mut(0).copy_from_slice(x);
        self.transform(&m).data
    }

    /// Squared learned distance ‖L(a − b)‖² between two feature vectors.
    pub fn pair_dist(&self, a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len(), "pair dim mismatch");
        let diff: Vec<f32> =
            a.iter().zip(b).map(|(x, y)| x - y).collect();
        self.transform_vec(&diff).iter().map(|v| v * v).sum()
    }

    /// Squared learned distances for difference rows (b × d), one per
    /// row — the batch form of [`MetricModel::pair_dist`].
    pub fn pair_dists(&self, diffs: &Mat) -> Vec<f32> {
        let p = self.transform(diffs);
        (0..p.rows)
            .map(|r| p.row(r).iter().map(|v| v * v).sum())
            .collect()
    }

    /// Project a gallery once for repeated [`MetricModel::knn_projected`]
    /// queries (the serving pattern: amortize the gallery projection).
    pub fn project_gallery(&self, gallery: &Dataset) -> Mat {
        self.transform(&gallery.x)
    }

    /// k nearest gallery points to `query` under the learned metric.
    /// Returns `(gallery index, squared distance)` ascending by
    /// distance (ties broken toward the smaller index — the same
    /// deterministic order [`crate::eval::knn_accuracy`] uses).
    pub fn knn(
        &self,
        gallery: &Dataset,
        query: &[f32],
        k: usize,
    ) -> Vec<(usize, f32)> {
        self.knn_projected(&self.project_gallery(gallery), query, k)
    }

    /// [`MetricModel::knn`] against a pre-projected gallery.
    pub fn knn_projected(
        &self,
        projected: &Mat,
        query: &[f32],
        k: usize,
    ) -> Vec<(usize, f32)> {
        let q = self.transform_vec(query);
        crate::eval::nearest_k(projected, &q, k)
            .into_iter()
            .map(|(dist, idx)| (idx, dist))
            .collect()
    }

    /// Write the versioned binary artifact (see module docs).
    ///
    /// Crash-atomic via [`crate::linalg::io::atomic_write`]: a process
    /// killed mid-save leaves either the previous complete artifact or
    /// the new one, never a torn file that [`MetricModel::load`] would
    /// half-parse.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        crate::linalg::io::atomic_write(path, |f| {
            f.write_all(MAGIC)?;
            f.write_all(&self.meta.version.to_le_bytes())?;
            f.write_all(&self.meta.k.to_le_bytes())?;
            f.write_all(&self.meta.d.to_le_bytes())?;
            f.write_all(&self.meta.seed.to_le_bytes())?;
            f.write_all(&self.meta.config_digest.to_le_bytes())?;
            write_mat(f, &self.l)?;
            Ok(())
        })
    }

    /// Load a model artifact written by [`MetricModel::save`].
    pub fn load(path: &Path) -> anyhow::Result<MetricModel> {
        let mut f =
            std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(
            &magic == MAGIC,
            "not a DMLPSMM1 metric model file (bad magic)"
        );
        let mut b4 = [0u8; 4];
        f.read_exact(&mut b4)?;
        let version = u32::from_le_bytes(b4);
        anyhow::ensure!(
            version == FORMAT_VERSION,
            "unsupported metric model format version {version} \
             (this build reads version {FORMAT_VERSION})"
        );
        let mut b8 = [0u8; 8];
        let mut next_u64 = |f: &mut dyn Read| -> anyhow::Result<u64> {
            f.read_exact(&mut b8)?;
            Ok(u64::from_le_bytes(b8))
        };
        let k = next_u64(&mut f)?;
        let d = next_u64(&mut f)?;
        let seed = next_u64(&mut f)?;
        let config_digest = next_u64(&mut f)?;
        let l = read_mat(&mut f)?;
        anyhow::ensure!(
            l.rows as u64 == k && l.cols as u64 == d,
            "model header says {k}x{d} but payload is {}x{}",
            l.rows,
            l.cols
        );
        Ok(MetricModel {
            l,
            meta: ModelMeta { version, k, d, seed, config_digest },
        })
    }
}

/// FNV-1a 64-bit digest of the config's (stable, sorted-key) JSON
/// rendering — the provenance fingerprint stored in model headers.
pub fn config_digest(cfg: &ExperimentConfig) -> u64 {
    fnv1a(cfg.to_json().to_string_pretty().as_bytes())
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}
