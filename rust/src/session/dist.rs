//! Distributed-training executor core.
//!
//! This is the orchestration that used to live in `ps::run_training`:
//! build the shard plan, pair sources, and channels; spawn the server
//! and workers; join and collect the [`TrainResult`]. It moved here so
//! the [`Session`](super::Session) builder is the single entry point;
//! the old `ps::run_training` survives as a deprecated shim that calls
//! straight into this function (and is pinned bit-identical to it by
//! the `api_session` golden tests).

use std::sync::mpsc::channel;
use std::sync::Arc;

use crate::config::{ExperimentConfig, PairMode};
use crate::data::{
    partition_pairs, ClassIndex, Dataset, ImplicitPairSampler, PairSet,
    WorkerPairs,
};
use crate::dml::{DmlProblem, EngineFactory, LrSchedule};
use crate::linalg::Mat;
use crate::metrics::Curve;
use crate::ps::{
    ProbeFn, RunOptions, Server, ServerConfig, ShardPlan, TrainResult,
    Worker, WorkerConfig, WorkerStats,
};

use super::events::{EventSink, ProbeEvent};

/// Run distributed DML training with the threaded parameter server.
///
/// * `engines` — factory each worker's computing thread uses.
/// * `events` — optional sink fed by the probe thread, the server
///   shards, and the workers; `None` is byte-for-byte the historical
///   protocol.
///
/// The probe engine (objective recording on the server's probe thread)
/// is always the native engine: probes are off the hot path and must
/// not depend on artifacts being present.
pub(crate) fn run_distributed(
    cfg: &ExperimentConfig,
    dataset: Arc<Dataset>,
    pairs: &PairSet,
    engines: EngineFactory,
    opts: &RunOptions,
    events: Option<Arc<dyn EventSink>>,
) -> anyhow::Result<TrainResult> {
    let problem =
        DmlProblem::new(cfg.dataset.dim, cfg.model.k, cfg.optim.lambda);
    let l0 = problem.init_l(cfg.model.init_scale, cfg.seed);
    let p = cfg.cluster.workers;
    anyhow::ensure!(p > 0, "need at least one worker");
    // BSP/SSP gates wait for server clocks that only advance when
    // gradients arrive and parameter broadcasts land; with message drops
    // and no retransmission the clock can stall below the gate forever.
    // Fail fast instead of deadlocking the run.
    anyhow::ensure!(
        cfg.cluster.consistency == crate::config::Consistency::Asp
            || (opts.faults.drop_grad_prob == 0.0
                && opts.faults.drop_param_prob == 0.0),
        "message drops require ASP consistency: BSP/SSP gates can \
         deadlock on a dropped update (no retransmission layer)"
    );

    // ---- the shard plan both sides agree on (clamped to the row count;
    //      server_shards = 0 is treated as 1 for configs predating the
    //      knob) ----
    let plan = ShardPlan::new(
        cfg.model.k,
        cfg.dataset.dim,
        cfg.cluster.server_shards.max(1),
    );
    let server_shards = plan.shards();

    // ---- pair sources: materialized shards (paper §4.1 clone-and-
    //      shuffle) or implicit (seed, w, t) samplers whose index
    //      spaces partition by worker ≡ w (mod P). The class index is
    //      O(n) in dataset size and shared by all samplers (workers
    //      and the probe alike). ----
    let stream_index = match cfg.cluster.pairs.mode {
        PairMode::Materialized => None,
        PairMode::Streaming => Some(Arc::new(ClassIndex::build(
            &dataset,
            cfg.cluster.pairs.imbalance,
        )?)),
    };
    let sources: Vec<WorkerPairs> = match &stream_index {
        None => partition_pairs(pairs, p, cfg.seed ^ 0x5A4D)?
            .into_iter()
            .map(WorkerPairs::Materialized)
            .collect(),
        Some(index) => (0..p)
            .map(|w| {
                WorkerPairs::Streaming(ImplicitPairSampler::with_index(
                    dataset.clone(),
                    index.clone(),
                    cfg.seed,
                    w,
                    p,
                    cfg.cluster.pairs.label_noise,
                ))
            })
            .collect(),
    };

    // ---- channels: workers → server (shared), server → each worker ----
    let (to_server_tx, to_server_rx) = channel();
    let mut to_worker_txs = Vec::with_capacity(p);
    let mut to_worker_rxs = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = channel();
        to_worker_txs.push(tx);
        to_worker_rxs.push(rx);
    }

    // ---- objective probe (runs on the server probe thread) ----
    let probe = make_probe(
        &dataset,
        pairs,
        cfg,
        opts.probe_pairs,
        stream_index,
        events.clone(),
    );

    // ---- spawn server ----
    let lr = LrSchedule::new(cfg.optim.lr, cfg.optim.lr_decay);
    let watch = crate::metrics::Stopwatch::start();
    let server = Server::spawn(
        ServerConfig {
            workers: p,
            server_batch: cfg.cluster.server_batch,
            lr,
            lr_scale: 1.0 / p as f32,
            probe_every: opts.probe_every,
            faults: opts.faults,
            seed: cfg.seed ^ 0x5E2,
            compression: cfg.cluster.compression,
            events: events.clone(),
        },
        plan.clone(),
        l0.clone(),
        to_server_rx,
        to_worker_txs,
        probe,
    );

    // ---- spawn workers ----
    let mut workers = Vec::with_capacity(p);
    for (w, source) in sources.into_iter().enumerate() {
        let wcfg = WorkerConfig {
            id: w,
            steps: cfg.optim.steps,
            batch_sim: cfg.optim.batch_sim,
            batch_dis: cfg.optim.batch_dis,
            lambda: cfg.optim.lambda,
            lr,
            consistency: cfg.cluster.consistency,
            faults: opts.faults,
            seed: cfg.seed ^ ((w as u64 + 1) << 16),
            threads: cfg.cluster.threads_per_worker,
            compression: cfg.cluster.compression,
            events: events.clone(),
        };
        workers.push(Worker::spawn(
            wcfg,
            plan.clone(),
            l0.clone(),
            dataset.clone(),
            source,
            to_server_tx.clone(),
            to_worker_rxs.remove(0),
            engines.clone(),
        ));
    }
    drop(to_server_tx); // server sees disconnect when all workers finish

    // ---- join ----
    let worker_stats: Vec<WorkerStats> =
        workers.into_iter().map(Worker::join).collect();
    let sr = server.join();
    Ok(TrainResult {
        l: sr.l,
        curve: sr.curve,
        applied_updates: sr.applied_updates,
        slice_updates: sr.slice_updates,
        broadcasts: sr.broadcasts,
        param_msgs: sr.param_msgs,
        server_shards,
        last_loss: sr.last_loss,
        grad_bytes_received: sr.grad_bytes_received,
        param_bytes_sent: sr.param_bytes_sent,
        worker_stats,
        wall_s: watch.elapsed_s(),
    })
}

/// Build the server-side objective probe: materializes a fixed pair
/// subsample (Send-safe buffers) and evaluates with a native engine
/// constructed inside the probe thread. In streaming mode the
/// subsample is drawn from a dedicated implicit sampler on a reserved
/// seed (the materialized pair sets may be empty — that's the point),
/// with the same scenario knobs the workers train under. Every probe
/// point is mirrored to the event sink.
fn make_probe(
    dataset: &Arc<Dataset>,
    pairs: &PairSet,
    cfg: &ExperimentConfig,
    probe_pairs: (usize, usize),
    stream_index: Option<Arc<ClassIndex>>,
    events: Option<Arc<dyn EventSink>>,
) -> ProbeFn {
    let lambda = cfg.optim.lambda;
    let probe = match stream_index {
        None => crate::dml::ObjectiveProbe::new(
            dataset,
            pairs,
            probe_pairs.0,
            probe_pairs.1,
            cfg.seed ^ 0x0B5,
        ),
        Some(index) => {
            let mut sampler = ImplicitPairSampler::with_index(
                dataset.clone(),
                index,
                cfg.seed ^ 0x0B5E,
                0,
                1,
                cfg.cluster.pairs.label_noise,
            );
            crate::dml::ObjectiveProbe::from_stream(
                dataset,
                &mut sampler,
                probe_pairs.0,
                probe_pairs.1,
            )
        }
    };
    let mut engine: Option<crate::dml::NativeEngine> = None;
    Box::new(move |l: &Mat, step: u64, t: f64, curve: &mut Curve| {
        let eng = engine.get_or_insert_with(crate::dml::NativeEngine::new);
        let obj = probe.eval(eng, l, lambda) as f64;
        curve.push(t, step as usize, obj);
        if let Some(sink) = &events {
            sink.on_probe(&ProbeEvent { step, time_s: t, objective: obj });
        }
    })
}
