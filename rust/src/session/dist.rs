//! Distributed-training executor core.
//!
//! This is the orchestration that used to live in `ps::run_training`:
//! build the shard plan, pair sources, and transport endpoints; spawn
//! the server and workers; join and collect the [`TrainResult`]. It
//! moved here so the [`Session`](super::Session) builder is the single
//! entry point; the old `ps::run_training` survives as a deprecated
//! shim that calls straight into this function (and is pinned
//! bit-identical to it by the `api_session` golden tests).
//!
//! Three entry points share the same parameterization helpers, so a
//! role runs with the *same* seeds and configs no matter which one
//! spawns it:
//!
//! * [`run_distributed`] — both sides in one process over
//!   [`MemoryTransport`] (the historical fast/test path, bit-identical
//!   to the pre-transport-trait tree).
//! * [`run_server_node`] — the server side only, over any
//!   [`Transport`]; used by `dmlps node --role server`.
//! * [`run_worker_node`] — one worker, over any [`Transport`]; used by
//!   `dmlps node --role worker`.
//!
//! Node processes do not ship datasets over the wire: every node
//! regenerates the dataset, initial L, pair partition, and shard plan
//! deterministically from the shared config + seed, exactly as the
//! in-process path builds them. The only cross-process traffic is the
//! PS protocol itself.

use std::sync::Arc;

use crate::config::{ExperimentConfig, PairMode};
use crate::data::{
    partition_pairs, ClassIndex, Dataset, ImplicitPairSampler, PairSet,
    WorkerPairs,
};
use crate::dml::{DmlProblem, EngineFactory, LrSchedule};
use crate::linalg::Mat;
use crate::metrics::Curve;
use crate::ps::{
    Checkpoint, MemoryTransport, ProbeFn, RunOptions, Server,
    ServerConfig, ShardPlan, TrainResult, Transport, Worker,
    WorkerConfig, WorkerResume, WorkerStats,
};

use super::events::{EventSink, ProbeEvent};

/// Guards shared by every entry point: a worker exists, and lossy
/// transports only combine with ASP (BSP/SSP gates wait on clocks that
/// a dropped, unretransmitted update can stall forever — fail fast
/// instead of deadlocking).
fn validate(cfg: &ExperimentConfig, opts: &RunOptions) -> anyhow::Result<()> {
    anyhow::ensure!(cfg.cluster.workers > 0, "need at least one worker");
    anyhow::ensure!(
        cfg.cluster.consistency == crate::config::Consistency::Asp
            || (opts.faults.drop_grad_prob == 0.0
                && opts.faults.drop_param_prob == 0.0),
        "message drops require ASP consistency: BSP/SSP gates can \
         deadlock on a dropped update (no retransmission layer)"
    );
    Ok(())
}

/// The shard plan both sides agree on (clamped to the row count;
/// `server_shards = 0` is treated as 1 for configs predating the knob).
pub fn plan_for(cfg: &ExperimentConfig) -> ShardPlan {
    ShardPlan::new(
        cfg.model.k,
        cfg.dataset.dim,
        cfg.cluster.server_shards.max(1),
    )
}

/// The deterministic initial L every role starts from.
fn init_l(cfg: &ExperimentConfig) -> Mat {
    DmlProblem::new(cfg.dataset.dim, cfg.model.k, cfg.optim.lambda)
        .init_l(cfg.model.init_scale, cfg.seed)
}

/// Load the newest consistent checkpoint when `opts.resume_from` names
/// a run directory. `Ok(None)` covers both "no resume requested" and
/// "nothing checkpointed yet" — the latter lets restart supervisors
/// pass `--resume` unconditionally and still get a correct fresh start
/// when a process died before the first generation landed.
fn load_resume(
    cfg: &ExperimentConfig,
    plan: &ShardPlan,
    opts: &RunOptions,
) -> anyhow::Result<Option<Arc<Checkpoint>>> {
    let Some(dir) = &opts.resume_from else {
        return Ok(None);
    };
    match crate::ps::checkpoint::load_latest(dir)? {
        None => Ok(None),
        Some(c) => {
            c.validate_for(plan, cfg.cluster.workers)?;
            Ok(Some(Arc::new(c)))
        }
    }
}

/// The L a (possibly resumed) run starts from: the checkpointed
/// parameters when resuming, the deterministic init otherwise.
fn start_l(
    cfg: &ExperimentConfig,
    plan: &ShardPlan,
    resume: &Option<Arc<Checkpoint>>,
) -> Mat {
    match resume {
        Some(c) => c.l(plan),
        None => init_l(cfg),
    }
}

/// Pair sources for all P workers (and the shared class index in
/// streaming mode). Deterministic in (cfg, seed): a worker node builds
/// the same partition the in-process run builds and takes its slot.
fn build_sources(
    cfg: &ExperimentConfig,
    dataset: &Arc<Dataset>,
    pairs: &PairSet,
) -> anyhow::Result<(Vec<WorkerPairs>, Option<Arc<ClassIndex>>)> {
    let p = cfg.cluster.workers;
    let stream_index = match cfg.cluster.pairs.mode {
        PairMode::Materialized => None,
        PairMode::Streaming => Some(Arc::new(ClassIndex::build(
            dataset,
            cfg.cluster.pairs.imbalance,
        )?)),
    };
    let sources: Vec<WorkerPairs> = match &stream_index {
        None => partition_pairs(pairs, p, cfg.seed ^ 0x5A4D)?
            .into_iter()
            .map(WorkerPairs::Materialized)
            .collect(),
        Some(index) => (0..p)
            .map(|w| {
                WorkerPairs::Streaming(ImplicitPairSampler::with_index(
                    dataset.clone(),
                    index.clone(),
                    cfg.seed,
                    w,
                    p,
                    cfg.cluster.pairs.label_noise,
                ))
            })
            .collect(),
    };
    Ok((sources, stream_index))
}

fn server_cfg(
    cfg: &ExperimentConfig,
    opts: &RunOptions,
    events: Option<Arc<dyn EventSink>>,
    resume: Option<Arc<Checkpoint>>,
) -> ServerConfig {
    let p = cfg.cluster.workers;
    ServerConfig {
        workers: p,
        server_batch: cfg.cluster.server_batch,
        lr: LrSchedule::new(cfg.optim.lr, cfg.optim.lr_decay),
        lr_scale: 1.0 / p as f32,
        probe_every: opts.probe_every,
        faults: opts.faults,
        seed: cfg.seed ^ 0x5E2,
        compression: cfg.cluster.compression,
        events,
        checkpoint: opts.checkpoint.clone(),
        resume,
    }
}

fn worker_cfg(
    cfg: &ExperimentConfig,
    w: usize,
    opts: &RunOptions,
    events: Option<Arc<dyn EventSink>>,
    resume: Option<WorkerResume>,
) -> WorkerConfig {
    WorkerConfig {
        id: w,
        steps: cfg.optim.steps,
        batch_sim: cfg.optim.batch_sim,
        batch_dis: cfg.optim.batch_dis,
        lambda: cfg.optim.lambda,
        lr: LrSchedule::new(cfg.optim.lr, cfg.optim.lr_decay),
        consistency: cfg.cluster.consistency,
        faults: opts.faults,
        seed: cfg.seed ^ ((w as u64 + 1) << 16),
        threads: cfg.cluster.threads_per_worker,
        compression: cfg.cluster.compression,
        events,
        resume,
    }
}

fn train_result_from_server(
    sr: crate::ps::ServerResult,
    server_shards: usize,
    worker_stats: Vec<WorkerStats>,
    wall_s: f64,
) -> TrainResult {
    TrainResult {
        l: sr.l,
        curve: sr.curve,
        applied_updates: sr.applied_updates,
        slice_updates: sr.slice_updates,
        broadcasts: sr.broadcasts,
        param_msgs: sr.param_msgs,
        server_shards,
        last_loss: sr.last_loss,
        grad_bytes_received: sr.grad_bytes_received,
        param_bytes_sent: sr.param_bytes_sent,
        misroutes: sr.misroutes,
        worker_stats,
        wall_s,
    }
}

/// Run distributed DML training with the threaded parameter server.
///
/// * `engines` — factory each worker's computing thread uses.
/// * `events` — optional sink fed by the probe thread, the server
///   shards, and the workers; `None` is byte-for-byte the historical
///   protocol.
///
/// The probe engine (objective recording on the server's probe thread)
/// is always the native engine: probes are off the hot path and must
/// not depend on artifacts being present.
pub(crate) fn run_distributed(
    cfg: &ExperimentConfig,
    dataset: Arc<Dataset>,
    pairs: &PairSet,
    engines: EngineFactory,
    opts: &RunOptions,
    events: Option<Arc<dyn EventSink>>,
) -> anyhow::Result<TrainResult> {
    validate(cfg, opts)?;
    let p = cfg.cluster.workers;
    let plan = plan_for(cfg);
    let server_shards = plan.shards();
    // whole-cluster resume: every role re-enters from the same
    // generation (in-process, "cluster" is these threads)
    let resume = load_resume(cfg, &plan, opts)?;
    let l0 = start_l(cfg, &plan, &resume);

    let (sources, stream_index) = build_sources(cfg, &dataset, pairs)?;

    // ---- transport: directly-wired channels, both sides local ----
    let mut transport = MemoryTransport::new(p);
    let (to_server_rx, to_worker_txs) = transport.server_endpoints()?;

    // ---- objective probe (runs on the server probe thread) ----
    let probe = make_probe(
        &dataset,
        pairs,
        cfg,
        opts.probe_pairs,
        stream_index,
        events.clone(),
    );

    // ---- spawn server ----
    let watch = crate::metrics::Stopwatch::start();
    let server = Server::spawn(
        server_cfg(cfg, opts, events.clone(), resume.clone()),
        plan.clone(),
        l0.clone(),
        to_server_rx,
        to_worker_txs,
        probe,
    );

    // ---- spawn workers ----
    let mut workers = Vec::with_capacity(p);
    for (w, source) in sources.into_iter().enumerate() {
        let (to_server_tx, from_server_rx) = transport.worker_endpoints(w)?;
        workers.push(Worker::spawn(
            worker_cfg(
                cfg,
                w,
                opts,
                events.clone(),
                resume.as_ref().map(|c| c.worker_resume(w)),
            ),
            plan.clone(),
            l0.clone(),
            dataset.clone(),
            source,
            to_server_tx,
            from_server_rx,
            engines.clone(),
        ));
    }
    // server sees disconnect when all workers finish
    transport.seal();

    // ---- join ----
    let worker_stats: Vec<WorkerStats> =
        workers.into_iter().map(Worker::join).collect();
    let sr = server.join();
    transport.finish();
    Ok(train_result_from_server(
        sr,
        server_shards,
        worker_stats,
        watch.elapsed_s(),
    ))
}

/// Run the server role of a multi-node deployment over `transport`
/// (socket-bridged endpoints in process mode; [`MemoryTransport`] works
/// too and is how the loopback tests drive this path in threads).
///
/// Returns a [`TrainResult`] with an empty `worker_stats` — worker
/// telemetry lives in the worker processes; the manager merges their
/// reports. Same seeds, same configs, same fold behavior as
/// [`run_distributed`], so a 1-worker BSP `mode=none` run is
/// bit-identical across entry points.
pub fn run_server_node(
    cfg: &ExperimentConfig,
    dataset: Arc<Dataset>,
    pairs: &PairSet,
    opts: &RunOptions,
    events: Option<Arc<dyn EventSink>>,
    transport: &mut dyn Transport,
) -> anyhow::Result<TrainResult> {
    validate(cfg, opts)?;
    let plan = plan_for(cfg);
    let server_shards = plan.shards();
    let resume = load_resume(cfg, &plan, opts)?;
    let l0 = start_l(cfg, &plan, &resume);
    // only the probe's pair subsample is needed server-side
    let stream_index = match cfg.cluster.pairs.mode {
        PairMode::Materialized => None,
        PairMode::Streaming => Some(Arc::new(ClassIndex::build(
            &dataset,
            cfg.cluster.pairs.imbalance,
        )?)),
    };
    let probe = make_probe(
        &dataset,
        pairs,
        cfg,
        opts.probe_pairs,
        stream_index,
        events.clone(),
    );
    let (from_workers, to_workers) = transport.server_endpoints()?;
    let watch = crate::metrics::Stopwatch::start();
    let server = Server::spawn(
        server_cfg(cfg, opts, events, resume),
        plan,
        l0,
        from_workers,
        to_workers,
        probe,
    );
    let sr = server.join();
    Ok(train_result_from_server(
        sr,
        server_shards,
        Vec::new(),
        watch.elapsed_s(),
    ))
}

/// Run worker `w` of a multi-node deployment over `transport`. Builds
/// the full P-way pair partition deterministically and takes slot `w`,
/// so the pairs this worker trains on are exactly the ones
/// [`run_distributed`] would hand it.
pub fn run_worker_node(
    cfg: &ExperimentConfig,
    w: usize,
    dataset: Arc<Dataset>,
    pairs: &PairSet,
    engines: EngineFactory,
    opts: &RunOptions,
    events: Option<Arc<dyn EventSink>>,
    transport: &mut dyn Transport,
) -> anyhow::Result<WorkerStats> {
    validate(cfg, opts)?;
    anyhow::ensure!(
        w < cfg.cluster.workers,
        "worker id {w} out of range ({} workers)",
        cfg.cluster.workers
    );
    let plan = plan_for(cfg);
    let resume = load_resume(cfg, &plan, opts)?;
    let l0 = start_l(cfg, &plan, &resume);
    let (mut sources, _) = build_sources(cfg, &dataset, pairs)?;
    let source = sources.swap_remove(w);
    let (to_server_tx, from_server_rx) = transport.worker_endpoints(w)?;
    let worker = Worker::spawn(
        worker_cfg(
            cfg,
            w,
            opts,
            events,
            resume.as_ref().map(|c| c.worker_resume(w)),
        ),
        plan,
        l0,
        dataset,
        source,
        to_server_tx,
        from_server_rx,
        engines,
    );
    Ok(worker.join())
}

/// Build the server-side objective probe: materializes a fixed pair
/// subsample (Send-safe buffers) and evaluates with a native engine
/// constructed inside the probe thread. In streaming mode the
/// subsample is drawn from a dedicated implicit sampler on a reserved
/// seed (the materialized pair sets may be empty — that's the point),
/// with the same scenario knobs the workers train under. Every probe
/// point is mirrored to the event sink.
fn make_probe(
    dataset: &Arc<Dataset>,
    pairs: &PairSet,
    cfg: &ExperimentConfig,
    probe_pairs: (usize, usize),
    stream_index: Option<Arc<ClassIndex>>,
    events: Option<Arc<dyn EventSink>>,
) -> ProbeFn {
    let lambda = cfg.optim.lambda;
    let probe = match stream_index {
        None => crate::dml::ObjectiveProbe::new(
            dataset,
            pairs,
            probe_pairs.0,
            probe_pairs.1,
            cfg.seed ^ 0x0B5,
        ),
        Some(index) => {
            let mut sampler = ImplicitPairSampler::with_index(
                dataset.clone(),
                index,
                cfg.seed ^ 0x0B5E,
                0,
                1,
                cfg.cluster.pairs.label_noise,
            );
            crate::dml::ObjectiveProbe::from_stream(
                dataset,
                &mut sampler,
                probe_pairs.0,
                probe_pairs.1,
            )
        }
    };
    let mut engine: Option<crate::dml::NativeEngine> = None;
    Box::new(move |l: &Mat, step: u64, t: f64, curve: &mut Curve| {
        let eng = engine.get_or_insert_with(crate::dml::NativeEngine::new);
        let obj = probe.eval(eng, l, lambda) as f64;
        curve.push(t, step as usize, obj);
        if let Some(sink) = &events {
            sink.on_probe(&ProbeEvent { step, time_s: t, objective: obj });
        }
    })
}
