//! # The public run surface: `Session` → `Run` → `MetricModel`
//!
//! The paper's pipeline is train-once/use-everywhere: learn L on the
//! parameter server, then serve the Mahalanobis metric for retrieval
//! and kNN. This module is that pipeline as an API. One builder
//! describes a run, three executors perform it, one report type comes
//! back, and the learned metric leaves as a durable artifact:
//!
//! ```no_run
//! use dmlps::config::Preset;
//! use dmlps::session::Session;
//!
//! # fn main() -> anyhow::Result<()> {
//! let run = Session::from_config(Preset::Tiny.config())
//!     .engine("native")
//!     .probe(20, (200, 200))
//!     .train_distributed()?;
//! println!("objective {:?} after {} updates",
//!          run.curve.final_objective(), run.applied_updates);
//!
//! // persist the learned metric, reload it, serve it — no retraining
//! let model = run.into_model()?;
//! model.save(std::path::Path::new("metric.bin"))?;
//! let model = dmlps::session::MetricModel::load(
//!     std::path::Path::new("metric.bin"))?;
//! let _neighbours = model.knn(&model_gallery(), &query(), 5);
//! # Ok(()) }
//! # fn model_gallery() -> dmlps::data::Dataset { unimplemented!() }
//! # fn query() -> Vec<f32> { unimplemented!() }
//! ```
//!
//! ## Builder
//!
//! [`Session::from_config`] starts from an [`ExperimentConfig`] (preset,
//! JSON file, or hand-built); chainable overrides refine it:
//!
//! * [`Session::engine`] / [`Session::engine_factory`] — compute backend
//!   ("native" | "xla" | "auto", or an explicit [`EngineFactory`]).
//! * [`Session::faults`] / [`Session::probe`] / [`Session::run_options`]
//!   — transport fault injection and probe cadence.
//! * [`Session::data`] — reuse generated [`ExperimentData`] across runs
//!   (benches sweep many configs over one dataset); omitted, the
//!   session generates data from the config.
//! * [`Session::pair_source`] — explicit train dataset + pair set for
//!   the distributed path (what the deprecated `ps::run_training`
//!   shim feeds through).
//! * [`Session::events`] — an [`EventSink`] fed live by the probe
//!   thread, server shards, and workers.
//! * [`Session::topology`] / [`Session::sim_knobs`] — simulated-cluster
//!   shape and cost model.
//!
//! ## Executors
//!
//! * [`Session::train_distributed`] — the real threaded parameter
//!   server (paper §4.2).
//! * [`Session::train_sequential`] — single-thread SGD (paper §5.4's
//!   comparison setting).
//! * [`Session::simulate`] — the discrete-event cluster simulator
//!   (paper Fig 2/3 scalability studies).
//!
//! All three return the unified [`Run`] report; the training executors
//! additionally attach a [`MetricModel`] artifact.

mod dist;
mod events;
mod model;
mod seq;
mod sim;

pub use dist::{plan_for, run_server_node, run_worker_node};
pub use events::{BroadcastEvent, DoneEvent, EventSink, ProbeEvent};
pub use model::{config_digest, MetricModel, ModelMeta};
pub use sim::{calibrate_for, sim_scaled, SimKnobs, SimScaled};

pub use crate::linalg::simd::{KernelBackend, KernelReport};

pub(crate) use dist::run_distributed;
pub(crate) use seq::run_sequential;
pub(crate) use sim::run_simulated;

use std::sync::Arc;

use crate::baselines::ApTrace;
use crate::config::{CompressionMode, ExperimentConfig, PairMode};
use crate::data::{Dataset, ExperimentData, PairSet};
use crate::dml::EngineFactory;
use crate::linalg::Mat;
use crate::metrics::{Curve, Stopwatch};
use crate::ps::{FaultSpec, RunOptions, TrainResult, WorkerStats};

/// Which executor produced a [`Run`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunKind {
    /// Real threaded parameter server ([`Session::train_distributed`]).
    Distributed,
    /// Single-thread SGD ([`Session::train_sequential`]).
    Sequential,
    /// Discrete-event cluster simulation ([`Session::simulate`]).
    Simulated,
}

/// The unified run report every executor returns — the merge of the
/// historical `TrainResult`, `SingleThreadRun`, and `SimResult` shapes.
/// Fields an executor does not produce are zero/empty (e.g. a
/// sequential run has no worker stats; a simulated run has no model).
#[derive(Debug)]
pub struct Run {
    pub kind: RunKind,
    /// The trained metric artifact (`None` for simulated runs, which
    /// model time, not parameters worth serving).
    pub model: Option<MetricModel>,
    /// Objective-vs-time convergence curve.
    pub curve: Curve,
    /// Real wall-clock seconds this executor took.
    pub wall_s: f64,
    /// Logical full-gradient updates folded into the global L.
    pub applied_updates: u64,
    /// Per-shard slice applications summed over shards.
    pub slice_updates: u64,
    /// Broadcast rounds summed over shards.
    pub broadcasts: u64,
    /// Physical parameter slice messages shipped to workers.
    pub param_msgs: u64,
    /// Server shard count the run actually used.
    pub server_shards: usize,
    /// Mean worker-reported minibatch loss over the last window.
    pub last_loss: f32,
    /// Encoded gradient payload bytes the server folded.
    pub grad_bytes_received: u64,
    /// Encoded parameter payload bytes shipped to workers.
    pub param_bytes_sent: u64,
    /// Gradient messages the server router skipped for naming a shard
    /// outside the plan. Zero on every healthy run.
    pub misroutes: u64,
    /// Per-worker telemetry (distributed runs).
    pub worker_stats: Vec<WorkerStats>,
    /// AP-vs-time trace on held-out test pairs (sequential runs).
    pub ap_trace: ApTrace,
    /// Simulated seconds to the update budget (simulated runs).
    pub sim_seconds: f64,
    /// Mean update staleness (simulated runs).
    pub mean_staleness: f64,
    /// Which compute-kernel backend (scalar reference vs explicit SIMD)
    /// served this run's GEMM/scan hot paths, and why dispatch chose it.
    pub kernel: KernelReport,
}

impl Run {
    fn empty(kind: RunKind) -> Run {
        Run {
            kind,
            model: None,
            curve: Curve::default(),
            wall_s: 0.0,
            applied_updates: 0,
            slice_updates: 0,
            broadcasts: 0,
            param_msgs: 0,
            server_shards: 0,
            last_loss: 0.0,
            grad_bytes_received: 0,
            param_bytes_sent: 0,
            misroutes: 0,
            worker_stats: Vec::new(),
            ap_trace: ApTrace::new(),
            sim_seconds: 0.0,
            mean_staleness: 0.0,
            kernel: crate::linalg::simd::report(),
        }
    }

    /// The learned projection L, for runs that trained one.
    pub fn l(&self) -> anyhow::Result<&Mat> {
        Ok(self.require_model()?.l())
    }

    /// The trained metric artifact, erroring for simulated runs.
    pub fn require_model(&self) -> anyhow::Result<&MetricModel> {
        self.model.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "this {:?} run produced no metric model", self.kind
            )
        })
    }

    /// Consume the run and keep only the metric artifact.
    pub fn into_model(self) -> anyhow::Result<MetricModel> {
        let kind = self.kind;
        self.model.ok_or_else(|| {
            anyhow::anyhow!("this {kind:?} run produced no metric model")
        })
    }

    fn from_train_result(cfg: &ExperimentConfig, r: TrainResult) -> Run {
        Run {
            model: Some(MetricModel::new(r.l, cfg)),
            curve: r.curve,
            wall_s: r.wall_s,
            applied_updates: r.applied_updates,
            slice_updates: r.slice_updates,
            broadcasts: r.broadcasts,
            param_msgs: r.param_msgs,
            server_shards: r.server_shards,
            last_loss: r.last_loss,
            grad_bytes_received: r.grad_bytes_received,
            param_bytes_sent: r.param_bytes_sent,
            misroutes: r.misroutes,
            worker_stats: r.worker_stats,
            ..Run::empty(RunKind::Distributed)
        }
    }
}

/// How the session obtains engines (resolved at execute time, so a
/// name like "auto" sees the artifacts that exist when the run starts).
#[derive(Clone)]
enum EngineSel {
    Name(String),
    Factory(EngineFactory),
}

/// Builder for one fully-described run. See the [module docs](self).
#[derive(Clone)]
pub struct Session {
    cfg: ExperimentConfig,
    opts: RunOptions,
    engine: EngineSel,
    data: Option<Arc<ExperimentData>>,
    pair_source: Option<(Arc<Dataset>, Arc<PairSet>)>,
    events: Option<Arc<dyn EventSink>>,
    sim: SimKnobs,
    machines: usize,
    cores_per_machine: usize,
}

impl Session {
    /// Start a session from a config (preset, loaded JSON, or
    /// hand-built). Every knob the config carries — workers, shards,
    /// consistency, pair pipeline, wire compression — is honored as-is;
    /// the chainable overrides below cover what a config cannot say.
    pub fn from_config(cfg: ExperimentConfig) -> Session {
        Session {
            cfg,
            opts: RunOptions::default(),
            engine: EngineSel::Name("native".into()),
            data: None,
            pair_source: None,
            events: None,
            sim: SimKnobs::default(),
            machines: 1,
            cores_per_machine: 16,
        }
    }

    /// The config this session will run.
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Transport fault injection (drops, latency) for distributed runs.
    pub fn faults(mut self, faults: FaultSpec) -> Session {
        self.opts.faults = faults;
        self
    }

    /// Probe cadence (applied updates between curve points) and probe
    /// subsample sizes (similar, dissimilar).
    pub fn probe(mut self, every: u64, pairs: (usize, usize)) -> Session {
        self.opts.probe_every = every;
        self.opts.probe_pairs = pairs;
        self
    }

    /// Replace the whole option block (faults + probe knobs) at once.
    pub fn run_options(mut self, opts: RunOptions) -> Session {
        self.opts = opts;
        self
    }

    /// Select the engine by name: "native", "xla", or "auto".
    pub fn engine(mut self, name: &str) -> Session {
        self.engine = EngineSel::Name(name.into());
        self
    }

    /// Supply an explicit engine factory (overrides [`Session::engine`]).
    pub fn engine_factory(mut self, factory: EngineFactory) -> Session {
        self.engine = EngineSel::Factory(factory);
        self
    }

    /// Reuse already-generated experiment data instead of generating
    /// from the config (benches sweep many configs over one dataset).
    pub fn data(mut self, data: Arc<ExperimentData>) -> Session {
        self.data = Some(data);
        self
    }

    /// Explicit train dataset + pair set for the distributed executor
    /// (the raw `ps::run_training` calling convention). Takes
    /// precedence over [`Session::data`] for
    /// [`Session::train_distributed`]. Accepts a bare [`PairSet`] or an
    /// `Arc<PairSet>` (share, don't clone, when sweeping configs).
    pub fn pair_source(
        mut self,
        dataset: Arc<Dataset>,
        pairs: impl Into<Arc<PairSet>>,
    ) -> Session {
        self.pair_source = Some((dataset, pairs.into()));
        self
    }

    /// Install an [`EventSink`] fed live by the run.
    pub fn events(mut self, sink: Arc<dyn EventSink>) -> Session {
        self.events = Some(sink);
        self
    }

    /// Simulated-cluster shape for [`Session::simulate`].
    pub fn topology(
        mut self,
        machines: usize,
        cores_per_machine: usize,
    ) -> Session {
        self.machines = machines.max(1);
        self.cores_per_machine = cores_per_machine.max(1);
        self
    }

    /// Simulated-cluster cost knobs for [`Session::simulate`].
    pub fn sim_knobs(mut self, knobs: SimKnobs) -> Session {
        self.sim = knobs;
        self
    }

    // ------------------------------------------------------------------
    // executors
    // ------------------------------------------------------------------

    /// Train on the real threaded parameter server (paper §4.2): P
    /// worker machines, S server shards, ASP/BSP/SSP consistency, the
    /// configured pair pipeline and wire compression.
    pub fn train_distributed(&self) -> anyhow::Result<Run> {
        let engines = self.resolve_engines()?;
        let result = match &self.pair_source {
            Some((dataset, pairs)) => run_distributed(
                &self.cfg,
                dataset.clone(),
                pairs,
                engines,
                &self.opts,
                self.events.clone(),
            )?,
            None => {
                let data = self.resolve_data(self.cfg.cluster.pairs.mode);
                let dataset = Arc::new(clone_dataset(&data.train));
                run_distributed(
                    &self.cfg,
                    dataset,
                    &data.pairs,
                    engines,
                    &self.opts,
                    self.events.clone(),
                )?
            }
        };
        Ok(Run::from_train_result(&self.cfg, result))
    }

    /// Train single-threaded (the paper's §5.4 setting): plain SGD on
    /// one engine, with an AP-vs-time trace on held-out test pairs.
    /// Needs held-out test pairs for the AP trace, so it consumes full
    /// [`Session::data`] (never a bare [`Session::pair_source`]) and
    /// only the materialized pair pipeline — both enforced, not
    /// silently downgraded.
    pub fn train_sequential(&self) -> anyhow::Result<Run> {
        anyhow::ensure!(
            self.pair_source.is_none(),
            "train_sequential does not consume a pair_source override \
             (it needs test pairs for the AP trace) — pass a full \
             dataset via .data(..) instead"
        );
        anyhow::ensure!(
            self.cfg.cluster.pairs.mode == PairMode::Materialized,
            "train_sequential supports only the materialized pair \
             pipeline (drop the streaming pairs mode)"
        );
        let mut engine = (self.resolve_engines()?)()?;
        let data = self.resolve_data(PairMode::Materialized);
        let outcome = run_sequential(
            &self.cfg,
            &data,
            engine.as_mut(),
            self.opts.probe_every as usize,
            self.opts.probe_pairs,
            self.events.as_ref(),
        )?;
        Ok(Run {
            model: Some(MetricModel::new(outcome.l, &self.cfg)),
            curve: outcome.curve,
            wall_s: outcome.wall_s,
            applied_updates: self.cfg.optim.steps as u64,
            ap_trace: outcome.ap_trace,
            ..Run::empty(RunKind::Sequential)
        })
    }

    /// Run the discrete-event cluster simulator at the configured
    /// [`Session::topology`] with the [`Session::sim_knobs`] cost
    /// model — the paper's Fig 2/3 scalability instrument.
    pub fn simulate(&self) -> anyhow::Result<Run> {
        // the simulator's workload consumes materialized pair shards
        // and charges dense f32 bytes per message; fail clearly rather
        // than silently ignoring the config's pipeline/wire knobs
        anyhow::ensure!(
            self.cfg.cluster.pairs.mode == PairMode::Materialized,
            "simulate supports only the materialized pair pipeline \
             (drop the streaming pairs mode)"
        );
        anyhow::ensure!(
            self.cfg.cluster.compression.mode == CompressionMode::None,
            "simulate models the dense f32 wire only \
             (drop the '{}' compression mode)",
            self.cfg.cluster.compression.mode
        );
        let data = self.resolve_data(PairMode::Materialized);
        let watch = Stopwatch::start();
        let r = sim::run_simulated(
            &self.cfg,
            &data,
            self.machines,
            self.cores_per_machine,
            self.sim,
        )?;
        if let Some(sink) = &self.events {
            // the simulator records its own curve under simulated time;
            // probes are replayed to the sink after the fact
            for p in &r.curve.points {
                sink.on_probe(&ProbeEvent {
                    step: p.step as u64,
                    time_s: p.time_s,
                    objective: p.objective,
                });
            }
        }
        Ok(Run {
            curve: r.curve,
            wall_s: watch.elapsed_s(),
            applied_updates: r.applied_updates,
            broadcasts: r.broadcasts,
            sim_seconds: r.sim_seconds,
            mean_staleness: r.mean_staleness,
            ..Run::empty(RunKind::Simulated)
        })
    }

    // ------------------------------------------------------------------
    // plumbing
    // ------------------------------------------------------------------

    fn resolve_engines(&self) -> anyhow::Result<EngineFactory> {
        match &self.engine {
            EngineSel::Factory(f) => Ok(f.clone()),
            EngineSel::Name(name) => {
                crate::dml::engine_factory(name, &self.cfg)
            }
        }
    }

    /// The session's data: the override if one was supplied, else
    /// generated from the config with the given pair mode.
    fn resolve_data(&self, mode: PairMode) -> Arc<ExperimentData> {
        match &self.data {
            Some(d) => d.clone(),
            None => Arc::new(ExperimentData::generate_for(
                &self.cfg.dataset,
                mode,
                self.cfg.seed,
            )),
        }
    }
}

/// Deep-copy a dataset into a fresh allocation (the worker threads
/// share it behind an `Arc`).
pub(crate) fn clone_dataset(ds: &Dataset) -> Dataset {
    Dataset {
        x: ds.x.clone(),
        labels: ds.labels.clone(),
        n_classes: ds.n_classes,
    }
}
