//! Run-lifecycle event callbacks.
//!
//! An [`EventSink`] is the one sanctioned window into a running
//! [`Session`](super::Session): the server's probe thread reports every
//! objective probe, shard update threads report parameter broadcasts,
//! and each worker reports its completion. Before this trait existed,
//! the CLI and benches peeked at internals (or simply could not observe
//! a run until it finished); now they install a sink instead.
//!
//! All methods default to no-ops, so a sink implements only what it
//! cares about. Sinks are shared across threads (`Send + Sync`) and are
//! called from hot-adjacent paths — implementations should be cheap or
//! hand off to their own channel.

/// One objective probe, as recorded on the server's probe thread (or by
/// the sequential trainer's inline probe).
#[derive(Clone, Copy, Debug)]
pub struct ProbeEvent {
    /// Applied (logical) update count at probe time.
    pub step: u64,
    /// Seconds since run start (wall clock, or simulated time for
    /// [`Session::simulate`](super::Session::simulate) runs).
    pub time_s: f64,
    /// Objective value at this probe.
    pub objective: f64,
}

/// One parameter broadcast round, reported by the owning server shard's
/// update thread when it publishes a fresh slice.
#[derive(Clone, Copy, Debug)]
pub struct BroadcastEvent {
    /// Server shard that published the slice.
    pub shard: usize,
    /// Slice version (the shard's applied-update count).
    pub version: u64,
    /// The shard's SSP clock at publish time.
    pub clock: u64,
    /// Encoded payload bytes of the broadcast slice.
    pub encoded_bytes: u64,
}

/// A worker's computing thread finished its step budget. Reported from
/// inside the worker, so transport-side counters (grads sent/dropped)
/// are not yet folded in — read those from
/// [`Run::worker_stats`](super::Run::worker_stats) after the run.
#[derive(Clone, Copy, Debug)]
pub struct DoneEvent {
    /// Worker id.
    pub worker: usize,
    /// Steps the computing thread completed.
    pub steps: u64,
    /// Last minibatch loss the worker observed.
    pub last_loss: f32,
    /// Seconds spent blocked on the consistency gate.
    pub wait_s: f64,
    /// Max observed staleness (own step − min-over-shards clock).
    pub max_staleness: u64,
}

/// Callbacks fed by a running session. Install one with
/// [`Session::events`](super::Session::events).
pub trait EventSink: Send + Sync {
    /// Called for every recorded objective-curve point.
    fn on_probe(&self, _event: &ProbeEvent) {}

    /// Called for every parameter broadcast round a server shard emits
    /// (distributed runs only).
    fn on_broadcast(&self, _event: &BroadcastEvent) {}

    /// Called once per worker when its computing thread finishes
    /// (distributed runs only).
    fn on_done(&self, _event: &DoneEvent) {}
}
